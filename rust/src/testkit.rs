//! Property-testing support (proptest is not available in this build
//! environment; this provides the same style of randomized invariant
//! checking with explicit seeds so failures reproduce exactly).
//!
//! ```no_run
//! use cloudcoaster::testkit::property;
//! property("queue never loses tasks", 50, |rng| {
//!     let n = rng.below(100) + 1;
//!     // ... build a random scenario, assert invariants ...
//! });
//! ```

use crate::sim::Rng;

/// Run `check` against `cases` independently-seeded RNGs. On panic, the
/// failing seed is printed so the case replays deterministically.
pub fn property<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, cases: u64, check: F) {
    for case in 0..cases {
        let seed = 0xC10D_C0A5_7E00_0000u64 | case;
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            check(&mut rng);
        });
        if let Err(err) = result {
            eprintln!("property {name:?} FAILED at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(err);
        }
    }
}

/// Uniform f64 in [lo, hi).
pub fn uniform(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
    lo + (hi - lo) * rng.f64()
}

/// Random usize in [lo, hi].
pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below((hi - lo + 1) as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_runs_all_cases() {
        use std::sync::atomic::{AtomicU64, Ordering};
        static COUNT: AtomicU64 = AtomicU64::new(0);
        property("counting", 10, |_| {
            COUNT.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(COUNT.load(Ordering::SeqCst), 10);
    }

    #[test]
    #[should_panic]
    fn property_propagates_failures() {
        property("fails", 5, |rng| {
            assert!(rng.f64() < -1.0, "impossible");
        });
    }

    #[test]
    fn helpers_in_range() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let x = uniform(&mut rng, 5.0, 10.0);
            assert!((5.0..10.0).contains(&x));
            let u = usize_in(&mut rng, 3, 7);
            assert!((3..=7).contains(&u));
        }
    }
}
