//! Experiment reports: run a configured experiment end-to-end and distill
//! the numbers the paper reports (Figure 3 CDFs, Table 1 rows, headline
//! ratios), using the XLA analytics artifacts when available.

use std::path::PathBuf;

use anyhow::Result;

use crate::coordinator::config::{ExperimentConfig, SchedulerKind, WorkloadSource};
use crate::coordinator::runner::{simulate_source, simulate_with, RunResult, SimConfig};
use crate::metrics::Cdf;
use crate::runtime::{Analytics, AnalyticsEngine};
use crate::sched::{Centralized, Hybrid, Scheduler, Sparrow};
use crate::sim::Rng;
use crate::trace::{synth, TraceStats, Workload};

/// Summary statistics of one delay population.
#[derive(Clone, Debug)]
pub struct DelayStats {
    pub n: usize,
    pub mean: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl DelayStats {
    fn of(samples: &mut crate::metrics::DelaySamples) -> DelayStats {
        DelayStats {
            n: samples.len(),
            mean: samples.mean(),
            max: samples.max(),
            p50: samples.percentile(0.5),
            p90: samples.percentile(0.9),
            p99: samples.percentile(0.99),
        }
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }
}

/// Everything one experiment produces.
#[derive(Clone, Debug)]
pub struct Report {
    pub name: String,
    pub scheduler: &'static str,
    pub r: f64,
    pub short_delay: DelayStats,
    pub long_delay: DelayStats,
    /// Figure 3: short-task queueing-delay CDF.
    pub cdf: Cdf,
    /// Table 1 columns.
    pub avg_transients: f64,
    pub max_transients: f64,
    pub mean_lifetime_h: f64,
    pub max_lifetime_h: f64,
    pub r_normalized_avg: f64,
    pub transients_requested: u64,
    pub transients_revoked: u64,
    pub tasks_rescheduled: u64,
    /// Run mechanics.
    pub end_time: f64,
    pub events: u64,
    pub wall_ms: f64,
    pub events_per_sec: f64,
    /// Streaming-memory high-water mark: jobs concurrently resident.
    pub peak_resident_jobs: usize,
    /// Arena-memory high-water mark: task slots concurrently resident
    /// (the generational arena recycles finished slots, so this is
    /// load-bound, not trace-bound).
    pub peak_resident_tasks: usize,
    /// Which analytics engine produced the CDF ("xla" or "native").
    pub analytics_engine: &'static str,
}

/// Resolve the artifacts directory: $CLOUDCOASTER_ARTIFACTS or
/// `<manifest>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("CLOUDCOASTER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// Materialise the workload for a config.
pub fn build_workload(cfg: &ExperimentConfig) -> Result<Workload> {
    Ok(match &cfg.workload {
        WorkloadSource::YahooLike(p) => synth::yahoo_like(p, &mut Rng::new(cfg.seed)),
        WorkloadSource::GoogleLike(p) => synth::google_like(p, &mut Rng::new(cfg.seed)),
        WorkloadSource::Csv(path) => crate::trace::read_csv(std::path::Path::new(path), 90.0)?,
    })
}

/// Build the scheduler instance for a kind.
pub fn build_scheduler(kind: SchedulerKind, probe_ratio: f64) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::Centralized => Box::new(Centralized),
        SchedulerKind::Sparrow => Box::new(Sparrow::new(probe_ratio)),
        SchedulerKind::Hawk => Box::new(Hybrid::hawk(probe_ratio)),
        SchedulerKind::Eagle => Box::new(Hybrid::eagle(probe_ratio)),
        SchedulerKind::CloudCoaster => Box::new(Hybrid::cloudcoaster(probe_ratio)),
    }
}

/// Run one experiment end-to-end (workload synthesis → simulation →
/// analytics) and distill the report.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<Report> {
    let mut analytics = AnalyticsEngine::auto(&artifacts_dir());
    if cfg.scenario.as_ref().map(|s| s.reshapes_workload()).unwrap_or(false) {
        // Streaming scenario: no eager workload is ever materialised —
        // memory stays O(active jobs) regardless of trace length.
        return run_experiment_on(cfg, &Workload::default(), analytics.as_dyn());
    }
    let workload = build_workload(cfg)?;
    run_experiment_on(cfg, &workload, analytics.as_dyn())
}

/// Like [`run_experiment`] but with a shared workload + analytics engine
/// (sweeps reuse both across runs).
///
/// When the config carries a scenario that reshapes the workload
/// (non-`workload` source or a combinator stack), the run streams its
/// own [`crate::trace::ArrivalSource`] pipeline and `workload` is
/// ignored — scenario points on a sweep grid each synthesize lazily in
/// O(active-jobs) memory. Passthrough scenarios (e.g. the manager-less
/// baseline) keep the shared eager workload: the streamed and eager
/// paths are bit-identical, and sharing skips re-synthesis.
pub fn run_experiment_on(
    cfg: &ExperimentConfig,
    workload: &Workload,
    analytics: &mut dyn Analytics,
) -> Result<Report> {
    let sim_cfg: SimConfig = cfg.to_sim_config();
    let mut scheduler = build_scheduler(cfg.scheduler, cfg.probe_ratio);
    let result = match &cfg.scenario {
        Some(spec) if spec.reshapes_workload() => {
            let source = spec.build_source(cfg)?;
            simulate_source(source, scheduler.as_mut(), &sim_cfg, Some(&mut *analytics))
        }
        _ => simulate_with(workload, scheduler.as_mut(), &sim_cfg, Some(&mut *analytics)),
    };
    distill(cfg, result, analytics)
}

fn distill(cfg: &ExperimentConfig, mut run: RunResult, analytics: &mut dyn Analytics) -> Result<Report> {
    let end = run.end_time;
    // Figure 3 CDF through the analytics engine (XLA artifacts when
    // available): samples -> f32, evaluated at uniform edges.
    let samples: Vec<f32> =
        run.rec.short_delays.as_slice().iter().map(|&d| d as f32).collect();
    let max_delay = samples.iter().copied().fold(1e-6f32, f32::max);
    let n_edges = crate::runtime::artifacts::EDGES;
    let edges: Vec<f32> = (0..n_edges)
        .map(|i| max_delay * i as f32 / (n_edges - 1) as f32)
        .collect();
    let (_counts, cdf_vals) = analytics.delay_cdf(&samples, &edges)?;
    let cdf = Cdf {
        edges: edges.iter().map(|&e| e as f64).collect(),
        values: cdf_vals.iter().map(|&v| v as f64).collect(),
        n_samples: samples.len(),
    };

    let scheduler: &'static str = match run.scheduler.as_str() {
        "hawk" => "hawk",
        "eagle" => "eagle",
        "cloudcoaster" => "cloudcoaster",
        "sparrow" => "sparrow",
        _ => "centralized",
    };
    let name = match &cfg.scenario {
        Some(spec) if spec.name != "default" => {
            format!("{} r={} [{}]", scheduler, cfg.r, spec.name)
        }
        _ => format!("{} r={}", scheduler, cfg.r),
    };
    Ok(Report {
        name,
        scheduler,
        r: cfg.r,
        short_delay: DelayStats::of(&mut run.rec.short_delays),
        long_delay: DelayStats::of(&mut run.rec.long_delays),
        cdf,
        avg_transients: run.rec.cost.avg_active(end),
        max_transients: run.rec.cost.max_active(),
        mean_lifetime_h: run.rec.cost.mean_lifetime_hours(),
        max_lifetime_h: run.rec.cost.max_lifetime_hours(),
        r_normalized_avg: run.rec.cost.r_normalized_avg(end),
        transients_requested: run.rec.transients_requested,
        transients_revoked: run.rec.transients_revoked,
        tasks_rescheduled: run.rec.tasks_rescheduled,
        end_time: end,
        events: run.events,
        wall_ms: run.wall_ms,
        events_per_sec: run.events as f64 / (run.wall_ms / 1000.0).max(1e-9),
        peak_resident_jobs: run.peak_resident_jobs,
        peak_resident_tasks: run.peak_resident_tasks,
        analytics_engine: analytics.name(),
    })
}

/// Render Table 1 (plus context columns) from a set of reports.
pub fn table1_markdown(reports: &[Report]) -> String {
    let mut out = String::new();
    out.push_str("| run | r | avg life (h) | max life (h) | avg transient | r-norm avg on-demand | requested |\n");
    out.push_str("|---|---|---|---|---|---|---|\n");
    for rep in reports {
        out.push_str(&format!(
            "| {} | {:.0} | {:.2} | {:.1} | {:.1} | {:.1} | {} |\n",
            rep.name,
            rep.r,
            rep.mean_lifetime_h,
            rep.max_lifetime_h,
            rep.avg_transients,
            rep.r_normalized_avg,
            rep.transients_requested,
        ));
    }
    out
}

/// Render the Figure 3 summary (delay stats per run + headline ratios
/// against the first report, which should be the baseline).
pub fn fig3_markdown(reports: &[Report]) -> String {
    let mut out = String::new();
    out.push_str("| run | short mean (s) | short p50 | short p99 | short max | long mean | speedup mean | speedup max |\n");
    out.push_str("|---|---|---|---|---|---|---|---|\n");
    let base = reports.first();
    for rep in reports {
        let (su_mean, su_max) = match base {
            Some(b) if b.short_delay.mean > 0.0 => (
                b.short_delay.mean / rep.short_delay.mean.max(1e-9),
                b.short_delay.max / rep.short_delay.max.max(1e-9),
            ),
            _ => (1.0, 1.0),
        };
        out.push_str(&format!(
            "| {} | {:.1} | {:.1} | {:.1} | {:.0} | {:.1} | {:.2}x | {:.2}x |\n",
            rep.name,
            rep.short_delay.mean,
            rep.short_delay.p50,
            rep.short_delay.p99,
            rep.short_delay.max,
            rep.long_delay.mean,
            su_mean,
            su_max,
        ));
    }
    out
}

/// CSV of CDF series for plotting Figure 3 (one column block per run).
pub fn fig3_cdf_csv(reports: &[Report]) -> String {
    let mut out = String::from("run,edge,cdf\n");
    for rep in reports {
        for (e, v) in rep.cdf.edges.iter().zip(&rep.cdf.values) {
            out.push_str(&format!("{},{e:.3},{v:.6}\n", rep.name));
        }
    }
    out
}

/// Short human-readable summary for the CLI.
pub fn summary_line(rep: &Report) -> String {
    format!(
        "{:<18} short mean {:>8.1}s  p99 {:>8.1}s  max {:>7.0}s | long mean {:>7.1}s | \
         avg transients {:>6.1} (r-norm {:>5.1}) | {:.1}k ev/s [{}]",
        rep.name,
        rep.short_delay.mean,
        rep.short_delay.p99,
        rep.short_delay.max,
        rep.long_delay.mean,
        rep.avg_transients,
        rep.r_normalized_avg,
        rep.events_per_sec / 1000.0,
        rep.analytics_engine,
    )
}

/// Workload description for reports. Streaming scenarios are described
/// by their spec instead of materialised (that would defeat the O(1)
/// memory point of replaying a long trace).
pub fn workload_summary(cfg: &ExperimentConfig) -> Result<String> {
    if let Some(spec) = &cfg.scenario {
        if spec.reshapes_workload() {
            return Ok(format!(
                "scenario '{}' ({} combinator{}, streamed)",
                spec.name,
                spec.stack.len(),
                if spec.stack.len() == 1 { "" } else { "s" },
            ));
        }
    }
    Ok(TraceStats::of(&build_workload(cfg)?).summary())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeAnalytics;
    use crate::trace::synth::YahooLikeParams;

    fn tiny_cfg(kind: SchedulerKind) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.scheduler = kind;
        cfg.cluster_size = 120;
        cfg.short_partition = 8;
        let mut p = YahooLikeParams::default();
        p.horizon = 3000.0;
        cfg.workload = WorkloadSource::YahooLike(p);
        cfg
    }

    #[test]
    fn end_to_end_report_native_analytics() {
        let cfg = tiny_cfg(SchedulerKind::Eagle);
        let w = build_workload(&cfg).unwrap();
        let mut analytics = NativeAnalytics;
        let rep = run_experiment_on(&cfg, &w, &mut analytics).unwrap();
        assert!(rep.short_delay.n > 0);
        assert_eq!(rep.analytics_engine, "native");
        assert!(rep.cdf.values.last().copied().unwrap_or(0.0) > 0.999);
        assert_eq!(rep.avg_transients, 0.0); // baseline has none
    }

    #[test]
    fn cloudcoaster_report_has_transients() {
        let mut cfg = tiny_cfg(SchedulerKind::CloudCoaster);
        cfg.threshold = 0.5; // small cluster needs a lower trigger
        let w = build_workload(&cfg).unwrap();
        let mut analytics = NativeAnalytics;
        let rep = run_experiment_on(&cfg, &w, &mut analytics).unwrap();
        assert!(rep.transients_requested > 0);
        assert!(rep.max_transients > 0.0);
    }

    #[test]
    fn markdown_tables_render() {
        let cfg = tiny_cfg(SchedulerKind::Eagle);
        let w = build_workload(&cfg).unwrap();
        let mut analytics = NativeAnalytics;
        let rep = run_experiment_on(&cfg, &w, &mut analytics).unwrap();
        let reports = vec![rep];
        assert!(table1_markdown(&reports).contains("r-norm"));
        assert!(fig3_markdown(&reports).contains("speedup"));
        assert!(fig3_cdf_csv(&reports).lines().count() > 10);
        assert!(!summary_line(&reports[0]).is_empty());
    }
}
