//! Experiment reports: run a configured experiment end-to-end and distill
//! the numbers the paper reports (Figure 3 CDFs, Table 1 rows, headline
//! ratios), using the XLA analytics artifacts when available.

use std::path::PathBuf;

use anyhow::Result;

use crate::coordinator::config::{ExperimentConfig, SchedulerKind, WorkloadSource};
use crate::coordinator::runner::{simulate_source, simulate_with, RunResult, SimConfig};
use crate::metrics::Cdf;
use crate::runtime::{Analytics, AnalyticsEngine};
use crate::sched::{Centralized, Hybrid, Scheduler, Sparrow};
use crate::sim::Rng;
use crate::trace::{synth, TraceStats, Workload};

/// Summary statistics of one delay population.
///
/// Works from either [`crate::metrics::DelayDist`] backend: `n`, `mean`
/// and `max` are exact (bit-identical across backends); `p50`/`p90`/
/// `p99` are exact on the Vec backend and within the histogram's
/// documented ≤1% relative bound on the default sketch, under the
/// shared ceil-based nearest-rank convention. Empty populations (a
/// zero-short-task run) yield well-defined zeros, never NaN.
#[derive(Clone, Debug)]
// lint: allow(check-dead-pub): flows out as the `Report` delay-field type; consumers read its fields through `Report` without naming it
pub struct DelayStats {
    pub n: usize,
    pub mean: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl DelayStats {
    fn of(dist: &mut crate::metrics::DelayDist) -> DelayStats {
        DelayStats {
            n: dist.len(),
            mean: dist.mean(),
            max: dist.max(),
            p50: dist.percentile(0.5),
            p90: dist.percentile(0.9),
            p99: dist.percentile(0.99),
        }
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }
}

/// Everything one experiment produces.
#[derive(Clone, Debug)]
pub struct Report {
    pub name: String,
    pub scheduler: &'static str,
    pub r: f64,
    pub short_delay: DelayStats,
    pub long_delay: DelayStats,
    /// Figure 3: short-task queueing-delay CDF.
    pub cdf: Cdf,
    /// Table 1 columns.
    pub avg_transients: f64,
    pub max_transients: f64,
    pub mean_lifetime_h: f64,
    pub max_lifetime_h: f64,
    pub r_normalized_avg: f64,
    pub transients_requested: u64,
    pub transients_revoked: u64,
    pub tasks_rescheduled: u64,
    /// Run mechanics.
    pub end_time: f64,
    pub events: u64,
    pub wall_ms: f64,
    pub events_per_sec: f64,
    /// Streaming-memory high-water mark: jobs concurrently resident.
    pub peak_resident_jobs: usize,
    /// Arena-memory high-water mark: task slots concurrently resident
    /// (the generational arena recycles finished slots, so this is
    /// load-bound, not trace-bound).
    pub peak_resident_tasks: usize,
    /// Server-arena high-water mark: on-demand size + peak concurrent
    /// transients (retired transient slots recycle, so this is
    /// load-bound even under revocation churn).
    pub peak_resident_servers: usize,
    /// Resident bytes of the delay structures (short/long delays +
    /// lifetimes): constant on the default histogram backend, O(trace)
    /// in `exact_delay_samples` reference mode. The CI memory smoke
    /// pins the default flat under trace scaling.
    pub delay_struct_bytes: usize,
    /// Resident bytes of the sampled snapshot series (l_r + active
    /// transients): bounded by the rebucketing ring on the default
    /// path, O(horizon) only in the exact reference modes.
    pub snapshot_series_bytes: usize,
    /// Which analytics engine produced the CDF ("xla" or "native").
    pub analytics_engine: &'static str,
    /// Hot-path profile (`Some` only when the run had `profile = true`).
    /// Reported out-of-band (stderr + `--profile-out` JSON) — never part
    /// of the default stdout surface or the bit-identity goldens.
    pub profile: Option<crate::sim::ProfileReport>,
}

/// A federated run distilled: per-cluster reports plus the aggregate
/// (merged delay histograms — mergeable by design — summed cost
/// ledgers, cross-cluster transient watermarks).
#[derive(Clone, Debug)]
pub struct FederatedReport {
    pub aggregate: Report,
    pub per_cluster: Vec<Report>,
    /// High-water mark of Σ (active + provisioning) transients across
    /// clusters; with pooled sharing, `<= shared_cap` always.
    pub peak_total_fleet: usize,
    /// Total transient units the sharing mode admits (`None` =
    /// uncoupled budgets).
    pub shared_cap: Option<usize>,
}

/// Resolve the artifacts directory: $CLOUDCOASTER_ARTIFACTS or
/// `<manifest>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("CLOUDCOASTER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// Materialise the workload for a config.
pub fn build_workload(cfg: &ExperimentConfig) -> Result<Workload> {
    Ok(match &cfg.workload {
        WorkloadSource::YahooLike(p) => synth::yahoo_like(p, &mut Rng::new(cfg.seed)),
        WorkloadSource::GoogleLike(p) => synth::google_like(p, &mut Rng::new(cfg.seed)),
        WorkloadSource::Csv(path) => crate::trace::read_csv(std::path::Path::new(path), 90.0)?,
    })
}

/// Build the scheduler instance for a kind.
pub fn build_scheduler(kind: SchedulerKind, probe_ratio: f64) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::Centralized => Box::new(Centralized),
        SchedulerKind::Sparrow => Box::new(Sparrow::new(probe_ratio)),
        SchedulerKind::Hawk => Box::new(Hybrid::hawk(probe_ratio)),
        SchedulerKind::Eagle => Box::new(Hybrid::eagle(probe_ratio)),
        SchedulerKind::CloudCoaster => Box::new(Hybrid::cloudcoaster(probe_ratio)),
    }
}

/// Run one experiment end-to-end (workload synthesis → simulation →
/// analytics) and distill the report.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<Report> {
    let mut analytics = AnalyticsEngine::auto(&artifacts_dir());
    let streams = cfg.scenario.as_ref().map(|s| s.reshapes_workload()).unwrap_or(false);
    if streams || cfg.federation.is_some() {
        // Streaming scenario or federation: no shared eager workload is
        // ever materialised — members stream their own pipelines and
        // memory stays O(active jobs) regardless of trace length.
        return run_experiment_on(cfg, &Workload::default(), analytics.as_dyn());
    }
    let workload = build_workload(cfg)?;
    run_experiment_on(cfg, &workload, analytics.as_dyn())
}

/// Like [`run_experiment`] but with a shared workload + analytics engine
/// (sweeps reuse both across runs).
///
/// When the config carries a scenario that reshapes the workload
/// (non-`workload` source or a combinator stack), the run streams its
/// own [`crate::trace::ArrivalSource`] pipeline and `workload` is
/// ignored — scenario points on a sweep grid each synthesize lazily in
/// O(active-jobs) memory. Passthrough scenarios (e.g. the manager-less
/// baseline) keep the shared eager workload: the streamed and eager
/// paths are bit-identical, and sharing skips re-synthesis.
pub fn run_experiment_on(
    cfg: &ExperimentConfig,
    workload: &Workload,
    analytics: &mut dyn Analytics,
) -> Result<Report> {
    if cfg.federation.is_some() {
        // Federated config: the members build their own streaming
        // pipelines (`workload` is ignored) and the grid sees the
        // aggregate report — so router/budget-sharing axes sweep like
        // any other knob. Per-cluster reports come from
        // [`run_federated_experiment`].
        return Ok(run_federated_experiment_with(cfg, analytics)?.aggregate);
    }
    let sim_cfg: SimConfig = cfg.to_sim_config();
    let mut scheduler = build_scheduler(cfg.scheduler, cfg.probe_ratio);
    let result = match &cfg.scenario {
        Some(spec) if spec.reshapes_workload() => {
            let source = spec.build_source(cfg)?;
            simulate_source(source, scheduler.as_mut(), &sim_cfg, Some(&mut *analytics))
        }
        _ => simulate_with(workload, scheduler.as_mut(), &sim_cfg, Some(&mut *analytics)),
    };
    distill(cfg, result, analytics)
}

/// Figure 3 CDF at uniform edges spanning [0, exact max], from either
/// delay backend (shared by the single-run and federated-aggregate
/// distills). The edge grid is identical on both backends (max is exact
/// in the sketch, and f64->f32 casting is monotone, so the cast of the
/// max equals the max of the casts the old per-sample fold computed).
fn build_cdf(short_delays: &mut crate::metrics::DelayDist, analytics: &mut dyn Analytics) -> Result<Cdf> {
    let n_samples = short_delays.len();
    let max_delay = (short_delays.max() as f32).max(1e-6);
    let n_edges = crate::runtime::artifacts::EDGES;
    let edges: Vec<f32> = (0..n_edges)
        .map(|i| max_delay * i as f32 / (n_edges - 1) as f32)
        .collect();
    Ok(if short_delays.is_exact() {
        // Exact backend: evaluate through the analytics engine (XLA
        // artifacts when available) over the raw f32 samples, as the
        // pre-sketch pipeline always did. Zero samples stay a defined
        // all-zeros CDF (the engine divides by max(n, 1)).
        let samples: Vec<f32> = short_delays
            .samples()
            .expect("exact backend has samples")
            .iter()
            .map(|&d| d as f32)
            .collect();
        let (_counts, cdf_vals) = analytics.delay_cdf(&samples, &edges)?;
        Cdf {
            edges: edges.iter().map(|&e| e as f64).collect(),
            values: cdf_vals.iter().map(|&v| v as f64).collect(),
            n_samples,
        }
    } else {
        // Sketch backend: the histogram answers the CDF directly — no
        // per-sample pass exists to hand the analytics engine. Values
        // are bucket-approximate (the explicitly-approximate quantile
        // surface); edges and sample count are exact. The final edge
        // evaluates at the *exact* f64 max (its f32 rendering may round
        // down past the top bucket), so a non-empty CDF always closes
        // at 1.0 like the per-sample path.
        let exact_max = short_delays.max();
        let values = edges
            .iter()
            .enumerate()
            .map(|(i, &e)| {
                let at = if i + 1 == n_edges { exact_max.max(e as f64) } else { e as f64 };
                short_delays.cdf_at(at)
            })
            .collect();
        Cdf {
            edges: edges.iter().map(|&e| e as f64).collect(),
            values,
            n_samples,
        }
    })
}

fn distill(cfg: &ExperimentConfig, mut run: RunResult, analytics: &mut dyn Analytics) -> Result<Report> {
    let end = run.end_time;
    let cdf = build_cdf(&mut run.rec.short_delays, analytics)?;

    let scheduler: &'static str = match run.scheduler.as_str() {
        "hawk" => "hawk",
        "eagle" => "eagle",
        "cloudcoaster" => "cloudcoaster",
        "sparrow" => "sparrow",
        _ => "centralized",
    };
    let name = match &cfg.scenario {
        Some(spec) if spec.name != "default" => {
            format!("{} r={} [{}]", scheduler, cfg.r, spec.name)
        }
        _ => format!("{} r={}", scheduler, cfg.r),
    };
    Ok(Report {
        name,
        scheduler,
        r: cfg.r,
        short_delay: DelayStats::of(&mut run.rec.short_delays),
        long_delay: DelayStats::of(&mut run.rec.long_delays),
        cdf,
        avg_transients: run.rec.cost.avg_active(end),
        max_transients: run.rec.cost.max_active(),
        mean_lifetime_h: run.rec.cost.mean_lifetime_hours(),
        max_lifetime_h: run.rec.cost.max_lifetime_hours(),
        r_normalized_avg: run.rec.cost.r_normalized_avg(end),
        transients_requested: run.rec.transients_requested,
        transients_revoked: run.rec.transients_revoked,
        tasks_rescheduled: run.rec.tasks_rescheduled,
        end_time: end,
        events: run.events,
        wall_ms: run.wall_ms,
        events_per_sec: run.events as f64 / (run.wall_ms / 1000.0).max(1e-9),
        peak_resident_jobs: run.peak_resident_jobs,
        peak_resident_tasks: run.peak_resident_tasks,
        peak_resident_servers: run.peak_resident_servers,
        delay_struct_bytes: run.rec.delay_struct_bytes(),
        snapshot_series_bytes: run.rec.snapshot_series_bytes(),
        analytics_engine: analytics.name(),
        profile: run.profile,
    })
}

/// Distill a federation's aggregate [`Report`]: delay populations and
/// transient lifetimes merge exactly across clusters (bucket-wise on
/// the sketch backend), cost integrals sum (the aggregate average is
/// Σ per-cluster server·seconds over the global horizon), counters sum,
/// the active-transient peak is the federation's cross-cluster
/// watermark, and memory headlines sum (total resident footprint).
fn distill_aggregate(
    cfg: &ExperimentConfig,
    outcome: &crate::coordinator::runner::FederationOutcome,
    analytics: &mut dyn Analytics,
) -> Result<Report> {
    let runs = &outcome.runs;
    assert!(!runs.is_empty(), "federation produced no runs");
    let end = runs.iter().map(|r| r.end_time).fold(0.0f64, f64::max);
    // One merge implementation: `Recorder::absorb` (delay populations,
    // lifetimes and counters; its unit tests are the contract). Cost
    // *integrals* deliberately stay per-run — they are recombined over
    // the global horizon below, not pointwise mergeable.
    let mut merged = runs[0].rec.clone();
    for r in &runs[1..] {
        merged.absorb(&r.rec);
    }
    let cdf = build_cdf(&mut merged.short_delays, analytics)?;
    // Σ transient server·seconds across clusters, averaged over the
    // global horizon — the federated Table 1 "average transients".
    let total_server_secs: f64 =
        runs.iter().map(|r| r.rec.cost.transient_hours(r.end_time) * 3600.0).sum();
    let avg_transients = if end > 0.0 { total_server_secs / end } else { 0.0 };
    let scheduler: &'static str = match runs[0].scheduler.as_str() {
        "hawk" => "hawk",
        "eagle" => "eagle",
        "cloudcoaster" => "cloudcoaster",
        "sparrow" => "sparrow",
        _ => "centralized",
    };
    let events: u64 = runs.iter().map(|r| r.events).sum();
    Ok(Report {
        name: format!(
            "federated×{} [{}] {} r={}",
            outcome.clusters, outcome.router, scheduler, cfg.r
        ),
        scheduler,
        r: cfg.r,
        short_delay: DelayStats::of(&mut merged.short_delays),
        long_delay: DelayStats::of(&mut merged.long_delays),
        cdf,
        avg_transients,
        max_transients: outcome.peak_total_active,
        mean_lifetime_h: merged.cost.lifetimes.mean() / 3600.0,
        max_lifetime_h: merged.cost.lifetimes.max() / 3600.0,
        r_normalized_avg: avg_transients / cfg.r,
        transients_requested: merged.transients_requested,
        transients_revoked: merged.transients_revoked,
        tasks_rescheduled: merged.tasks_rescheduled,
        end_time: end,
        events,
        wall_ms: outcome.wall_ms,
        events_per_sec: events as f64 / (outcome.wall_ms / 1000.0).max(1e-9),
        peak_resident_jobs: runs.iter().map(|r| r.peak_resident_jobs).sum(),
        peak_resident_tasks: runs.iter().map(|r| r.peak_resident_tasks).sum(),
        peak_resident_servers: runs.iter().map(|r| r.peak_resident_servers).sum(),
        delay_struct_bytes: runs.iter().map(|r| r.rec.delay_struct_bytes()).sum(),
        snapshot_series_bytes: runs.iter().map(|r| r.rec.snapshot_series_bytes()).sum(),
        analytics_engine: analytics.name(),
        // Per-member profiles stay on the per-cluster reports; no
        // meaningful cross-cluster merge exists for wall-time splits.
        profile: None,
    })
}

/// Run a federated experiment end-to-end with a caller-supplied
/// analytics engine: every member cluster simulated in global
/// event-time order, then distilled into per-cluster reports plus the
/// merged aggregate.
pub fn run_federated_experiment_with(
    cfg: &ExperimentConfig,
    analytics: &mut dyn Analytics,
) -> Result<FederatedReport> {
    let spec = cfg.federation.clone().unwrap_or_default();
    let outcome = crate::coordinator::runner::run_federation(cfg)?;
    let aggregate = distill_aggregate(cfg, &outcome, analytics)?;
    let peak_total_fleet = outcome.peak_total_fleet;
    let shared_cap = outcome.shared_cap;
    let per_cluster: Vec<Report> = outcome
        .runs
        .into_iter()
        .enumerate()
        .map(|(i, run)| distill(&spec.member_config(cfg, i), run, analytics))
        .collect::<Result<_>>()?;
    Ok(FederatedReport { aggregate, per_cluster, peak_total_fleet, shared_cap })
}

/// [`run_federated_experiment_with`] with the auto-detected analytics
/// engine — the `[federation]` / `--scenario federated-burst` entry
/// point.
pub fn run_federated_experiment(cfg: &ExperimentConfig) -> Result<FederatedReport> {
    let mut analytics = AnalyticsEngine::auto(&artifacts_dir());
    run_federated_experiment_with(cfg, analytics.as_dyn())
}

/// Render Table 1 (plus context columns) from a set of reports.
pub fn table1_markdown(reports: &[Report]) -> String {
    let mut out = String::new();
    out.push_str("| run | r | avg life (h) | max life (h) | avg transient | r-norm avg on-demand | requested |\n");
    out.push_str("|---|---|---|---|---|---|---|\n");
    for rep in reports {
        out.push_str(&format!(
            "| {} | {:.0} | {:.2} | {:.1} | {:.1} | {:.1} | {} |\n",
            rep.name,
            rep.r,
            rep.mean_lifetime_h,
            rep.max_lifetime_h,
            rep.avg_transients,
            rep.r_normalized_avg,
            rep.transients_requested,
        ));
    }
    out
}

/// Render the Figure 3 summary (delay stats per run + headline ratios
/// against the first report, which should be the baseline).
pub fn fig3_markdown(reports: &[Report]) -> String {
    let mut out = String::new();
    out.push_str("| run | short mean (s) | short p50 | short p99 | short max | long mean | speedup mean | speedup max |\n");
    out.push_str("|---|---|---|---|---|---|---|---|\n");
    let base = reports.first();
    for rep in reports {
        let (su_mean, su_max) = match base {
            Some(b) if b.short_delay.mean > 0.0 => (
                b.short_delay.mean / rep.short_delay.mean.max(1e-9),
                b.short_delay.max / rep.short_delay.max.max(1e-9),
            ),
            _ => (1.0, 1.0),
        };
        out.push_str(&format!(
            "| {} | {:.1} | {:.1} | {:.1} | {:.0} | {:.1} | {:.2}x | {:.2}x |\n",
            rep.name,
            rep.short_delay.mean,
            rep.short_delay.p50,
            rep.short_delay.p99,
            rep.short_delay.max,
            rep.long_delay.mean,
            su_mean,
            su_max,
        ));
    }
    out
}

/// CSV of CDF series for plotting Figure 3 (one column block per run).
pub fn fig3_cdf_csv(reports: &[Report]) -> String {
    let mut out = String::from("run,edge,cdf\n");
    for rep in reports {
        for (e, v) in rep.cdf.edges.iter().zip(&rep.cdf.values) {
            out.push_str(&format!("{},{e:.3},{v:.6}\n", rep.name));
        }
    }
    out
}

/// Short human-readable summary for the CLI.
pub fn summary_line(rep: &Report) -> String {
    format!(
        "{:<18} short mean {:>8.1}s  p99 {:>8.1}s  max {:>7.0}s | long mean {:>7.1}s | \
         avg transients {:>6.1} (r-norm {:>5.1}) | {:.1}k ev/s [{}]",
        rep.name,
        rep.short_delay.mean,
        rep.short_delay.p99,
        rep.short_delay.max,
        rep.long_delay.mean,
        rep.avg_transients,
        rep.r_normalized_avg,
        rep.events_per_sec / 1000.0,
        rep.analytics_engine,
    )
}

/// Workload description for reports. Streaming scenarios are described
/// by their spec instead of materialised (that would defeat the O(1)
/// memory point of replaying a long trace).
pub fn workload_summary(cfg: &ExperimentConfig) -> Result<String> {
    let fed = match &cfg.federation {
        Some(f) => format!(
            "federation of {} (router {}, budget {}) over ",
            f.clusters,
            f.router.name(),
            f.budget_sharing.name(),
        ),
        None => String::new(),
    };
    if let Some(spec) = &cfg.scenario {
        if spec.reshapes_workload() {
            return Ok(format!(
                "{fed}scenario '{}' ({} combinator{}, streamed)",
                spec.name,
                spec.stack.len(),
                if spec.stack.len() == 1 { "" } else { "s" },
            ));
        }
    }
    if cfg.federation.is_some() {
        // Federated members always stream their own pipelines
        // (`run_experiment` never materialises an eager workload for
        // them) — describing the config must not either, or a long CSV
        // trace would be loaded into RAM just for this summary line.
        return Ok(format!("{fed}configured workload, streamed per member"));
    }
    Ok(format!("{fed}{}", TraceStats::of(&build_workload(cfg)?).summary()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeAnalytics;
    use crate::trace::synth::YahooLikeParams;

    fn tiny_cfg(kind: SchedulerKind) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.scheduler = kind;
        cfg.cluster_size = 120;
        cfg.short_partition = 8;
        let mut p = YahooLikeParams::default();
        p.horizon = 3000.0;
        cfg.workload = WorkloadSource::YahooLike(p);
        cfg
    }

    #[test]
    fn end_to_end_report_native_analytics() {
        let cfg = tiny_cfg(SchedulerKind::Eagle);
        let w = build_workload(&cfg).unwrap();
        let mut analytics = NativeAnalytics;
        let rep = run_experiment_on(&cfg, &w, &mut analytics).unwrap();
        assert!(rep.short_delay.n > 0);
        assert_eq!(rep.analytics_engine, "native");
        assert!(rep.cdf.values.last().copied().unwrap_or(0.0) > 0.999);
        assert_eq!(rep.avg_transients, 0.0); // baseline has none
    }

    #[test]
    fn cloudcoaster_report_has_transients() {
        let mut cfg = tiny_cfg(SchedulerKind::CloudCoaster);
        cfg.threshold = 0.5; // small cluster needs a lower trigger
        let w = build_workload(&cfg).unwrap();
        let mut analytics = NativeAnalytics;
        let rep = run_experiment_on(&cfg, &w, &mut analytics).unwrap();
        assert!(rep.transients_requested > 0);
        assert!(rep.max_transients > 0.0);
    }

    #[test]
    fn zero_short_task_run_reports_defined_zeros() {
        // Regression (empty-run audit): a long-only trace through a
        // manager-less wiring produces NO short tasks — every short
        // stat and the CDF must be finite, well-defined zeros.
        use crate::coordinator::runner::{simulate_with, SimConfig};
        use crate::trace::{Job, Workload};
        use crate::util::JobId;
        let jobs: Vec<Job> = (0..6)
            .map(|i| Job {
                id: JobId(i),
                arrival: i as f64 * 50.0,
                task_durations: vec![400.0, 500.0],
                is_long: true,
            })
            .collect();
        let w = Workload::new(jobs, 90.0);
        for exact in [false, true] {
            let cfg = SimConfig {
                n_general: 16,
                n_short_reserved: 2,
                exact_delay_samples: exact,
                ..Default::default()
            };
            let mut sched = crate::sched::Hybrid::eagle(2.0);
            let run = simulate_with(&w, &mut sched, &cfg, None);
            let mut ecfg = ExperimentConfig::paper_defaults();
            ecfg.scheduler = SchedulerKind::Eagle;
            let rep = super::distill(&ecfg, run, &mut NativeAnalytics).unwrap();
            assert_eq!(rep.short_delay.n, 0);
            for v in [
                rep.short_delay.mean,
                rep.short_delay.max,
                rep.short_delay.p50,
                rep.short_delay.p90,
                rep.short_delay.p99,
            ] {
                assert_eq!(v, 0.0, "empty short-delay stat not zero (exact={exact})");
            }
            assert!(rep.cdf.values.iter().all(|v| v.is_finite()), "CDF has NaN");
            assert!(rep.cdf.values.iter().all(|&v| v == 0.0), "empty CDF not all-zero");
            assert_eq!(rep.cdf.quantile(0.99), 0.0);
            assert!(rep.long_delay.n > 0);
            // The markdown tables render finite text, no NaN.
            let md = fig3_markdown(&[rep]);
            assert!(!md.contains("NaN"), "markdown rendered NaN: {md}");
        }
    }

    #[test]
    fn sketch_and_exact_reports_agree_on_exact_fields() {
        let cfg = tiny_cfg(SchedulerKind::Eagle);
        let w = build_workload(&cfg).unwrap();
        let run = |exact: bool| {
            use crate::coordinator::runner::simulate_with;
            let mut sim_cfg = cfg.to_sim_config();
            sim_cfg.exact_delay_samples = exact;
            let mut sched = build_scheduler(cfg.scheduler, cfg.probe_ratio);
            let res = simulate_with(&w, sched.as_mut(), &sim_cfg, None);
            super::distill(&cfg, res, &mut NativeAnalytics).unwrap()
        };
        let sk = run(false);
        let ex = run(true);
        assert_eq!(sk.short_delay.n, ex.short_delay.n);
        assert_eq!(sk.short_delay.mean.to_bits(), ex.short_delay.mean.to_bits());
        assert_eq!(sk.short_delay.max.to_bits(), ex.short_delay.max.to_bits());
        assert_eq!(sk.events, ex.events);
        assert_eq!(sk.end_time.to_bits(), ex.end_time.to_bits());
        // Quantiles are the explicitly-approximate fields: within the
        // histogram's documented relative bound (plus the sub-ms
        // absolute floor for near-zero delays).
        for (a, b) in [
            (sk.short_delay.p50, ex.short_delay.p50),
            (sk.short_delay.p90, ex.short_delay.p90),
            (sk.short_delay.p99, ex.short_delay.p99),
        ] {
            assert!(
                (a - b).abs() <= 0.011 * b.abs() + 1e-3,
                "quantile diverged past the bucket bound: {a} vs {b}"
            );
        }
        // Sketch memory is fixed; exact grows with the run.
        assert!(sk.delay_struct_bytes < ex.delay_struct_bytes);
    }

    #[test]
    fn markdown_tables_render() {
        let cfg = tiny_cfg(SchedulerKind::Eagle);
        let w = build_workload(&cfg).unwrap();
        let mut analytics = NativeAnalytics;
        let rep = run_experiment_on(&cfg, &w, &mut analytics).unwrap();
        let reports = vec![rep];
        assert!(table1_markdown(&reports).contains("r-norm"));
        assert!(fig3_markdown(&reports).contains("speedup"));
        assert!(fig3_cdf_csv(&reports).lines().count() > 10);
        assert!(!summary_line(&reports[0]).is_empty());
    }
}
