//! Minimal TOML-subset parser for experiment config files (no external
//! TOML crate is available in this build environment; see DESIGN.md §3).
//!
//! Supported: `[section]` headers, `key = value` with string / integer /
//! float / boolean / inline scalar array values, `#` comments. That is
//! the full surface the config files in `examples/` and the CLI use.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed TOML-subset value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// `section.key -> value` map (keys in the root section have no prefix).
pub type Table = BTreeMap<String, Value>;

fn parse_scalar(raw: &str) -> Result<Value> {
    let raw = raw.trim();
    if raw.starts_with('"') && raw.ends_with('"') && raw.len() >= 2 {
        return Ok(Value::Str(raw[1..raw.len() - 1].to_string()));
    }
    if raw == "true" {
        return Ok(Value::Bool(true));
    }
    if raw == "false" {
        return Ok(Value::Bool(false));
    }
    if raw.starts_with('[') && raw.ends_with(']') {
        let inner = &raw[1..raw.len() - 1];
        let items: Result<Vec<Value>> = inner
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(parse_scalar)
            .collect();
        return Ok(Value::Array(items?));
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value {raw:?}")
}

/// Parse TOML-subset text into a flat `section.key` table.
pub fn parse(text: &str) -> Result<Table> {
    let mut table = Table::new();
    let mut section = String::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = match line.find('#') {
            // Strip comments, but not inside quoted strings.
            Some(idx) if !line[..idx].contains('"') || line[..idx].matches('"').count() % 2 == 0 => {
                &line[..idx]
            }
            _ => line,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') && line.ends_with(']') {
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let full_key = if section.is_empty() {
            key.trim().to_string()
        } else {
            format!("{section}.{}", key.trim())
        };
        let value =
            parse_scalar(value).with_context(|| format!("line {}", lineno + 1))?;
        table.insert(full_key, value);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let t = parse(
            r#"
            # experiment
            seed = 42
            [cluster]
            servers = 4000
            threshold = 0.95
            name = "paper"
            enabled = true
            ratios = [1, 2, 3]
            "#,
        )
        .unwrap();
        assert_eq!(t["seed"], Value::Int(42));
        assert_eq!(t["cluster.servers"].as_usize(), Some(4000));
        assert_eq!(t["cluster.threshold"].as_f64(), Some(0.95));
        assert_eq!(t["cluster.name"].as_str(), Some("paper"));
        assert_eq!(t["cluster.enabled"].as_bool(), Some(true));
        assert_eq!(
            t["cluster.ratios"],
            Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(3)])
        );
    }

    #[test]
    fn int_promotes_to_f64() {
        let t = parse("x = 3").unwrap();
        assert_eq!(t["x"].as_f64(), Some(3.0));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let t = parse("# hi\n\na = 1 # trailing\n").unwrap();
        assert_eq!(t["a"], Value::Int(1));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("just words").is_err());
        assert!(parse("x = @nope").is_err());
    }

    #[test]
    fn string_with_hash_preserved() {
        let t = parse("s = \"a#b\"\n").unwrap();
        assert_eq!(t["s"].as_str(), Some("a#b"));
    }
}
