//! High-level experiment configuration: the paper's (N, N_s, p, r, L_r^T,
//! provisioning-delay) knobs plus workload selection, loadable from a
//! TOML-subset file or built programmatically.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::cluster::QueuePolicy;
use crate::coordinator::runner::SimConfig;
use crate::coordinator::scenario::{FederationSpec, ScenarioSpec};
use crate::coordinator::toml::{parse, Table};
use crate::trace::synth::{GoogleLikeParams, YahooLikeParams};
use crate::transient::{Budget, ManagerConfig, MarketConfig};

/// Which placement policy to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    Centralized,
    Sparrow,
    /// Hawk (ATC'15) — Eagle's predecessor, no succinct state.
    Hawk,
    /// Eagle hybrid — the paper's *Baseline*.
    Eagle,
    /// Eagle + transient manager + on-demand duplication.
    CloudCoaster,
}

impl SchedulerKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "centralized" => SchedulerKind::Centralized,
            "sparrow" => SchedulerKind::Sparrow,
            "hawk" => SchedulerKind::Hawk,
            "eagle" | "baseline" => SchedulerKind::Eagle,
            "cloudcoaster" => SchedulerKind::CloudCoaster,
            other => bail!("unknown scheduler {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::Centralized => "centralized",
            SchedulerKind::Sparrow => "sparrow",
            SchedulerKind::Hawk => "hawk",
            SchedulerKind::Eagle => "eagle",
            SchedulerKind::CloudCoaster => "cloudcoaster",
        }
    }
}

/// Workload source.
#[derive(Clone, Debug)]
pub enum WorkloadSource {
    YahooLike(YahooLikeParams),
    GoogleLike(GoogleLikeParams),
    Csv(String),
}

/// One experiment = cluster geometry + budget + policy + workload.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Total on-demand cluster size (paper: 4000).
    pub cluster_size: usize,
    /// Static short-only partition size N_s (paper: 80).
    pub short_partition: usize,
    /// Fraction of N_s converted to transient budget (paper: 0.5).
    pub p: f64,
    /// Cost ratio r (paper sweeps 1, 2, 3).
    pub r: f64,
    /// Long-load-ratio threshold L_r^T (paper: 0.95).
    pub threshold: f64,
    /// Transient provisioning delay, seconds (paper: 120).
    pub provisioning_delay: f64,
    /// Mean time to revocation (None = paper regime, never revoked).
    pub mttf: Option<f64>,
    /// Spot bid (fraction of on-demand price). `None` = the paper's
    /// fixed 1/r pricing; `Some(bid)` enables the dynamic price process
    /// (requests fail and servers are revoked on price crossings).
    pub bid: Option<f64>,
    pub scheduler: SchedulerKind,
    /// Probes per short task.
    pub probe_ratio: f64,
    pub queue_policy: QueuePolicy,
    /// Shrink conservativeness (1 = paper; usize::MAX = symmetric).
    pub max_removals_per_recalc: usize,
    pub aggressive_add: bool,
    /// Min seconds between drains (see [`ManagerConfig::drain_cooldown`]).
    pub drain_cooldown: f64,
    /// Predictive resizing via the lr_forecast artifact (abl-forecast).
    pub predictive: bool,
    pub snapshot_interval: f64,
    /// Run on the reference `BinaryHeap` event engine instead of the
    /// default calendar queue (`[engine] reference = true` /
    /// `--reference-engine true`). Bit-identical results; kept for the
    /// CI engine-equivalence smoke and golden comparisons.
    pub reference_engine: bool,
    /// Serve hot per-server fields from the cluster's dense
    /// struct-of-arrays mirror (default) or the reference `Server`
    /// struct layout (`soa_hot_fields = false` / `--soa-hot-fields
    /// false`). Bit-identical results either way; kept for golden
    /// comparisons of the SoA read path.
    pub soa_hot_fields: bool,
    /// Enable the hot-path profiler (`profile = true` / `--profile`).
    /// Reported on stderr + optional JSON; the default stdout surface
    /// stays byte-identical to an unprofiled run.
    pub profile: bool,
    pub seed: u64,
    pub workload: WorkloadSource,
    /// Declarative workload scenario (source + combinator stack +
    /// optional manager-less override). `None` = plain workload.
    pub scenario: Option<ScenarioSpec>,
    /// Multi-cluster federation (member count, router, budget sharing,
    /// storm stagger). `None` = a single plain cluster. Each member
    /// cluster gets this config with its own seed and staggered storm
    /// windows (see [`FederationSpec::member_config`]).
    pub federation: Option<FederationSpec>,
}

impl ExperimentConfig {
    /// The paper's §4 default configuration with CloudCoaster at r = 3.
    pub fn paper_defaults() -> Self {
        ExperimentConfig {
            cluster_size: 4000,
            short_partition: 80,
            p: 0.5,
            r: 3.0,
            threshold: 0.95,
            provisioning_delay: 120.0,
            mttf: None,
            bid: None,
            scheduler: SchedulerKind::CloudCoaster,
            probe_ratio: 2.0,
            queue_policy: QueuePolicy::Srpt { starvation_limit: 600.0 },
            max_removals_per_recalc: 1,
            aggressive_add: true,
            drain_cooldown: 120.0,
            predictive: false,
            snapshot_interval: 60.0,
            reference_engine: false,
            soa_hot_fields: true,
            profile: false,
            seed: 42,
            workload: WorkloadSource::YahooLike(YahooLikeParams::default()),
            scenario: None,
            federation: None,
        }
    }

    /// The paper's *Baseline*: Eagle on the statically provisioned
    /// cluster (full 80-server on-demand short partition, no transients).
    pub fn paper_baseline() -> Self {
        ExperimentConfig { scheduler: SchedulerKind::Eagle, ..Self::paper_defaults() }
    }

    /// Derive low-level simulation parameters.
    ///
    /// Cluster geometry (§3.1/§4): the general partition is
    /// `cluster_size - short_partition`. The baseline keeps all
    /// `short_partition` servers on-demand; CloudCoaster keeps
    /// `(1-p)·N_s` on-demand and manages up to `K = r·N_s·p` transients.
    pub fn to_sim_config(&self) -> SimConfig {
        let n_general = self.cluster_size - self.short_partition;
        let mut sim = self.to_sim_config_inner(n_general);
        // Scenario override: manager-less baseline keeps the cluster
        // geometry of its scheduler but drops the TransientManager
        // component entirely (scheduler-only wiring).
        if self.scenario.as_ref().map(|s| s.manager_off).unwrap_or(false) {
            sim.manager = None;
        }
        sim
    }

    fn to_sim_config_inner(&self, n_general: usize) -> SimConfig {
        match self.scheduler {
            SchedulerKind::CloudCoaster => {
                let budget = Budget::new(self.short_partition, self.p, self.r);
                let manager = ManagerConfig {
                    threshold: self.threshold,
                    market: MarketConfig {
                        cost_ratio: self.r,
                        provisioning_delay: self.provisioning_delay,
                        mttf: self.mttf,
                        pricing: self.bid.map(|bid| crate::transient::PricingConfig {
                            bid,
                            ..Default::default()
                        }),
                        ..Default::default()
                    },
                    budget,
                    max_removals_per_recalc: self.max_removals_per_recalc,
                    aggressive_add: self.aggressive_add,
                    drain_cooldown: self.drain_cooldown,
                    predictive: self.predictive,
                };
                SimConfig {
                    n_general,
                    n_short_reserved: budget.ondemand_short(),
                    queue_policy: self.queue_policy,
                    manager: Some(manager),
                    snapshot_interval: self.snapshot_interval,
                    reference_engine: self.reference_engine,
                    soa_hot_fields: self.soa_hot_fields,
                    profile: self.profile,
                    seed: self.seed,
                    ..Default::default()
                }
            }
            _ => SimConfig {
                n_general,
                n_short_reserved: self.short_partition,
                queue_policy: self.queue_policy,
                manager: None,
                snapshot_interval: self.snapshot_interval,
                reference_engine: self.reference_engine,
                soa_hot_fields: self.soa_hot_fields,
                profile: self.profile,
                seed: self.seed,
                ..Default::default()
            },
        }
    }

    /// Load from a TOML-subset file (all keys optional; see
    /// `examples/paper.toml`).
    pub fn from_toml_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        Self::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> Result<Self> {
        let t = parse(text)?;
        let mut cfg = Self::paper_defaults();
        let get_f64 = |t: &Table, k: &str| t.get(k).and_then(|v| v.as_f64());
        let get_usize = |t: &Table, k: &str| t.get(k).and_then(|v| v.as_usize());
        if let Some(v) = get_usize(&t, "cluster.servers") {
            cfg.cluster_size = v;
        }
        if let Some(v) = get_usize(&t, "cluster.short_partition") {
            cfg.short_partition = v;
        }
        if let Some(v) = get_f64(&t, "transient.p") {
            cfg.p = v;
        }
        if let Some(v) = get_f64(&t, "transient.r") {
            cfg.r = v;
        }
        if let Some(v) = get_f64(&t, "transient.threshold") {
            cfg.threshold = v;
        }
        if let Some(v) = get_f64(&t, "transient.provisioning_delay") {
            cfg.provisioning_delay = v;
        }
        if let Some(v) = get_f64(&t, "transient.mttf") {
            cfg.mttf = if v > 0.0 { Some(v) } else { None };
        }
        if let Some(v) = get_f64(&t, "transient.bid") {
            cfg.bid = if v > 0.0 { Some(v) } else { None };
        }
        if let Some(v) = t.get("transient.predictive").and_then(|v| v.as_bool()) {
            cfg.predictive = v;
        }
        if let Some(v) = t.get("scheduler.kind").and_then(|v| v.as_str()) {
            cfg.scheduler = SchedulerKind::parse(v)?;
        }
        if let Some(v) = get_f64(&t, "scheduler.probe_ratio") {
            cfg.probe_ratio = v;
        }
        if let Some(v) = get_f64(&t, "scheduler.starvation_limit") {
            cfg.queue_policy = QueuePolicy::Srpt { starvation_limit: v };
        }
        if let Some(v) = t.get("scheduler.fifo").and_then(|v| v.as_bool()) {
            if v {
                cfg.queue_policy = QueuePolicy::Fifo;
            }
        }
        if let Some(v) = t.get("engine.reference").and_then(|v| v.as_bool()) {
            cfg.reference_engine = v;
        }
        if let Some(v) = t.get("engine.soa_hot_fields").and_then(|v| v.as_bool()) {
            cfg.soa_hot_fields = v;
        }
        if let Some(v) = t.get("profile").and_then(|v| v.as_bool()) {
            cfg.profile = v;
        }
        if let Some(v) = t.get("seed").and_then(|v| v.as_u64()) {
            cfg.seed = v;
        }
        if let Some(v) = get_f64(&t, "workload.horizon") {
            if let WorkloadSource::YahooLike(p) = &mut cfg.workload {
                p.horizon = v;
            }
        }
        if let Some(v) = t.get("workload.csv").and_then(|v| v.as_str()) {
            cfg.workload = WorkloadSource::Csv(v.to_string());
        }
        cfg.scenario = ScenarioSpec::from_table(&t)?;
        cfg.federation = FederationSpec::from_table(&t)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.short_partition >= self.cluster_size {
            bail!("short partition must be smaller than the cluster");
        }
        if !(0.0..=1.0).contains(&self.p) {
            bail!("p must be in [0,1]");
        }
        if self.r < 1.0 {
            bail!("cost ratio r must be >= 1");
        }
        if !(0.0..=1.0).contains(&self.threshold) {
            bail!("threshold must be in [0,1]");
        }
        if let Some(scenario) = &self.scenario {
            scenario.validate()?;
        }
        if let Some(federation) = &self.federation {
            federation.validate()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults_match_section_4() {
        let c = ExperimentConfig::paper_defaults();
        assert_eq!(c.cluster_size, 4000);
        assert_eq!(c.short_partition, 80);
        assert_eq!(c.p, 0.5);
        assert_eq!(c.threshold, 0.95);
        assert_eq!(c.provisioning_delay, 120.0);
        let sim = c.to_sim_config();
        assert_eq!(sim.n_general, 3920);
        assert_eq!(sim.n_short_reserved, 40); // (1-p)·80
        let mgr = sim.manager.unwrap();
        assert_eq!(mgr.budget.max_transients(), 120); // r·N·p
    }

    #[test]
    fn baseline_has_no_manager_and_full_partition() {
        let sim = ExperimentConfig::paper_baseline().to_sim_config();
        assert!(sim.manager.is_none());
        assert_eq!(sim.n_short_reserved, 80);
    }

    #[test]
    fn toml_overrides() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            seed = 7
            [cluster]
            servers = 1000
            short_partition = 20
            [transient]
            r = 2
            threshold = 0.9
            [scheduler]
            kind = "eagle"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.cluster_size, 1000);
        assert_eq!(cfg.short_partition, 20);
        assert_eq!(cfg.r, 2.0);
        assert_eq!(cfg.threshold, 0.9);
        assert_eq!(cfg.scheduler, SchedulerKind::Eagle);
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(ExperimentConfig::from_toml("[cluster]\nservers = 10\nshort_partition = 10\n").is_err());
        assert!(ExperimentConfig::from_toml("[transient]\nr = 0.5\n").is_err());
        assert!(ExperimentConfig::from_toml("[scheduler]\nkind = \"nope\"\n").is_err());
    }

    #[test]
    fn scenario_section_parses_and_overrides_manager() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            [scenario]
            name = "storm"
            storm_windows = [600, 1200]
            storm_intensity = 4
            manager = "none"
            "#,
        )
        .unwrap();
        let spec = cfg.scenario.as_ref().unwrap();
        assert_eq!(spec.name, "storm");
        assert!(spec.manager_off);
        assert!(spec.reshapes_workload());
        // CloudCoaster geometry, but the manager component is dropped.
        let sim = cfg.to_sim_config();
        assert!(sim.manager.is_none());
        assert_eq!(sim.n_short_reserved, 40); // still (1-p)·N_s
    }

    #[test]
    fn config_without_scenario_has_none() {
        let cfg = ExperimentConfig::from_toml("seed = 1\n").unwrap();
        assert!(cfg.scenario.is_none());
        assert!(cfg.federation.is_none());
    }

    #[test]
    fn federation_section_parses_through_config() {
        use crate::coordinator::scenario::{BudgetSharing, RouterKind};
        let cfg = ExperimentConfig::from_toml(
            r#"
            [scenario]
            storm_windows = [600, 1200]
            [federation]
            clusters = 2
            router = "round-robin"
            budget_sharing = "split"
            stagger = 300
            "#,
        )
        .unwrap();
        let fed = cfg.federation.as_ref().unwrap();
        assert_eq!(fed.clusters, 2);
        assert_eq!(fed.router, RouterKind::RoundRobin);
        assert_eq!(fed.budget_sharing, BudgetSharing::Split);
        assert_eq!(fed.stagger, 300.0);
        // Invalid federation blocks are config errors.
        assert!(ExperimentConfig::from_toml("[federation]\nclusters = 0\n").is_err());
    }

    #[test]
    fn invalid_scenario_rejected_by_config() {
        assert!(ExperimentConfig::from_toml("[scenario]\nstorm_windows = [9, 1]\n").is_err());
    }

    #[test]
    fn profile_and_soa_keys_parse_and_thread_through() {
        let cfg = ExperimentConfig::from_toml(
            "profile = true\n[engine]\nsoa_hot_fields = false\n",
        )
        .unwrap();
        assert!(cfg.profile);
        assert!(!cfg.soa_hot_fields);
        let sim = cfg.to_sim_config();
        assert!(sim.profile);
        assert!(!sim.soa_hot_fields);
        // Defaults: SoA reads on, profiling off — on both scheduler arms.
        let d = ExperimentConfig::paper_defaults().to_sim_config();
        assert!(d.soa_hot_fields && !d.profile);
        let b = ExperimentConfig::paper_baseline().to_sim_config();
        assert!(b.soa_hot_fields && !b.profile);
    }

    #[test]
    fn scheduler_kind_roundtrip() {
        for k in ["centralized", "sparrow", "hawk", "eagle", "cloudcoaster"] {
            assert_eq!(SchedulerKind::parse(k).unwrap().name(), k);
        }
        assert_eq!(SchedulerKind::parse("baseline").unwrap(), SchedulerKind::Eagle);
    }
}
