//! Parameter sweeps: the paper's evaluation grid (Baseline + r ∈ {1,2,3})
//! and the ablation grids (threshold, revocation MTTF, shrink policy,
//! market bid, forecast, scheduler family).
//!
//! All grids go through one generic driver: a named list of
//! [`GridPoint`]s (config variants) executed over a single shared
//! workload, either serially or fanned out across OS threads by
//! [`run_sweep_parallel`]. Runs are embarrassingly parallel — every RNG
//! stream forks off the per-run config seed, so every *simulation*
//! field of a report (delays, CDFs, events, end times, transient
//! counts) is bit-identical regardless of thread count; only the
//! wall-clock fields (`wall_ms`, `events_per_sec`) vary run to run.
//! Results are written slot-addressed so output order never depends on
//! scheduling.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::coordinator::config::{ExperimentConfig, SchedulerKind};
use crate::coordinator::report::{artifacts_dir, build_workload, run_experiment_on, Report};
use crate::runtime::AnalyticsEngine;
use crate::trace::Workload;

/// Worker threads for grid fan-out: all cores (1 if undetectable).
/// Shared by the CLI default, the benches and the examples.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// One cell of a sweep grid: a report name plus the config to run.
#[derive(Clone, Debug)]
pub struct GridPoint {
    pub name: String,
    pub cfg: ExperimentConfig,
}

impl GridPoint {
    pub fn new(name: impl Into<String>, cfg: ExperimentConfig) -> Self {
        GridPoint { name: name.into(), cfg }
    }
}

/// Run a grid serially (thread count 1) — the generic driver every named
/// sweep uses.
pub fn run_grid(base: &ExperimentConfig, points: &[GridPoint]) -> Result<Vec<Report>> {
    run_sweep_parallel(base, points, 1)
}

/// Run a grid across up to `threads` OS threads. The workload is built
/// once from `base` and shared (read-only) by every run; each worker
/// owns its analytics engine. Reports come back in grid order with all
/// simulation fields identical to a serial run (wall-clock timing
/// fields excepted).
pub fn run_sweep_parallel(
    base: &ExperimentConfig,
    points: &[GridPoint],
    threads: usize,
) -> Result<Vec<Report>> {
    // When every point streams its own scenario pipeline (or federates —
    // members always build their own pipelines), the shared eager
    // workload would never be read — skip materialising it.
    let all_streaming = !points.is_empty()
        && points.iter().all(|p| {
            p.cfg.federation.is_some()
                || p.cfg.scenario.as_ref().map(|s| s.reshapes_workload()).unwrap_or(false)
        });
    let workload = if all_streaming { Workload::default() } else { build_workload(base)? };
    run_points_on(&workload, points, threads)
}

/// Like [`run_sweep_parallel`] with a caller-supplied workload.
pub(crate) fn run_points_on(
    workload: &Workload,
    points: &[GridPoint],
    threads: usize,
) -> Result<Vec<Report>> {
    if points.is_empty() {
        return Ok(Vec::new());
    }
    let threads = threads.max(1).min(points.len());
    if threads == 1 {
        let mut analytics = AnalyticsEngine::auto(&artifacts_dir());
        let mut reports = Vec::with_capacity(points.len());
        for point in points {
            let mut rep = run_experiment_on(&point.cfg, workload, analytics.as_dyn())?;
            rep.name = point.name.clone();
            reports.push(rep);
        }
        return Ok(reports);
    }

    // Work-stealing over point indices; slot-addressed results keep the
    // output order independent of thread interleaving.
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<Report>>>> =
        points.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut analytics = AnalyticsEngine::auto(&artifacts_dir());
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= points.len() {
                        break;
                    }
                    let point = &points[i];
                    let rep =
                        run_experiment_on(&point.cfg, workload, analytics.as_dyn()).map(
                            |mut r| {
                                r.name = point.name.clone();
                                r
                            },
                        );
                    *slots[i].lock().unwrap() = Some(rep);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| slot.into_inner().unwrap().expect("worker filled every claimed slot"))
        .collect()
}

// ------------------------------------------------------- grid builders

/// The paper's §4 grid: Eagle baseline, then CloudCoaster at each r.
pub fn paper_points(base: &ExperimentConfig, ratios: &[f64]) -> Vec<GridPoint> {
    let mut points = Vec::with_capacity(1 + ratios.len());
    let mut baseline = base.clone();
    baseline.scheduler = SchedulerKind::Eagle;
    points.push(GridPoint::new("baseline(eagle)", baseline));
    for &r in ratios {
        let mut cfg = base.clone();
        cfg.scheduler = SchedulerKind::CloudCoaster;
        cfg.r = r;
        points.push(GridPoint::new(format!("cloudcoaster r={r:.0}"), cfg));
    }
    points
}

/// Ablation: sensitivity to the long-load-ratio threshold L_r^T.
pub fn threshold_points(base: &ExperimentConfig, thresholds: &[f64]) -> Vec<GridPoint> {
    thresholds
        .iter()
        .map(|&t| {
            let mut cfg = base.clone();
            cfg.scheduler = SchedulerKind::CloudCoaster;
            cfg.threshold = t;
            GridPoint::new(format!("L_r^T={t:.2}"), cfg)
        })
        .collect()
}

/// Ablation: behaviour under forced revocations (§3.3 resilience path).
pub fn revocation_points(base: &ExperimentConfig, mttfs: &[Option<f64>]) -> Vec<GridPoint> {
    mttfs
        .iter()
        .map(|&mttf| {
            let mut cfg = base.clone();
            cfg.scheduler = SchedulerKind::CloudCoaster;
            cfg.mttf = mttf;
            let name = match mttf {
                Some(m) => format!("mttf={:.1}h", m / 3600.0),
                None => "mttf=inf".to_string(),
            };
            GridPoint::new(name, cfg)
        })
        .collect()
}

/// Ablation: the paper's asymmetric grow/shrink policy vs. a symmetric
/// aggressive one.
pub fn policy_points(base: &ExperimentConfig) -> Vec<GridPoint> {
    [
        ("paper(asym+cooldown)", 1usize, true, 120.0),
        ("paper-literal(no-cooldown)", 1, true, 0.0),
        ("symmetric-aggressive", usize::MAX, true, 0.0),
        ("symmetric-slow", 1, false, 120.0),
    ]
    .into_iter()
    .map(|(name, removals, aggressive, cooldown)| {
        let mut cfg = base.clone();
        cfg.scheduler = SchedulerKind::CloudCoaster;
        cfg.max_removals_per_recalc = removals;
        cfg.aggressive_add = aggressive;
        cfg.drain_cooldown = cooldown;
        GridPoint::new(name, cfg)
    })
    .collect()
}

/// Ablation: bid level on the dynamic spot market (§2.4's Amazon model;
/// the paper's evaluation uses fixed 1/r pricing, `bid = None`).
pub fn bid_points(base: &ExperimentConfig, bids: &[Option<f64>]) -> Vec<GridPoint> {
    bids.iter()
        .map(|&bid| {
            let mut cfg = base.clone();
            cfg.scheduler = SchedulerKind::CloudCoaster;
            cfg.bid = bid;
            let name = match bid {
                Some(b) => format!("bid={b:.2}"),
                None => "fixed-1/r".to_string(),
            };
            GridPoint::new(name, cfg)
        })
        .collect()
}

/// Ablation: reactive (§3.2) vs predictive (lr_forecast artifact)
/// resizing.
pub fn forecast_points(base: &ExperimentConfig) -> Vec<GridPoint> {
    [("reactive(paper)", false), ("predictive(forecast)", true)]
        .into_iter()
        .map(|(name, predictive)| {
            let mut cfg = base.clone();
            cfg.scheduler = SchedulerKind::CloudCoaster;
            cfg.predictive = predictive;
            GridPoint::new(name, cfg)
        })
        .collect()
}

/// Scenario axis: burst-storm intensity over the configured workload —
/// scenario parameters sweep like any other grid knob. Each point
/// carries its own spec; the runs stream their sources lazily (no
/// shared eager workload is consulted).
pub fn storm_intensity_points(
    base: &ExperimentConfig,
    intensities: &[f64],
) -> Result<Vec<GridPoint>> {
    // Resolve the registry storm once (for a CSV workload this scans
    // the trace to place the windows inside it — fallible).
    let storm = crate::coordinator::scenario::named("burst-storm", base)?;
    Ok(intensities
        .iter()
        .map(|&k| {
            let mut cfg = base.clone();
            let mut spec = storm.clone();
            for c in &mut spec.stack {
                if let crate::coordinator::scenario::CombinatorSpec::BurstStorm {
                    intensity,
                    ..
                } = c
                {
                    *intensity = k;
                }
            }
            spec.name = format!("storm-x{k:.1}");
            cfg.scenario = Some(spec);
            GridPoint::new(format!("storm-intensity={k:.1}"), cfg)
        })
        .collect())
}

/// Scenario axis: splice point (as a fraction of `horizon`) at which the
/// workload switches to a replayed CSV regime.
pub fn splice_points(
    base: &ExperimentConfig,
    csv: &str,
    horizon: f64,
    fractions: &[f64],
) -> Vec<GridPoint> {
    fractions
        .iter()
        .map(|&f| {
            let mut cfg = base.clone();
            let mut spec = crate::coordinator::scenario::ScenarioSpec::passthrough();
            spec.name = format!("splice@{f:.2}");
            spec.stack.push(crate::coordinator::scenario::CombinatorSpec::SpliceCsv {
                path: csv.to_string(),
                at: f * horizon,
            });
            cfg.scenario = Some(spec);
            GridPoint::new(format!("splice-at={f:.2}"), cfg)
        })
        .collect()
}

/// Federation axis: router front end, holding cluster count and budget
/// sharing fixed — one grid cell per [`RouterKind`]. Points whose base
/// config has no `[federation]` get the registry's two-cluster default.
pub fn router_points(
    base: &ExperimentConfig,
    routers: &[crate::coordinator::scenario::RouterKind],
) -> Vec<GridPoint> {
    routers
        .iter()
        .map(|&router| {
            let mut cfg = base.clone();
            let mut fed = cfg.federation.clone().unwrap_or(
                crate::coordinator::scenario::FederationSpec {
                    clusters: 2,
                    ..Default::default()
                },
            );
            fed.router = router;
            cfg.federation = Some(fed);
            GridPoint::new(format!("router={}", router.name()), cfg)
        })
        .collect()
}

/// Federation axis: budget sharing (none / split / pooled) across a
/// fixed member count — the elasticity ablation: does pooling one
/// cluster's quiet headroom into another's burst pay?
pub fn budget_sharing_points(base: &ExperimentConfig) -> Vec<GridPoint> {
    use crate::coordinator::scenario::BudgetSharing;
    [BudgetSharing::None, BudgetSharing::Split, BudgetSharing::Pooled]
        .into_iter()
        .map(|sharing| {
            let mut cfg = base.clone();
            let mut fed = cfg.federation.clone().unwrap_or(
                crate::coordinator::scenario::FederationSpec {
                    clusters: 2,
                    ..Default::default()
                },
            );
            fed.budget_sharing = sharing;
            cfg.federation = Some(fed);
            GridPoint::new(format!("budget={}", sharing.name()), cfg)
        })
        .collect()
}

/// Scheduler-family comparison (context for §5 related work).
pub fn scheduler_points(base: &ExperimentConfig) -> Vec<GridPoint> {
    [
        SchedulerKind::Centralized,
        SchedulerKind::Sparrow,
        SchedulerKind::Hawk,
        SchedulerKind::Eagle,
        SchedulerKind::CloudCoaster,
    ]
    .into_iter()
    .map(|kind| {
        let mut cfg = base.clone();
        cfg.scheduler = kind;
        GridPoint::new(kind.name(), cfg)
    })
    .collect()
}

// ------------------------------------------------ named sweep wrappers

/// The paper's §4 grid: Eagle baseline, then CloudCoaster at each r.
pub fn paper_sweep(base: &ExperimentConfig, ratios: &[f64]) -> Result<Vec<Report>> {
    run_grid(base, &paper_points(base, ratios))
}

/// Ablation: sensitivity to the long-load-ratio threshold L_r^T.
pub fn threshold_sweep(base: &ExperimentConfig, thresholds: &[f64]) -> Result<Vec<Report>> {
    run_grid(base, &threshold_points(base, thresholds))
}

/// Ablation: behaviour under forced revocations (§3.3 resilience path).
pub fn revocation_sweep(base: &ExperimentConfig, mttfs: &[Option<f64>]) -> Result<Vec<Report>> {
    run_grid(base, &revocation_points(base, mttfs))
}

/// Ablation: asymmetric vs symmetric grow/shrink policies.
pub fn policy_sweep(base: &ExperimentConfig) -> Result<Vec<Report>> {
    run_grid(base, &policy_points(base))
}

/// Ablation: bid level on the dynamic spot market.
pub fn bid_sweep(base: &ExperimentConfig, bids: &[Option<f64>]) -> Result<Vec<Report>> {
    run_grid(base, &bid_points(base, bids))
}

/// Ablation: reactive vs predictive resizing.
pub fn forecast_sweep(base: &ExperimentConfig) -> Result<Vec<Report>> {
    run_grid(base, &forecast_points(base))
}

// The scheduler/storm/router/budget axes are reachable through
// `cloudcoaster ablate --what …`, which builds the same `*_points`
// grids and fans them out across threads; the serial one-shot wrappers
// those axes once had were never called from anywhere and are gone.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::WorkloadSource;
    use crate::trace::synth::YahooLikeParams;

    fn tiny_base() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.cluster_size = 120;
        cfg.short_partition = 8;
        cfg.threshold = 0.5;
        let mut p = YahooLikeParams::default();
        p.horizon = 2000.0;
        cfg.workload = WorkloadSource::YahooLike(p);
        cfg
    }

    #[test]
    fn sweep_types_are_send() {
        fn assert_send<T: Send>() {}
        fn assert_sync<T: Sync>() {}
        assert_send::<Workload>();
        assert_sync::<Workload>();
        assert_send::<ExperimentConfig>();
        assert_send::<crate::coordinator::runner::SimConfig>();
        assert_send::<Report>();
        assert_send::<AnalyticsEngine>();
        assert_send::<GridPoint>();
        assert_sync::<GridPoint>();
    }

    #[test]
    fn paper_sweep_shapes() {
        let reports = paper_sweep(&tiny_base(), &[1.0, 3.0]).unwrap();
        assert_eq!(reports.len(), 3);
        assert_eq!(reports[0].name, "baseline(eagle)");
        assert_eq!(reports[0].avg_transients, 0.0);
        assert!(reports[2].transients_requested > 0);
        // Same workload: identical sample counts across runs.
        assert_eq!(reports[0].short_delay.n, reports[1].short_delay.n);
    }

    #[test]
    fn threshold_sweep_runs() {
        let reports = threshold_sweep(&tiny_base(), &[0.3, 0.9]).unwrap();
        assert_eq!(reports.len(), 2);
        // Lower threshold -> at least as many transients requested.
        assert!(reports[0].transients_requested >= reports[1].transients_requested);
    }

    #[test]
    fn policy_sweep_runs() {
        let reports = policy_sweep(&tiny_base()).unwrap();
        assert_eq!(reports.len(), 4);
    }

    #[test]
    fn parallel_sweep_matches_serial_exactly() {
        let base = tiny_base();
        let points = paper_points(&base, &[1.0, 2.0, 3.0]);
        let serial = run_sweep_parallel(&base, &points, 1).unwrap();
        for threads in [2, 4, 7] {
            let parallel = run_sweep_parallel(&base, &points, threads).unwrap();
            assert_eq!(serial.len(), parallel.len());
            for (a, b) in serial.iter().zip(&parallel) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.events, b.events);
                assert_eq!(a.end_time, b.end_time);
                assert_eq!(a.short_delay.n, b.short_delay.n);
                assert_eq!(a.short_delay.mean, b.short_delay.mean);
                assert_eq!(a.short_delay.p99, b.short_delay.p99);
                assert_eq!(a.long_delay.mean, b.long_delay.mean);
                assert_eq!(a.transients_requested, b.transients_requested);
                assert_eq!(a.cdf.values, b.cdf.values);
                assert_eq!(a.cdf.edges, b.cdf.edges);
            }
        }
    }

    #[test]
    fn empty_grid_is_fine() {
        let base = tiny_base();
        assert!(run_sweep_parallel(&base, &[], 4).unwrap().is_empty());
    }

    #[test]
    fn storm_intensity_sweeps_like_any_other_axis() {
        let reports = storm_sweep(&tiny_base(), &[1.0, 3.0]).unwrap();
        assert_eq!(reports.len(), 2);
        // Intensity 1 = the plain workload; intensity 3 injects copies
        // inside the storm window, so strictly more tasks complete.
        let n1 = reports[0].short_delay.n + reports[0].long_delay.n;
        let n3 = reports[1].short_delay.n + reports[1].long_delay.n;
        assert!(n3 > n1, "storm did not inject work ({n1} vs {n3})");
        assert!(reports[1].peak_resident_jobs > 0);
    }

    #[test]
    fn storm_sweep_is_deterministic_across_thread_counts() {
        let base = tiny_base();
        let points = storm_intensity_points(&base, &[1.5, 2.5]).unwrap();
        let serial = run_sweep_parallel(&base, &points, 1).unwrap();
        let parallel = run_sweep_parallel(&base, &points, 4).unwrap();
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.events, b.events);
            assert_eq!(a.end_time, b.end_time);
            assert_eq!(a.short_delay.n, b.short_delay.n);
            assert_eq!(a.peak_resident_jobs, b.peak_resident_jobs);
            assert_eq!(a.peak_resident_tasks, b.peak_resident_tasks);
            assert_eq!(a.peak_resident_servers, b.peak_resident_servers);
        }
    }
}
