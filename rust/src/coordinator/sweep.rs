//! Parameter sweeps: the paper's evaluation grid (Baseline + r ∈ {1,2,3})
//! and the ablation grids (threshold, revocation MTTF, shrink policy).
//! One workload + one analytics engine are shared across the whole sweep
//! so runs differ only in the swept parameter.

use anyhow::Result;

use crate::coordinator::config::{ExperimentConfig, SchedulerKind};
use crate::coordinator::report::{build_workload, run_experiment_on, Report};
use crate::runtime::AnalyticsEngine;

/// The paper's §4 grid: Eagle baseline, then CloudCoaster at each r.
pub fn paper_sweep(base: &ExperimentConfig, ratios: &[f64]) -> Result<Vec<Report>> {
    let mut analytics = AnalyticsEngine::auto(&crate::coordinator::report::artifacts_dir());
    let workload = build_workload(base)?;
    let mut reports = Vec::new();

    let mut baseline = base.clone();
    baseline.scheduler = SchedulerKind::Eagle;
    let mut rep = run_experiment_on(&baseline, &workload, analytics.as_dyn())?;
    rep.name = "baseline(eagle)".to_string();
    reports.push(rep);

    for &r in ratios {
        let mut cfg = base.clone();
        cfg.scheduler = SchedulerKind::CloudCoaster;
        cfg.r = r;
        let mut rep = run_experiment_on(&cfg, &workload, analytics.as_dyn())?;
        rep.name = format!("cloudcoaster r={r:.0}");
        reports.push(rep);
    }
    Ok(reports)
}

/// Ablation: sensitivity to the long-load-ratio threshold L_r^T.
pub fn threshold_sweep(base: &ExperimentConfig, thresholds: &[f64]) -> Result<Vec<Report>> {
    let mut analytics = AnalyticsEngine::auto(&crate::coordinator::report::artifacts_dir());
    let workload = build_workload(base)?;
    let mut reports = Vec::new();
    for &t in thresholds {
        let mut cfg = base.clone();
        cfg.scheduler = SchedulerKind::CloudCoaster;
        cfg.threshold = t;
        let mut rep = run_experiment_on(&cfg, &workload, analytics.as_dyn())?;
        rep.name = format!("L_r^T={t:.2}");
        reports.push(rep);
    }
    Ok(reports)
}

/// Ablation: behaviour under forced revocations (§3.3 resilience path).
pub fn revocation_sweep(base: &ExperimentConfig, mttfs: &[Option<f64>]) -> Result<Vec<Report>> {
    let mut analytics = AnalyticsEngine::auto(&crate::coordinator::report::artifacts_dir());
    let workload = build_workload(base)?;
    let mut reports = Vec::new();
    for &mttf in mttfs {
        let mut cfg = base.clone();
        cfg.scheduler = SchedulerKind::CloudCoaster;
        cfg.mttf = mttf;
        let mut rep = run_experiment_on(&cfg, &workload, analytics.as_dyn())?;
        rep.name = match mttf {
            Some(m) => format!("mttf={:.1}h", m / 3600.0),
            None => "mttf=inf".to_string(),
        };
        reports.push(rep);
    }
    Ok(reports)
}

/// Ablation: the paper's asymmetric grow/shrink policy vs. a symmetric
/// aggressive one.
pub fn policy_sweep(base: &ExperimentConfig) -> Result<Vec<Report>> {
    let mut analytics = AnalyticsEngine::auto(&crate::coordinator::report::artifacts_dir());
    let workload = build_workload(base)?;
    let mut reports = Vec::new();
    for (name, removals, aggressive, cooldown) in [
        ("paper(asym+cooldown)", 1usize, true, 120.0),
        ("paper-literal(no-cooldown)", 1, true, 0.0),
        ("symmetric-aggressive", usize::MAX, true, 0.0),
        ("symmetric-slow", 1, false, 120.0),
    ] {
        let mut cfg = base.clone();
        cfg.scheduler = SchedulerKind::CloudCoaster;
        cfg.max_removals_per_recalc = removals;
        cfg.aggressive_add = aggressive;
        cfg.drain_cooldown = cooldown;
        let mut rep = run_experiment_on(&cfg, &workload, analytics.as_dyn())?;
        rep.name = name.to_string();
        reports.push(rep);
    }
    Ok(reports)
}

/// Ablation: bid level on the dynamic spot market (§2.4's Amazon model;
/// the paper's evaluation uses fixed 1/r pricing, `bid = None`).
pub fn bid_sweep(base: &ExperimentConfig, bids: &[Option<f64>]) -> Result<Vec<Report>> {
    let mut analytics = AnalyticsEngine::auto(&crate::coordinator::report::artifacts_dir());
    let workload = build_workload(base)?;
    let mut reports = Vec::new();
    for &bid in bids {
        let mut cfg = base.clone();
        cfg.scheduler = SchedulerKind::CloudCoaster;
        cfg.bid = bid;
        let mut rep = run_experiment_on(&cfg, &workload, analytics.as_dyn())?;
        rep.name = match bid {
            Some(b) => format!("bid={b:.2}"),
            None => "fixed-1/r".to_string(),
        };
        reports.push(rep);
    }
    Ok(reports)
}

/// Ablation: reactive (§3.2) vs predictive (lr_forecast artifact)
/// resizing.
pub fn forecast_sweep(base: &ExperimentConfig) -> Result<Vec<Report>> {
    let mut analytics = AnalyticsEngine::auto(&crate::coordinator::report::artifacts_dir());
    let workload = build_workload(base)?;
    let mut reports = Vec::new();
    for (name, predictive) in [("reactive(paper)", false), ("predictive(forecast)", true)] {
        let mut cfg = base.clone();
        cfg.scheduler = SchedulerKind::CloudCoaster;
        cfg.predictive = predictive;
        let mut rep = run_experiment_on(&cfg, &workload, analytics.as_dyn())?;
        rep.name = name.to_string();
        reports.push(rep);
    }
    Ok(reports)
}

/// Scheduler-family comparison (context for §5 related work).
pub fn scheduler_sweep(base: &ExperimentConfig) -> Result<Vec<Report>> {
    let mut analytics = AnalyticsEngine::auto(&crate::coordinator::report::artifacts_dir());
    let workload = build_workload(base)?;
    let mut reports = Vec::new();
    for kind in [
        SchedulerKind::Centralized,
        SchedulerKind::Sparrow,
        SchedulerKind::Hawk,
        SchedulerKind::Eagle,
        SchedulerKind::CloudCoaster,
    ] {
        let mut cfg = base.clone();
        cfg.scheduler = kind;
        let mut rep = run_experiment_on(&cfg, &workload, analytics.as_dyn())?;
        rep.name = kind.name().to_string();
        reports.push(rep);
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::WorkloadSource;
    use crate::trace::synth::YahooLikeParams;

    fn tiny_base() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.cluster_size = 120;
        cfg.short_partition = 8;
        cfg.threshold = 0.5;
        let mut p = YahooLikeParams::default();
        p.horizon = 2000.0;
        cfg.workload = WorkloadSource::YahooLike(p);
        cfg
    }

    #[test]
    fn paper_sweep_shapes() {
        let reports = paper_sweep(&tiny_base(), &[1.0, 3.0]).unwrap();
        assert_eq!(reports.len(), 3);
        assert_eq!(reports[0].name, "baseline(eagle)");
        assert_eq!(reports[0].avg_transients, 0.0);
        assert!(reports[2].transients_requested > 0);
        // Same workload: identical sample counts across runs.
        assert_eq!(reports[0].short_delay.n, reports[1].short_delay.n);
    }

    #[test]
    fn threshold_sweep_runs() {
        let reports = threshold_sweep(&tiny_base(), &[0.3, 0.9]).unwrap();
        assert_eq!(reports.len(), 2);
        // Lower threshold -> at least as many transients requested.
        assert!(reports[0].transients_requested >= reports[1].transients_requested);
    }

    #[test]
    fn policy_sweep_runs() {
        let reports = policy_sweep(&tiny_base()).unwrap();
        assert_eq!(reports.len(), 4);
    }
}
