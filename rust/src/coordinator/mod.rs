//! The experiment coordinator: configuration (TOML-subset + programmatic),
//! the declarative scenario registry, the simulation runner, parameter
//! sweeps, and report generation.

pub mod config;
pub mod replicate;
pub mod report;
pub mod runner;
pub mod scenario;
pub mod sweep;
pub mod toml;

pub use config::{ExperimentConfig, SchedulerKind, WorkloadSource};
pub use report::{run_experiment, run_federated_experiment, FederatedReport, Report};
pub use runner::{
    build_federation, build_world, build_world_from_source, run_federation, simulate,
    simulate_source, simulate_with, FederationOutcome, RunResult, SimConfig,
};
pub use scenario::{
    BudgetSharing, CombinatorSpec, FederationSpec, RouterKind, ScenarioSpec, SourceSpec,
};
pub use sweep::{run_grid, run_sweep_parallel, GridPoint};
