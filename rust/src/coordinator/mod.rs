//! The experiment coordinator: configuration (TOML-subset + programmatic),
//! the declarative scenario registry, the simulation runner, parameter
//! sweeps, and report generation.

pub mod config;
pub mod replicate;
pub mod report;
pub mod runner;
pub mod scenario;
pub mod sweep;
pub mod toml;

pub use config::{ExperimentConfig, SchedulerKind, WorkloadSource};
pub use report::{run_experiment, Report};
pub use runner::{
    build_world, build_world_from_source, simulate, simulate_source, simulate_with,
    RunResult, SimConfig,
};
pub use scenario::{CombinatorSpec, ScenarioSpec, SourceSpec};
pub use sweep::{run_grid, run_sweep_parallel, GridPoint};
