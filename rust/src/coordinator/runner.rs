//! The simulation runner: drives the event loop over a workload, wiring
//! the scheduler, the cluster, the transient manager and the metrics
//! recorder together.

use std::time::Instant;

use crate::cluster::{Cluster, QueuePolicy, ServerState};
use crate::metrics::Recorder;
use crate::sched::{SchedCtx, Scheduler};
use crate::sim::{Engine, Event, Rng};
use crate::trace::Workload;
use crate::transient::{ManagerConfig, TransientManager};
use crate::util::{JobId, TaskId, Time};

/// Low-level simulation parameters (cluster geometry + hooks).
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// General-partition size (long + short), on-demand.
    pub n_general: usize,
    /// On-demand short-only partition size.
    pub n_short_reserved: usize,
    pub queue_policy: QueuePolicy,
    /// Transient manager (None = statically provisioned baseline).
    pub manager: Option<ManagerConfig>,
    /// Metrics sampling period, seconds.
    pub snapshot_interval: f64,
    /// Hawk-style task stealing: probes an idle server sends looking for
    /// a busy victim (0 disables stealing).
    pub steal_probes: usize,
    /// Max queued short tasks moved per steal.
    pub steal_batch: usize,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            n_general: 3920,
            n_short_reserved: 80,
            queue_policy: QueuePolicy::Srpt { starvation_limit: 600.0 },
            manager: None,
            snapshot_interval: 60.0,
            steal_probes: 8,
            steal_batch: 8,
            seed: 1,
        }
    }
}

/// Everything a run produces.
pub struct RunResult {
    pub scheduler: String,
    pub rec: Recorder,
    /// Simulation end time (all work finished), seconds.
    pub end_time: Time,
    /// Events processed.
    pub events: u64,
    /// Wall-clock runtime, milliseconds.
    pub wall_ms: f64,
    /// (adds, drains, failed_requests) if a manager ran.
    pub manager_stats: Option<(u64, u64, u64)>,
}

impl RunResult {
    /// Events per second of wall clock (§Perf headline for L3).
    pub fn events_per_sec(&self) -> f64 {
        self.events as f64 / (self.wall_ms / 1000.0).max(1e-9)
    }
}

/// Steal probes for a newly idle server: sample candidates from the
/// short pools (where load-spike queues live) and the general partition,
/// steal from the first victim with queued work.
fn try_steal(
    cluster: &mut Cluster,
    thief: crate::util::ServerId,
    cfg: &SimConfig,
    rng: &mut Rng,
    engine: &mut Engine,
    rec: &mut Recorder,
) {
    // Long-hosting victims are fine: we only take their *short* tasks.
    for probe in 0..cfg.steal_probes {
        // Alternate between short pools and the general partition.
        let victim = if probe % 2 == 0 {
            let shorts = cluster.short_reserved.len() + cluster.transient_pool.len();
            if shorts == 0 {
                continue;
            }
            let k = rng.below(shorts as u64) as usize;
            if k < cluster.short_reserved.len() {
                cluster.short_reserved[k]
            } else {
                cluster.transient_pool[k - cluster.short_reserved.len()]
            }
        } else {
            cluster.general[rng.below(cluster.general.len() as u64) as usize]
        };
        if cluster.server(victim).queue.is_empty() {
            continue;
        }
        if cluster.steal_short_tasks(victim, thief, cfg.steal_batch, engine, rec) > 0 {
            return;
        }
    }
}

/// Run `workload` under `scheduler` with the given config.
pub fn simulate(
    workload: &Workload,
    scheduler: &mut dyn Scheduler,
    cfg: &SimConfig,
) -> RunResult {
    simulate_with(workload, scheduler, cfg, None)
}

/// Like [`simulate`], with an optional analytics engine for the
/// predictive-resizing path (the l_r forecast runs on the snapshot/epoch
/// cadence through the AOT-compiled artifact when the manager has
/// `predictive = true`).
pub fn simulate_with(
    workload: &Workload,
    scheduler: &mut dyn Scheduler,
    cfg: &SimConfig,
    mut analytics: Option<&mut dyn crate::runtime::Analytics>,
) -> RunResult {
    let wall0 = Instant::now();
    let r = cfg.manager.as_ref().map(|m| m.budget.r).unwrap_or(1.0);
    let mut cluster = Cluster::new(cfg.n_general, cfg.n_short_reserved, cfg.queue_policy);
    let mut engine = Engine::new();
    let mut rec = Recorder::new(r);
    let mut root_rng = Rng::new(cfg.seed);
    let mut sched_rng = root_rng.fork(0x5C); // probe sampling stream
    let mut manager = cfg
        .manager
        .clone()
        .map(|m| TransientManager::new(m, root_rng.fork(0x7A)));

    // Per-job bookkeeping for response-time metrics.
    let mut job_remaining: Vec<u32> =
        workload.jobs.iter().map(|j| j.num_tasks() as u32).collect();
    let mut outstanding_tasks: u64 = workload.num_tasks() as u64;
    let mut next_job = 0usize;
    let mut task_ids: Vec<TaskId> = Vec::new();

    // Predictive resizing state: l_r history ring + forecast horizon in
    // snapshot steps.
    let predictive = cfg.manager.as_ref().map(|m| m.predictive).unwrap_or(false);
    let window = crate::runtime::artifacts::FORECAST_WINDOW;
    let mut lr_history: Vec<f32> = Vec::with_capacity(window);
    let horizon_steps = cfg
        .manager
        .as_ref()
        .map(|m| (m.market.provisioning_delay / cfg.snapshot_interval).ceil() as f32)
        .unwrap_or(1.0);

    if !workload.jobs.is_empty() {
        engine.schedule(workload.jobs[0].arrival, Event::JobArrival(JobId(0)));
        engine.schedule(cfg.snapshot_interval, Event::Snapshot);
    }

    while let Some((now, event)) = engine.pop() {
        // Did this event change long-task occupancy? (The paper's §3.2
        // recalculation trigger.)
        let mut long_event = false;

        match event {
            Event::JobArrival(jid) => {
                let job = &workload.jobs[jid.index()];
                task_ids.clear();
                for &d in &job.task_durations {
                    task_ids.push(cluster.add_task(job.id, d, job.is_long, now));
                }
                let mut ctx = SchedCtx {
                    cluster: &mut cluster,
                    engine: &mut engine,
                    rec: &mut rec,
                    rng: &mut sched_rng,
                };
                scheduler.place_job(job, &task_ids, &mut ctx);
                long_event = job.is_long;
                next_job = jid.index() + 1;
                if next_job < workload.jobs.len() {
                    engine.schedule(
                        workload.jobs[next_job].arrival,
                        Event::JobArrival(JobId(next_job as u32)),
                    );
                }
            }
            Event::TaskFinish { server, task } => {
                // A revocation may have killed this execution after its
                // finish event was scheduled (the task restarts elsewhere
                // with a new finish event) — ignore the stale one.
                let (is_long, jid) = {
                    let t = cluster.task(task);
                    if t.state != crate::cluster::TaskState::Running || t.ran_on != Some(server)
                    {
                        continue;
                    }
                    (t.is_long, t.job)
                };
                let drained = cluster.on_task_finish(server, task, &mut engine, &mut rec);
                if drained {
                    cluster.retire(server, now, &mut rec);
                } else if cfg.steal_probes > 0
                    && cluster.server(server).is_idle()
                    && cluster.server(server).accepting()
                {
                    // Hawk-lineage randomized stealing: the newly idle
                    // server probes for a busy victim and takes a batch of
                    // its queued shorts.
                    try_steal(&mut cluster, server, cfg, &mut sched_rng, &mut engine, &mut rec);
                }
                outstanding_tasks -= 1;
                let rem = &mut job_remaining[jid.index()];
                *rem -= 1;
                if *rem == 0 {
                    let job = &workload.jobs[jid.index()];
                    rec.job_finished(job.is_long, now - job.arrival);
                }
                long_event = is_long;
            }
            Event::TransientReady(sid) => {
                if let Some(mgr) = manager.as_mut() {
                    mgr.on_ready(sid, &mut cluster, &engine, &mut rec);
                }
            }
            Event::RevocationWarning(sid) => {
                if let Some(mgr) = manager.as_mut() {
                    mgr.on_warning(sid, &mut cluster, &engine, &mut rec);
                }
            }
            Event::Revoked(sid) => {
                let state = cluster.server(sid).state;
                if matches!(state, ServerState::Active | ServerState::Draining) {
                    let orphans = cluster.revoke(sid, now, &mut rec);
                    if !orphans.is_empty() {
                        let mut ctx = SchedCtx {
                            cluster: &mut cluster,
                            engine: &mut engine,
                            rec: &mut rec,
                            rng: &mut sched_rng,
                        };
                        scheduler.replace_orphans(&orphans, &mut ctx);
                    }
                }
            }
            Event::DrainComplete(sid) => {
                if cluster.server(sid).state == ServerState::Draining
                    && cluster.server(sid).is_idle()
                {
                    cluster.retire(sid, now, &mut rec);
                }
            }
            Event::Snapshot => {
                let lr = cluster.long_load_ratio();
                rec.snapshot(now, lr, cluster.transient_pool.len() as f64);
                if predictive {
                    if lr_history.len() == window {
                        lr_history.rotate_left(1);
                        lr_history.pop();
                    }
                    lr_history.push(lr as f32);
                    if lr_history.len() == window {
                        if let (Some(mgr), Some(eng)) = (manager.as_mut(), analytics.as_deref_mut())
                        {
                            if let Ok((forecast, _, _)) =
                                eng.lr_forecast(&lr_history, horizon_steps)
                            {
                                mgr.prewarm(forecast as f64, &mut cluster, &mut engine, &mut rec);
                            }
                        }
                    }
                }
                if outstanding_tasks > 0 || next_job < workload.jobs.len() {
                    engine.schedule_after(cfg.snapshot_interval, Event::Snapshot);
                }
            }
        }

        if long_event {
            if let Some(mgr) = manager.as_mut() {
                mgr.maybe_resize(&mut cluster, &mut engine, &mut rec);
            }
        }
    }

    let end_time = engine.now();
    // Close out lifetimes for transients still up at simulation end.
    let live: Vec<_> = cluster
        .servers
        .iter()
        .filter(|s| {
            s.kind == crate::cluster::ServerKind::Transient
                && matches!(s.state, ServerState::Active | ServerState::Draining)
        })
        .map(|s| s.id)
        .collect();
    for sid in live {
        cluster.retire(sid, end_time, &mut rec);
    }
    debug_assert_eq!(outstanding_tasks, 0, "tasks lost by the simulation");
    #[cfg(debug_assertions)]
    cluster.check_invariants();

    RunResult {
        scheduler: scheduler.name().to_string(),
        rec,
        end_time,
        events: engine.processed(),
        wall_ms: wall0.elapsed().as_secs_f64() * 1000.0,
        manager_stats: manager.map(|m| (m.adds, m.drains, m.failed_requests)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Hybrid;
    use crate::trace::synth::{yahoo_like, YahooLikeParams};
    use crate::transient::Budget;

    fn small_workload(seed: u64) -> Workload {
        let mut p = YahooLikeParams::default();
        p.horizon = 4000.0;
        yahoo_like(&p, &mut Rng::new(seed))
    }

    fn small_cfg() -> SimConfig {
        SimConfig { n_general: 128, n_short_reserved: 8, ..Default::default() }
    }

    #[test]
    fn all_tasks_complete_under_eagle() {
        let w = small_workload(3);
        let mut sched = Hybrid::eagle(2.0);
        let res = simulate(&w, &mut sched, &small_cfg());
        assert_eq!(res.rec.tasks_finished as usize, w.num_tasks());
        assert_eq!(
            res.rec.short_delays.len() + res.rec.long_delays.len(),
            w.num_tasks()
        );
        assert!(res.end_time >= w.last_arrival());
        assert!(res.events > w.num_tasks() as u64);
    }

    #[test]
    fn cloudcoaster_uses_transients_and_completes() {
        let w = small_workload(3);
        let mut sched = Hybrid::cloudcoaster(2.0);
        let mut cfg = small_cfg();
        cfg.n_short_reserved = 4; // p=0.5 of an 8-server static partition
        cfg.manager = Some(ManagerConfig {
            threshold: 0.6,
            ..ManagerConfig::paper(Budget::new(8, 0.5, 3.0))
        });
        let res = simulate(&w, &mut sched, &cfg);
        assert_eq!(res.rec.tasks_finished as usize, w.num_tasks());
        let (adds, _, _) = res.manager_stats.unwrap();
        assert!(adds > 0, "manager never resized");
        // All transients are closed out at end: every requested server
        // eventually became active and has a recorded lifetime.
        assert_eq!(res.rec.cost.active_now(), 0.0);
        assert_eq!(res.rec.cost.lifetimes.len() as u64, res.rec.transients_requested);
    }

    #[test]
    fn deterministic_given_seed() {
        let w = small_workload(9);
        let run = || {
            let mut sched = Hybrid::eagle(2.0);
            simulate(&w, &mut sched, &small_cfg())
        };
        let a = run();
        let b = run();
        assert_eq!(a.events, b.events);
        assert_eq!(a.end_time, b.end_time);
        assert_eq!(a.rec.short_delays.as_slice(), b.rec.short_delays.as_slice());
    }

    #[test]
    fn empty_workload_is_a_noop() {
        let w = Workload::default();
        let mut sched = Hybrid::eagle(2.0);
        let res = simulate(&w, &mut sched, &small_cfg());
        assert_eq!(res.events, 0);
        assert_eq!(res.rec.tasks_finished, 0);
    }

    #[test]
    fn revocations_do_not_lose_tasks() {
        let w = small_workload(5);
        let mut sched = Hybrid::cloudcoaster(2.0);
        let mut cfg = small_cfg();
        cfg.n_short_reserved = 4;
        let mut mgr = ManagerConfig {
            threshold: 0.5,
            ..ManagerConfig::paper(Budget::new(8, 0.5, 3.0))
        };
        mgr.market.mttf = Some(600.0); // aggressive revocations
        cfg.manager = Some(mgr);
        let res = simulate(&w, &mut sched, &cfg);
        // Every task finishes exactly once even under heavy revocation.
        assert_eq!(res.rec.tasks_finished as usize, w.num_tasks());
    }
}
