//! The simulation runner: composes a [`World`] (engine + cluster +
//! recorder + RNG streams) with the standard component wiring — snapshot
//! sampler, optional transient manager, scheduler adapter, work stealer
//! — and distills a [`RunResult`].
//!
//! The event loop itself lives in [`crate::sim::World`]; this module is
//! pure wiring, so alternative scenarios (manager-less baselines, custom
//! samplers, injected burst storms) are a different `add_component`
//! sequence — or a different [`ArrivalSource`] pipeline — not a
//! different runner. Component dispatch order matters for determinism
//! and mirrors the original monolithic loop: sampler → manager →
//! scheduler → stealer.
//!
//! Entry points: [`simulate`] / [`simulate_with`] replay an eager
//! [`Workload`] through the world's borrowed-lookahead fast path (jobs
//! dispatched by reference — no per-pull clone), [`simulate_source`]
//! streams any [`ArrivalSource`] — including the declarative
//! `[scenario]` pipelines resolved by [`crate::coordinator::scenario`].
//! Either way the generational task arena keeps memory O(active tasks).

use std::time::Instant;

use anyhow::Result;

use crate::cluster::{Cluster, QueuePolicy};
use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::scenario::{BudgetSharing, FederationSpec, RouterKind, ScenarioSpec};
use crate::metrics::Recorder;
use crate::sched::Scheduler;
use crate::sim::{
    ClassSplit, Federation, JobRouter, LeastQueued, ProfileReport, RoundRobin, Rng,
    SchedulerComponent, SnapshotSampler, TransientManagerComponent, WorkStealer, World,
};
use crate::trace::{ArrivalSource, Workload};
use crate::transient::{ManagerConfig, SharedBudget};
use crate::util::{Time, RNG_ARRIVALS, RNG_MARKET};

/// Low-level simulation parameters (cluster geometry + hooks).
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// General-partition size (long + short), on-demand.
    pub n_general: usize,
    /// On-demand short-only partition size.
    pub n_short_reserved: usize,
    pub queue_policy: QueuePolicy,
    /// Transient manager (None = statically provisioned baseline).
    pub manager: Option<ManagerConfig>,
    /// Metrics sampling period, seconds.
    pub snapshot_interval: f64,
    /// Hawk-style task stealing: probes an idle server sends looking for
    /// a busy victim (0 disables stealing).
    pub steal_probes: usize,
    /// Max queued short tasks moved per steal.
    pub steal_batch: usize,
    /// Recycle finished task-arena slots (default). `false` keeps the
    /// arena append-only — the pre-arena reference behaviour used by the
    /// recycling golden tests; every simulation field is bit-identical
    /// either way, only resident memory differs.
    pub recycle_task_slots: bool,
    /// Recycle retired server-arena slots (default). `false` keeps one
    /// slot per transient ever requested — the append-only reference
    /// behaviour for golden comparisons; every simulation field is
    /// bit-identical either way, only resident memory differs.
    pub recycle_server_slots: bool,
    /// Record every delay sample in exact Vecs instead of the default
    /// fixed-memory histogram sketches. Reference mode for golden
    /// comparisons: count/mean/min/max are bit-identical either way;
    /// only the explicitly-approximate quantile fields differ, within
    /// the histogram's documented ≤1% bound. Exact mode's memory grows
    /// with the trace. Also keeps the snapshot series unbounded (the
    /// fully-exact reference build).
    pub exact_delay_samples: bool,
    /// Keep the sampled snapshot series (`Recorder::lr_series` /
    /// `transient_series`) unbounded — one point per interval for the
    /// whole horizon — instead of the default fixed-capacity ring that
    /// coarsens its sampling 2x when full. Reference mode for golden
    /// comparisons of the series themselves; all *report* fields are
    /// identical either way (nothing distilled reads the series).
    pub exact_snapshot_series: bool,
    /// Run on the pre-calendar `BinaryHeap` event engine
    /// ([`crate::sim::Engine::reference`]) instead of the default
    /// calendar queue. Reference mode for golden comparisons (the CI
    /// engine-equivalence smoke diffs the two): every simulation field
    /// is bit-identical either way, only event-queue wall-clock
    /// differs.
    pub reference_engine: bool,
    /// Serve the cluster's hot per-server fields (est_work, queue
    /// depth, accepting/long/transient tags, ready_seq) from the dense
    /// struct-of-arrays mirror (default). `false` reads the same values
    /// back through the `Server` structs — the reference layout for
    /// golden comparisons; every simulation field is bit-identical
    /// either way, only probe-path cache behaviour differs.
    pub soa_hot_fields: bool,
    /// Enable the hot-path profiler: per-event-class counts and wall
    /// time, per-component wall time, allocation-pool hit/miss
    /// counters. Reported on stderr (and via `--profile-out` as JSON)
    /// so the default stdout surface stays byte-identical to an
    /// unprofiled run — profiling is excluded from the bit-identity
    /// surface entirely.
    pub profile: bool,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            n_general: 3920,
            n_short_reserved: 80,
            queue_policy: QueuePolicy::Srpt { starvation_limit: 600.0 },
            manager: None,
            snapshot_interval: 60.0,
            steal_probes: 8,
            steal_batch: 8,
            recycle_task_slots: true,
            recycle_server_slots: true,
            exact_delay_samples: false,
            exact_snapshot_series: false,
            reference_engine: false,
            soa_hot_fields: true,
            profile: false,
            seed: 1,
        }
    }
}

/// Everything a run produces.
pub struct RunResult {
    pub scheduler: String,
    pub rec: Recorder,
    /// Simulation end time (all work finished), seconds.
    pub end_time: Time,
    /// Events processed.
    pub events: u64,
    /// Wall-clock runtime, milliseconds.
    pub wall_ms: f64,
    /// (adds, drains, failed_requests) if a manager ran.
    pub manager_stats: Option<(u64, u64, u64)>,
    /// High-water mark of concurrently resident job records — bounded
    /// by cluster load, not trace length, on the streaming path.
    pub peak_resident_jobs: usize,
    /// High-water mark of concurrently resident task-arena slots — the
    /// generational arena recycles finished slots, so this (not total
    /// task count) bounds task memory.
    pub peak_resident_tasks: usize,
    /// High-water mark of concurrently resident server-arena slots:
    /// on-demand size + peak concurrent transients — retired transient
    /// slots recycle, so this (not transients ever requested) bounds
    /// server memory even under revocation churn.
    pub peak_resident_servers: usize,
    /// Hot-path profile (`Some` only when `SimConfig::profile` was on).
    pub profile: Option<ProfileReport>,
}

impl RunResult {
    /// Events per second of wall clock (§Perf headline for L3).
    pub fn events_per_sec(&self) -> f64 {
        self.events as f64 / (self.wall_ms / 1000.0).max(1e-9)
    }
}

/// Build the standard component wiring for `cfg` on a fresh [`World`]
/// replaying an eager workload through the borrowed-lookahead fast path
/// (each job is dispatched by reference — no per-pull clone, unlike
/// routing through a [`crate::trace::WorkloadReplay`]; bit-identical
/// results).
pub fn build_world<'a>(
    workload: &'a Workload,
    scheduler: &'a mut (dyn Scheduler + 'a),
    cfg: &SimConfig,
    analytics: Option<&'a mut (dyn crate::runtime::Analytics + 'a)>,
) -> World<'a> {
    let mut world =
        World::from_workload(workload, build_cluster(cfg), build_recorder(cfg), cfg.seed);
    world.engine = build_engine(cfg);
    wire_standard(&mut world, scheduler, cfg, analytics);
    world
}

/// Build the standard component wiring for `cfg` on a fresh [`World`]
/// over any streaming [`ArrivalSource`].
///
/// Exposed so custom scenarios can start from the canonical composition
/// and add/replace components (or swap in a combinator pipeline).
pub fn build_world_from_source<'a>(
    source: Box<dyn ArrivalSource + 'a>,
    scheduler: &'a mut (dyn Scheduler + 'a),
    cfg: &SimConfig,
    analytics: Option<&'a mut (dyn crate::runtime::Analytics + 'a)>,
) -> World<'a> {
    let mut world = World::new(source, build_cluster(cfg), build_recorder(cfg), cfg.seed);
    world.engine = build_engine(cfg);
    wire_standard(&mut world, scheduler, cfg, analytics);
    world
}

fn build_cluster(cfg: &SimConfig) -> Cluster {
    let mut cluster = Cluster::new(cfg.n_general, cfg.n_short_reserved, cfg.queue_policy);
    cluster.set_task_recycling(cfg.recycle_task_slots);
    cluster.set_server_recycling(cfg.recycle_server_slots);
    cluster.set_soa_hot_fields(cfg.soa_hot_fields);
    cluster
}

/// Engine sized from the configured load: pending events are dominated
/// by one `TaskFinish` per busy server plus transient lifecycle and
/// periodic events, so the hint is static fleet + transient cap. Purely
/// a pre-allocation hint — every simulation field is bit-identical for
/// any hint (and for the reference `BinaryHeap` engine).
fn build_engine(cfg: &SimConfig) -> crate::sim::Engine {
    let transient_cap =
        cfg.manager.as_ref().map(|m| m.budget.max_transients()).unwrap_or(0);
    let hint = cfg.n_general + cfg.n_short_reserved + transient_cap + 64;
    if cfg.reference_engine {
        crate::sim::Engine::reference_with_capacity(hint)
    } else {
        crate::sim::Engine::with_capacity(hint)
    }
}

fn build_recorder(cfg: &SimConfig) -> Recorder {
    let r = cfg.manager.as_ref().map(|m| m.budget.r).unwrap_or(1.0);
    let snapshot_points = if cfg.exact_delay_samples || cfg.exact_snapshot_series {
        0 // unbounded exact series (reference modes)
    } else {
        crate::metrics::DEFAULT_SNAPSHOT_POINTS
    };
    Recorder::with_options(r, cfg.exact_delay_samples, snapshot_points)
}

/// The canonical component composition shared by the eager and streaming
/// entry points.
fn wire_standard<'a>(
    world: &mut World<'a>,
    scheduler: &'a mut (dyn Scheduler + 'a),
    cfg: &SimConfig,
    analytics: Option<&'a mut (dyn crate::runtime::Analytics + 'a)>,
) {
    wire_standard_shared(world, scheduler, cfg, analytics, None)
}

/// [`wire_standard`] plus an optional federated [`SharedBudget`] handle
/// for the transient manager (the cross-cluster lease pool).
fn wire_standard_shared<'a>(
    world: &mut World<'a>,
    scheduler: &'a mut (dyn Scheduler + 'a),
    cfg: &SimConfig,
    analytics: Option<&'a mut (dyn crate::runtime::Analytics + 'a)>,
    shared: Option<SharedBudget>,
) {
    if cfg.profile {
        world.enable_profiler();
    }
    // Snapshot sampler first: it records l_r before any same-event
    // mutation and publishes the prewarm forecast the manager consumes.
    let predictive = cfg.manager.as_ref().map(|m| m.predictive).unwrap_or(false);
    if predictive {
        let horizon_steps = cfg
            .manager
            .as_ref()
            .map(|m| (m.market.provisioning_delay / cfg.snapshot_interval).ceil() as f32)
            .unwrap_or(1.0);
        world.add_component(Box::new(SnapshotSampler::predictive(
            cfg.snapshot_interval,
            horizon_steps,
            analytics,
        )));
    } else {
        world.add_component(Box::new(SnapshotSampler::new(cfg.snapshot_interval)));
    }

    // Transient manager (market RNG stream forks with RNG_MARKET, after
    // the scheduler stream's RNG_SCHED — the original runner's fork
    // order; see util/rng_labels.rs for the table).
    if let Some(mcfg) = cfg.manager.clone() {
        let market_rng = world.fork_rng(RNG_MARKET);
        let component = match shared {
            Some(pool) => TransientManagerComponent::with_shared_budget(mcfg, market_rng, pool),
            None => TransientManagerComponent::new(mcfg, market_rng),
        };
        world.add_component(Box::new(component));
    }

    world.add_component(Box::new(SchedulerComponent::new(scheduler)));

    if cfg.steal_probes > 0 {
        world.add_component(Box::new(WorkStealer {
            probes: cfg.steal_probes,
            batch: cfg.steal_batch,
        }));
    }
}

/// Run `workload` under `scheduler` with the given config.
pub fn simulate(
    workload: &Workload,
    scheduler: &mut dyn Scheduler,
    cfg: &SimConfig,
) -> RunResult {
    simulate_with(workload, scheduler, cfg, None)
}

/// Like [`simulate`], with an optional analytics engine for the
/// predictive-resizing path (the l_r forecast runs on the snapshot/epoch
/// cadence through the AOT-compiled artifact when the manager has
/// `predictive = true`). Eager workloads replay through the
/// borrowed-lookahead fast path — no per-job clone.
pub fn simulate_with<'a>(
    workload: &'a Workload,
    scheduler: &'a mut (dyn Scheduler + 'a),
    cfg: &SimConfig,
    analytics: Option<&'a mut (dyn crate::runtime::Analytics + 'a)>,
) -> RunResult {
    let wall0 = Instant::now();
    let name = scheduler.name().to_string();
    let world = build_world(workload, scheduler, cfg, analytics);
    run_and_distill(world, name, wall0)
}

/// Run a streaming [`ArrivalSource`] under `scheduler` with the given
/// config — the scenario-pipeline entry point. Memory stays O(active
/// tasks): the source is pulled one job ahead of the simulation clock
/// and finished task slots recycle through the generational arena.
pub fn simulate_source<'a>(
    source: Box<dyn ArrivalSource + 'a>,
    scheduler: &'a mut (dyn Scheduler + 'a),
    cfg: &SimConfig,
    analytics: Option<&'a mut (dyn crate::runtime::Analytics + 'a)>,
) -> RunResult {
    let wall0 = Instant::now();
    let name = scheduler.name().to_string();
    let world = build_world_from_source(source, scheduler, cfg, analytics);
    run_and_distill(world, name, wall0)
}

fn run_and_distill(mut world: World<'_>, name: String, wall0: Instant) -> RunResult {
    world.run();
    let wall_ms = wall0.elapsed().as_secs_f64() * 1000.0;
    distill_world(world, name, wall_ms)
}

/// Extract a [`RunResult`] from a world that has already run (shared by
/// the single-world entry points and the federation driver).
fn distill_world(mut world: World<'_>, name: String, wall_ms: f64) -> RunResult {
    let manager_stats = world.component::<TransientManagerComponent>().map(|m| m.stats());
    let end_time = world.engine.now();
    let events = world.engine.processed();
    let peak_resident_jobs = world.peak_resident_jobs();
    let peak_resident_tasks = world.peak_resident_tasks();
    let peak_resident_servers = world.peak_resident_servers();
    let profile = world.take_profile();
    RunResult {
        scheduler: name,
        rec: world.rec,
        end_time,
        events,
        wall_ms,
        manager_stats,
        peak_resident_jobs,
        peak_resident_tasks,
        peak_resident_servers,
        profile,
    }
}

// ----------------------------------------------------------- federation

/// Everything a federated run produces: one [`RunResult`] per member
/// cluster plus the cross-cluster watermarks the aggregate report and
/// the budget-cap invariant read.
pub struct FederationOutcome {
    pub runs: Vec<RunResult>,
    /// High-water mark of Σ (active + provisioning) transients — with
    /// pooled sharing this never exceeds [`FederationOutcome::shared_cap`].
    pub peak_total_fleet: usize,
    /// High-water mark of Σ active transients (the aggregate's
    /// `max_transients`).
    pub peak_total_active: f64,
    /// Total transient units the sharing mode admits across the
    /// federation (`None` when budgets are uncoupled).
    pub shared_cap: Option<usize>,
    /// Router name, for report labels.
    pub router: &'static str,
    pub clusters: usize,
    /// Wall-clock of the whole federated run, milliseconds.
    pub wall_ms: f64,
}

/// Build the canonical federation for `cfg` + `spec`: one member world
/// per cluster — each with its own cluster geometry, scenario-resolved
/// arrival pipeline (storm windows staggered per member), recorder and
/// seed-forked RNG streams — wired with the standard components,
/// sharing one transient-lease pool when the spec says so, behind the
/// spec's router. `scheds` must hold one scheduler per cluster (the
/// members borrow them for the federation's lifetime).
pub fn build_federation<'a>(
    cfg: &ExperimentConfig,
    spec: &FederationSpec,
    scheds: &'a mut [Box<dyn Scheduler>],
) -> Result<Federation<'a>> {
    spec.validate()?;
    let n = spec.clusters;
    assert_eq!(scheds.len(), n, "one scheduler per member cluster");
    let member_cfgs: Vec<ExperimentConfig> =
        (0..n).map(|i| spec.member_config(cfg, i)).collect();

    // Budget sharing: `K` is one cluster's §3.1 cap r·N_s·p. Pooled
    // sharing stretches that single-cluster budget across the whole
    // federation (the elasticity experiment: N clusters, one budget);
    // split sharing gives each member a hard K/N slice of the same
    // total; uncoupled members each keep their own full K.
    let k = member_cfgs[0]
        .to_sim_config()
        .manager
        .as_ref()
        .map(|m| m.budget.max_transients())
        .unwrap_or(0);
    let (shareds, total_cap): (Vec<Option<SharedBudget>>, Option<usize>) =
        match spec.budget_sharing {
            BudgetSharing::None => (vec![None; n], None),
            BudgetSharing::Pooled => {
                let pool = SharedBudget::new(k);
                ((0..n).map(|_| Some(pool.clone())).collect(), Some(k))
            }
            // Hard slices summing to exactly K: the first K mod N
            // members absorb the remainder, so no unit is lost to
            // integer division (with N > K the tail members get
            // zero-transient slices — a deliberately austere split).
            BudgetSharing::Split => (
                (0..n)
                    .map(|i| Some(SharedBudget::new(k / n + usize::from(i < k % n))))
                    .collect(),
                Some(k),
            ),
        };

    let routed = spec.router != RouterKind::PassThrough;
    let mut worlds: Vec<World<'a>> = Vec::with_capacity(n);
    let mut sources: Vec<Box<dyn ArrivalSource>> = Vec::new();
    let mut arr_rngs: Vec<Rng> = Vec::new();
    for ((mc, sched), shared) in member_cfgs.iter().zip(scheds.iter_mut()).zip(&shareds) {
        let sim_cfg = mc.to_sim_config();
        let scenario = mc.scenario.clone().unwrap_or_else(ScenarioSpec::passthrough);
        let mut world = if routed {
            World::new_inbox(build_cluster(&sim_cfg), build_recorder(&sim_cfg), sim_cfg.seed)
        } else {
            World::new(
                scenario.build_source(mc)?,
                build_cluster(&sim_cfg),
                build_recorder(&sim_cfg),
                sim_cfg.seed,
            )
        };
        world.engine = build_engine(&sim_cfg);
        wire_standard_shared(&mut world, sched.as_mut(), &sim_cfg, None, shared.clone());
        if routed {
            // The member's canonical arrival stream (RNG_ARRIVALS,
            // forked after wiring exactly where `World::start` would
            // fork it) drives the federation's pull from this member's
            // source, so a routed member consumes the identical stream
            // a standalone run of the same config would.
            arr_rngs.push(world.fork_rng(RNG_ARRIVALS));
            sources.push(scenario.build_source(mc)?);
        }
        worlds.push(world);
    }

    let mut federation = if routed {
        let router: Box<dyn JobRouter> = match spec.router {
            RouterKind::RoundRobin => Box::new(RoundRobin::default()),
            RouterKind::LeastQueued => Box::new(LeastQueued),
            RouterKind::ClassSplit => Box::new(ClassSplit::default()),
            RouterKind::PassThrough => unreachable!("routed implies a non-identity router"),
        };
        Federation::routed(worlds, sources, arr_rngs, router)
    } else {
        Federation::passthrough(worlds)
    };
    federation.set_shared_budgets(shareds, total_cap);
    Ok(federation)
}

/// Run `cfg`'s federation end-to-end (the `[federation]` block, or a
/// single pass-through member when the config has none) and distill one
/// [`RunResult`] per member plus the cross-cluster watermarks.
pub fn run_federation(cfg: &ExperimentConfig) -> Result<FederationOutcome> {
    let wall0 = Instant::now();
    let spec = cfg.federation.clone().unwrap_or_default();
    let n = spec.clusters;
    // `member_config` never changes the scheduler kind, so one name
    // serves every member's RunResult.
    let scheduler_name = cfg.scheduler.name().to_string();
    let mut scheds: Vec<Box<dyn Scheduler>> = (0..n)
        .map(|_| crate::coordinator::report::build_scheduler(cfg.scheduler, cfg.probe_ratio))
        .collect();
    let mut federation = build_federation(cfg, &spec, &mut scheds)?;
    // `pdes_threads = 0` (the default) runs the serial reference merge;
    // any N >= 1 runs conservative-window PDES — bit-identical reports
    // either way, so the choice is purely a wall-clock knob.
    if spec.pdes_threads > 0 {
        federation.run_pdes(spec.pdes_threads);
    } else {
        federation.run();
    }
    // Read the cap off the federation: the builder that sized the pools
    // recorded it, so the reported bound is the enforced bound.
    let shared_cap = federation.shared_cap();
    let peak_total_fleet = federation.peak_total_fleet();
    let peak_total_active = federation.peak_total_active();
    let wall_ms = wall0.elapsed().as_secs_f64() * 1000.0;
    // The members ran interleaved in one loop, so the federation's wall
    // clock is shared; attribute it in proportion to events processed,
    // so each member's `events_per_sec` reflects the run's actual
    // simulation rate instead of understating it by a factor of N.
    let total_events: u64 =
        federation.members().iter().map(|m| m.engine.processed()).sum();
    let runs: Vec<RunResult> = federation
        .into_members()
        .into_iter()
        .map(|world| {
            let share = if total_events > 0 {
                world.engine.processed() as f64 / total_events as f64
            } else {
                1.0 / n as f64
            };
            distill_world(world, scheduler_name.clone(), wall_ms * share)
        })
        .collect();
    Ok(FederationOutcome {
        runs,
        peak_total_fleet,
        peak_total_active,
        shared_cap,
        router: spec.router.name(),
        clusters: n,
        wall_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Hybrid;
    use crate::sim::Rng;
    use crate::trace::synth::{yahoo_like, YahooLikeParams};
    use crate::transient::Budget;

    fn small_workload(seed: u64) -> Workload {
        let mut p = YahooLikeParams::default();
        p.horizon = 4000.0;
        yahoo_like(&p, &mut Rng::new(seed))
    }

    fn small_cfg() -> SimConfig {
        SimConfig { n_general: 128, n_short_reserved: 8, ..Default::default() }
    }

    #[test]
    fn all_tasks_complete_under_eagle() {
        let w = small_workload(3);
        let mut sched = Hybrid::eagle(2.0);
        let res = simulate(&w, &mut sched, &small_cfg());
        assert_eq!(res.rec.tasks_finished as usize, w.num_tasks());
        assert_eq!(
            res.rec.short_delays.len() + res.rec.long_delays.len(),
            w.num_tasks()
        );
        assert!(res.end_time >= w.last_arrival());
        assert!(res.events > w.num_tasks() as u64);
    }

    #[test]
    fn cloudcoaster_uses_transients_and_completes() {
        let w = small_workload(3);
        let mut sched = Hybrid::cloudcoaster(2.0);
        let mut cfg = small_cfg();
        cfg.n_short_reserved = 4; // p=0.5 of an 8-server static partition
        cfg.manager = Some(ManagerConfig {
            threshold: 0.6,
            ..ManagerConfig::paper(Budget::new(8, 0.5, 3.0))
        });
        let res = simulate(&w, &mut sched, &cfg);
        assert_eq!(res.rec.tasks_finished as usize, w.num_tasks());
        let (adds, _, _) = res.manager_stats.unwrap();
        assert!(adds > 0, "manager never resized");
        // All transients are closed out at end: every requested server
        // eventually became active and has a recorded lifetime.
        assert_eq!(res.rec.cost.active_now(), 0.0);
        assert_eq!(res.rec.cost.lifetimes.len() as u64, res.rec.transients_requested);
    }

    #[test]
    fn deterministic_given_seed() {
        let w = small_workload(9);
        let run = || {
            let mut sched = Hybrid::eagle(2.0);
            simulate(&w, &mut sched, &small_cfg())
        };
        let a = run();
        let b = run();
        assert_eq!(a.events, b.events);
        assert_eq!(a.end_time, b.end_time);
        assert_eq!(a.rec.short_delays, b.rec.short_delays);
    }

    #[test]
    fn empty_workload_is_a_noop() {
        let w = Workload::default();
        let mut sched = Hybrid::eagle(2.0);
        let res = simulate(&w, &mut sched, &small_cfg());
        assert_eq!(res.events, 0);
        assert_eq!(res.rec.tasks_finished, 0);
    }

    #[test]
    fn revocations_do_not_lose_tasks() {
        let w = small_workload(5);
        let mut sched = Hybrid::cloudcoaster(2.0);
        let mut cfg = small_cfg();
        cfg.n_short_reserved = 4;
        let mut mgr = ManagerConfig {
            threshold: 0.5,
            ..ManagerConfig::paper(Budget::new(8, 0.5, 3.0))
        };
        mgr.market.mttf = Some(600.0); // aggressive revocations
        cfg.manager = Some(mgr);
        let res = simulate(&w, &mut sched, &cfg);
        // Every task finishes exactly once even under heavy revocation.
        assert_eq!(res.rec.tasks_finished as usize, w.num_tasks());
    }

    #[test]
    fn manager_less_world_has_no_manager_stats() {
        let w = small_workload(7);
        let mut sched = Hybrid::eagle(2.0);
        let res = simulate(&w, &mut sched, &small_cfg());
        assert!(res.manager_stats.is_none());
    }

    #[test]
    fn streaming_source_matches_eager_replay() {
        use crate::trace::synth::YahooSource;
        let mut p = YahooLikeParams::default();
        p.horizon = 4000.0;
        let cfg = SimConfig { seed: 3, ..small_cfg() };
        let w = yahoo_like(&p, &mut Rng::new(3));
        let mut eager_sched = Hybrid::eagle(2.0);
        let eager = simulate(&w, &mut eager_sched, &cfg);
        let mut stream_sched = Hybrid::eagle(2.0);
        let source = Box::new(YahooSource::new(&p, &mut Rng::new(3)));
        let streamed = simulate_source(source, &mut stream_sched, &cfg, None);
        assert_eq!(eager.events, streamed.events);
        assert_eq!(eager.end_time, streamed.end_time);
        assert_eq!(eager.rec.short_delays, streamed.rec.short_delays);
        // Resident jobs, task slots and server slots are bounded by
        // load, not the trace — and identically on the eager
        // (borrowed-lookahead) and streaming paths.
        assert!(streamed.peak_resident_jobs < w.num_jobs());
        assert_eq!(eager.peak_resident_tasks, streamed.peak_resident_tasks);
        assert!(streamed.peak_resident_tasks < w.num_tasks());
        assert_eq!(eager.peak_resident_servers, streamed.peak_resident_servers);
    }

    #[test]
    fn stealing_disabled_is_a_valid_wiring() {
        let w = small_workload(11);
        let mut cfg = small_cfg();
        cfg.steal_probes = 0;
        let mut sched = Hybrid::eagle(2.0);
        let res = simulate(&w, &mut sched, &cfg);
        assert_eq!(res.rec.tasks_finished as usize, w.num_tasks());
    }
}
