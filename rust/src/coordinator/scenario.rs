//! The declarative scenario registry: a [`ScenarioSpec`] names an
//! arrival source, an ordered combinator stack, and an optional
//! manager-less override — everything the runner needs to stream a
//! workload scenario, so new arrival patterns are registry wiring, not
//! new code paths.
//!
//! A spec comes from one of two places:
//!
//! * the `[scenario]` section of a config file (see
//!   [`ScenarioSpec::from_table`]) — fully declarative:
//!
//!   ```toml
//!   [workload]
//!   csv = "trace.csv"            # base trace (any workload source works)
//!
//!   [scenario]
//!   name = "storm-replay"
//!   storm_windows = [3600, 7200] # start,end pairs (seconds)
//!   storm_intensity = 3.0        # arrival-rate multiplier in-window
//!   manager = "none"             # manager-less baseline wiring
//!   ```
//!
//! * the named registry ([`named`], CLI `--scenario NAME`) — canned
//!   compositions over the experiment's configured workload:
//!   `default`, `managerless` (scheduler only, no `TransientManager`
//!   component — the ROADMAP's manager-less baseline), `burst-storm`
//!   (storm windows injected into the configured workload; over a CSV
//!   workload this is a burst-storm trace replay).
//!
//! Combinators declared in one `[scenario]` block apply in a fixed
//! canonical order: `TimeWindow` → `RateScale` → `MergeCsv` →
//! `SpliceCsv` → `BurstStorm` → `Take` (slice, scale, compose, inject,
//! cap). Scenario parameters are plain config data, so sweeps can put
//! them on a grid axis like any other knob (see
//! [`crate::coordinator::sweep::storm_intensity_points`]).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::config::{ExperimentConfig, WorkloadSource};
use crate::coordinator::toml::{Table, Value};
use crate::sim::Rng;
use crate::trace::synth::{GoogleLikeParams, GoogleSource, YahooLikeParams, YahooSource};
use crate::trace::{self, ArrivalSource, CsvStream};
use crate::util::Time;

/// Scenario names resolvable by [`named`] / the CLI `--scenario` flag.
pub const SCENARIO_NAMES: &[&str] =
    &["default", "managerless", "burst-storm", "federated-burst"];

/// Every key the `[scenario]` TOML section understands (closed set:
/// unknown keys are config errors, not silent no-ops).
const SCENARIO_KEYS: &[&str] = &[
    "name",
    "source",
    "csv",
    "manager",
    "window_start",
    "window_end",
    "rate_scale",
    "merge_csv",
    "splice_csv",
    "splice_at",
    "storm_windows",
    "storm_intensity",
    "take",
];

/// Which base source a scenario streams from.
#[derive(Clone, Debug, PartialEq)]
pub enum SourceSpec {
    /// Inherit the experiment's `[workload]` selection (default).
    Workload,
    /// Yahoo-like synthesis (the experiment's params when its workload
    /// is Yahoo-like, calibrated defaults otherwise).
    Yahoo,
    /// Google-like synthesis (same inheritance rule).
    Google,
    /// Streaming CSV replay of the given trace file.
    Csv(String),
}

/// One combinator in a scenario's stack.
#[derive(Clone, Debug, PartialEq)]
pub enum CombinatorSpec {
    /// Slice `[start, end)` out of the source, rebased to t = 0.
    TimeWindow { start: Time, end: Time },
    /// Multiply the arrival rate by compressing time.
    RateScale { factor: f64 },
    /// Merge a second, CSV-replayed source by arrival time.
    MergeCsv { path: String },
    /// Switch to a CSV-replayed source at time `at` (regime change).
    SpliceCsv { path: String, at: Time },
    /// Inject rate-multiplied storm windows.
    BurstStorm { windows: Vec<(Time, Time)>, intensity: f64 },
    /// Cap the stream at `jobs` jobs.
    Take { jobs: usize },
}

impl CombinatorSpec {
    fn validate(&self) -> Result<()> {
        match self {
            CombinatorSpec::TimeWindow { start, end } => {
                if !(*start >= 0.0 && start < end && end.is_finite()) {
                    bail!("scenario window must satisfy 0 <= start < end (got {start}..{end})");
                }
            }
            CombinatorSpec::RateScale { factor } => {
                if !(*factor > 0.0 && factor.is_finite()) {
                    bail!("scenario rate_scale must be positive (got {factor})");
                }
            }
            CombinatorSpec::MergeCsv { .. } => {}
            CombinatorSpec::SpliceCsv { at, .. } => {
                if !(*at >= 0.0 && at.is_finite()) {
                    bail!("scenario splice_at must be finite and >= 0 (got {at})");
                }
            }
            CombinatorSpec::BurstStorm { windows, intensity } => {
                if windows.is_empty() {
                    bail!("burst storm needs at least one window");
                }
                for &(s, e) in windows {
                    if !(s.is_finite() && e.is_finite() && s >= 0.0 && s < e) {
                        bail!("storm window must satisfy 0 <= start < end (got {s}..{e})");
                    }
                }
                if !(*intensity >= 1.0 && intensity.is_finite()) {
                    bail!("storm intensity must be >= 1 (got {intensity})");
                }
            }
            CombinatorSpec::Take { jobs } => {
                if *jobs == 0 {
                    bail!("scenario take must be > 0");
                }
            }
        }
        Ok(())
    }

    fn apply<'a>(
        &self,
        src: Box<dyn ArrivalSource + 'a>,
    ) -> Result<Box<dyn ArrivalSource + 'a>> {
        Ok(match self {
            CombinatorSpec::TimeWindow { start, end } => {
                Box::new(trace::TimeWindow::new(src, *start, *end))
            }
            CombinatorSpec::RateScale { factor } => {
                Box::new(trace::RateScale::new(src, *factor))
            }
            CombinatorSpec::MergeCsv { path } => Box::new(trace::Merge::new(
                src,
                Box::new(CsvStream::open(Path::new(path), 90.0)?),
            )),
            CombinatorSpec::SpliceCsv { path, at } => Box::new(trace::Splice::new(
                src,
                Box::new(CsvStream::open(Path::new(path), 90.0)?),
                *at,
            )),
            CombinatorSpec::BurstStorm { windows, intensity } => {
                Box::new(trace::BurstStorm::new(src, windows.clone(), *intensity))
            }
            CombinatorSpec::Take { jobs } => Box::new(trace::Take::new(src, *jobs)),
        })
    }
}

/// A declarative workload scenario: base source + combinator stack +
/// optional manager-less override.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub source: SourceSpec,
    /// Combinators, applied innermost-first.
    pub stack: Vec<CombinatorSpec>,
    /// Force the transient manager off (`manager = "none"`): scheduler
    /// only, no `TransientManager` component — the manager-less
    /// baseline wiring.
    pub manager_off: bool,
}

impl ScenarioSpec {
    /// The identity scenario: the configured workload, no combinators,
    /// manager wiring inherited.
    pub fn passthrough() -> Self {
        ScenarioSpec {
            name: "default".to_string(),
            source: SourceSpec::Workload,
            stack: Vec::new(),
            manager_off: false,
        }
    }

    /// Does this scenario change the *workload* at all? (A passthrough
    /// scenario can keep using the eager shared-workload path — e.g. in
    /// sweeps — because the streamed and eager runs are bit-identical.)
    pub fn reshapes_workload(&self) -> bool {
        self.source != SourceSpec::Workload || !self.stack.is_empty()
    }

    pub fn validate(&self) -> Result<()> {
        for c in &self.stack {
            c.validate()?;
        }
        Ok(())
    }

    /// Build the streaming source for this scenario. All randomness
    /// forks off `cfg.seed`, exactly as the eager workload builder's
    /// generators do, so a passthrough scenario streams the bit-same
    /// trace the eager path materialises.
    pub fn build_source(&self, cfg: &ExperimentConfig) -> Result<Box<dyn ArrivalSource>> {
        // Programmatically-built specs (sweep axes, library callers)
        // may never have passed config validation — check here so a bad
        // spec is an `Err`, not a combinator assert in a worker thread.
        self.validate()?;
        let mut root = Rng::new(cfg.seed);
        let mut src: Box<dyn ArrivalSource> = match &self.source {
            SourceSpec::Workload => workload_source(&cfg.workload, &mut root)?,
            SourceSpec::Yahoo => {
                let p = match &cfg.workload {
                    WorkloadSource::YahooLike(p) => p.clone(),
                    _ => YahooLikeParams::default(),
                };
                Box::new(YahooSource::new(&p, &mut root))
            }
            SourceSpec::Google => {
                let p = match &cfg.workload {
                    WorkloadSource::GoogleLike(p) => p.clone(),
                    _ => GoogleLikeParams::default(),
                };
                Box::new(GoogleSource::new(&p, &mut root))
            }
            SourceSpec::Csv(path) => Box::new(CsvStream::open(Path::new(path), 90.0)?),
        };
        for c in &self.stack {
            src = c.apply(src)?;
        }
        Ok(src)
    }

    /// Parse the `[scenario]` section out of a parsed config table.
    /// Returns `None` when the file has no scenario keys. A key that is
    /// present with the wrong type is an error, never a silent no-op —
    /// a mistyped combinator must not run the unmodified workload.
    pub fn from_table(t: &Table) -> Result<Option<ScenarioSpec>> {
        if !t.keys().any(|k| k.starts_with("scenario.")) {
            return Ok(None);
        }
        // The key set is closed — reject unknown keys so a typo'd
        // combinator (`window_strat`, `manger`) cannot silently run the
        // unmodified workload.
        for k in t.keys() {
            if let Some(rest) = k.strip_prefix("scenario.") {
                if !SCENARIO_KEYS.contains(&rest) {
                    bail!("unknown scenario key {rest:?} (known keys: {SCENARIO_KEYS:?})");
                }
            }
        }
        let mut spec = ScenarioSpec::passthrough();
        if let Some(v) = key_str(t, "name")? {
            spec.name = v.to_string();
        }
        match key_str(t, "source")? {
            None | Some("workload") => {}
            Some("yahoo") => spec.source = SourceSpec::Yahoo,
            Some("google") => spec.source = SourceSpec::Google,
            Some("csv") => {
                let path = key_str(t, "csv")?
                    .context("scenario.source = \"csv\" needs scenario.csv = \"<path>\"")?;
                spec.source = SourceSpec::Csv(path.to_string());
            }
            Some(other) => bail!("unknown scenario source {other:?} (workload|yahoo|google|csv)"),
        }
        match key_str(t, "manager")? {
            Some("none") => spec.manager_off = true,
            None | Some("inherit") => {}
            Some(other) => bail!("scenario.manager must be \"none\" or \"inherit\", got {other:?}"),
        }

        // Combinators, in the canonical application order.
        match (key_f64(t, "window_start")?, key_f64(t, "window_end")?) {
            (Some(start), Some(end)) => {
                spec.stack.push(CombinatorSpec::TimeWindow { start, end });
            }
            (None, None) => {}
            _ => bail!("scenario window needs both window_start and window_end"),
        }
        if let Some(factor) = key_f64(t, "rate_scale")? {
            spec.stack.push(CombinatorSpec::RateScale { factor });
        }
        if let Some(path) = key_str(t, "merge_csv")? {
            spec.stack.push(CombinatorSpec::MergeCsv { path: path.to_string() });
        }
        if let Some(path) = key_str(t, "splice_csv")? {
            let at = key_f64(t, "splice_at")?
                .context("scenario.splice_csv needs scenario.splice_at = <seconds>")?;
            spec.stack.push(CombinatorSpec::SpliceCsv { path: path.to_string(), at });
        }
        if let Some(v) = key(t, "storm_windows") {
            let Value::Array(items) = v else {
                bail!("scenario.storm_windows must be an array of start,end pairs");
            };
            let flat: Vec<f64> = items
                .iter()
                .map(|v| v.as_f64().context("storm_windows entries must be numbers"))
                .collect::<Result<_>>()?;
            if flat.len() % 2 != 0 {
                bail!("scenario.storm_windows must hold start,end pairs");
            }
            let windows: Vec<(Time, Time)> =
                flat.chunks(2).map(|w| (w[0], w[1])).collect();
            let intensity = key_f64(t, "storm_intensity")?.unwrap_or(3.0);
            spec.stack.push(CombinatorSpec::BurstStorm { windows, intensity });
        } else if key(t, "storm_intensity").is_some() {
            bail!("scenario.storm_intensity needs scenario.storm_windows = [start, end, ...]");
        }
        if let Some(v) = key(t, "take") {
            let jobs = v.as_usize().context("scenario.take must be a positive integer")?;
            spec.stack.push(CombinatorSpec::Take { jobs });
        }

        spec.validate()?;
        Ok(Some(spec))
    }
}

/// `scenario.<k>` lookup in a parsed config table.
fn key<'t>(t: &'t Table, k: &str) -> Option<&'t Value> {
    t.get(&format!("scenario.{k}"))
}

/// Typed lookup: present-but-mistyped keys are errors, never no-ops.
fn key_f64(t: &Table, k: &str) -> Result<Option<f64>> {
    match key(t, k) {
        None => Ok(None),
        Some(v) => {
            Ok(Some(v.as_f64().with_context(|| format!("scenario.{k} must be a number"))?))
        }
    }
}

fn key_str<'t>(t: &'t Table, k: &str) -> Result<Option<&'t str>> {
    match key(t, k) {
        None => Ok(None),
        Some(v) => {
            Ok(Some(v.as_str().with_context(|| format!("scenario.{k} must be a string"))?))
        }
    }
}

// ------------------------------------------------------------ federation

/// Every key the `[federation]` TOML section understands (closed set:
/// unknown keys are config errors, not silent no-ops).
const FEDERATION_KEYS: &[&str] =
    &["clusters", "router", "budget_sharing", "stagger", "pdes_threads"];

/// Which [`crate::sim::JobRouter`] fronts a federation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterKind {
    /// No routing: each job executes on the cluster whose source
    /// produced it (the identity front end; members own their feeds).
    PassThrough,
    RoundRobin,
    LeastQueued,
    /// Class-aware short/long split across the member halves.
    ClassSplit,
}

impl RouterKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "passthrough" | "pass-through" => RouterKind::PassThrough,
            "round-robin" => RouterKind::RoundRobin,
            "least-queued" => RouterKind::LeastQueued,
            "class-split" => RouterKind::ClassSplit,
            other => bail!(
                "unknown federation router {other:?} \
                 (passthrough|round-robin|least-queued|class-split)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RouterKind::PassThrough => "passthrough",
            RouterKind::RoundRobin => "round-robin",
            RouterKind::LeastQueued => "least-queued",
            RouterKind::ClassSplit => "class-split",
        }
    }
}

/// How the transient budget couples across federated clusters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BudgetSharing {
    /// Uncoupled: every cluster keeps its own full budget cap K.
    None,
    /// One hard K/N slice per cluster (the total never exceeds K, but
    /// idle headroom is not transferable).
    Split,
    /// One pooled cap K drawn from by all clusters: a quiet cluster's
    /// headroom serves another's burst — CloudCoaster's elasticity
    /// argument at federation scope.
    Pooled,
}

impl BudgetSharing {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "none" => BudgetSharing::None,
            "split" => BudgetSharing::Split,
            "pooled" => BudgetSharing::Pooled,
            other => bail!("unknown budget_sharing {other:?} (none|split|pooled)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BudgetSharing::None => "none",
            BudgetSharing::Split => "split",
            BudgetSharing::Pooled => "pooled",
        }
    }
}

/// A declarative multi-cluster federation: member count, router front
/// end, budget coupling, and the per-cluster storm stagger. Parsed from
/// a `[federation]` TOML block or resolved from the registry
/// (`--scenario federated-burst`); `build_federation` in
/// `coordinator::runner` turns it plus the experiment config into wired
/// member worlds.
#[derive(Clone, Debug, PartialEq)]
pub struct FederationSpec {
    /// Member cluster count (N = 1 with a passthrough router reproduces
    /// the single-world run bit-identically).
    pub clusters: usize,
    pub router: RouterKind,
    pub budget_sharing: BudgetSharing,
    /// Seconds added per member index to every `BurstStorm` window of
    /// the member's scenario: member i's storms shift by `i·stagger`,
    /// so bursts sweep across the federation instead of striking every
    /// cluster at once.
    pub stagger: f64,
    /// Worker threads for conservative-window PDES inside the one
    /// federated run (`Federation::run_pdes`). `0` — the default — runs
    /// the serial reference merge; any `N >= 1` runs the windowed path
    /// (bit-identical reports at every value, pinned by
    /// `tests/federation_golden.rs`).
    pub pdes_threads: usize,
}

impl Default for FederationSpec {
    fn default() -> Self {
        FederationSpec {
            clusters: 1,
            router: RouterKind::PassThrough,
            budget_sharing: BudgetSharing::None,
            stagger: 0.0,
            pdes_threads: 0,
        }
    }
}

impl FederationSpec {
    pub fn validate(&self) -> Result<()> {
        if self.clusters == 0 {
            bail!("federation needs at least one cluster");
        }
        if self.clusters > 64 {
            bail!("federation.clusters capped at 64 (got {})", self.clusters);
        }
        if !(self.stagger >= 0.0 && self.stagger.is_finite()) {
            bail!("federation.stagger must be finite and >= 0 (got {})", self.stagger);
        }
        if self.pdes_threads > 512 {
            bail!(
                "federation.pdes_threads capped at 512 (got {}); 0 = serial merge",
                self.pdes_threads
            );
        }
        Ok(())
    }

    /// Parse the `[federation]` section out of a parsed config table
    /// (`None` when the file has none; mistyped or unknown keys are
    /// errors, never silent no-ops).
    pub fn from_table(t: &Table) -> Result<Option<FederationSpec>> {
        if !t.keys().any(|k| k.starts_with("federation.")) {
            return Ok(None);
        }
        for k in t.keys() {
            if let Some(rest) = k.strip_prefix("federation.") {
                if !FEDERATION_KEYS.contains(&rest) {
                    bail!("unknown federation key {rest:?} (known keys: {FEDERATION_KEYS:?})");
                }
            }
        }
        let mut spec = FederationSpec::default();
        if let Some(v) = t.get("federation.clusters") {
            spec.clusters =
                v.as_usize().context("federation.clusters must be a positive integer")?;
        }
        if let Some(v) = t.get("federation.router") {
            spec.router =
                RouterKind::parse(v.as_str().context("federation.router must be a string")?)?;
        }
        if let Some(v) = t.get("federation.budget_sharing") {
            spec.budget_sharing = BudgetSharing::parse(
                v.as_str().context("federation.budget_sharing must be a string")?,
            )?;
        }
        if let Some(v) = t.get("federation.stagger") {
            spec.stagger = v.as_f64().context("federation.stagger must be a number")?;
        }
        if let Some(v) = t.get("federation.pdes_threads") {
            spec.pdes_threads = v
                .as_usize()
                .context("federation.pdes_threads must be a non-negative integer")?;
        }
        spec.validate()?;
        Ok(Some(spec))
    }

    /// Derive member `i`'s experiment config: its own seed (base + i, as
    /// `replicate` numbers its runs) and its scenario's storm windows
    /// shifted by `i·stagger`. The member config carries no `federation`
    /// of its own — it is exactly what a standalone run of that member
    /// would use, which is what makes the N = 1 pass-through federation
    /// reproduce the plain world bit-for-bit.
    pub fn member_config(&self, base: &ExperimentConfig, i: usize) -> ExperimentConfig {
        let mut cfg = base.clone();
        cfg.federation = None;
        cfg.seed = base.seed.wrapping_add(i as u64);
        if self.stagger > 0.0 {
            if let Some(spec) = &mut cfg.scenario {
                for c in &mut spec.stack {
                    if let CombinatorSpec::BurstStorm { windows, .. } = c {
                        for w in windows.iter_mut() {
                            w.0 += i as f64 * self.stagger;
                            w.1 += i as f64 * self.stagger;
                        }
                    }
                }
            }
        }
        cfg
    }
}

/// Registry federation for a `--scenario` name: `federated-burst`
/// resolves to two clusters under staggered storm windows sharing one
/// pooled transient budget (the cross-cluster elasticity scenario);
/// every other name federates nothing (`None`).
pub fn named_federation(
    name: &str,
    cfg: &ExperimentConfig,
) -> Result<Option<FederationSpec>> {
    Ok(match name {
        "federated-burst" => {
            let h = default_horizon(cfg)?;
            Some(FederationSpec {
                clusters: 2,
                router: RouterKind::PassThrough,
                budget_sharing: BudgetSharing::Pooled,
                stagger: 0.20 * h,
                ..Default::default()
            })
        }
        _ => None,
    })
}

/// Stream the experiment's `[workload]` selection — the streaming twin
/// of `report::build_workload` (same seeds, same forks, bit-identical
/// jobs). Only the scenario pipeline builder needs it; widen to `pub`
/// if an external caller ever streams workloads directly.
pub(crate) fn workload_source(
    ws: &WorkloadSource,
    root: &mut Rng,
) -> Result<Box<dyn ArrivalSource>> {
    Ok(match ws {
        WorkloadSource::YahooLike(p) => Box::new(YahooSource::new(p, root)),
        WorkloadSource::GoogleLike(p) => Box::new(GoogleSource::new(p, root)),
        WorkloadSource::Csv(path) => Box::new(CsvStream::open(Path::new(path), 90.0)?),
    })
}

/// The scenario's workload horizon, used to size default storm windows.
/// For a CSV workload the trace file's last arrival is read (one
/// validation pass, O(1) memory) so registry storms land *inside* the
/// replayed trace instead of past its end.
fn default_horizon(cfg: &ExperimentConfig) -> Result<f64> {
    Ok(match &cfg.workload {
        WorkloadSource::YahooLike(p) => p.horizon,
        WorkloadSource::GoogleLike(p) => p.horizon,
        WorkloadSource::Csv(path) => {
            CsvStream::open(Path::new(path), 90.0)?.last_arrival().max(1.0)
        }
    })
}

/// Resolve a registry scenario by name against an experiment config
/// (CLI `--scenario NAME`).
pub fn named(name: &str, cfg: &ExperimentConfig) -> Result<ScenarioSpec> {
    Ok(match name {
        "default" => ScenarioSpec::passthrough(),
        "managerless" => ScenarioSpec {
            name: "managerless".to_string(),
            manager_off: true,
            ..ScenarioSpec::passthrough()
        },
        "burst-storm" => {
            let h = default_horizon(cfg)?;
            ScenarioSpec {
                name: "burst-storm".to_string(),
                stack: vec![CombinatorSpec::BurstStorm {
                    windows: vec![(0.25 * h, 0.40 * h)],
                    intensity: 3.0,
                }],
                ..ScenarioSpec::passthrough()
            }
        }
        // The workload half of the federated scenario: the same storm
        // base as `burst-storm`; the federation half (two clusters,
        // pooled budget, per-cluster stagger applied to these windows)
        // comes from [`named_federation`].
        "federated-burst" => {
            let h = default_horizon(cfg)?;
            ScenarioSpec {
                name: "federated-burst".to_string(),
                stack: vec![CombinatorSpec::BurstStorm {
                    windows: vec![(0.25 * h, 0.40 * h)],
                    intensity: 3.0,
                }],
                ..ScenarioSpec::passthrough()
            }
        }
        other => bail!("unknown scenario {other:?} (available: {SCENARIO_NAMES:?})"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::toml::parse;
    use crate::trace::collect_jobs;

    #[test]
    fn passthrough_does_not_reshape() {
        let s = ScenarioSpec::passthrough();
        assert!(!s.reshapes_workload());
        assert!(!s.manager_off);
        let mut m = ScenarioSpec::passthrough();
        m.manager_off = true;
        assert!(!m.reshapes_workload()); // manager-off alone keeps the workload
    }

    #[test]
    fn named_registry_resolves_all_names() {
        let cfg = ExperimentConfig::paper_defaults();
        for name in SCENARIO_NAMES {
            let spec = named(name, &cfg).unwrap();
            spec.validate().unwrap();
        }
        assert!(named("nope", &cfg).is_err());
        assert!(named("managerless", &cfg).unwrap().manager_off);
        assert!(named("burst-storm", &cfg).unwrap().reshapes_workload());
    }

    #[test]
    fn from_table_parses_a_full_stack() {
        let t = parse(
            r#"
            [scenario]
            name = "kitchen-sink"
            source = "yahoo"
            manager = "none"
            window_start = 0
            window_end = 7200
            rate_scale = 1.5
            storm_windows = [600, 1200, 3000, 3600]
            storm_intensity = 2.5
            take = 500
            "#,
        )
        .unwrap();
        let spec = ScenarioSpec::from_table(&t).unwrap().unwrap();
        assert_eq!(spec.name, "kitchen-sink");
        assert_eq!(spec.source, SourceSpec::Yahoo);
        assert!(spec.manager_off);
        assert_eq!(spec.stack.len(), 4);
        assert_eq!(spec.stack[0], CombinatorSpec::TimeWindow { start: 0.0, end: 7200.0 });
        assert_eq!(spec.stack[1], CombinatorSpec::RateScale { factor: 1.5 });
        assert_eq!(
            spec.stack[2],
            CombinatorSpec::BurstStorm {
                windows: vec![(600.0, 1200.0), (3000.0, 3600.0)],
                intensity: 2.5
            }
        );
        assert_eq!(spec.stack[3], CombinatorSpec::Take { jobs: 500 });
    }

    #[test]
    fn from_table_absent_section_is_none() {
        let t = parse("[cluster]\nservers = 100\n").unwrap();
        assert!(ScenarioSpec::from_table(&t).unwrap().is_none());
    }

    #[test]
    fn from_table_rejects_bad_specs() {
        for text in [
            "[scenario]\nsource = \"csv\"\n",             // missing csv path
            "[scenario]\nwindow_start = 5\n",             // half a window
            "[scenario]\nstorm_intensity = 2\n",          // storm without windows
            "[scenario]\nstorm_windows = [5, 1]\n",       // start >= end
            "[scenario]\nstorm_windows = [1, 5, 9]\n",    // odd pair list
            "[scenario]\nsplice_csv = \"x.csv\"\n",       // missing splice_at
            "[scenario]\nrate_scale = 0\n",               // non-positive
            "[scenario]\nmanager = \"maybe\"\n",          // unknown mode
            "[scenario]\nsource = \"martian\"\n",         // unknown source
            "[scenario]\ntake = 5.5\n",                   // mistyped: float take
            "[scenario]\ntake = -5\n",                    // mistyped: negative take
            "[scenario]\nrate_scale = \"2\"\n",           // mistyped: string number
            "[scenario]\nstorm_windows = 600\n",          // mistyped: scalar windows
            "[scenario]\nname = 5\n",                     // mistyped: numeric name
            "[scenario]\nmanger = \"none\"\n",            // typo'd key
            "[scenario]\nwindow_strat = 600\n",           // typo'd key
        ] {
            let t = parse(text).unwrap();
            assert!(ScenarioSpec::from_table(&t).is_err(), "accepted: {text}");
        }
    }

    #[test]
    fn federation_table_parses_and_rejects() {
        let t = parse(
            r#"
            [federation]
            clusters = 3
            router = "least-queued"
            budget_sharing = "pooled"
            stagger = 600
            "#,
        )
        .unwrap();
        let spec = FederationSpec::from_table(&t).unwrap().unwrap();
        assert_eq!(spec.clusters, 3);
        assert_eq!(spec.router, RouterKind::LeastQueued);
        assert_eq!(spec.budget_sharing, BudgetSharing::Pooled);
        assert_eq!(spec.stagger, 600.0);
        // Absent section is None.
        let t = parse("[cluster]\nservers = 100\n").unwrap();
        assert!(FederationSpec::from_table(&t).unwrap().is_none());
        for text in [
            "[federation]\nclusters = 0\n",             // no members
            "[federation]\nclusters = 100\n",           // over the cap
            "[federation]\nrouter = \"hashring\"\n",    // unknown router
            "[federation]\nbudget_sharing = \"all\"\n", // unknown sharing
            "[federation]\nstagger = -5\n",             // negative stagger
            "[federation]\nclusers = 2\n",              // typo'd key
            "[federation]\nclusters = \"two\"\n",       // mistyped value
        ] {
            let t = parse(text).unwrap();
            assert!(FederationSpec::from_table(&t).is_err(), "accepted: {text}");
        }
    }

    #[test]
    fn member_config_staggers_storms_and_seeds() {
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.seed = 100;
        cfg.scenario = Some(ScenarioSpec {
            name: "storm".into(),
            source: SourceSpec::Workload,
            stack: vec![CombinatorSpec::BurstStorm {
                windows: vec![(1000.0, 2000.0)],
                intensity: 3.0,
            }],
            manager_off: false,
        });
        let fed = FederationSpec { clusters: 2, stagger: 500.0, ..Default::default() };
        let m0 = fed.member_config(&cfg, 0);
        let m1 = fed.member_config(&cfg, 1);
        assert_eq!(m0.seed, 100);
        assert_eq!(m1.seed, 101);
        assert!(m0.federation.is_none() && m1.federation.is_none());
        let windows = |c: &ExperimentConfig| match &c.scenario.as_ref().unwrap().stack[0] {
            CombinatorSpec::BurstStorm { windows, .. } => windows.clone(),
            _ => unreachable!(),
        };
        assert_eq!(windows(&m0), vec![(1000.0, 2000.0)]);
        assert_eq!(windows(&m1), vec![(1500.0, 2500.0)]);
        // Member 0 of a zero-index federation is the base config exactly
        // (scenario untouched) — the N = 1 bit-identity precondition.
        assert_eq!(m0.scenario, cfg.scenario);
    }

    #[test]
    fn named_federation_registry() {
        let mut cfg = ExperimentConfig::paper_defaults();
        if let WorkloadSource::YahooLike(p) = &mut cfg.workload {
            p.horizon = 10_000.0;
        }
        let fed = named_federation("federated-burst", &cfg).unwrap().unwrap();
        assert_eq!(fed.clusters, 2);
        assert_eq!(fed.budget_sharing, BudgetSharing::Pooled);
        assert!((fed.stagger - 2000.0).abs() < 1e-9);
        fed.validate().unwrap();
        assert!(named_federation("burst-storm", &cfg).unwrap().is_none());
        // And the scenario half resolves from the same name.
        let spec = named("federated-burst", &cfg).unwrap();
        assert!(spec.reshapes_workload());
    }

    #[test]
    fn build_source_rejects_unvalidated_programmatic_specs() {
        // A library caller can build any spec; build_source must return
        // Err (not panic in a combinator assert) for invalid ones.
        let cfg = ExperimentConfig::paper_defaults();
        let mut spec = ScenarioSpec::passthrough();
        spec.stack.push(CombinatorSpec::BurstStorm {
            windows: vec![(0.0, 100.0)],
            intensity: 0.5,
        });
        assert!(spec.build_source(&cfg).is_err());
    }

    #[test]
    fn build_source_streams_a_storm_scenario() {
        let mut cfg = ExperimentConfig::paper_defaults();
        if let WorkloadSource::YahooLike(p) = &mut cfg.workload {
            p.horizon = 2000.0;
        }
        let spec = named("burst-storm", &cfg).unwrap();
        let mut src = spec.build_source(&cfg).unwrap();
        let jobs = collect_jobs(src.as_mut(), &mut Rng::new(cfg.seed));
        assert!(!jobs.is_empty());
        assert!(jobs.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        // Deterministic per seed.
        let mut src2 = named("burst-storm", &cfg).unwrap().build_source(&cfg).unwrap();
        let jobs2 = collect_jobs(src2.as_mut(), &mut Rng::new(cfg.seed));
        assert_eq!(jobs.len(), jobs2.len());
    }
}
