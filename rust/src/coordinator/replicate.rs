//! Multi-seed replication: the paper reports single-trace numbers; this
//! re-runs the headline comparison across independently-seeded synthetic
//! traces and summarises the distribution of the improvement ratios, so
//! the reproduction's claims carry confidence intervals.

use anyhow::Result;

use crate::coordinator::config::{ExperimentConfig, SchedulerKind};
use crate::coordinator::report::{build_workload, run_experiment_on};
use crate::metrics::StreamingStats;
use crate::runtime::AnalyticsEngine;

/// Distribution of the headline ratios across seeds.
#[derive(Debug)]
// lint: allow(check-dead-pub): flows out as `replicate()`'s return type; callers print `summary()` without naming it
pub struct Replication {
    pub seeds: Vec<u64>,
    /// baseline_mean_delay / cloudcoaster_mean_delay per seed.
    pub mean_speedups: Vec<f64>,
    /// baseline_max_delay / cloudcoaster_max_delay per seed.
    pub max_speedups: Vec<f64>,
    /// r-normalized saving vs the static short budget per seed.
    pub savings: Vec<f64>,
}

impl Replication {
    fn stats(xs: &[f64]) -> (f64, f64, f64, f64) {
        let mut s = StreamingStats::new();
        for &x in xs {
            s.push(x);
        }
        (s.mean(), s.std_dev(), s.min(), s.max())
    }

    pub fn summary(&self) -> String {
        let (m, sd, lo, hi) = Self::stats(&self.mean_speedups);
        let (mm, msd, mlo, mhi) = Self::stats(&self.max_speedups);
        let (sm, ssd, slo, shi) = Self::stats(&self.savings);
        format!(
            "over {} seeds:\n  avg-delay speedup: {m:.2}X ± {sd:.2} (range {lo:.2}–{hi:.2}; paper 4.8X)\n  \
             max-delay speedup: {mm:.2}X ± {msd:.2} (range {mlo:.2}–{mhi:.2}; paper 1.83X)\n  \
             cost saving:       {:.1}% ± {:.1} (range {:.1}–{:.1}; paper 29.5%)",
            self.seeds.len(),
            100.0 * sm,
            100.0 * ssd,
            100.0 * slo,
            100.0 * shi,
        )
    }
}

/// Run baseline + CloudCoaster(r = base.r) for each seed.
pub fn replicate(base: &ExperimentConfig, seeds: &[u64]) -> Result<Replication> {
    let mut analytics = AnalyticsEngine::auto(&crate::coordinator::report::artifacts_dir());
    let mut out = Replication {
        seeds: seeds.to_vec(),
        mean_speedups: Vec::new(),
        max_speedups: Vec::new(),
        savings: Vec::new(),
    };
    let static_budget = base.short_partition as f64 * base.p;
    for &seed in seeds {
        let mut cfg = base.clone();
        cfg.seed = seed;
        let workload = build_workload(&cfg)?;
        let mut baseline_cfg = cfg.clone();
        baseline_cfg.scheduler = SchedulerKind::Eagle;
        let baseline = run_experiment_on(&baseline_cfg, &workload, analytics.as_dyn())?;
        let mut cc_cfg = cfg.clone();
        cc_cfg.scheduler = SchedulerKind::CloudCoaster;
        let cc = run_experiment_on(&cc_cfg, &workload, analytics.as_dyn())?;
        out.mean_speedups
            .push(baseline.short_delay.mean / cc.short_delay.mean.max(1e-9));
        out.max_speedups
            .push(baseline.short_delay.max / cc.short_delay.max.max(1e-9));
        out.savings
            .push((static_budget - cc.r_normalized_avg) / static_budget.max(1e-9));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::WorkloadSource;
    use crate::trace::synth::YahooLikeParams;

    #[test]
    fn replication_across_seeds() {
        let mut base = ExperimentConfig::paper_defaults();
        base.cluster_size = 200;
        base.short_partition = 10;
        base.threshold = 0.7;
        let mut p = YahooLikeParams::default();
        p.horizon = 1500.0;
        p.short_arrivals.calm_rate /= 15.0;
        p.short_arrivals.burst_rate /= 15.0;
        p.long_arrivals.calm_rate /= 10.0;
        p.long_arrivals.burst_rate /= 10.0;
        base.workload = WorkloadSource::YahooLike(p);
        let rep = replicate(&base, &[1, 2, 3]).unwrap();
        assert_eq!(rep.mean_speedups.len(), 3);
        assert!(rep.mean_speedups.iter().all(|&x| x.is_finite() && x > 0.0));
        assert!(!rep.summary().is_empty());
        // Different seeds produce different traces/ratios.
        assert!(rep.mean_speedups[0] != rep.mean_speedups[1]
            || rep.mean_speedups[1] != rep.mean_speedups[2]);
    }
}
