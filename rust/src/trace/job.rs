//! Workload records: jobs, their tasks, and the short/long classification
//! that drives every hybrid scheduler in the paper.

use crate::util::{JobId, Time};

/// A job from the workload trace: an arrival time plus a bag of tasks.
///
/// Following the Eagle/Hawk simulators (which the paper builds on), each
/// task has its own duration and the job is classified short or long once,
/// at trace level, by its mean task duration vs. the cutoff — hybrid
/// schedulers are assumed to know the class on arrival (estimated runtimes
/// from recurring-job history).
#[derive(Clone, Debug)]
pub struct Job {
    pub id: JobId,
    pub arrival: Time,
    /// Per-task durations, seconds.
    pub task_durations: Vec<f64>,
    pub is_long: bool,
}

impl Job {
    pub fn num_tasks(&self) -> usize {
        self.task_durations.len()
    }

    /// Total work (sum of task durations), seconds.
    pub fn total_work(&self) -> f64 {
        self.task_durations.iter().sum()
    }

    pub fn mean_duration(&self) -> f64 {
        if self.task_durations.is_empty() {
            0.0
        } else {
            self.total_work() / self.task_durations.len() as f64
        }
    }
}

/// A full workload: jobs sorted by arrival time.
#[derive(Clone, Debug, Default)]
pub struct Workload {
    pub jobs: Vec<Job>,
    /// Short/long classification cutoff (seconds of mean task duration)
    /// used when the workload was built; recorded for reports.
    pub cutoff: f64,
}

impl Workload {
    pub fn new(mut jobs: Vec<Job>, cutoff: f64) -> Self {
        jobs.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        for (i, job) in jobs.iter_mut().enumerate() {
            job.id = JobId(i as u32);
        }
        Workload { jobs, cutoff }
    }

    pub fn num_jobs(&self) -> usize {
        self.jobs.len()
    }

    pub fn num_tasks(&self) -> usize {
        self.jobs.iter().map(Job::num_tasks).sum()
    }

    /// Simulation horizon: last arrival (the run itself continues until
    /// the event queue quiesces).
    pub fn last_arrival(&self) -> Time {
        self.jobs.last().map(|j| j.arrival).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(arrival: f64, durs: &[f64], is_long: bool) -> Job {
        Job { id: JobId(0), arrival, task_durations: durs.to_vec(), is_long }
    }

    #[test]
    fn workload_sorts_and_reindexes() {
        let w = Workload::new(
            vec![job(5.0, &[1.0], false), job(1.0, &[2.0], true), job(3.0, &[3.0], false)],
            90.0,
        );
        let arrivals: Vec<f64> = w.jobs.iter().map(|j| j.arrival).collect();
        assert_eq!(arrivals, vec![1.0, 3.0, 5.0]);
        let ids: Vec<u32> = w.jobs.iter().map(|j| j.id.0).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        assert_eq!(w.last_arrival(), 5.0);
    }

    #[test]
    fn job_work_accounting() {
        let j = job(0.0, &[10.0, 20.0, 30.0], false);
        assert_eq!(j.num_tasks(), 3);
        assert!((j.total_work() - 60.0).abs() < 1e-12);
        assert!((j.mean_duration() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn empty_workload() {
        let w = Workload::new(vec![], 90.0);
        assert_eq!(w.num_jobs(), 0);
        assert_eq!(w.num_tasks(), 0);
        assert_eq!(w.last_arrival(), 0.0);
    }
}
