//! Streaming workloads: the [`ArrivalSource`] pull abstraction and the
//! combinator algebra on top of it.
//!
//! A source yields jobs one at a time, **nondecreasing in arrival time**,
//! so the simulation can pull arrivals lazily into its event queue with a
//! single job of lookahead — peak resident job count is set by cluster
//! load, not trace length. Implementations:
//!
//! * the synthetic generators ([`crate::trace::synth::YahooSource`],
//!   [`crate::trace::synth::GoogleSource`]) — streaming twins of
//!   `yahoo_like` / `google_like`, bit-identical per seed;
//! * the CSV trace replayer ([`crate::trace::CsvStream`]);
//! * eager back-compat adapters ([`WorkloadReplay`], [`VecSource`]).
//!
//! Combinators compose sources declaratively — [`BurstStorm`] (inject
//! rate-multiplied storm windows), [`RateScale`], [`TimeWindow`],
//! [`Splice`] / [`Merge`] of heterogeneous sources, [`Take`] — each
//! deterministic under the forked-RNG scheme: sources own their forked
//! streams, and the driver's arrival stream passed to [`next_job`] is
//! consumed only by combinators that inject randomness (in a fixed pull
//! order), so a fixed seed pins the whole pipeline.
//!
//! Job ids emitted by sources are placeholders; the simulation driver
//! (or [`collect_workload`]) assigns sequential ids in emission order.
//!
//! [`next_job`]: ArrivalSource::next_job

use crate::sim::Rng;
use crate::trace::{Job, Workload};
use crate::util::Time;

/// A pull-based stream of jobs, nondecreasing in arrival time.
///
/// `Send` so a member world (which owns its source) can advance on a
/// federation PDES worker thread; sources are plain data plus forked
/// RNG streams, so the bound costs implementors nothing.
pub trait ArrivalSource: Send {
    /// Pull the next job, or `None` when the trace is exhausted.
    ///
    /// `rng` is the driver-owned arrival stream; replay and synthetic
    /// sources ignore it (they own their forked streams), combinators
    /// that inject randomness draw from it.
    fn next_job(&mut self, rng: &mut Rng) -> Option<Job>;

    /// Short/long classification cutoff (seconds of mean task duration)
    /// this source was built with; recorded on collected workloads.
    fn cutoff(&self) -> f64 {
        90.0
    }
}

/// Drain a source into a job vector (ids are left as emitted).
pub fn collect_jobs(source: &mut dyn ArrivalSource, rng: &mut Rng) -> Vec<Job> {
    let mut jobs = Vec::new();
    while let Some(job) = source.next_job(rng) {
        jobs.push(job);
    }
    jobs
}

/// Drain a source into an eager [`Workload`] (sorted, ids reassigned).
pub fn collect_workload(source: &mut dyn ArrivalSource, rng: &mut Rng) -> Workload {
    let cutoff = source.cutoff();
    Workload::new(collect_jobs(source, rng), cutoff)
}

// ------------------------------------------------- back-compat adapters

/// Streams a borrowed eager [`Workload`] — the back-compat adapter that
/// lets a `&Workload` run through any `ArrivalSource` consumer (e.g. as
/// the base of a combinator stack).
///
/// Each pull clones the job (one allocation + memcpy of its durations).
/// The simulation itself no longer pays that: `World::from_workload`
/// (used by `simulate` / `simulate_with` / `build_world`) replays eager
/// workloads through a borrowed-lookahead fast path that hands jobs to
/// dispatch by reference, bit-identically. This adapter remains for
/// combinator pipelines over eager data.
pub struct WorkloadReplay<'w> {
    workload: &'w Workload,
    next: usize,
}

impl<'w> WorkloadReplay<'w> {
    pub fn new(workload: &'w Workload) -> Self {
        WorkloadReplay { workload, next: 0 }
    }
}

impl ArrivalSource for WorkloadReplay<'_> {
    fn next_job(&mut self, _rng: &mut Rng) -> Option<Job> {
        let job = self.workload.jobs.get(self.next)?.clone();
        self.next += 1;
        Some(job)
    }

    fn cutoff(&self) -> f64 {
        self.workload.cutoff
    }
}

/// Streams an owned job vector (must be sorted by arrival; asserted).
pub struct VecSource {
    jobs: std::vec::IntoIter<Job>,
    cutoff: f64,
}

impl VecSource {
    /// `jobs` must be nondecreasing in arrival time.
    pub fn new(jobs: Vec<Job>, cutoff: f64) -> Self {
        assert!(
            jobs.windows(2).all(|w| w[0].arrival <= w[1].arrival),
            "VecSource jobs must be sorted by arrival"
        );
        VecSource { jobs: jobs.into_iter(), cutoff }
    }
}

impl From<Workload> for VecSource {
    fn from(w: Workload) -> Self {
        let cutoff = w.cutoff;
        VecSource { jobs: w.jobs.into_iter(), cutoff }
    }
}

impl ArrivalSource for VecSource {
    fn next_job(&mut self, _rng: &mut Rng) -> Option<Job> {
        self.jobs.next()
    }

    fn cutoff(&self) -> f64 {
        self.cutoff
    }
}

// -------------------------------------------------------- combinators

/// Inject rate-multiplied storm windows: every job whose arrival falls
/// inside a `[start, end)` window is emitted `intensity` times (copies
/// share the arrival and task durations but are distinct jobs), so the
/// arrival rate inside the window is multiplied by `intensity` while the
/// trace outside is untouched.
///
/// Fractional intensities are resolved probabilistically per job from
/// the driver's arrival stream (e.g. 2.5 → one guaranteed extra copy
/// plus another with probability 0.5), which keeps storms exactly
/// reproducible under a fixed seed.
pub struct BurstStorm<'a> {
    inner: Box<dyn ArrivalSource + 'a>,
    /// `(start, end)` storm windows, seconds.
    windows: Vec<(Time, Time)>,
    intensity: f64,
    /// Copies of the current in-window job still owed.
    pending: Option<(Job, usize)>,
}

impl<'a> BurstStorm<'a> {
    pub fn new(
        inner: Box<dyn ArrivalSource + 'a>,
        windows: Vec<(Time, Time)>,
        intensity: f64,
    ) -> Self {
        assert!(intensity >= 1.0, "storm intensity must be >= 1 (got {intensity})");
        assert!(
            windows.iter().all(|&(s, e)| s.is_finite() && e.is_finite() && s < e),
            "storm windows must be finite with start < end"
        );
        BurstStorm { inner, windows, intensity, pending: None }
    }

    fn in_window(&self, t: Time) -> bool {
        self.windows.iter().any(|&(s, e)| t >= s && t < e)
    }
}

impl ArrivalSource for BurstStorm<'_> {
    fn next_job(&mut self, rng: &mut Rng) -> Option<Job> {
        if let Some((job, left)) = self.pending.take() {
            if left > 1 {
                self.pending = Some((job.clone(), left - 1));
            }
            return Some(job);
        }
        let job = self.inner.next_job(rng)?;
        if self.in_window(job.arrival) {
            let extra_f = self.intensity - 1.0;
            let mut extra = extra_f.floor() as usize;
            let frac = extra_f - extra_f.floor();
            if frac > 0.0 && rng.f64() < frac {
                extra += 1;
            }
            if extra > 0 {
                self.pending = Some((job.clone(), extra));
            }
        }
        Some(job)
    }

    fn cutoff(&self) -> f64 {
        self.inner.cutoff()
    }
}

/// Multiply the arrival rate by `factor` by compressing arrival times
/// (`arrival / factor`); task durations are untouched.
pub struct RateScale<'a> {
    inner: Box<dyn ArrivalSource + 'a>,
    factor: f64,
}

impl<'a> RateScale<'a> {
    pub fn new(inner: Box<dyn ArrivalSource + 'a>, factor: f64) -> Self {
        assert!(factor > 0.0 && factor.is_finite(), "rate factor must be positive");
        RateScale { inner, factor }
    }
}

impl ArrivalSource for RateScale<'_> {
    fn next_job(&mut self, rng: &mut Rng) -> Option<Job> {
        let mut job = self.inner.next_job(rng)?;
        job.arrival /= self.factor;
        Some(job)
    }

    fn cutoff(&self) -> f64 {
        self.inner.cutoff()
    }
}

/// Slice `[start, end)` out of a source and rebase it to t = 0 (jobs
/// before `start` are skipped; the stream ends at the first arrival at
/// or past `end`).
pub struct TimeWindow<'a> {
    inner: Box<dyn ArrivalSource + 'a>,
    start: Time,
    end: Time,
    done: bool,
}

impl<'a> TimeWindow<'a> {
    pub fn new(inner: Box<dyn ArrivalSource + 'a>, start: Time, end: Time) -> Self {
        assert!(start >= 0.0 && start < end, "window must satisfy 0 <= start < end");
        TimeWindow { inner, start, end, done: false }
    }
}

impl ArrivalSource for TimeWindow<'_> {
    fn next_job(&mut self, rng: &mut Rng) -> Option<Job> {
        if self.done {
            return None;
        }
        loop {
            let Some(mut job) = self.inner.next_job(rng) else {
                self.done = true;
                return None;
            };
            if job.arrival < self.start {
                continue;
            }
            if job.arrival >= self.end {
                // Arrivals are nondecreasing: nothing later can qualify.
                self.done = true;
                return None;
            }
            job.arrival -= self.start;
            return Some(job);
        }
    }

    fn cutoff(&self) -> f64 {
        self.inner.cutoff()
    }
}

/// Pass through the first `n` jobs, then end the stream.
pub struct Take<'a> {
    inner: Box<dyn ArrivalSource + 'a>,
    left: usize,
}

impl<'a> Take<'a> {
    pub fn new(inner: Box<dyn ArrivalSource + 'a>, n: usize) -> Self {
        Take { inner, left: n }
    }
}

impl ArrivalSource for Take<'_> {
    fn next_job(&mut self, rng: &mut Rng) -> Option<Job> {
        if self.left == 0 {
            return None;
        }
        self.left -= 1;
        self.inner.next_job(rng)
    }

    fn cutoff(&self) -> f64 {
        self.inner.cutoff()
    }
}

/// Merge two heterogeneous sources by arrival time (ties go to `a`) —
/// e.g. a Yahoo-like interactive stream over a replayed batch trace.
pub struct Merge<'a> {
    a: Box<dyn ArrivalSource + 'a>,
    b: Box<dyn ArrivalSource + 'a>,
    /// One-job lookahead per side; outer `None` = not pulled yet.
    peek_a: Option<Option<Job>>,
    peek_b: Option<Option<Job>>,
}

impl<'a> Merge<'a> {
    pub fn new(a: Box<dyn ArrivalSource + 'a>, b: Box<dyn ArrivalSource + 'a>) -> Self {
        Merge { a, b, peek_a: None, peek_b: None }
    }
}

impl ArrivalSource for Merge<'_> {
    fn next_job(&mut self, rng: &mut Rng) -> Option<Job> {
        if self.peek_a.is_none() {
            self.peek_a = Some(self.a.next_job(rng));
        }
        if self.peek_b.is_none() {
            self.peek_b = Some(self.b.next_job(rng));
        }
        let take_a = match (self.peek_a.as_ref().unwrap(), self.peek_b.as_ref().unwrap()) { // lint: allow(panic-surface): both peeks populated just above
            (Some(ja), Some(jb)) => ja.arrival <= jb.arrival,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (None, None) => return None,
        };
        if take_a {
            self.peek_a.take().unwrap() // lint: allow(panic-surface): the match above proved this side is Some
        } else {
            self.peek_b.take().unwrap() // lint: allow(panic-surface): the match above proved this side is Some
        }
    }

    fn cutoff(&self) -> f64 {
        self.a.cutoff()
    }
}

/// Regime change at time `at`: jobs from `first` with arrival < `at`,
/// then `second`'s trace appended starting at `at` (its arrivals are
/// shifted by `at`) — the Alibaba-style mixed-regime composition.
pub struct Splice<'a> {
    first: Box<dyn ArrivalSource + 'a>,
    second: Box<dyn ArrivalSource + 'a>,
    at: Time,
    in_second: bool,
}

impl<'a> Splice<'a> {
    pub fn new(
        first: Box<dyn ArrivalSource + 'a>,
        second: Box<dyn ArrivalSource + 'a>,
        at: Time,
    ) -> Self {
        assert!(at >= 0.0 && at.is_finite(), "splice point must be finite and >= 0");
        Splice { first, second, at, in_second: false }
    }
}

impl ArrivalSource for Splice<'_> {
    fn next_job(&mut self, rng: &mut Rng) -> Option<Job> {
        if !self.in_second {
            match self.first.next_job(rng) {
                Some(job) if job.arrival < self.at => return Some(job),
                // First regime over (past the splice point or exhausted).
                _ => self.in_second = true,
            }
        }
        let mut job = self.second.next_job(rng)?;
        job.arrival += self.at;
        Some(job)
    }

    fn cutoff(&self) -> f64 {
        self.first.cutoff()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::JobId;

    fn job(arrival: f64) -> Job {
        Job { id: JobId(0), arrival, task_durations: vec![1.0], is_long: false }
    }

    fn jobs_of(src: &mut dyn ArrivalSource, seed: u64) -> Vec<Job> {
        collect_jobs(src, &mut Rng::new(seed))
    }

    fn arrivals_of(src: &mut dyn ArrivalSource, seed: u64) -> Vec<f64> {
        jobs_of(src, seed).iter().map(|j| j.arrival).collect()
    }

    #[test]
    fn workload_replay_streams_in_order() {
        let w = Workload::new(vec![job(3.0), job(1.0), job(2.0)], 90.0);
        let mut src = WorkloadReplay::new(&w);
        assert_eq!(arrivals_of(&mut src, 0), vec![1.0, 2.0, 3.0]);
        assert_eq!(src.cutoff(), 90.0);
    }

    #[test]
    fn vec_source_accepts_sorted_input() {
        let mut ok = VecSource::new(vec![job(1.0), job(1.0), job(2.0)], 90.0);
        assert_eq!(jobs_of(&mut ok, 0).len(), 3);
    }

    #[test]
    #[should_panic(expected = "sorted by arrival")]
    fn vec_source_rejects_unsorted_input() {
        VecSource::new(vec![job(2.0), job(1.0)], 90.0);
    }

    #[test]
    fn burst_storm_multiplies_in_window_only() {
        let base: Vec<Job> = (0..100).map(|i| job(i as f64)).collect();
        let mut storm =
            BurstStorm::new(Box::new(VecSource::new(base, 90.0)), vec![(20.0, 40.0)], 3.0);
        let arrivals = arrivals_of(&mut storm, 1);
        let inside = arrivals.iter().filter(|&&t| (20.0..40.0).contains(&t)).count();
        let outside = arrivals.len() - inside;
        assert_eq!(inside, 20 * 3);
        assert_eq!(outside, 80);
        assert!(arrivals.windows(2).all(|w| w[0] <= w[1]), "storm broke ordering");
    }

    #[test]
    fn burst_storm_fractional_intensity_is_seed_deterministic() {
        let mk = || {
            let base: Vec<Job> = (0..200).map(|i| job(i as f64 * 0.5)).collect();
            BurstStorm::new(Box::new(VecSource::new(base, 90.0)), vec![(10.0, 60.0)], 2.5)
        };
        let a = arrivals_of(&mut mk(), 9);
        let b = arrivals_of(&mut mk(), 9);
        assert_eq!(a, b);
        // Expected count: 100 in-window jobs x 2.5 on average; strictly
        // between the floor (x2) and ceiling (x3) shows the fractional
        // coin actually flipped both ways.
        let inside = a.iter().filter(|&&t| (10.0..60.0).contains(&t)).count();
        assert!((200..300).contains(&inside), "inside={inside}");
    }

    #[test]
    fn rate_scale_compresses_time() {
        let base: Vec<Job> = (0..10).map(|i| job(i as f64 * 10.0)).collect();
        let mut scaled = RateScale::new(Box::new(VecSource::new(base, 90.0)), 2.0);
        let arrivals = arrivals_of(&mut scaled, 0);
        assert_eq!(arrivals[1], 5.0);
        assert_eq!(arrivals[9], 45.0);
    }

    #[test]
    fn time_window_slices_and_rebases() {
        let base: Vec<Job> = (0..100).map(|i| job(i as f64)).collect();
        let mut win = TimeWindow::new(Box::new(VecSource::new(base, 90.0)), 30.0, 50.0);
        let arrivals = arrivals_of(&mut win, 0);
        assert_eq!(arrivals.len(), 20);
        assert_eq!(arrivals[0], 0.0);
        assert_eq!(arrivals[19], 19.0);
    }

    #[test]
    fn take_caps_the_stream() {
        let base: Vec<Job> = (0..100).map(|i| job(i as f64)).collect();
        let mut take = Take::new(Box::new(VecSource::new(base, 90.0)), 7);
        assert_eq!(arrivals_of(&mut take, 0).len(), 7);
    }

    #[test]
    fn merge_interleaves_by_arrival_with_ties_to_a() {
        let a: Vec<Job> = vec![job(1.0), job(4.0), job(6.0)];
        let b: Vec<Job> = vec![job(2.0), job(4.0), job(9.0)];
        let mut m = Merge::new(
            Box::new(VecSource::new(a, 90.0)),
            Box::new(VecSource::new(b, 50.0)),
        );
        assert_eq!(m.cutoff(), 90.0); // first source's cutoff wins
        let arrivals = arrivals_of(&mut m, 0);
        assert_eq!(arrivals, vec![1.0, 2.0, 4.0, 4.0, 6.0, 9.0]);
    }

    #[test]
    fn splice_switches_regime_and_shifts() {
        let a: Vec<Job> = vec![job(1.0), job(2.0), job(50.0)];
        let b: Vec<Job> = vec![job(0.5), job(3.0)];
        let mut s = Splice::new(
            Box::new(VecSource::new(a, 90.0)),
            Box::new(VecSource::new(b, 90.0)),
            10.0,
        );
        // 50.0 >= splice point: dropped, second regime starts shifted.
        assert_eq!(arrivals_of(&mut s, 0), vec![1.0, 2.0, 10.5, 13.0]);
    }

    #[test]
    fn collect_workload_reassigns_ids() {
        let base: Vec<Job> = vec![job(0.0), job(1.0), job(2.0)];
        let mut src = VecSource::new(base, 42.0);
        let w = collect_workload(&mut src, &mut Rng::new(0));
        assert_eq!(w.cutoff, 42.0);
        let ids: Vec<u32> = w.jobs.iter().map(|j| j.id.0).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }
}
