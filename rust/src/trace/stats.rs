//! Trace shape statistics — used by reports and by the calibration tests
//! that check synthetic workloads match the published trace shapes.

use crate::trace::{Job, Workload};

/// Summary statistics of a workload, mirroring the numbers the Hawk/Eagle
/// papers report for their traces.
#[derive(Clone, Debug)]
pub struct TraceStats {
    pub jobs: usize,
    pub tasks: usize,
    pub short_jobs: usize,
    pub long_jobs: usize,
    /// Fraction of jobs that are short.
    pub short_job_frac: f64,
    /// Fraction of total cluster time consumed by long jobs.
    pub long_work_frac: f64,
    pub mean_tasks_per_job: f64,
    pub max_tasks_per_job: usize,
    pub mean_short_duration: f64,
    pub mean_long_duration: f64,
    pub horizon: f64,
    /// Total work / horizon — servers' worth of average demand.
    pub mean_demand_servers: f64,
}

impl TraceStats {
    pub fn of(w: &Workload) -> TraceStats {
        let jobs = w.num_jobs();
        let tasks = w.num_tasks();
        let long_jobs = w.jobs.iter().filter(|j| j.is_long).count();
        let short_jobs = jobs - long_jobs;
        let total_work: f64 = w.jobs.iter().map(Job::total_work).sum();
        let long_work: f64 = w.jobs.iter().filter(|j| j.is_long).map(Job::total_work).sum();
        let short_durs: Vec<f64> = w
            .jobs
            .iter()
            .filter(|j| !j.is_long)
            .flat_map(|j| j.task_durations.iter().copied())
            .collect();
        let long_durs: Vec<f64> = w
            .jobs
            .iter()
            .filter(|j| j.is_long)
            .flat_map(|j| j.task_durations.iter().copied())
            .collect();
        let horizon = w.last_arrival().max(1.0);
        TraceStats {
            jobs,
            tasks,
            short_jobs,
            long_jobs,
            short_job_frac: if jobs == 0 { 0.0 } else { short_jobs as f64 / jobs as f64 },
            long_work_frac: if total_work > 0.0 { long_work / total_work } else { 0.0 },
            mean_tasks_per_job: if jobs == 0 { 0.0 } else { tasks as f64 / jobs as f64 },
            max_tasks_per_job: w.jobs.iter().map(Job::num_tasks).max().unwrap_or(0),
            mean_short_duration: crate::util::mean(&short_durs),
            mean_long_duration: crate::util::mean(&long_durs),
            horizon,
            mean_demand_servers: total_work / horizon,
        }
    }

    /// One-line human-readable summary for reports.
    pub fn summary(&self) -> String {
        format!(
            "{} jobs ({} short / {} long, {:.1}% short), {} tasks \
             (mean {:.1}/job, max {}), short μ={:.1}s long μ={:.0}s, \
             long-work {:.1}%, mean demand {:.0} servers over {:.1}h",
            self.jobs,
            self.short_jobs,
            self.long_jobs,
            100.0 * self.short_job_frac,
            self.tasks,
            self.mean_tasks_per_job,
            self.max_tasks_per_job,
            self.mean_short_duration,
            self.mean_long_duration,
            100.0 * self.long_work_frac,
            self.mean_demand_servers,
            self.horizon / 3600.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Rng;
    use crate::trace::synth::{yahoo_like, YahooLikeParams};

    #[test]
    fn stats_consistency() {
        let mut rng = Rng::new(5);
        let w = yahoo_like(&YahooLikeParams::default(), &mut rng);
        let s = TraceStats::of(&w);
        assert_eq!(s.jobs, s.short_jobs + s.long_jobs);
        assert_eq!(s.tasks, w.num_tasks());
        assert!(s.long_work_frac >= 0.0 && s.long_work_frac <= 1.0);
        assert!(s.mean_demand_servers > 0.0);
        assert!(!s.summary().is_empty());
    }

    #[test]
    fn empty_workload_stats() {
        let s = TraceStats::of(&Workload::default());
        assert_eq!(s.jobs, 0);
        assert_eq!(s.mean_tasks_per_job, 0.0);
    }
}
