//! Synthetic workload generators calibrated to the traces the paper uses.
//!
//! The real Yahoo trace [5,9] and Google cluster trace [23] are not
//! redistributable, so we synthesize workloads that match the *shape
//! statistics* those papers report (see DESIGN.md §3 — substitutions):
//!
//! * **Yahoo-like** (evaluation workload, Figure 3 / Table 1): ~90% short
//!   jobs, long jobs orders of magnitude longer (2% of jobs ≈ 90%+ of
//!   cluster time), heavy-tailed task counts, MMPP-bursty arrivals.
//! * **Google-like** (motivation workload, Figure 1): task counts from 1
//!   to ~50,000 (mean ≈ 35), bursty arrivals over a multi-day horizon.
//!
//! The schedulers only observe (arrival, #tasks, durations, class), so
//! matching those marginals plus burstiness reproduces the queueing
//! behaviour the paper measures.

use crate::sim::Rng;
use crate::trace::{ArrivalSource, Job, Mmpp, MmppStream, Workload};
use crate::util::{
    JobId, Time, RNG_GOOGLE_ARRIVALS, RNG_GOOGLE_SIZES, RNG_YAHOO_LONG_ARRIVALS,
    RNG_YAHOO_LONG_SIZES, RNG_YAHOO_SHORT_ARRIVALS, RNG_YAHOO_SHORT_SIZES,
};

/// Parameters for the Yahoo-like evaluation workload.
///
/// Defaults are calibrated (see EXPERIMENTS.md §Calibration) so that the
/// Eagle baseline on the paper's cluster (4000 servers, 80 short-only)
/// lands in the paper's operating regime: short tasks suffering hundreds
/// of seconds of average queueing delay during long-job bursts.
#[derive(Clone, Debug)]
pub struct YahooLikeParams {
    /// Trace horizon, seconds (paper's Table 1 shows ≥ 12.8 h of activity;
    /// we default to 24 h).
    pub horizon: f64,
    /// Short-job arrival process.
    pub short_arrivals: Mmpp,
    /// Long-job arrival process (long bursts are what drive l_r up).
    pub long_arrivals: Mmpp,
    /// Short job: geometric-ish task count via rounded Pareto.
    pub short_tasks_mean: f64,
    pub short_tasks_alpha: f64,
    pub short_tasks_max: usize,
    /// Short task duration: lognormal (seconds).
    pub short_dur_mu: f64,
    pub short_dur_sigma: f64,
    /// Long job task counts (Pareto tail, capped).
    pub long_tasks_mean: f64,
    pub long_tasks_alpha: f64,
    pub long_tasks_max: usize,
    /// Long task duration: lognormal (seconds).
    pub long_dur_mu: f64,
    pub long_dur_sigma: f64,
    /// Short/long classification cutoff on mean task duration, seconds.
    pub cutoff: f64,
}

impl Default for YahooLikeParams {
    fn default() -> Self {
        YahooLikeParams {
            horizon: 86_400.0,
            // Shorts: steady stream punctuated by sharp interactive
            // bursts (~0.25 jobs/s mean): burst peaks briefly exceed even
            // the transient-enlarged short partition, which is what keeps
            // CloudCoaster's tail honest (Figure 3's CDF crossover).
            short_arrivals: Mmpp {
                calm_rate: 0.15,
                burst_rate: 1.2,
                calm_dwell: 2400.0,
                burst_dwell: 240.0,
            },
            // Longs: the cluster runs hot (Yahoo-style production load) —
            // the "calm" MMPP state here is the *high-occupancy* phase
            // (~70% of the time, general partition saturated, l_r ≳ 0.95)
            // and the "burst" state is the drain dip between batches.
            long_arrivals: Mmpp {
                calm_rate: 0.020,
                burst_rate: 0.008,
                calm_dwell: 21_600.0,
                burst_dwell: 9_000.0,
            },
            short_tasks_mean: 15.0,
            short_tasks_alpha: 1.6,
            short_tasks_max: 400,
            short_dur_mu: 3.2, // exp(3.2 + 0.6^2/2) ≈ 29.4 s mean
            short_dur_sigma: 0.6,
            long_tasks_mean: 120.0,
            long_tasks_alpha: 1.4,
            long_tasks_max: 4000,
            long_dur_mu: 7.4, // exp(7.4 + 0.8^2/2) ≈ 2250 s mean
            long_dur_sigma: 0.8,
            cutoff: 90.0,
        }
    }
}

fn pareto_count(rng: &mut Rng, mean: f64, alpha: f64, max: usize) -> usize {
    // Pareto with scale xm chosen so the (uncapped) mean matches `mean`:
    // E[X] = alpha*xm/(alpha-1) for alpha>1.
    let xm = mean * (alpha - 1.0) / alpha;
    let x = rng.pareto(xm.max(1.0), alpha);
    (x.round() as usize).clamp(1, max)
}

/// Streaming Yahoo-like generator: two class streams (short / long), each
/// an [`MmppStream`] plus an independent size stream, merged by arrival
/// time with ties going to the short class — exactly the order the eager
/// [`yahoo_like`] sort produced, so a fixed-seed streamed trace is
/// bit-identical to its eager twin (pinned by tests below).
///
/// Independent streams per class: tuning the short-job knobs must not
/// reshuffle the long jobs (and vice versa) or calibration thrashes.
pub struct YahooSource {
    params: YahooLikeParams,
    short_arr: MmppStream,
    long_arr: MmppStream,
    short_size: Rng,
    long_size: Rng,
    next_short: Option<Time>,
    next_long: Option<Time>,
}

impl YahooSource {
    /// Fork order off `rng` matches the eager generator exactly
    /// (short arrivals, long arrivals, short sizes, long sizes — see
    /// `util/rng_labels.rs`).
    pub fn new(params: &YahooLikeParams, rng: &mut Rng) -> Self {
        let short_arr_rng = rng.fork(RNG_YAHOO_SHORT_ARRIVALS);
        let long_arr_rng = rng.fork(RNG_YAHOO_LONG_ARRIVALS);
        let short_size = rng.fork(RNG_YAHOO_SHORT_SIZES);
        let long_size = rng.fork(RNG_YAHOO_LONG_SIZES);
        let mut short_arr =
            MmppStream::new(params.short_arrivals.clone(), params.horizon, short_arr_rng);
        let mut long_arr =
            MmppStream::new(params.long_arrivals.clone(), params.horizon, long_arr_rng);
        let next_short = short_arr.next_arrival();
        let next_long = long_arr.next_arrival();
        YahooSource {
            params: params.clone(),
            short_arr,
            long_arr,
            short_size,
            long_size,
            next_short,
            next_long,
        }
    }
}

impl ArrivalSource for YahooSource {
    fn next_job(&mut self, _rng: &mut Rng) -> Option<Job> {
        // Merge the class streams; ties go short-first, matching the
        // stable sort over [shorts..., longs...] in the eager path.
        let take_short = match (self.next_short, self.next_long) {
            (Some(s), Some(l)) => s <= l,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (None, None) => return None,
        };
        let p = &self.params;
        if take_short {
            // lint: allow(panic-surface): the match on (next_short, next_long) above only selects a side whose head is Some
            let t = self.next_short.take().expect("short head checked above");
            self.next_short = self.short_arr.next_arrival();
            let n = pareto_count(
                &mut self.short_size,
                p.short_tasks_mean,
                p.short_tasks_alpha,
                p.short_tasks_max,
            );
            let durs: Vec<f64> = (0..n)
                .map(|_| self.short_size.lognormal(p.short_dur_mu, p.short_dur_sigma))
                .collect();
            Some(Job { id: JobId(0), arrival: t, task_durations: durs, is_long: false })
        } else {
            // lint: allow(panic-surface): the match on (next_short, next_long) above only selects a side whose head is Some
            let t = self.next_long.take().expect("long head checked above");
            self.next_long = self.long_arr.next_arrival();
            let n = pareto_count(
                &mut self.long_size,
                p.long_tasks_mean,
                p.long_tasks_alpha,
                p.long_tasks_max,
            );
            let durs: Vec<f64> = (0..n)
                .map(|_| self.long_size.lognormal(p.long_dur_mu, p.long_dur_sigma))
                .collect();
            Some(Job { id: JobId(0), arrival: t, task_durations: durs, is_long: true })
        }
    }

    fn cutoff(&self) -> f64 {
        self.params.cutoff
    }
}

/// Synthesize the Yahoo-like evaluation workload (eager: drains a
/// [`YahooSource`] into a sorted [`Workload`]).
pub fn yahoo_like(params: &YahooLikeParams, rng: &mut Rng) -> Workload {
    let mut source = YahooSource::new(params, rng);
    // The synthetic source owns its forked streams and never draws from
    // the driver stream, so a throwaway sink is fine here.
    let mut sink = Rng::new(0);
    Workload::new(crate::trace::collect_jobs(&mut source, &mut sink), params.cutoff)
}

/// Parameters for the Google-like motivation workload (Figure 1).
#[derive(Clone, Debug)]
pub struct GoogleLikeParams {
    /// Horizon, seconds. The Google trace spans 29 days; Figure 1 plots
    /// the whole thing. Default: 7 days (enough to show the 6X swing).
    pub horizon: f64,
    pub arrivals: Mmpp,
    /// Task counts: mean ≈ 35, max ≈ 49,960 (paper §2.3).
    pub tasks_alpha: f64,
    pub tasks_max: usize,
    pub dur_mu: f64,
    pub dur_sigma: f64,
}

impl Default for GoogleLikeParams {
    fn default() -> Self {
        GoogleLikeParams {
            horizon: 7.0 * 86_400.0,
            arrivals: Mmpp {
                calm_rate: 0.02,
                burst_rate: 0.15,
                calm_dwell: 14_400.0,
                burst_dwell: 3_600.0,
            },
            tasks_alpha: 1.05, // very heavy tail: mean ~35 with max ~50k
            tasks_max: 49_960,
            dur_mu: 5.0,
            dur_sigma: 1.4,
        }
    }
}

/// Streaming Google-like generator: one MMPP arrival stream plus one
/// size stream (forks `RNG_GOOGLE_ARRIVALS` / `RNG_GOOGLE_SIZES`, as
/// in the eager path). Jobs are
/// classified short / long by mean task duration against the standard
/// 90 s cutoff, as the hybrid schedulers require.
pub struct GoogleSource {
    params: GoogleLikeParams,
    arr: MmppStream,
    size: Rng,
    next_arrival: Option<Time>,
}

impl GoogleSource {
    pub fn new(params: &GoogleLikeParams, rng: &mut Rng) -> Self {
        let arr_rng = rng.fork(RNG_GOOGLE_ARRIVALS);
        let size = rng.fork(RNG_GOOGLE_SIZES);
        let mut arr = MmppStream::new(params.arrivals.clone(), params.horizon, arr_rng);
        let next_arrival = arr.next_arrival();
        GoogleSource { params: params.clone(), arr, size, next_arrival }
    }
}

impl ArrivalSource for GoogleSource {
    fn next_job(&mut self, _rng: &mut Rng) -> Option<Job> {
        let t = self.next_arrival.take()?;
        self.next_arrival = self.arr.next_arrival();
        let p = &self.params;
        // Pareto with alpha near 1 gives the 1..50k spread with mean ~35.
        let n =
            (self.size.pareto(1.0, p.tasks_alpha).round() as usize).clamp(1, p.tasks_max);
        let durs: Vec<f64> =
            (0..n).map(|_| self.size.lognormal(p.dur_mu, p.dur_sigma)).collect();
        let is_long = durs.iter().sum::<f64>() / n as f64 >= 90.0;
        Some(Job { id: JobId(0), arrival: t, task_durations: durs, is_long })
    }
}

/// Synthesize the Google-like workload used for the Figure 1 analysis
/// and the future-work scheduler evaluation (eager: drains a
/// [`GoogleSource`]).
pub fn google_like(params: &GoogleLikeParams, rng: &mut Rng) -> Workload {
    let mut source = GoogleSource::new(params, rng);
    let mut sink = Rng::new(0);
    Workload::new(crate::trace::collect_jobs(&mut source, &mut sink), 90.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn yahoo_like_is_mostly_short_jobs_mostly_long_work() {
        let mut rng = Rng::new(42);
        let w = yahoo_like(&YahooLikeParams::default(), &mut rng);
        assert!(w.num_jobs() > 5_000, "jobs={}", w.num_jobs());
        let shorts = w.jobs.iter().filter(|j| !j.is_long).count();
        let short_frac = shorts as f64 / w.num_jobs() as f64;
        assert!(short_frac > 0.85, "short_frac={short_frac}");
        let long_work: f64 =
            w.jobs.iter().filter(|j| j.is_long).map(Job::total_work).sum();
        let total_work: f64 = w.jobs.iter().map(Job::total_work).sum();
        let long_work_frac = long_work / total_work;
        assert!(long_work_frac > 0.85, "long_work_frac={long_work_frac}");
    }

    #[test]
    fn yahoo_like_deterministic_per_seed() {
        let p = YahooLikeParams::default();
        let w1 = yahoo_like(&p, &mut Rng::new(7));
        let w2 = yahoo_like(&p, &mut Rng::new(7));
        assert_eq!(w1.num_jobs(), w2.num_jobs());
        assert_eq!(w1.num_tasks(), w2.num_tasks());
        for (a, b) in w1.jobs.iter().zip(&w2.jobs) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.task_durations, b.task_durations);
        }
        let w3 = yahoo_like(&p, &mut Rng::new(8));
        assert_ne!(w1.num_tasks(), w3.num_tasks());
    }

    #[test]
    fn yahoo_like_durations_split_by_cutoff() {
        let mut rng = Rng::new(1);
        let w = yahoo_like(&YahooLikeParams::default(), &mut rng);
        let mean_short = crate::util::mean(
            &w.jobs.iter().filter(|j| !j.is_long).map(Job::mean_duration).collect::<Vec<_>>(),
        );
        let mean_long = crate::util::mean(
            &w.jobs.iter().filter(|j| j.is_long).map(Job::mean_duration).collect::<Vec<_>>(),
        );
        // "orders of magnitude different" (§1)
        assert!(mean_long / mean_short > 20.0, "short={mean_short} long={mean_long}");
    }

    #[test]
    fn google_like_task_count_shape() {
        let mut rng = Rng::new(23);
        let w = google_like(&GoogleLikeParams::default(), &mut rng);
        assert!(w.num_jobs() > 1_000);
        let counts: Vec<usize> = w.jobs.iter().map(Job::num_tasks).collect();
        let max = *counts.iter().max().unwrap();
        let mean = counts.iter().sum::<usize>() as f64 / counts.len() as f64;
        assert!(max > 5_000, "max={max}"); // heavy tail reaches thousands
        assert!(mean > 5.0 && mean < 150.0, "mean={mean}");
        assert!(counts.iter().any(|&c| c == 1)); // singletons exist
    }

    #[test]
    fn tasks_have_positive_durations() {
        let mut rng = Rng::new(3);
        let w = yahoo_like(&YahooLikeParams::default(), &mut rng);
        for j in &w.jobs {
            assert!(j.task_durations.iter().all(|&d| d > 0.0));
        }
    }

    /// The streaming source IS the eager generator (the eager fn drains
    /// it), but pin the contract anyway: pulling a fresh source job by
    /// job reproduces the eager workload bit-exactly, in order, without
    /// touching the driver RNG stream.
    #[test]
    fn yahoo_source_streams_eager_workload_bit_exactly() {
        let mut p = YahooLikeParams::default();
        p.horizon = 3000.0;
        let eager = yahoo_like(&p, &mut Rng::new(77));
        let mut src = YahooSource::new(&p, &mut Rng::new(77));
        let mut sink = Rng::new(123);
        let sink_probe = Rng::new(123).next_u64();
        let mut n = 0usize;
        while let Some(job) = src.next_job(&mut sink) {
            let e = &eager.jobs[n];
            assert_eq!(job.arrival.to_bits(), e.arrival.to_bits(), "job {n} arrival");
            assert_eq!(job.task_durations, e.task_durations, "job {n} durations");
            assert_eq!(job.is_long, e.is_long, "job {n} class");
            n += 1;
        }
        assert_eq!(n, eager.num_jobs());
        assert_eq!(sink.next_u64(), sink_probe, "source drew from the driver stream");
    }

    #[test]
    fn yahoo_source_arrivals_nondecreasing() {
        let mut p = YahooLikeParams::default();
        p.horizon = 3000.0;
        let mut src = YahooSource::new(&p, &mut Rng::new(5));
        let mut sink = Rng::new(0);
        let mut last = f64::NEG_INFINITY;
        while let Some(job) = src.next_job(&mut sink) {
            assert!(job.arrival >= last);
            last = job.arrival;
        }
    }

    #[test]
    fn google_source_streams_eager_workload_bit_exactly() {
        let mut p = GoogleLikeParams::default();
        p.horizon = 40_000.0;
        let eager = google_like(&p, &mut Rng::new(23));
        let mut src = GoogleSource::new(&p, &mut Rng::new(23));
        let mut sink = Rng::new(0);
        let mut n = 0usize;
        while let Some(job) = src.next_job(&mut sink) {
            let e = &eager.jobs[n];
            assert_eq!(job.arrival.to_bits(), e.arrival.to_bits());
            assert_eq!(job.task_durations, e.task_durations);
            assert_eq!(job.is_long, e.is_long);
            n += 1;
        }
        assert_eq!(n, eager.num_jobs());
        assert_eq!(src.cutoff(), 90.0);
    }
}
