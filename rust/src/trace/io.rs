//! Trace persistence: a simple CSV format (one row per task) so synthetic
//! workloads can be saved, diffed, and replayed byte-identically, and so
//! users can feed in their own traces.
//!
//! Format (header + rows):
//! ```text
//! job_id,arrival,is_long,duration
//! 0,12.500,0,37.2
//! ```

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::trace::{Job, Workload};
use crate::util::JobId;

/// Write a workload to CSV (one row per task).
pub fn write_csv(w: &Workload, path: &Path) -> Result<()> {
    let file = File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut out = BufWriter::new(file);
    writeln!(out, "job_id,arrival,is_long,duration")?;
    for job in &w.jobs {
        for &d in &job.task_durations {
            // `{}` on f64 prints the shortest representation that parses
            // back to the same bits — traces roundtrip exactly.
            writeln!(out, "{},{},{},{}", job.id.0, job.arrival, job.is_long as u8, d)?;
        }
    }
    Ok(())
}

/// Read a workload from CSV produced by [`write_csv`] (or hand-authored).
pub fn read_csv(path: &Path, cutoff: f64) -> Result<Workload> {
    let file = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let reader = BufReader::new(file);
    let mut lines = reader.lines();
    let header = lines.next().context("empty trace file")??;
    if header.trim() != "job_id,arrival,is_long,duration" {
        bail!("unexpected trace header: {header:?}");
    }
    // job_id -> (arrival, is_long, durations); ids may be interleaved.
    let mut jobs: Vec<Option<Job>> = Vec::new();
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let mut fields = line.split(',');
        let parse_err = || format!("trace line {}: {line:?}", lineno + 2);
        let id: usize = fields.next().context("missing job_id")?.trim().parse().with_context(parse_err)?;
        let arrival: f64 = fields.next().context("missing arrival")?.trim().parse().with_context(parse_err)?;
        let is_long: u8 = fields.next().context("missing is_long")?.trim().parse().with_context(parse_err)?;
        let duration: f64 = fields.next().context("missing duration")?.trim().parse().with_context(parse_err)?;
        if duration <= 0.0 || arrival < 0.0 {
            bail!("trace line {}: non-positive duration or negative arrival", lineno + 2);
        }
        if id >= jobs.len() {
            jobs.resize_with(id + 1, || None);
        }
        let job = jobs[id].get_or_insert_with(|| Job {
            id: JobId(id as u32),
            arrival,
            task_durations: Vec::new(),
            is_long: is_long != 0,
        });
        if (job.arrival - arrival).abs() > 1e-9 {
            bail!("trace line {}: job {id} has inconsistent arrival times", lineno + 2);
        }
        job.task_durations.push(duration);
    }
    let jobs: Vec<Job> = jobs.into_iter().flatten().collect();
    if jobs.is_empty() {
        bail!("trace file {} contains no tasks", path.display());
    }
    Ok(Workload::new(jobs, cutoff))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Rng;
    use crate::trace::synth::{yahoo_like, YahooLikeParams};

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cloudcoaster_test_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_preserves_workload() {
        let mut rng = Rng::new(77);
        let mut params = YahooLikeParams::default();
        params.horizon = 2000.0; // small trace for the test
        let w = yahoo_like(&params, &mut rng);
        let path = tmp("roundtrip.csv");
        write_csv(&w, &path).unwrap();
        let r = read_csv(&path, w.cutoff).unwrap();
        assert_eq!(w.num_jobs(), r.num_jobs());
        assert_eq!(w.num_tasks(), r.num_tasks());
        for (a, b) in w.jobs.iter().zip(&r.jobs) {
            assert!((a.arrival - b.arrival).abs() < 1e-5);
            assert_eq!(a.is_long, b.is_long);
            assert_eq!(a.num_tasks(), b.num_tasks());
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage_header() {
        let path = tmp("badheader.csv");
        std::fs::write(&path, "nope\n1,2,3,4\n").unwrap();
        assert!(read_csv(&path, 90.0).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_negative_duration() {
        let path = tmp("negdur.csv");
        std::fs::write(&path, "job_id,arrival,is_long,duration\n0,1.0,0,-5.0\n").unwrap();
        assert!(read_csv(&path, 90.0).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_missing_file() {
        assert!(read_csv(Path::new("/nonexistent/trace.csv"), 90.0).is_err());
    }
}
