//! Trace persistence: a simple CSV format (one row per task) so synthetic
//! workloads can be saved, diffed, and replayed byte-identically, and so
//! users can feed in their own traces.
//!
//! Format (header + rows):
//! ```text
//! job_id,arrival,is_long,duration
//! 0,12.500,0,37.2
//! ```
//!
//! Float round-trip is **bit-exact**: `{}` on `f64` prints the shortest
//! decimal that parses back to the same bits, so write → read preserves
//! arrival times and durations exactly — the property the streaming
//! replayer's golden-determinism guarantee rests on. Non-finite values
//! (NaN/inf) are rejected on read: they would otherwise slip past the
//! sign checks and poison the event queue.
//!
//! Two readers:
//!
//! * [`read_csv`] — eager, tolerant of rows interleaved across jobs;
//!   materialises a full [`Workload`] (O(trace) memory).
//! * [`CsvStream`] — a streaming [`ArrivalSource`]: O(1) memory replay
//!   for rows grouped by job and sorted by arrival (what [`write_csv`]
//!   emits). The file is validated end-to-end at `open` time, so the
//!   pull path is infallible.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::sim::Rng;
use crate::trace::{ArrivalSource, Job, Workload};
use crate::util::JobId;

const HEADER: &str = "job_id,arrival,is_long,duration";

/// Write a workload to CSV (one row per task).
pub fn write_csv(w: &Workload, path: &Path) -> Result<()> {
    let file = File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut out = BufWriter::new(file);
    writeln!(out, "{HEADER}")?;
    for job in &w.jobs {
        debug_assert!(job.arrival.is_finite());
        for &d in &job.task_durations {
            // `{}` on f64 prints the shortest representation that parses
            // back to the same bits — traces roundtrip exactly.
            writeln!(out, "{},{},{},{}", job.id.0, job.arrival, job.is_long as u8, d)?;
        }
    }
    Ok(())
}

/// One parsed task row.
#[derive(Clone, Copy, Debug)]
struct RawRow {
    id: u64,
    arrival: f64,
    is_long: bool,
    duration: f64,
}

fn parse_row(line: &str, lineno: usize) -> Result<RawRow> {
    let mut fields = line.split(',');
    let parse_err = || format!("trace line {lineno}: {line:?}");
    let id: u64 =
        fields.next().context("missing job_id")?.trim().parse().with_context(parse_err)?;
    let arrival: f64 =
        fields.next().context("missing arrival")?.trim().parse().with_context(parse_err)?;
    let is_long: u8 =
        fields.next().context("missing is_long")?.trim().parse().with_context(parse_err)?;
    let duration: f64 =
        fields.next().context("missing duration")?.trim().parse().with_context(parse_err)?;
    if !arrival.is_finite() || !duration.is_finite() {
        bail!("trace line {lineno}: non-finite arrival or duration");
    }
    if duration <= 0.0 || arrival < 0.0 {
        bail!("trace line {lineno}: non-positive duration or negative arrival");
    }
    Ok(RawRow { id, arrival, is_long: is_long != 0, duration })
}

/// Read a workload from CSV produced by [`write_csv`] (or hand-authored).
/// Rows of one job may be interleaved with other jobs' rows.
pub fn read_csv(path: &Path, cutoff: f64) -> Result<Workload> {
    let file = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let reader = BufReader::new(file);
    let mut lines = reader.lines();
    let header = lines.next().context("empty trace file")??;
    if header.trim() != HEADER {
        bail!("unexpected trace header: {header:?}");
    }
    // job_id -> (arrival, is_long, durations); ids may be interleaved.
    let mut jobs: Vec<Option<Job>> = Vec::new();
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let row = parse_row(&line, lineno + 2)?;
        let id = row.id as usize;
        if id >= jobs.len() {
            jobs.resize_with(id + 1, || None);
        }
        let job = jobs[id].get_or_insert_with(|| Job {
            id: JobId(id as u32),
            arrival: row.arrival,
            task_durations: Vec::new(),
            is_long: row.is_long,
        });
        if (job.arrival - row.arrival).abs() > 1e-9 {
            bail!("trace line {}: job {id} has inconsistent arrival times", lineno + 2);
        }
        job.task_durations.push(row.duration);
    }
    let jobs: Vec<Job> = jobs.into_iter().flatten().collect();
    if jobs.is_empty() {
        bail!("trace file {} contains no tasks", path.display());
    }
    Ok(Workload::new(jobs, cutoff))
}

/// Streaming CSV replayer: an [`ArrivalSource`] that reads one job's rows
/// at a time, so arbitrarily long traces replay in O(1) memory.
///
/// Requires rows grouped by job and nondecreasing in arrival across
/// groups — exactly what [`write_csv`] emits. [`CsvStream::open`] runs a
/// full validation pass (parse every row, check grouping/ordering) before
/// the replay handle is returned, so configuration errors surface at
/// scenario-build time and the streaming pull path never fails. (If the
/// file is mutated between validation and replay, the pull path panics
/// rather than yielding garbage.)
pub struct CsvStream {
    lines: std::io::Lines<BufReader<File>>,
    lineno: usize,
    cutoff: f64,
    lookahead: Option<RawRow>,
    num_jobs: usize,
    num_tasks: usize,
    last_arrival: f64,
    path: PathBuf,
}

impl CsvStream {
    /// Validate `path` end-to-end, then open it for streaming replay.
    pub fn open(path: &Path, cutoff: f64) -> Result<Self> {
        // ---- validation pass: O(1) memory, full parse ----
        let file = File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut lines = BufReader::new(file).lines();
        let header = lines.next().context("empty trace file")??;
        if header.trim() != HEADER {
            bail!("unexpected trace header: {header:?}");
        }
        let mut num_jobs = 0usize;
        let mut num_tasks = 0usize;
        let mut group: Option<RawRow> = None;
        for (lineno, line) in lines.enumerate() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let row = parse_row(&line, lineno + 2)?;
            // `RawRow` is Copy, so the group head can be inspected by
            // value with no borrow held across the reassignment below.
            let new_group = match group {
                Some(g) if g.id == row.id => {
                    if (g.arrival - row.arrival).abs() > 1e-9 {
                        bail!(
                            "trace line {}: job {} has inconsistent arrival times",
                            lineno + 2,
                            row.id
                        );
                    }
                    false
                }
                Some(g) => {
                    // Strictly increasing ids across groups: catches a
                    // job id split into non-adjacent groups (which the
                    // eager reader would merge — a silent divergence)
                    // in O(1) memory. write_csv always satisfies this.
                    if row.id <= g.id {
                        bail!(
                            "trace line {}: streaming replay requires strictly \
                             increasing job ids (job {} after job {}); use the eager \
                             reader (`[workload] csv`) for interleaved traces",
                            lineno + 2,
                            row.id,
                            g.id
                        );
                    }
                    if row.arrival < g.arrival {
                        bail!(
                            "trace line {}: streaming replay requires rows grouped by job \
                             and sorted by arrival (job {} at {} after job {} at {})",
                            lineno + 2,
                            row.id,
                            row.arrival,
                            g.id,
                            g.arrival
                        );
                    }
                    true
                }
                None => true,
            };
            if new_group {
                num_jobs += 1;
                group = Some(row);
            }
            num_tasks += 1;
        }
        if num_tasks == 0 {
            bail!("trace file {} contains no tasks", path.display());
        }

        // ---- reopen for the replay pass ----
        let file = File::open(path).with_context(|| format!("reopen {}", path.display()))?;
        let mut lines = BufReader::new(file).lines();
        let _ = lines.next(); // header, already validated
        Ok(CsvStream {
            lines,
            lineno: 1,
            cutoff,
            lookahead: None,
            num_jobs,
            num_tasks,
            last_arrival: group.map(|g| g.arrival).unwrap_or(0.0),
            path: path.to_path_buf(),
        })
    }

    /// Jobs in the file (counted during validation).
    pub fn num_jobs(&self) -> usize {
        self.num_jobs
    }

    /// Arrival time of the last job in the file — the trace's effective
    /// horizon (recorded during validation; scenario defaults use it to
    /// place storm windows inside the replayed trace).
    pub fn last_arrival(&self) -> f64 {
        self.last_arrival
    }

    /// Tasks (rows) in the file.
    pub fn num_tasks(&self) -> usize {
        self.num_tasks
    }

    fn read_row(&mut self) -> Option<RawRow> {
        loop {
            let line = match self.lines.next()? {
                Ok(l) => l,
                Err(e) => panic!("{}: I/O error mid-replay: {e}", self.path.display()), // lint: allow(panic-surface): replay cannot continue past a torn read; fail loud per LINTS.md
            };
            self.lineno += 1;
            if line.trim().is_empty() {
                continue;
            }
            let lineno = self.lineno;
            return Some(parse_row(&line, lineno).unwrap_or_else(|e| {
                panic!("{}: file changed since validation: {e:#}", self.path.display()) // lint: allow(panic-surface): rows were validated at open; a parse failure here means the file mutated mid-run
            }));
        }
    }
}

impl ArrivalSource for CsvStream {
    fn next_job(&mut self, _rng: &mut Rng) -> Option<Job> {
        let first = match self.lookahead.take() {
            Some(row) => row,
            None => self.read_row()?,
        };
        let mut durs = vec![first.duration];
        loop {
            match self.read_row() {
                Some(row) if row.id == first.id => durs.push(row.duration),
                other => {
                    self.lookahead = other;
                    break;
                }
            }
        }
        Some(Job {
            id: JobId(0),
            arrival: first.arrival,
            task_durations: durs,
            is_long: first.is_long,
        })
    }

    fn cutoff(&self) -> f64 {
        self.cutoff
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::collect_jobs;
    use crate::trace::synth::{yahoo_like, YahooLikeParams};

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cloudcoaster_test_{name}_{}", std::process::id()));
        p
    }

    fn small_workload() -> Workload {
        let mut params = YahooLikeParams::default();
        params.horizon = 2000.0; // small trace for the test
        yahoo_like(&params, &mut Rng::new(77))
    }

    #[test]
    fn roundtrip_preserves_workload_bit_exactly() {
        let w = small_workload();
        let path = tmp("roundtrip.csv");
        write_csv(&w, &path).unwrap();
        let r = read_csv(&path, w.cutoff).unwrap();
        assert_eq!(w.num_jobs(), r.num_jobs());
        assert_eq!(w.num_tasks(), r.num_tasks());
        for (a, b) in w.jobs.iter().zip(&r.jobs) {
            // `{}` printing guarantees bit-exact float round-trips.
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
            assert_eq!(a.is_long, b.is_long);
            assert_eq!(a.task_durations, b.task_durations);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn stream_roundtrip_matches_workload_bit_exactly() {
        let w = small_workload();
        let path = tmp("stream_roundtrip.csv");
        write_csv(&w, &path).unwrap();
        let mut stream = CsvStream::open(&path, w.cutoff).unwrap();
        assert_eq!(stream.num_jobs(), w.num_jobs());
        assert_eq!(stream.num_tasks(), w.num_tasks());
        let jobs = collect_jobs(&mut stream, &mut Rng::new(0));
        assert_eq!(jobs.len(), w.num_jobs());
        for (a, b) in w.jobs.iter().zip(&jobs) {
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
            assert_eq!(a.is_long, b.is_long);
            assert_eq!(a.task_durations, b.task_durations);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage_header() {
        let path = tmp("badheader.csv");
        std::fs::write(&path, "nope\n1,2,3,4\n").unwrap();
        assert!(read_csv(&path, 90.0).is_err());
        assert!(CsvStream::open(&path, 90.0).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_negative_duration() {
        let path = tmp("negdur.csv");
        std::fs::write(&path, "job_id,arrival,is_long,duration\n0,1.0,0,-5.0\n").unwrap();
        assert!(read_csv(&path, 90.0).is_err());
        assert!(CsvStream::open(&path, 90.0).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_non_finite_values() {
        for row in ["0,NaN,0,5.0", "0,1.0,0,NaN", "0,inf,0,5.0", "0,1.0,0,inf"] {
            let path = tmp("nonfinite.csv");
            std::fs::write(&path, format!("job_id,arrival,is_long,duration\n{row}\n"))
                .unwrap();
            assert!(read_csv(&path, 90.0).is_err(), "eager accepted {row:?}");
            assert!(CsvStream::open(&path, 90.0).is_err(), "stream accepted {row:?}");
            std::fs::remove_file(path).ok();
        }
    }

    #[test]
    fn stream_rejects_split_job_groups() {
        // Job 0's rows split around job 1: the eager reader merges them
        // into one 2-task job; the streaming reader must refuse rather
        // than silently emit two 1-task jobs.
        let path = tmp("splitgroup.csv");
        std::fs::write(
            &path,
            "job_id,arrival,is_long,duration\n0,5.0,0,1.0\n1,5.0,0,1.0\n0,5.0,0,2.0\n",
        )
        .unwrap();
        assert!(CsvStream::open(&path, 90.0).is_err());
        assert!(read_csv(&path, 90.0).is_ok());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn stream_records_trace_horizon() {
        let w = small_workload();
        let path = tmp("horizon.csv");
        write_csv(&w, &path).unwrap();
        let stream = CsvStream::open(&path, w.cutoff).unwrap();
        assert_eq!(stream.last_arrival(), w.last_arrival());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn stream_rejects_unsorted_groups() {
        let path = tmp("unsorted.csv");
        std::fs::write(
            &path,
            "job_id,arrival,is_long,duration\n0,5.0,0,1.0\n1,2.0,0,1.0\n",
        )
        .unwrap();
        assert!(CsvStream::open(&path, 90.0).is_err());
        // The eager reader tolerates it (it sorts).
        assert!(read_csv(&path, 90.0).is_ok());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_missing_file() {
        assert!(read_csv(Path::new("/nonexistent/trace.csv"), 90.0).is_err());
        assert!(CsvStream::open(Path::new("/nonexistent/trace.csv"), 90.0).is_err());
    }
}
