//! Workload traces: job/task records, bursty arrival processes, synthetic
//! generators calibrated to the paper's traces, CSV persistence, and
//! shape statistics.

mod io;
mod job;
mod mmpp;
mod stats;
pub mod synth;

pub use io::{read_csv, write_csv};
pub use job::{Job, Workload};
pub use mmpp::Mmpp;
pub use stats::TraceStats;
