//! Workload traces: job/task records, bursty arrival processes, synthetic
//! generators calibrated to the paper's traces, CSV persistence, shape
//! statistics — and the streaming [`ArrivalSource`] layer the simulator
//! pulls from.
//!
//! Two ways to describe a workload:
//!
//! * **Eager**: a [`Workload`] (`Vec<Job>` sorted by arrival) — built by
//!   [`synth::yahoo_like`] / [`synth::google_like`] / [`read_csv`],
//!   persisted with [`write_csv`]. Memory is O(trace).
//! * **Streaming**: an [`ArrivalSource`] pulled one job at a time —
//!   [`synth::YahooSource`] / [`synth::GoogleSource`] (bit-identical per
//!   seed to their eager twins), [`CsvStream`] (replay a trace file in
//!   O(1) memory), or a [`WorkloadReplay`] / [`VecSource`] adapter over
//!   an eager workload. Combinators ([`BurstStorm`], [`RateScale`],
//!   [`TimeWindow`], [`Splice`], [`Merge`], [`Take`]) compose sources
//!   into scenarios; see [`crate::coordinator::scenario`] for the
//!   declarative `[scenario]` registry on top.
//!
//! The eager generators are thin collectors over the streaming ones, so
//! the two paths cannot drift.

mod io;
mod job;
mod mmpp;
mod source;
mod stats;
pub mod synth;

pub use io::{read_csv, write_csv, CsvStream};
pub use job::{Job, Workload};
pub use mmpp::{Mmpp, MmppStream};
pub use source::{
    collect_jobs, collect_workload, ArrivalSource, BurstStorm, Merge, RateScale, Splice,
    Take, TimeWindow, VecSource, WorkloadReplay,
};
pub use stats::TraceStats;
