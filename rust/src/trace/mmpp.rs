//! Markov-modulated Poisson process (MMPP) — the burstiness substrate.
//!
//! The paper's motivation (§2.3, Figure 1) is that job arrivals are
//! *bursty*: phases of calm interleaved with phases where the arrival rate
//! spikes. A 2-state MMPP is the standard minimal model for this: a hidden
//! Markov chain alternates between a CALM and a BURST state, each with its
//! own Poisson arrival rate and exponentially-distributed dwell time.

use crate::sim::Rng;
use crate::util::Time;

/// Two-state Markov-modulated Poisson arrival process.
#[derive(Clone, Debug)]
pub struct Mmpp {
    /// Arrivals per second in the calm state.
    pub calm_rate: f64,
    /// Arrivals per second in the burst state.
    pub burst_rate: f64,
    /// Mean dwell time in the calm state, seconds.
    pub calm_dwell: f64,
    /// Mean dwell time in the burst state, seconds.
    pub burst_dwell: f64,
}

impl Mmpp {
    /// A plain Poisson process (burstiness disabled) at `rate`/s.
    pub fn poisson(rate: f64) -> Self {
        Mmpp { calm_rate: rate, burst_rate: rate, calm_dwell: 1.0, burst_dwell: 1.0 }
    }

    /// Long-run average arrival rate.
    pub fn mean_rate(&self) -> f64 {
        let p_burst = self.burst_dwell / (self.calm_dwell + self.burst_dwell);
        self.calm_rate * (1.0 - p_burst) + self.burst_rate * p_burst
    }

    /// Generate all arrival times in `[0, horizon)`.
    ///
    /// Eager convenience over [`MmppStream`]: drains the stream and
    /// propagates the consumed RNG state back to `rng`, so call sites
    /// that interleave other draws on the same stream are unaffected by
    /// the streaming refactor.
    pub fn arrivals(&self, horizon: Time, rng: &mut Rng) -> Vec<Time> {
        let mut out = Vec::with_capacity((self.mean_rate() * horizon) as usize + 16);
        let mut stream = MmppStream::new(self.clone(), horizon, rng.clone());
        while let Some(t) = stream.next_arrival() {
            out.push(t);
        }
        *rng = stream.into_rng();
        out
    }
}

/// Streaming MMPP arrival generator: the same state machine as
/// [`Mmpp::arrivals`], one arrival per pull, O(1) memory.
///
/// Draw-for-draw identical to the eager generator: pulling the stream to
/// exhaustion consumes exactly the RNG sequence the eager loop consumed,
/// so a fixed-seed streamed trace is bit-identical to its eager twin.
#[derive(Clone, Debug)]
pub struct MmppStream {
    mmpp: Mmpp,
    rng: Rng,
    horizon: Time,
    t: Time,
    in_burst: bool,
    /// Time at which the modulating chain next flips state.
    phase_end: Time,
}

impl MmppStream {
    pub fn new(mmpp: Mmpp, horizon: Time, mut rng: Rng) -> Self {
        let phase_end = rng.exponential(mmpp.calm_dwell);
        MmppStream { mmpp, rng, horizon, t: 0.0, in_burst: false, phase_end }
    }

    /// The next arrival time in `[0, horizon)`, or `None` once the
    /// process has run past the horizon. Nondecreasing across calls.
    pub fn next_arrival(&mut self) -> Option<Time> {
        while self.t < self.horizon {
            let rate = if self.in_burst { self.mmpp.burst_rate } else { self.mmpp.calm_rate };
            let dt =
                if rate > 0.0 { self.rng.exponential(1.0 / rate) } else { f64::INFINITY };
            if self.t + dt < self.phase_end {
                self.t += dt;
                if self.t < self.horizon {
                    return Some(self.t);
                }
            } else {
                // Jump to the phase boundary and flip the modulating state.
                self.t = self.phase_end;
                self.in_burst = !self.in_burst;
                let dwell =
                    if self.in_burst { self.mmpp.burst_dwell } else { self.mmpp.calm_dwell };
                self.phase_end = self.t + self.rng.exponential(dwell);
            }
        }
        None
    }

    /// Recover the RNG (with its consumed state) after draining.
    pub fn into_rng(self) -> Rng {
        self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_rate_matches() {
        let p = Mmpp::poisson(0.5);
        assert!((p.mean_rate() - 0.5).abs() < 1e-12);
        let mut rng = Rng::new(1);
        let arrivals = p.arrivals(100_000.0, &mut rng);
        let rate = arrivals.len() as f64 / 100_000.0;
        assert!((rate - 0.5).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn arrivals_sorted_and_in_horizon() {
        let m = Mmpp { calm_rate: 0.1, burst_rate: 2.0, calm_dwell: 300.0, burst_dwell: 60.0 };
        let mut rng = Rng::new(2);
        let a = m.arrivals(10_000.0, &mut rng);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        assert!(a.iter().all(|&t| (0.0..10_000.0).contains(&t)));
    }

    #[test]
    fn mmpp_mean_rate_between_states() {
        let m = Mmpp { calm_rate: 0.1, burst_rate: 2.0, calm_dwell: 300.0, burst_dwell: 100.0 };
        let mean = m.mean_rate();
        assert!(mean > 0.1 && mean < 2.0);
        let mut rng = Rng::new(3);
        let a = m.arrivals(500_000.0, &mut rng);
        let emp = a.len() as f64 / 500_000.0;
        assert!((emp - mean).abs() / mean < 0.1, "emp={emp} mean={mean}");
    }

    #[test]
    fn bursty_process_has_higher_variance_than_poisson() {
        // Count arrivals in 100 s windows; MMPP should have a higher
        // index of dispersion than a Poisson process of the same mean rate.
        let m = Mmpp { calm_rate: 0.05, burst_rate: 1.0, calm_dwell: 500.0, burst_dwell: 100.0 };
        let p = Mmpp::poisson(m.mean_rate());
        let dispersion = |a: &[f64]| {
            let horizon = 200_000.0;
            let bins = (horizon / 100.0) as usize;
            let mut counts = vec![0.0f64; bins];
            for &t in a {
                counts[(t / 100.0) as usize] += 1.0;
            }
            let mean = counts.iter().sum::<f64>() / bins as f64;
            let var =
                counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>() / bins as f64;
            var / mean
        };
        let mut rng = Rng::new(4);
        let dm = dispersion(&m.arrivals(200_000.0, &mut rng));
        let dp = dispersion(&p.arrivals(200_000.0, &mut rng));
        assert!(dm > 2.0 * dp, "mmpp dispersion {dm} vs poisson {dp}");
    }

    #[test]
    fn zero_rate_produces_no_arrivals() {
        let m = Mmpp::poisson(0.0);
        let mut rng = Rng::new(5);
        assert!(m.arrivals(1000.0, &mut rng).is_empty());
    }

    #[test]
    fn stream_matches_eager_bit_exactly() {
        let m = Mmpp { calm_rate: 0.1, burst_rate: 2.0, calm_dwell: 300.0, burst_dwell: 60.0 };
        let mut eager_rng = Rng::new(21);
        let eager = m.arrivals(20_000.0, &mut eager_rng);
        let mut stream = MmppStream::new(m, 20_000.0, Rng::new(21));
        let mut streamed = Vec::new();
        while let Some(t) = stream.next_arrival() {
            streamed.push(t);
        }
        assert_eq!(eager.len(), streamed.len());
        for (a, b) in eager.iter().zip(&streamed) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Draining consumed the same RNG state on both paths.
        assert_eq!(eager_rng.next_u64(), stream.into_rng().next_u64());
    }

    #[test]
    fn stream_is_exhausted_after_horizon() {
        let mut s = MmppStream::new(Mmpp::poisson(0.5), 100.0, Rng::new(3));
        while s.next_arrival().is_some() {}
        assert!(s.next_arrival().is_none());
    }
}
