//! Markov-modulated Poisson process (MMPP) — the burstiness substrate.
//!
//! The paper's motivation (§2.3, Figure 1) is that job arrivals are
//! *bursty*: phases of calm interleaved with phases where the arrival rate
//! spikes. A 2-state MMPP is the standard minimal model for this: a hidden
//! Markov chain alternates between a CALM and a BURST state, each with its
//! own Poisson arrival rate and exponentially-distributed dwell time.

use crate::sim::Rng;
use crate::util::Time;

/// Two-state Markov-modulated Poisson arrival process.
#[derive(Clone, Debug)]
pub struct Mmpp {
    /// Arrivals per second in the calm state.
    pub calm_rate: f64,
    /// Arrivals per second in the burst state.
    pub burst_rate: f64,
    /// Mean dwell time in the calm state, seconds.
    pub calm_dwell: f64,
    /// Mean dwell time in the burst state, seconds.
    pub burst_dwell: f64,
}

impl Mmpp {
    /// A plain Poisson process (burstiness disabled) at `rate`/s.
    pub fn poisson(rate: f64) -> Self {
        Mmpp { calm_rate: rate, burst_rate: rate, calm_dwell: 1.0, burst_dwell: 1.0 }
    }

    /// Long-run average arrival rate.
    pub fn mean_rate(&self) -> f64 {
        let p_burst = self.burst_dwell / (self.calm_dwell + self.burst_dwell);
        self.calm_rate * (1.0 - p_burst) + self.burst_rate * p_burst
    }

    /// Generate all arrival times in `[0, horizon)`.
    pub fn arrivals(&self, horizon: Time, rng: &mut Rng) -> Vec<Time> {
        let mut out = Vec::with_capacity((self.mean_rate() * horizon) as usize + 16);
        let mut t = 0.0;
        let mut in_burst = false;
        // Time at which the modulating chain next flips state.
        let mut phase_end = rng.exponential(self.calm_dwell);
        while t < horizon {
            let rate = if in_burst { self.burst_rate } else { self.calm_rate };
            let dt = if rate > 0.0 { rng.exponential(1.0 / rate) } else { f64::INFINITY };
            if t + dt < phase_end {
                t += dt;
                if t < horizon {
                    out.push(t);
                }
            } else {
                // Jump to the phase boundary and flip the modulating state.
                t = phase_end;
                in_burst = !in_burst;
                let dwell = if in_burst { self.burst_dwell } else { self.calm_dwell };
                phase_end = t + rng.exponential(dwell);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_rate_matches() {
        let p = Mmpp::poisson(0.5);
        assert!((p.mean_rate() - 0.5).abs() < 1e-12);
        let mut rng = Rng::new(1);
        let arrivals = p.arrivals(100_000.0, &mut rng);
        let rate = arrivals.len() as f64 / 100_000.0;
        assert!((rate - 0.5).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn arrivals_sorted_and_in_horizon() {
        let m = Mmpp { calm_rate: 0.1, burst_rate: 2.0, calm_dwell: 300.0, burst_dwell: 60.0 };
        let mut rng = Rng::new(2);
        let a = m.arrivals(10_000.0, &mut rng);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        assert!(a.iter().all(|&t| (0.0..10_000.0).contains(&t)));
    }

    #[test]
    fn mmpp_mean_rate_between_states() {
        let m = Mmpp { calm_rate: 0.1, burst_rate: 2.0, calm_dwell: 300.0, burst_dwell: 100.0 };
        let mean = m.mean_rate();
        assert!(mean > 0.1 && mean < 2.0);
        let mut rng = Rng::new(3);
        let a = m.arrivals(500_000.0, &mut rng);
        let emp = a.len() as f64 / 500_000.0;
        assert!((emp - mean).abs() / mean < 0.1, "emp={emp} mean={mean}");
    }

    #[test]
    fn bursty_process_has_higher_variance_than_poisson() {
        // Count arrivals in 100 s windows; MMPP should have a higher
        // index of dispersion than a Poisson process of the same mean rate.
        let m = Mmpp { calm_rate: 0.05, burst_rate: 1.0, calm_dwell: 500.0, burst_dwell: 100.0 };
        let p = Mmpp::poisson(m.mean_rate());
        let dispersion = |a: &[f64]| {
            let horizon = 200_000.0;
            let bins = (horizon / 100.0) as usize;
            let mut counts = vec![0.0f64; bins];
            for &t in a {
                counts[(t / 100.0) as usize] += 1.0;
            }
            let mean = counts.iter().sum::<f64>() / bins as f64;
            let var =
                counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>() / bins as f64;
            var / mean
        };
        let mut rng = Rng::new(4);
        let dm = dispersion(&m.arrivals(200_000.0, &mut rng));
        let dp = dispersion(&p.arrivals(200_000.0, &mut rng));
        assert!(dm > 2.0 * dp, "mmpp dispersion {dm} vs poisson {dp}");
    }

    #[test]
    fn zero_rate_produces_no_arrivals() {
        let m = Mmpp::poisson(0.0);
        let mut rng = Rng::new(5);
        assert!(m.arrivals(1000.0, &mut rng).is_empty());
    }
}
