//! Fixed-size argmin segment tree over per-server load estimates.
//!
//! The centralized long-job scheduler places every long task on the
//! least-loaded general-partition server. A linear scan per task is
//! O(N·tasks) (~10^9 ops at paper scale); this tree makes placement
//! O(log N) per task and update O(log N) per load change.

/// Argmin segment tree over `n` f64 keys.
#[derive(Clone, Debug)]
pub struct MinTree {
    n: usize,
    /// tree[i] = index (into 0..n) of the min key in node i's range.
    tree: Vec<u32>,
    keys: Vec<f64>,
}

impl MinTree {
    pub fn new(n: usize) -> Self {
        assert!(n > 0, "empty MinTree");
        let size = n.next_power_of_two();
        let mut t = MinTree { n, tree: vec![0; 2 * size], keys: vec![0.0; size] };
        // Keys beyond n are +inf so they never win argmin.
        for i in n..size {
            t.keys[i] = f64::INFINITY;
        }
        for i in 0..size {
            t.tree[size + i] = i as u32;
        }
        for i in (1..size).rev() {
            t.tree[i] = t.argmin_children(i);
        }
        t
    }

    #[inline]
    fn size(&self) -> usize {
        self.keys.len()
    }

    #[inline]
    fn argmin_children(&self, node: usize) -> u32 {
        let l = self.tree[2 * node];
        let r = self.tree[2 * node + 1];
        if self.keys[l as usize] <= self.keys[r as usize] {
            l
        } else {
            r
        }
    }

    /// Set the key at `idx` and repair the path to the root.
    #[inline]
    pub fn update(&mut self, idx: usize, key: f64) {
        debug_assert!(idx < self.n);
        self.keys[idx] = key;
        // Repair the path to the root, stopping early once a node's
        // winner is unchanged AND is not the changed leaf — from there on
        // every ancestor compares the same (index, key) pairs as before.
        // (Measured: cuts the mean repair from log N to ~1.6 levels on
        // the simulator's workload; see EXPERIMENTS.md §Perf.)
        let mut node = (self.size() + idx) >> 1;
        while node >= 1 {
            let new = self.argmin_children(node);
            if self.tree[node] == new && new as usize != idx {
                return;
            }
            self.tree[node] = new;
            node >>= 1;
        }
    }

    /// Index of the global minimum key.
    #[inline]
    pub fn argmin(&self) -> usize {
        self.tree[1] as usize
    }

    /// The minimum key value.
    pub fn min_key(&self) -> f64 {
        self.keys[self.argmin()]
    }

    pub fn key(&self, idx: usize) -> f64 {
        self.keys[idx]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_argmin_under_updates() {
        let mut t = MinTree::new(10);
        for i in 0..10 {
            t.update(i, (10 - i) as f64);
        }
        assert_eq!(t.argmin(), 9);
        t.update(9, 100.0);
        assert_eq!(t.argmin(), 8);
        t.update(3, 0.5);
        assert_eq!(t.argmin(), 3);
        assert_eq!(t.min_key(), 0.5);
    }

    #[test]
    fn non_power_of_two_sizes() {
        let mut t = MinTree::new(7);
        for i in 0..7 {
            t.update(i, i as f64 + 1.0);
        }
        assert_eq!(t.argmin(), 0);
        t.update(0, 50.0);
        assert_eq!(t.argmin(), 1);
        // Phantom slots (7..8) must never win.
        for i in 0..7 {
            t.update(i, 1e12);
        }
        assert!(t.argmin() < 7);
    }

    #[test]
    fn matches_linear_scan_randomized() {
        let mut rng = crate::sim::Rng::new(99);
        let n = 37;
        let mut t = MinTree::new(n);
        let mut keys = vec![0.0f64; n];
        for step in 0..2000 {
            let i = rng.below(n as u64) as usize;
            let k = rng.f64() * 1000.0;
            t.update(i, k);
            keys[i] = k;
            if step % 10 == 0 {
                let lin = keys
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.total_cmp(b.1))
                    .unwrap()
                    .0;
                assert_eq!(keys[t.argmin()], keys[lin]);
            }
        }
    }

    #[test]
    fn single_element() {
        let mut t = MinTree::new(1);
        t.update(0, 42.0);
        assert_eq!(t.argmin(), 0);
        assert_eq!(t.min_key(), 42.0);
    }
}
