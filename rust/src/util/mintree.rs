//! Fixed-size argmin segment tree over per-server load keys.
//!
//! Least-loaded placement over a pool is the simulator's hottest query:
//! a linear scan per task is O(N·tasks) (~10^9 ops at paper scale); this
//! tree makes placement O(log N) per query and O(log N) per load change.
//! The tree is generic over the key so the cluster's [`PoolIndex`] can
//! keep one tree per pool with pool-appropriate keys: plain `est_work`
//! for the on-demand partitions, lexicographic `(depth, est_work)` for
//! the transient pool's drain-victim query.
//!
//! [`PoolIndex`]: crate::cluster::PoolIndex

/// A key usable in a [`MinTree`]: totally ordered via [`IndexKey::le`]
/// (f64 keys use `total_cmp`, so no NaN surprises), with a "smallest
/// possible" initial value and a "never wins argmin" sentinel.
pub trait IndexKey: Copy + std::fmt::Debug {
    /// Initial key of a live slot (an idle server carries zero load).
    const ZERO: Self;
    /// Sentinel for phantom/tombstoned slots; must compare `>=` every
    /// real key so those slots never win the argmin.
    const MAX_KEY: Self;
    /// Total order; ties resolve to the *left* operand in the tree, so
    /// the global argmin is the lowest-index minimal slot — matching
    /// `Iterator::min_by`'s first-minimal convention.
    fn le(&self, other: &Self) -> bool;
}

impl IndexKey for f64 {
    const ZERO: Self = 0.0;
    const MAX_KEY: Self = f64::INFINITY;

    #[inline]
    fn le(&self, other: &Self) -> bool {
        self.total_cmp(other) != std::cmp::Ordering::Greater
    }
}

/// Lexicographic `(queue depth, est_work)` — the transient manager's
/// "fastest to free" drain-victim key.
impl IndexKey for (u32, f64) {
    const ZERO: Self = (0, 0.0);
    const MAX_KEY: Self = (u32::MAX, f64::INFINITY);

    #[inline]
    fn le(&self, other: &Self) -> bool {
        match self.0.cmp(&other.0) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => self.1.total_cmp(&other.1) != std::cmp::Ordering::Greater,
        }
    }
}

/// Lexicographic `(queue depth, est_work, ready_seq)` — the transient
/// drain-victim key with an explicit activation-order tie-break. The
/// trailing `ready_seq` (unique per transient activation) makes exact
/// key ties impossible, so the argmin is independent of *tree-slot*
/// order — which lets the transient index recycle tree slots while
/// preserving the historical "first-minimal in `TransientReady` order"
/// tie-break bit-exactly.
impl IndexKey for (u32, f64, u64) {
    const ZERO: Self = (0, 0.0, 0);
    const MAX_KEY: Self = (u32::MAX, f64::INFINITY, u64::MAX);

    #[inline]
    fn le(&self, other: &Self) -> bool {
        match self.0.cmp(&other.0) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => match self.1.total_cmp(&other.1) {
                std::cmp::Ordering::Less => true,
                std::cmp::Ordering::Greater => false,
                std::cmp::Ordering::Equal => self.2 <= other.2,
            },
        }
    }
}

/// Argmin segment tree over `n` keys.
#[derive(Clone, Debug)]
pub struct MinTree<K: IndexKey = f64> {
    n: usize,
    /// tree[i] = index (into 0..n) of the min key in node i's range.
    tree: Vec<u32>,
    keys: Vec<K>,
}

impl<K: IndexKey> MinTree<K> {
    pub fn new(n: usize) -> Self {
        assert!(n > 0, "empty MinTree");
        let size = n.next_power_of_two();
        let mut t = MinTree { n, tree: vec![0; 2 * size], keys: vec![K::ZERO; size] };
        // Keys beyond n are the sentinel so they never win argmin.
        for i in n..size {
            t.keys[i] = K::MAX_KEY;
        }
        for i in 0..size {
            t.tree[size + i] = i as u32;
        }
        for i in (1..size).rev() {
            t.tree[i] = t.argmin_children(i);
        }
        t
    }

    /// Number of live slots.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    #[inline]
    fn size(&self) -> usize {
        self.keys.len()
    }

    #[inline]
    fn argmin_children(&self, node: usize) -> u32 {
        let l = self.tree[2 * node];
        let r = self.tree[2 * node + 1];
        if self.keys[l as usize].le(&self.keys[r as usize]) {
            l
        } else {
            r
        }
    }

    /// Set the key at `idx` and repair the path to the root.
    #[inline]
    pub fn update(&mut self, idx: usize, key: K) {
        debug_assert!(idx < self.n);
        self.keys[idx] = key;
        // Repair the path to the root, stopping early once a node's
        // winner is unchanged AND is not the changed leaf — from there on
        // every ancestor compares the same (index, key) pairs as before.
        // (Measured: cuts the mean repair from log N to ~1.6 levels on
        // the simulator's workload; see EXPERIMENTS.md §Perf.)
        let mut node = (self.size() + idx) >> 1;
        while node >= 1 {
            let new = self.argmin_children(node);
            if self.tree[node] == new && new as usize != idx {
                return;
            }
            self.tree[node] = new;
            node >>= 1;
        }
    }

    /// Index of the global minimum key (lowest index on ties).
    #[inline]
    pub fn argmin(&self) -> usize {
        self.tree[1] as usize
    }

    /// The minimum key value.
    pub fn min_key(&self) -> K {
        self.keys[self.argmin()]
    }

    pub fn key(&self, idx: usize) -> K {
        self.keys[idx]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_argmin_under_updates() {
        let mut t: MinTree = MinTree::new(10);
        for i in 0..10 {
            t.update(i, (10 - i) as f64);
        }
        assert_eq!(t.argmin(), 9);
        t.update(9, 100.0);
        assert_eq!(t.argmin(), 8);
        t.update(3, 0.5);
        assert_eq!(t.argmin(), 3);
        assert_eq!(t.min_key(), 0.5);
    }

    #[test]
    fn non_power_of_two_sizes() {
        let mut t: MinTree = MinTree::new(7);
        for i in 0..7 {
            t.update(i, i as f64 + 1.0);
        }
        assert_eq!(t.argmin(), 0);
        t.update(0, 50.0);
        assert_eq!(t.argmin(), 1);
        // Phantom slots (7..8) must never win.
        for i in 0..7 {
            t.update(i, 1e12);
        }
        assert!(t.argmin() < 7);
    }

    #[test]
    fn matches_linear_scan_randomized() {
        let mut rng = crate::sim::Rng::new(99);
        let n = 37;
        let mut t: MinTree = MinTree::new(n);
        let mut keys = vec![0.0f64; n];
        for step in 0..2000 {
            let i = rng.below(n as u64) as usize;
            let k = rng.f64() * 1000.0;
            t.update(i, k);
            keys[i] = k;
            if step % 10 == 0 {
                let lin = keys
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.total_cmp(b.1))
                    .unwrap()
                    .0;
                assert_eq!(keys[t.argmin()], keys[lin]);
            }
        }
    }

    #[test]
    fn single_element() {
        let mut t: MinTree = MinTree::new(1);
        t.update(0, 42.0);
        assert_eq!(t.argmin(), 0);
        assert_eq!(t.min_key(), 42.0);
    }

    #[test]
    fn ties_break_to_lowest_index() {
        // Matches Iterator::min_by's first-minimal convention — placement
        // tie-breaks must be identical to the legacy linear scans.
        let mut t: MinTree = MinTree::new(8);
        for i in 0..8 {
            t.update(i, 5.0);
        }
        assert_eq!(t.argmin(), 0);
        t.update(0, 9.0);
        assert_eq!(t.argmin(), 1);
        t.update(4, 5.0); // still tied with 1,2,3,...
        assert_eq!(t.argmin(), 1);
    }

    #[test]
    fn lexicographic_depth_estwork_keys() {
        let mut t: MinTree<(u32, f64)> = MinTree::new(4);
        t.update(0, (2, 1.0));
        t.update(1, (1, 100.0));
        t.update(2, (1, 50.0));
        t.update(3, (3, 0.0));
        // depth dominates; est_work breaks depth ties.
        assert_eq!(t.argmin(), 2);
        t.update(2, (1, 200.0));
        assert_eq!(t.argmin(), 1);
        t.update(1, <(u32, f64)>::MAX_KEY); // tombstone
        assert_eq!(t.argmin(), 2);
    }

    #[test]
    fn seq_tagged_keys_break_ties_by_activation_order() {
        let mut t: MinTree<(u32, f64, u64)> = MinTree::new(4);
        // Equal (depth, est_work); seq decides — independent of slot
        // order, so reusing tree slots cannot change the winner.
        t.update(0, (0, 0.0, 7));
        t.update(1, (0, 0.0, 3));
        t.update(2, (0, 0.0, 5));
        t.update(3, <(u32, f64, u64)>::MAX_KEY);
        assert_eq!(t.argmin(), 1);
        t.update(1, (1, 0.0, 3)); // deeper queue loses despite lower seq
        assert_eq!(t.argmin(), 2);
        t.update(0, (0, -1.0, 7)); // est_work dominates seq
        assert_eq!(t.argmin(), 0);
    }
}
