//! Small shared utilities: typed ids, total-ordered simulation time, and
//! numeric helpers used across the simulator.

use std::cmp::Ordering;
use std::fmt;

mod mintree;
mod rng_labels;
pub use mintree::{IndexKey, MinTree};
pub use rng_labels::*;

/// Simulation time in seconds since simulation start.
pub type Time = f64;

macro_rules! id_type {
    ($(#[$doc:meta])* $name:ident) => {
        $(#[$doc])*
        #[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
        pub struct $name(pub u32);

        impl $name {
            #[inline]
            pub fn index(self) -> usize {
                self.0 as usize
            }
        }

        impl fmt::Debug for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, concat!(stringify!($name), "({})"), self.0)
            }
        }

        impl fmt::Display for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "{}", self.0)
            }
        }
    };
}

id_type!(
    /// Identifies a job in the workload trace.
    JobId
);

/// A generation-tagged handle into the [`crate::cluster::Cluster`]
/// **server** arena — the server twin of [`TaskRef`], superseding the
/// old raw `ServerId`.
///
/// `slot` indexes the arena; `gen` is the slot's generation at the time
/// the handle was issued. On-demand servers live forever in the arena
/// prefix (generation 0); a *retired transient's* slot is released —
/// and its generation bumped — so any handle that outlives the server
/// (a stale `Revoked`/`RevocationWarning` event, a revoked execution's
/// `TaskFinish`) fails the generation check instead of silently acting
/// on whatever transient reuses the slot.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ServerRef {
    pub slot: u32,
    pub gen: u32,
}

impl ServerRef {
    /// Arena slot as a `usize` index.
    #[inline]
    pub fn index(self) -> usize {
        self.slot as usize
    }

    /// Generation-0 handle — the identity every on-demand server keeps
    /// for the whole run (their slots never recycle), and the first
    /// incarnation of each transient slot.
    #[inline]
    pub fn initial(slot: u32) -> Self {
        ServerRef { slot, gen: 0 }
    }
}

impl fmt::Debug for ServerRef {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ServerRef({}@{})", self.slot, self.gen)
    }
}

impl fmt::Display for ServerRef {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}@{}", self.slot, self.gen)
    }
}

/// A generation-tagged handle into the [`crate::cluster::Cluster`] task
/// arena.
///
/// `slot` indexes the arena; `gen` is the slot's generation at the time
/// the handle was issued. The arena recycles the slot of a finished task
/// once its liveness count (outstanding queue copies + pending
/// `TaskFinish` events) reaches zero, bumping the generation — so any
/// handle that outlives its task (a §3.3 shadow copy, a revoked
/// execution's stale finish event) fails the generation check instead of
/// silently aliasing whatever task reuses the slot.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskRef {
    pub slot: u32,
    pub gen: u32,
}

impl TaskRef {
    /// Arena slot as a `usize` index.
    #[inline]
    pub fn index(self) -> usize {
        self.slot as usize
    }
}

impl fmt::Debug for TaskRef {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "TaskRef({}@{})", self.slot, self.gen)
    }
}

impl fmt::Display for TaskRef {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}@{}", self.slot, self.gen)
    }
}

/// `f64` wrapper with a total order, used as the event-queue key.
///
/// Simulation times are always finite (the engine rejects NaN), so the
/// total order is the natural one.
#[derive(Clone, Copy, PartialEq, Debug)]
pub struct OrderedTime(pub Time);

impl Eq for OrderedTime {}

impl PartialOrd for OrderedTime {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrderedTime {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Ceil-based nearest-rank index for quantile `q` over `n` samples:
/// `rank = clamp(ceil(q·n), 1, n)`, returned as a 0-based index.
///
/// This is the crate-wide quantile convention (pinned by unit tests in
/// `metrics::stats`): q = 0 is the minimum, q = 1 the maximum, and the
/// returned value is always an observed sample — no interpolation, no
/// platform-dependent `.round()` half-away behaviour on exact .5 ranks
/// (e.g. n = 2, q = 0.5 is *defined* to be the lower sample).
#[inline]
pub fn nearest_rank_index(n: usize, q: f64) -> usize {
    debug_assert!(n > 0);
    let rank = (q.clamp(0.0, 1.0) * n as f64).ceil() as usize;
    rank.clamp(1, n) - 1
}

/// Exact percentile via sorting a copy; `q` in [0,1]. Ceil-based
/// nearest-rank (see [`nearest_rank_index`]); 0.0 on empty input.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    v[nearest_rank_index(v.len(), q)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_time_sorts_naturally() {
        let mut v = vec![OrderedTime(3.0), OrderedTime(1.0), OrderedTime(2.0)];
        v.sort();
        assert_eq!(v, vec![OrderedTime(1.0), OrderedTime(2.0), OrderedTime(3.0)]);
    }

    #[test]
    fn ids_are_compact() {
        assert_eq!(std::mem::size_of::<JobId>(), 4);
        assert_eq!(JobId(7).index(), 7);
        assert_eq!(std::mem::size_of::<TaskRef>(), 8);
        assert_eq!(TaskRef { slot: 7, gen: 3 }.index(), 7);
        assert_ne!(TaskRef { slot: 7, gen: 3 }, TaskRef { slot: 7, gen: 4 });
        assert_eq!(std::mem::size_of::<ServerRef>(), 8);
        assert_eq!(ServerRef { slot: 7, gen: 3 }.index(), 7);
        assert_ne!(ServerRef { slot: 7, gen: 3 }, ServerRef { slot: 7, gen: 4 });
        assert_eq!(ServerRef::initial(7), ServerRef { slot: 7, gen: 0 });
    }

    #[test]
    fn mean_and_percentile() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn nearest_rank_is_ceil_based_and_defined_on_half_ranks() {
        // n = 2, q = 0.5 -> ceil(1.0) = rank 1 -> the LOWER sample; the
        // old `(q*(n-1)).round()` formulation hit .5 and depended on
        // round-half-away semantics.
        assert_eq!(nearest_rank_index(2, 0.5), 0);
        // n = 10, q = 0.99 -> ceil(9.9) = rank 10 -> the maximum.
        assert_eq!(nearest_rank_index(10, 0.99), 9);
        // n = 10, q = 0.9 -> ceil(9.0) = rank 9 (not 10).
        assert_eq!(nearest_rank_index(10, 0.9), 8);
        assert_eq!(nearest_rank_index(5, 0.0), 0);
        assert_eq!(nearest_rank_index(5, 1.0), 4);
        assert_eq!(nearest_rank_index(1, 0.37), 0);
        let xs = [1.0, 2.0];
        assert_eq!(percentile(&xs, 0.5), 1.0);
    }
}
