//! The crate-wide RNG fork-label registry.
//!
//! Every deterministic RNG stream in the simulator is forked off a
//! parent with `Rng::fork(label)`; the label, mixed into the child
//! seed, *is* the stream's identity. Two streams forking the same
//! label off the same parent collide; a call site inventing an ad-hoc
//! literal creates a stream nothing audits. This table is therefore
//! the single source of truth: `pallas-lint`'s `rng-label-registry`
//! rule parses it, checks the values are unique, and requires every
//! non-test `fork(..)` call site to name one of these constants.
//!
//! ## Fork order
//!
//! Label uniqueness makes streams independent of fork *order*, but the
//! golden suites pin the canonical wiring order anyway — reordering
//! forks off a shared parent changes which raw draws each child seeds
//! from. The canonical sequence off a world's root RNG is:
//!
//! | # | label | constant | forked by | when |
//! |---|-------|----------|-----------|------|
//! | 1 | `0x5C`  | [`RNG_SCHED`]    | `World::new`           | at construction |
//! | 2 | `0x7A`  | [`RNG_MARKET`]   | `coordinator::runner`  | while wiring components |
//! | 3 | `0xAE`  | [`RNG_ARRIVALS`] | `World::start` (or the federation driver, in its stead) | at start |
//!
//! The synthetic-trace generators fork off the *arrivals* stream they
//! are handed, in declaration order below: Yahoo-like draws `0xA11`,
//! `0xA22`, `0xB22`, `0xB33` (short/long arrival processes, then
//! short/long size streams); Google-like draws `0xC33`, `0xD44`
//! (arrival process, then sizes). Streaming and eager generator paths
//! share these labels so both produce bit-identical workloads.
//!
//! Adding a stream: append a constant with a fresh value, document the
//! forking site in the table above, and use the constant at the call
//! site — `pallas-lint` fails on raw literals and on value collisions.

/// Scheduler decision stream — probe target choices, tie-break jitter.
/// Forked first, in `World::new`.
pub const RNG_SCHED: u64 = 0x5C;

/// Transient-market stream — lease lifetime and readiness draws.
/// Forked by the runner while wiring the transient manager.
pub const RNG_MARKET: u64 = 0x7A;

/// Arrival-feed stream — drives the workload source. Forked in
/// `World::start`, or pre-forked by the federation driver when a
/// shared feed routes jobs across member worlds.
pub const RNG_ARRIVALS: u64 = 0xAE;

/// Yahoo-like generator: short-class MMPP arrival process.
pub const RNG_YAHOO_SHORT_ARRIVALS: u64 = 0xA11;

/// Yahoo-like generator: long-class MMPP arrival process.
pub const RNG_YAHOO_LONG_ARRIVALS: u64 = 0xA22;

/// Yahoo-like generator: short-class task-count/duration sizes.
pub const RNG_YAHOO_SHORT_SIZES: u64 = 0xB22;

/// Yahoo-like generator: long-class task-count/duration sizes.
pub const RNG_YAHOO_LONG_SIZES: u64 = 0xB33;

/// Google-like generator: MMPP arrival process.
pub const RNG_GOOGLE_ARRIVALS: u64 = 0xC33;

/// Google-like generator: task-count/duration sizes.
pub const RNG_GOOGLE_SIZES: u64 = 0xD44;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_are_unique() {
        let all = [
            RNG_SCHED,
            RNG_MARKET,
            RNG_ARRIVALS,
            RNG_YAHOO_SHORT_ARRIVALS,
            RNG_YAHOO_LONG_ARRIVALS,
            RNG_YAHOO_SHORT_SIZES,
            RNG_YAHOO_LONG_SIZES,
            RNG_GOOGLE_ARRIVALS,
            RNG_GOOGLE_SIZES,
        ];
        for (i, a) in all.iter().enumerate() {
            for b in &all[i + 1..] {
                assert_ne!(a, b, "fork label collision");
            }
        }
    }
}
