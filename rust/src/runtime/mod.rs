//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` (L2 JAX graphs wrapping L1 Pallas kernels)
//! and executes them from rust — python never runs on the request path.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO **text** is the interchange format
//! (serialized protos from jax ≥ 0.5 use 64-bit ids that xla_extension
//! 0.5.1 rejects).

mod analytics;
pub mod artifacts;

pub use analytics::{Analytics, AnalyticsEngine, ClusterStateOut, NativeAnalytics};
#[cfg(feature = "xla")]
pub use analytics::XlaAnalytics;
