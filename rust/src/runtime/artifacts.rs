//! Artifact shapes and manifest validation.
//!
//! These constants mirror `python/compile/shapes.py`. The AOT artifacts
//! are lowered with *fixed* shapes; the rust side pads inputs up to them
//! and streams larger workloads in chunks. `validate_manifest` cross-checks
//! the JSON manifest written by `aot.py` against these constants so a
//! shape drift between the two layers fails loudly at load time.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// Max servers per cluster-state snapshot.
pub const SERVERS: usize = 4096;
/// Tasks per interval-count kernel invocation.
pub const TASK_CHUNK: usize = 16384;
/// Time buckets per interval-count invocation.
pub const BUCKETS: usize = 2048;
/// Delay samples per delay-hist invocation.
pub const DELAY_CHUNK: usize = 16384;
/// CDF edges per delay-hist invocation.
pub const EDGES: usize = 512;
/// Padding sentinel for "never counted" entries (mirrors shapes.py).
pub const PAD_SENTINEL: f32 = 1e30;
/// Probe-score weight (mirrors shapes.ALPHA).
pub const ALPHA: f32 = 1.0;
/// l_r forecast window (mirrors shapes.FORECAST_WINDOW).
pub const FORECAST_WINDOW: usize = 128;
/// EWMA gain of the forecast (mirrors shapes.FORECAST_ALPHA).
pub const FORECAST_ALPHA: f32 = 0.1;

/// The artifacts the runtime loads.
pub const ARTIFACT_NAMES: [&str; 4] =
    ["cluster_state", "interval_count", "lr_forecast", "delay_hist"];

/// File name of an artifact.
pub fn artifact_file(name: &str) -> String {
    format!("{name}.hlo.txt")
}

/// Cheap structural validation of `manifest.json` against the constants
/// above (no JSON dependency available — we check the canonical
/// substrings the python side is guaranteed to emit).
pub fn validate_manifest(dir: &Path) -> Result<()> {
    let path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("read {}", path.display()))?;
    for name in ARTIFACT_NAMES {
        if !text.contains(&format!("\"{name}\"")) {
            bail!("manifest missing artifact {name:?}");
        }
    }
    for (label, dim) in [
        ("SERVERS", SERVERS),
        ("TASK_CHUNK", TASK_CHUNK),
        ("BUCKETS", BUCKETS),
        ("EDGES", EDGES),
    ] {
        let needle = format!("[\n          {dim}\n        ]");
        let flat = format!("[{dim}]");
        if !text.contains(&needle) && !text.contains(&flat) && !text.contains(&format!(" {dim}")) {
            bail!("manifest shape mismatch: expected {label}={dim} somewhere in manifest");
        }
    }
    Ok(())
}

/// Pad `data` to `len` with `fill`.
pub fn pad_to(data: &[f32], len: usize, fill: f32) -> Vec<f32> {
    assert!(data.len() <= len, "input {} exceeds artifact capacity {len}", data.len());
    let mut v = Vec::with_capacity(len);
    v.extend_from_slice(data);
    v.resize(len, fill);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_to_extends_with_fill() {
        let v = pad_to(&[1.0, 2.0], 4, 9.0);
        assert_eq!(v, vec![1.0, 2.0, 9.0, 9.0]);
    }

    #[test]
    #[should_panic(expected = "exceeds artifact capacity")]
    fn pad_to_rejects_oversize() {
        pad_to(&[1.0; 10], 5, 0.0);
    }

    #[test]
    fn artifact_files_named() {
        assert_eq!(artifact_file("cluster_state"), "cluster_state.hlo.txt");
    }

    #[test]
    fn validate_manifest_on_real_artifacts_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            validate_manifest(&dir).unwrap();
        }
    }

    #[test]
    fn validate_manifest_rejects_missing() {
        assert!(validate_manifest(Path::new("/nonexistent")).is_err());
    }
}
