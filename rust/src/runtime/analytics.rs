//! The analytics API the coordinator calls on the epoch path, with two
//! interchangeable engines:
//!
//! * `XlaAnalytics` (feature `xla`) — loads the AOT-compiled HLO
//!   artifacts (L2 JAX graphs wrapping the L1 Pallas kernels) and
//!   executes them on the PJRT CPU client. Python is never involved at
//!   runtime. The `xla` crate is not vendored in this offline build, so
//!   the engine is feature-gated; enabling `--features xla` additionally
//!   requires adding the prebuilt `xla` (xla_extension) dependency.
//! * [`NativeAnalytics`] — pure-rust reference implementation of the same
//!   semantics; the default engine, and the equivalence oracle in tests
//!   (`runtime_roundtrip`, gated on the same feature).

use std::path::Path;

use anyhow::Result;

use crate::runtime::artifacts::{ALPHA, FORECAST_ALPHA, FORECAST_WINDOW, PAD_SENTINEL};
#[cfg(feature = "xla")]
use crate::runtime::artifacts::{
    artifact_file, pad_to, validate_manifest, ARTIFACT_NAMES, BUCKETS, DELAY_CHUNK, EDGES,
    SERVERS, TASK_CHUNK,
};
#[cfg(feature = "xla")]
use anyhow::Context;
#[cfg(feature = "xla")]
use std::collections::HashMap;

/// Outputs of the cluster-state pass.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterStateOut {
    /// Per-server probe score (estimated wait; PAD_SENTINEL for padding).
    pub scores: Vec<f32>,
    /// [n_long_servers, total_backlog, total_queued, n_active].
    pub stats: [f32; 4],
    /// The long-load ratio l_r.
    pub l_r: f32,
}

/// Engine-agnostic analytics interface. `Send` so per-run engines can
/// move into worker threads for parallel sweeps.
pub trait Analytics: Send {
    /// One fused pass over the (padded) server vectors.
    fn cluster_state(
        &mut self,
        remaining_work: &[f32],
        long_counts: &[f32],
        queue_len: &[f32],
        active: &[f32],
    ) -> Result<ClusterStateOut>;

    /// Figure 1: concurrent tasks at each sample point. Streams task
    /// chunks; `starts.len() == ends.len()` arbitrary, `times.len()` must
    /// be <= BUCKETS.
    fn concurrency(&mut self, starts: &[f32], ends: &[f32], times: &[f32]) -> Result<Vec<f32>>;

    /// Figure 3: cumulative counts + CDF of `delays` at `edges`
    /// (`edges.len() <= EDGES`).
    fn delay_cdf(&mut self, delays: &[f32], edges: &[f32]) -> Result<(Vec<f32>, Vec<f32>)>;

    /// Predictive resizing: Holt level+trend forecast of l_r,
    /// `horizon_steps` snapshot intervals ahead. `history` must hold
    /// exactly [`FORECAST_WINDOW`] samples, oldest first.
    /// Returns `(forecast, level, slope)`.
    fn lr_forecast(&mut self, history: &[f32], horizon_steps: f32) -> Result<(f32, f32, f32)>;

    fn name(&self) -> &'static str;
}

// ------------------------------------------------------------------ native

/// Pure-rust reference engine (same semantics as kernels/ref.py).
#[derive(Default)]
pub struct NativeAnalytics;

impl Analytics for NativeAnalytics {
    fn cluster_state(
        &mut self,
        remaining_work: &[f32],
        long_counts: &[f32],
        queue_len: &[f32],
        active: &[f32],
    ) -> Result<ClusterStateOut> {
        let n = remaining_work.len();
        anyhow::ensure!(long_counts.len() == n && queue_len.len() == n && active.len() == n);
        let mut scores = Vec::with_capacity(n);
        let mut stats = [0f32; 4];
        for i in 0..n {
            let act = active[i] > 0.0;
            scores.push(if act { remaining_work[i] + ALPHA * queue_len[i] } else { PAD_SENTINEL });
            if act {
                if long_counts[i] > 0.0 {
                    stats[0] += 1.0;
                }
                stats[1] += remaining_work[i];
                stats[2] += queue_len[i];
                stats[3] += 1.0;
            }
        }
        let l_r = stats[0] / stats[3].max(1.0);
        Ok(ClusterStateOut { scores, stats, l_r })
    }

    fn concurrency(&mut self, starts: &[f32], ends: &[f32], times: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(starts.len() == ends.len());
        let mut counts = vec![0f32; times.len()];
        for (j, &t) in times.iter().enumerate() {
            let mut c = 0f32;
            for i in 0..starts.len() {
                if starts[i] <= t && ends[i] > t {
                    c += 1.0;
                }
            }
            counts[j] = c;
        }
        Ok(counts)
    }

    fn delay_cdf(&mut self, delays: &[f32], edges: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let n = delays.len().max(1) as f32;
        let counts: Vec<f32> = edges
            .iter()
            .map(|&e| delays.iter().filter(|&&d| d <= e).count() as f32)
            .collect();
        let cdf = counts.iter().map(|&c| c / n).collect();
        Ok((counts, cdf))
    }

    fn lr_forecast(&mut self, history: &[f32], horizon_steps: f32) -> Result<(f32, f32, f32)> {
        anyhow::ensure!(history.len() == FORECAST_WINDOW, "history must be FORECAST_WINDOW");
        let w = history.len();
        let mut wsum = 0.0f64;
        let mut level = 0.0f64;
        let mut kbar = 0.0f64;
        for (k, &x) in history.iter().enumerate() {
            let weight = (1.0 - FORECAST_ALPHA as f64).powi((w - 1 - k) as i32);
            wsum += weight;
            level += weight * x as f64;
            kbar += weight * k as f64;
        }
        level /= wsum;
        kbar /= wsum;
        let (mut var, mut cov) = (0.0f64, 0.0f64);
        for (k, &x) in history.iter().enumerate() {
            let weight = (1.0 - FORECAST_ALPHA as f64).powi((w - 1 - k) as i32);
            var += weight * (k as f64 - kbar) * (k as f64 - kbar);
            cov += weight * (k as f64 - kbar) * (x as f64 - level);
        }
        let slope = cov / var.max(1e-9);
        let forecast = (level + slope * (horizon_steps as f64 + (w - 1) as f64 - kbar))
            .clamp(0.0, 1.0);
        Ok((forecast as f32, level as f32, slope as f32))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

// --------------------------------------------------------------------- xla

/// PJRT-backed engine executing the AOT artifacts.
#[cfg(feature = "xla")]
pub struct XlaAnalytics {
    client: xla::PjRtClient,
    executables: HashMap<&'static str, xla::PjRtLoadedExecutable>, // lint: allow(unordered-iter): keyed by artifact name (insert/get only), never iterated
}

#[cfg(feature = "xla")]
impl XlaAnalytics {
    /// Load and compile all artifacts from `dir` (e.g. `artifacts/`).
    pub fn load(dir: &Path) -> Result<Self> {
        validate_manifest(dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut executables = HashMap::new(); // lint: allow(unordered-iter): construction of the keyed-access-only artifact map
        for name in ARTIFACT_NAMES {
            let path = dir.join(artifact_file(name));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compile {name}"))?;
            executables.insert(name, exe);
        }
        Ok(XlaAnalytics { client, executables })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn execute(&self, name: &'static str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executables.get(name).context("unknown artifact")?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // Lowered with return_tuple=True: always a tuple, even for 1 output.
        Ok(result.to_tuple()?)
    }
}

#[cfg(feature = "xla")]
fn lit(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

#[cfg(feature = "xla")]
impl Analytics for XlaAnalytics {
    fn cluster_state(
        &mut self,
        remaining_work: &[f32],
        long_counts: &[f32],
        queue_len: &[f32],
        active: &[f32],
    ) -> Result<ClusterStateOut> {
        let n = remaining_work.len();
        anyhow::ensure!(n <= SERVERS, "cluster exceeds artifact capacity");
        let rw = pad_to(remaining_work, SERVERS, 0.0);
        let lc = pad_to(long_counts, SERVERS, 0.0);
        let ql = pad_to(queue_len, SERVERS, 0.0);
        let act = pad_to(active, SERVERS, 0.0);
        let outs =
            self.execute("cluster_state", &[lit(&rw), lit(&lc), lit(&ql), lit(&act)])?;
        anyhow::ensure!(outs.len() == 3, "cluster_state arity");
        let mut scores = outs[0].to_vec::<f32>()?;
        scores.truncate(n);
        let stats_v = outs[1].to_vec::<f32>()?;
        let l_r = outs[2].to_vec::<f32>()?[0];
        Ok(ClusterStateOut {
            scores,
            stats: [stats_v[0], stats_v[1], stats_v[2], stats_v[3]],
            l_r,
        })
    }

    fn concurrency(&mut self, starts: &[f32], ends: &[f32], times: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(starts.len() == ends.len());
        anyhow::ensure!(times.len() <= BUCKETS, "too many sample points");
        let t = pad_to(times, BUCKETS, PAD_SENTINEL * 0.5); // finite, beyond all tasks
        let mut acc = vec![0f32; BUCKETS];
        // Stream tasks through the fixed-shape kernel in chunks; partial
        // counts add exactly (verified against ref in python tests).
        for chunk in 0..starts.len().div_ceil(TASK_CHUNK).max(1) {
            let lo = chunk * TASK_CHUNK;
            let hi = (lo + TASK_CHUNK).min(starts.len());
            let s = pad_to(&starts[lo..hi], TASK_CHUNK, PAD_SENTINEL);
            let e = pad_to(&ends[lo..hi], TASK_CHUNK, PAD_SENTINEL);
            let outs = self.execute("interval_count", &[lit(&s), lit(&e), lit(&t)])?;
            let counts = outs[0].to_vec::<f32>()?;
            for (a, c) in acc.iter_mut().zip(&counts) {
                *a += c;
            }
        }
        acc.truncate(times.len());
        Ok(acc)
    }

    fn delay_cdf(&mut self, delays: &[f32], edges: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(edges.len() <= EDGES, "too many edges");
        let e = pad_to(edges, EDGES, PAD_SENTINEL * 0.5);
        let n_valid = delays.len().max(1) as f32;
        let mut counts_acc = vec![0f32; EDGES];
        for chunk in 0..delays.len().div_ceil(DELAY_CHUNK).max(1) {
            let lo = chunk * DELAY_CHUNK;
            let hi = (lo + DELAY_CHUNK).min(delays.len());
            let d = pad_to(&delays[lo..hi], DELAY_CHUNK, PAD_SENTINEL);
            // n_valid is only used for the in-graph CDF normalisation of a
            // single chunk; we re-normalise after accumulation.
            let outs =
                self.execute("delay_hist", &[lit(&d), lit(&e), lit(&[n_valid])])?;
            let counts = outs[0].to_vec::<f32>()?;
            for (a, c) in counts_acc.iter_mut().zip(&counts) {
                *a += c;
            }
        }
        counts_acc.truncate(edges.len());
        let cdf = counts_acc.iter().map(|&c| c / n_valid).collect();
        Ok((counts_acc, cdf))
    }

    fn lr_forecast(&mut self, history: &[f32], horizon_steps: f32) -> Result<(f32, f32, f32)> {
        anyhow::ensure!(history.len() == FORECAST_WINDOW, "history must be FORECAST_WINDOW");
        let outs = self.execute("lr_forecast", &[lit(history), lit(&[horizon_steps])])?;
        let v = outs[0].to_vec::<f32>()?;
        Ok((v[0], v[1], v[2]))
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

// ---------------------------------------------------------------- dispatch

/// Engine selection: XLA when built with the `xla` feature and the
/// artifacts are present, else native.
pub enum AnalyticsEngine {
    #[cfg(feature = "xla")]
    Xla(XlaAnalytics),
    Native(NativeAnalytics),
}

impl AnalyticsEngine {
    /// Load XLA artifacts from `dir` if possible, else fall back to the
    /// native engine (silently when `dir` simply doesn't exist).
    pub fn auto(dir: &Path) -> AnalyticsEngine {
        #[cfg(feature = "xla")]
        {
            match XlaAnalytics::load(dir) {
                Ok(x) => return AnalyticsEngine::Xla(x),
                Err(err) => {
                    if dir.exists() {
                        eprintln!("falling back to native analytics: {err:#}");
                    }
                }
            }
        }
        #[cfg(not(feature = "xla"))]
        if dir.exists() {
            eprintln!(
                "artifacts present at {} but built without the `xla` feature; \
                 using native analytics",
                dir.display()
            );
        }
        AnalyticsEngine::Native(NativeAnalytics)
    }

    pub fn as_dyn(&mut self) -> &mut dyn Analytics {
        match self {
            #[cfg(feature = "xla")]
            AnalyticsEngine::Xla(x) => x,
            AnalyticsEngine::Native(n) => n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_cluster_state_semantics() {
        let mut eng = NativeAnalytics;
        let out = eng
            .cluster_state(
                &[10.0, 0.0, 5.0, 7.0],
                &[1.0, 0.0, 2.0, 0.0],
                &[2.0, 0.0, 1.0, 0.0],
                &[1.0, 1.0, 1.0, 0.0],
            )
            .unwrap();
        assert_eq!(out.stats[0], 2.0); // two active long servers
        assert_eq!(out.stats[3], 3.0); // three active
        assert!((out.l_r - 2.0 / 3.0).abs() < 1e-6);
        assert_eq!(out.scores[3], PAD_SENTINEL); // inactive
        assert!((out.scores[0] - 12.0).abs() < 1e-6);
    }

    #[test]
    fn native_concurrency_boundaries() {
        let mut eng = NativeAnalytics;
        let counts =
            eng.concurrency(&[10.0], &[20.0], &[9.0, 10.0, 15.0, 20.0, 25.0]).unwrap();
        assert_eq!(counts, vec![0.0, 1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn native_delay_cdf_normalises() {
        let mut eng = NativeAnalytics;
        let (counts, cdf) =
            eng.delay_cdf(&[1.0, 2.0, 3.0, 4.0], &[0.0, 2.0, 4.0]).unwrap();
        assert_eq!(counts, vec![0.0, 2.0, 4.0]);
        assert_eq!(cdf, vec![0.0, 0.5, 1.0]);
    }

    #[test]
    fn native_forecast_constant_series() {
        let mut eng = NativeAnalytics;
        let hist = vec![0.6f32; FORECAST_WINDOW];
        let (f, l, s) = eng.lr_forecast(&hist, 10.0).unwrap();
        assert!((f - 0.6).abs() < 1e-5);
        assert!((l - 0.6).abs() < 1e-5);
        assert!(s.abs() < 1e-6);
    }

    #[test]
    fn native_forecast_rejects_wrong_window() {
        let mut eng = NativeAnalytics;
        assert!(eng.lr_forecast(&[0.5; 10], 1.0).is_err());
    }

    #[test]
    fn auto_falls_back_without_artifacts() {
        let mut eng = AnalyticsEngine::auto(Path::new("/nonexistent"));
        assert_eq!(eng.as_dyn().name(), "native");
    }
}
