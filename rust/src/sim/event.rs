//! Simulation events.
//!
//! Every state change in the simulated cluster is driven by one of these
//! events popping off the [`crate::sim::Engine`] queue. Ordering is by
//! time, then by insertion sequence number — so same-timestamp events are
//! processed in the order they were scheduled, which keeps runs bitwise
//! deterministic. That `(time, seq)` order is representation-
//! independent: the calendar queue, the reference heap, and the batch
//! path (`Engine::pop_batch` draining a whole equal-time run at once)
//! all dispatch the identical per-event sequence.

use crate::util::{JobId, ServerRef, TaskRef};

/// A discrete event in the cluster simulation.
///
/// Every server-addressed event carries a generation-tagged
/// [`ServerRef`]: the server arena recycles retired transient slots, so
/// an event that outlives its server (a `Revoked` racing a drain, a
/// warning for an already-retired lease) fails the generation check at
/// pop and is skipped — it can never act on the slot's next tenant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// A job from the trace arrives at the scheduler front-end.
    JobArrival(JobId),
    /// The task currently running on `server` completes. Carries a
    /// generation-tagged [`TaskRef`]: the scheduled event holds a
    /// liveness ref on the arena slot, and a revocation that kills the
    /// execution leaves this event to resolve as stale at pop — it can
    /// never alias a recycled slot.
    TaskFinish { server: ServerRef, task: TaskRef },
    /// A requested transient server finishes provisioning and joins the
    /// dynamic short partition (paper: 120 s provisioning delay).
    TransientReady(ServerRef),
    /// The cloud provider signals an upcoming revocation (e.g. the 30 s
    /// spot warning); the server stops accepting new tasks.
    RevocationWarning(ServerRef),
    /// The transient server is revoked: its queue is lost; running and
    /// queued tasks survive only through their on-demand copies (§3.3).
    Revoked(ServerRef),
    /// A draining transient server has emptied its queue and shuts down.
    DrainComplete(ServerRef),
    /// Periodic metrics snapshot (timeseries of l_r, active transients,
    /// cost accounting) and the epoch hook for the XLA analytics path.
    Snapshot,
}

impl Event {
    /// Number of event classes (the profiler's counter-array size).
    pub const N_KINDS: usize = 7;

    /// Every kind label, in [`Event::kind_index`] order.
    pub const KINDS: [&'static str; Event::N_KINDS] = [
        "job_arrival",
        "task_finish",
        "transient_ready",
        "revocation_warning",
        "revoked",
        "drain_complete",
        "snapshot",
    ];

    /// Coarse event-class label used by the engine's trace hook and the
    /// profiling counters.
    pub fn kind(&self) -> &'static str {
        Event::KINDS[self.kind_index()]
    }

    /// Dense index of this event's class into [`Event::KINDS`] — the
    /// profiler counts into a fixed array instead of hashing labels.
    pub fn kind_index(&self) -> usize {
        match self {
            Event::JobArrival(_) => 0,
            Event::TaskFinish { .. } => 1,
            Event::TransientReady(_) => 2,
            Event::RevocationWarning(_) => 3,
            Event::Revoked(_) => 4,
            Event::DrainComplete(_) => 5,
            Event::Snapshot => 6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_are_distinct() {
        let kinds = [
            Event::JobArrival(JobId(0)).kind(),
            Event::TaskFinish { server: ServerRef::initial(0), task: TaskRef { slot: 0, gen: 0 } }.kind(),
            Event::TransientReady(ServerRef::initial(0)).kind(),
            Event::RevocationWarning(ServerRef::initial(0)).kind(),
            Event::Revoked(ServerRef::initial(0)).kind(),
            Event::DrainComplete(ServerRef::initial(0)).kind(),
            Event::Snapshot.kind(),
        ];
        let mut sorted = kinds.to_vec();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), kinds.len());
    }
}
