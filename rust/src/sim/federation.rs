//! Multi-cluster federation: N independent [`World`]s advanced in
//! **global event-time order**, with a pluggable [`JobRouter`] front end
//! dispatching arrivals across clusters and an optional
//! [`SharedBudget`] coupling their transient fleets.
//!
//! The paper evaluates CloudCoaster on one statically-provisioned
//! cluster; its elasticity argument is strongest when bursts are *not*
//! uniform across clusters (the co-located-workload regime production
//! trace studies report). A `Federation` makes that testable: each
//! member world owns its own cluster, scenario-resolved arrival
//! pipeline, recorder and RNG streams forked off its own seed — so each
//! member is bit-identical to the same world run standalone — while the
//! federation interleaves their event loops by earliest next event and,
//! optionally, lets the members draw transient leases from one pooled
//! budget, so one cluster's quiet period frees headroom for another's
//! burst.
//!
//! Two feed topologies:
//!
//! * **Pass-through** ([`Federation::passthrough`]): every member pulls
//!   from its own source exactly as a standalone [`World::run`] would.
//!   The federation only interleaves `step()`s (and reconciles the
//!   shared budget between them). An N = 1 pass-through federation is
//!   therefore *bit-identical* to the plain world — pinned by
//!   `tests/federation_golden.rs`.
//! * **Routed** ([`Federation::routed`]): the federation owns the
//!   per-cluster sources, merges them into one global arrival stream
//!   (earliest arrival first, ties to the lowest source index), and
//!   asks the [`JobRouter`] — which sees every member's queue state at
//!   the routing instant — where each job executes. Members run on
//!   inbox feeds ([`World::new_inbox`]); a routed arrival is injected
//!   when global time reaches it, so router decisions are a
//!   deterministic function of (sources, seeds, router), independent of
//!   host threading.
//!
//! Determinism: the merge is a strict order on `(time, member index)`,
//! arrivals route before equal-time member events, and every RNG stream
//! forks off per-member config seeds — a federated run is bit-identical
//! across repeats and sweep thread counts.
//!
//! The earliest-next-event merge keys on [`World::next_event_time`]
//! (the engine's O(1) `peek_time` — on the calendar queue the head is
//! restored eagerly after every mutation precisely so this stays a
//! `&self` constant-time read), and members advance via the
//! single-event [`World::step`], never the batch path: routed arrivals
//! must interleave *between* same-timestamp events exactly as the
//! per-event merge dictates. A standalone `World::run` uses batch
//! dispatch, which produces the identical event order — the N = 1
//! pass-through golden pins stepped-vs-batched equivalence end to end.

use crate::sim::{Rng, World};
use crate::trace::{ArrivalSource, Job};
use crate::transient::SharedBudget;
use crate::util::Time;

/// A router's read-only view of one member cluster at a routing instant.
#[derive(Clone, Copy, Debug)]
pub struct MemberView {
    /// Member index (the routing target space).
    pub index: usize,
    /// Tasks materialised but not yet finished on this member.
    pub outstanding_tasks: u64,
    /// Jobs resident (arrived, not fully finished).
    pub resident_jobs: usize,
}

/// Decides which member cluster executes an arriving job.
///
/// `origin` is the index of the per-cluster source that produced the
/// job (the pass-through identity); `members` is indexed by routing
/// target. Implementations must be deterministic functions of their own
/// state and the arguments — no wall clock, no global RNG.
pub trait JobRouter {
    fn name(&self) -> &'static str;
    fn route(&mut self, job: &Job, origin: usize, members: &[MemberView]) -> usize;
}

/// Round-robin over members, ignoring origin and load.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl JobRouter for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&mut self, _job: &Job, _origin: usize, members: &[MemberView]) -> usize {
        let t = self.next % members.len();
        self.next = (self.next + 1) % members.len();
        t
    }
}

/// Least-queued: the member with the fewest outstanding tasks (ties to
/// the lowest index) — the classic join-the-shortest-queue front end.
#[derive(Debug, Default)]
pub struct LeastQueued;

impl JobRouter for LeastQueued {
    fn name(&self) -> &'static str {
        "least-queued"
    }

    fn route(&mut self, _job: &Job, _origin: usize, members: &[MemberView]) -> usize {
        let mut best = 0usize;
        for m in members {
            if m.outstanding_tasks < members[best].outstanding_tasks {
                best = m.index;
            }
        }
        best
    }
}

/// Class-aware short/long split: long jobs round-robin over the first
/// half of the members, short jobs over the second half, so long-job
/// bursts never occupy the short-serving clusters (the federation-level
/// analogue of the paper's short-only partition). With a single member
/// both halves collapse to it.
#[derive(Debug, Default)]
pub struct ClassSplit {
    next_long: usize,
    next_short: usize,
}

impl JobRouter for ClassSplit {
    fn name(&self) -> &'static str {
        "class-split"
    }

    fn route(&mut self, job: &Job, _origin: usize, members: &[MemberView]) -> usize {
        let n = members.len();
        let long_half = n.div_ceil(2); // members [0, long_half) serve longs
        if job.is_long || long_half == n {
            let t = self.next_long % long_half;
            self.next_long = (self.next_long + 1) % long_half;
            t
        } else {
            let shorts = n - long_half;
            let t = long_half + self.next_short % shorts;
            self.next_short = (self.next_short + 1) % shorts;
            t
        }
    }
}

/// The routed-mode global arrival stream: per-cluster sources with one
/// job of lookahead each, merged by earliest arrival.
struct GlobalFeed {
    sources: Vec<Box<dyn ArrivalSource>>,
    /// Per-source arrival RNG: each member's 0xAE stream, forked by the
    /// builder in the member's canonical order so a routed member's
    /// source consumes the identical stream a standalone run would.
    rngs: Vec<Rng>,
    lookahead: Vec<Option<Job>>,
}

impl GlobalFeed {
    /// Earliest pending arrival as `(time, source index)`; ties break to
    /// the lowest source index (strict `<` keeps the first minimum).
    fn earliest(&self) -> Option<(Time, usize)> {
        let mut best: Option<(Time, usize)> = None;
        for (i, slot) in self.lookahead.iter().enumerate() {
            if let Some(job) = slot {
                if best.map_or(true, |(t, _)| job.arrival < t) {
                    best = Some((job.arrival, i));
                }
            }
        }
        best
    }

    fn refill(&mut self, i: usize) {
        debug_assert!(self.lookahead[i].is_none());
        self.lookahead[i] = self.sources[i].next_job(&mut self.rngs[i]);
    }

    fn exhausted(&self) -> bool {
        self.lookahead.iter().all(Option::is_none)
    }
}

/// N member worlds + merge loop + router + shared-budget reconciliation.
///
/// Built by `coordinator::runner::build_federation` (canonical wiring
/// from an `ExperimentConfig` with a `[federation]` block) or manually
/// from wired worlds for custom scenarios.
pub struct Federation<'w> {
    members: Vec<World<'w>>,
    /// `Some` in routed mode; pass-through members own their sources.
    feed: Option<GlobalFeed>,
    router: Option<Box<dyn JobRouter>>,
    /// Per-member shared-budget handles (pooled sharing: clones of one
    /// pool; split sharing: disjoint pools; `None`: uncoupled).
    shareds: Vec<Option<SharedBudget>>,
    /// Total transient units the sharing mode admits across all members
    /// (`None` = uncoupled). Recorded by the builder that sized the
    /// pools, so the reported cap can never drift from the enforced one.
    shared_cap: Option<usize>,
    /// Last reconciled fleet (active + provisioning transients) per
    /// member — the release-side bookkeeping for the shared pools.
    last_fleet: Vec<usize>,
    /// High-water mark of the summed fleet across members (the
    /// cross-cluster cap invariant: never exceeds a pooled cap).
    peak_total_fleet: usize,
    /// High-water mark of summed *active* transients (report headline).
    peak_total_active: f64,
    steps: u64,
}

impl<'w> Federation<'w> {
    /// Pass-through federation: members own their arrival sources; the
    /// federation interleaves their event loops and (optionally) couples
    /// their transient budgets.
    pub fn passthrough(members: Vec<World<'w>>) -> Self {
        let n = members.len();
        assert!(n > 0, "federation needs at least one member");
        Federation {
            members,
            feed: None,
            router: None,
            shareds: vec![None; n],
            shared_cap: None,
            last_fleet: vec![0; n],
            peak_total_fleet: 0,
            peak_total_active: 0.0,
            steps: 0,
        }
    }

    /// Routed federation: `members` must be inbox-fed
    /// ([`World::new_inbox`]); `sources`/`rngs` are the per-cluster
    /// arrival pipelines and their 0xAE streams (one per member, forked
    /// from the member in canonical order), merged into one global
    /// stream and dispatched by `router`.
    pub fn routed(
        members: Vec<World<'w>>,
        sources: Vec<Box<dyn ArrivalSource>>,
        rngs: Vec<Rng>,
        router: Box<dyn JobRouter>,
    ) -> Self {
        let n = members.len();
        assert!(n > 0, "federation needs at least one member");
        assert_eq!(sources.len(), n, "one source per member");
        assert_eq!(rngs.len(), n, "one arrival stream per member");
        let lookahead = (0..n).map(|_| None).collect();
        Federation {
            members,
            feed: Some(GlobalFeed { sources, rngs, lookahead }),
            router: Some(router),
            shareds: vec![None; n],
            shared_cap: None,
            last_fleet: vec![0; n],
            peak_total_fleet: 0,
            peak_total_active: 0.0,
            steps: 0,
        }
    }

    /// Attach per-member shared-budget handles (same length as members)
    /// and the total cap they enforce together (`Σ` of the pool caps for
    /// split sharing, the one pool's cap for pooled). The same handles
    /// must already be wired into the members' transient managers (the
    /// take side); the federation drives the release side.
    pub fn set_shared_budgets(
        &mut self,
        shareds: Vec<Option<SharedBudget>>,
        total_cap: Option<usize>,
    ) {
        assert_eq!(shareds.len(), self.members.len());
        self.shareds = shareds;
        self.shared_cap = total_cap;
    }

    /// Total transient units the sharing mode admits (`None` =
    /// uncoupled budgets) — the bound [`Federation::peak_total_fleet`]
    /// is checked against.
    pub fn shared_cap(&self) -> Option<usize> {
        self.shared_cap
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Member worlds, for post-run distillation.
    pub fn members(&self) -> &[World<'w>] {
        &self.members
    }

    /// High-water mark of Σ (active + provisioning) transients across
    /// members — with a pooled [`SharedBudget`] of cap K this never
    /// exceeds K (the federation cap invariant, pinned by
    /// `tests/federation_golden.rs`).
    pub fn peak_total_fleet(&self) -> usize {
        self.peak_total_fleet
    }

    /// High-water mark of Σ active transients across members.
    pub fn peak_total_active(&self) -> f64 {
        self.peak_total_active
    }

    /// Events processed across all members.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Consume the federation, handing back the member worlds (call
    /// after [`Federation::run`] to distill results).
    pub fn into_members(self) -> Vec<World<'w>> {
        self.members
    }

    fn views(&self) -> Vec<MemberView> {
        self.members
            .iter()
            .enumerate()
            .map(|(index, m)| MemberView {
                index,
                outstanding_tasks: m.outstanding_tasks(),
                resident_jobs: m.resident_jobs(),
            })
            .collect()
    }

    /// Earliest member event as `(time, member index)` (ties to the
    /// lowest index — strict `<` keeps the first minimum).
    fn earliest_event(&self) -> Option<(Time, usize)> {
        let mut best: Option<(Time, usize)> = None;
        for (i, m) in self.members.iter().enumerate() {
            if let Some(t) = m.next_event_time() {
                if best.map_or(true, |(bt, _)| t < bt) {
                    best = Some((t, i));
                }
            }
        }
        best
    }

    /// Post-step bookkeeping for member `i`: release shared-budget units
    /// for any fleet shrink (revocation, drain, retirement) and advance
    /// the cross-cluster peak watermarks.
    fn reconcile(&mut self, i: usize) {
        let fleet = {
            let c = &self.members[i].cluster;
            c.transient_pool.len() + c.provisioning_count()
        };
        let last = self.last_fleet[i];
        if fleet < last {
            if let Some(shared) = &self.shareds[i] {
                shared.release(last - fleet);
            }
        }
        self.last_fleet[i] = fleet;
        let total: usize = self.last_fleet.iter().sum();
        self.peak_total_fleet = self.peak_total_fleet.max(total);
        let active: f64 = self.members.iter().map(|m| m.rec.cost.active_now()).sum();
        self.peak_total_active = self.peak_total_active.max(active);
    }

    /// Drive every member to quiescence in global event-time order.
    ///
    /// Loop invariant: each iteration consumes exactly one unit of
    /// global progress — either the earliest pending arrival is routed
    /// (and the producing source refilled) or the member holding the
    /// earliest event steps once — so the run terminates whenever the
    /// member sources do.
    pub fn run(&mut self) {
        for m in &mut self.members {
            m.start();
        }
        if let Some(feed) = &mut self.feed {
            for i in 0..feed.sources.len() {
                feed.refill(i);
            }
            if feed.exhausted() {
                // Zero-job global stream: nothing will ever be routed.
                for m in &mut self.members {
                    m.close_inbox();
                }
            }
        }
        for i in 0..self.members.len() {
            self.reconcile(i);
        }

        loop {
            let next_arrival = self.feed.as_ref().and_then(GlobalFeed::earliest);
            let next_event = self.earliest_event();
            match (next_arrival, next_event) {
                (None, None) => break,
                // Arrivals route when global time reaches them: strictly
                // before later events, and before *equal-time* events so
                // the injected arrival competes inside the target's own
                // engine (a fixed, deterministic order).
                (Some((arrival, si)), ev) if ev.map_or(true, |(te, _)| arrival <= te) => {
                    let feed = self.feed.as_mut().expect("arrival without a feed");
                    let job = feed.lookahead[si].take().expect("earliest() said Some");
                    let views = self.views();
                    let router = self.router.as_mut().expect("routed mode has a router");
                    let target = router.route(&job, si, &views).min(views.len() - 1);
                    self.members[target].inject_job(job);
                    let feed = self.feed.as_mut().expect("feed still present");
                    feed.refill(si);
                    if feed.exhausted() {
                        for m in &mut self.members {
                            m.close_inbox();
                        }
                    }
                }
                (_, Some((_, i))) => {
                    self.members[i].step();
                    self.steps += 1;
                    self.reconcile(i);
                }
                // No member event but an arrival exists — handled by the
                // arrival arm above (its guard is true when ev is None).
                (Some(_), None) => unreachable!("arrival arm covers ev == None"),
            }
        }

        for m in &mut self.members {
            m.finish();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, QueuePolicy};
    use crate::metrics::Recorder;
    use crate::sched::Hybrid;
    use crate::sim::{SchedulerComponent, SnapshotSampler};
    use crate::trace::synth::{YahooLikeParams, YahooSource};
    use crate::util::JobId;

    fn tiny_params() -> YahooLikeParams {
        let mut p = YahooLikeParams::default();
        p.horizon = 2000.0;
        p
    }

    fn member<'s>(sched: &'s mut Hybrid, seed: u64) -> World<'s> {
        let p = tiny_params();
        let source = Box::new(YahooSource::new(&p, &mut Rng::new(seed)));
        let cluster = Cluster::new(96, 8, QueuePolicy::Fifo);
        let mut w = World::new(source, cluster, Recorder::new(1.0), seed);
        w.add_component(Box::new(SnapshotSampler::new(60.0)));
        w.add_component(Box::new(SchedulerComponent::new(sched)));
        w
    }

    #[test]
    fn n1_passthrough_matches_standalone_run() {
        let mut solo_sched = Hybrid::eagle(2.0);
        let mut solo = member(&mut solo_sched, 7);
        solo.run();

        let mut fed_sched = Hybrid::eagle(2.0);
        let fed_member = member(&mut fed_sched, 7);
        let mut fed = Federation::passthrough(vec![fed_member]);
        fed.run();
        let fed_world = &fed.members()[0];

        assert_eq!(solo.engine.processed(), fed_world.engine.processed());
        assert_eq!(solo.engine.now().to_bits(), fed_world.engine.now().to_bits());
        assert_eq!(solo.rec.tasks_finished, fed_world.rec.tasks_finished);
        assert_eq!(solo.rec.short_delays, fed_world.rec.short_delays);
        assert_eq!(solo.rec.long_delays, fed_world.rec.long_delays);
        assert_eq!(solo.peak_resident_jobs(), fed_world.peak_resident_jobs());
    }

    #[test]
    fn n2_passthrough_runs_both_members_to_completion() {
        let mut s0 = Hybrid::eagle(2.0);
        let mut s1 = Hybrid::eagle(2.0);
        let members = vec![member(&mut s0, 3), member(&mut s1, 4)];
        let mut fed = Federation::passthrough(members);
        fed.run();
        let total: u64 = fed.members().iter().map(|m| m.rec.tasks_finished).sum();
        assert!(total > 0);
        for m in fed.members() {
            assert!(m.rec.tasks_finished > 0, "a member ran no work");
            assert_eq!(m.outstanding_tasks(), 0);
        }
        assert_eq!(
            fed.steps(),
            fed.members().iter().map(|m| m.engine.processed()).sum::<u64>()
        );
    }

    #[test]
    fn routed_round_robin_preserves_and_splits_work() {
        // One real source + one empty-horizon source; round-robin must
        // land half the jobs on each member regardless of origin.
        let run = || {
            let mut s0 = Hybrid::eagle(2.0);
            let mut s1 = Hybrid::eagle(2.0);
            let mut w0 = World::new_inbox(
                Cluster::new(96, 8, QueuePolicy::Fifo),
                Recorder::new(1.0),
                11,
            );
            w0.add_component(Box::new(SnapshotSampler::new(60.0)));
            w0.add_component(Box::new(SchedulerComponent::new(&mut s0)));
            let mut w1 = World::new_inbox(
                Cluster::new(96, 8, QueuePolicy::Fifo),
                Recorder::new(1.0),
                12,
            );
            w1.add_component(Box::new(SnapshotSampler::new(60.0)));
            w1.add_component(Box::new(SchedulerComponent::new(&mut s1)));
            let r0 = w0.fork_rng(0xAE);
            let r1 = w1.fork_rng(0xAE);
            let p = tiny_params();
            let src0: Box<dyn ArrivalSource> =
                Box::new(YahooSource::new(&p, &mut Rng::new(11)));
            let mut empty = p.clone();
            empty.horizon = 0.0;
            let src1: Box<dyn ArrivalSource> =
                Box::new(YahooSource::new(&empty, &mut Rng::new(12)));
            let mut fed = Federation::routed(
                vec![w0, w1],
                vec![src0, src1],
                vec![r0, r1],
                Box::new(RoundRobin::default()),
            );
            fed.run();
            let per: Vec<u64> =
                fed.members().iter().map(|m| m.rec.tasks_finished).collect();
            let jobs: Vec<u64> = fed.members().iter().map(|m| m.jobs_seen()).collect();
            (per, jobs)
        };
        let (per, jobs) = run();
        let total_jobs: u64 = jobs.iter().sum();
        assert!(total_jobs > 1, "source produced too few jobs to split");
        // Round-robin: job counts differ by at most one.
        assert!(
            jobs[0].abs_diff(jobs[1]) <= 1,
            "round-robin split uneven: {jobs:?}"
        );
        assert!(per.iter().all(|&t| t > 0), "a member ran no tasks: {per:?}");
        // Deterministic per seed: a second identical run is identical.
        let (per2, jobs2) = run();
        assert_eq!(per, per2);
        assert_eq!(jobs, jobs2);
    }

    #[test]
    fn class_split_routes_by_job_class() {
        let views: Vec<MemberView> = (0..4)
            .map(|index| MemberView { index, outstanding_tasks: 0, resident_jobs: 0 })
            .collect();
        let mut r = ClassSplit::default();
        let job = |is_long: bool| Job {
            id: JobId(0),
            arrival: 0.0,
            task_durations: vec![1.0],
            is_long,
        };
        // Longs cycle members {0, 1}; shorts cycle members {2, 3}.
        assert_eq!(r.route(&job(true), 0, &views), 0);
        assert_eq!(r.route(&job(true), 0, &views), 1);
        assert_eq!(r.route(&job(true), 0, &views), 0);
        assert_eq!(r.route(&job(false), 0, &views), 2);
        assert_eq!(r.route(&job(false), 0, &views), 3);
        assert_eq!(r.route(&job(false), 0, &views), 2);
        // Single member: everything collapses to it.
        let one = vec![MemberView { index: 0, outstanding_tasks: 0, resident_jobs: 0 }];
        let mut r1 = ClassSplit::default();
        assert_eq!(r1.route(&job(false), 0, &one), 0);
        assert_eq!(r1.route(&job(true), 0, &one), 0);
    }

    #[test]
    fn least_queued_prefers_lowest_loaded_then_lowest_index() {
        let mk = |loads: [u64; 3]| {
            loads
                .iter()
                .enumerate()
                .map(|(index, &outstanding_tasks)| MemberView {
                    index,
                    outstanding_tasks,
                    resident_jobs: 0,
                })
                .collect::<Vec<_>>()
        };
        let mut r = LeastQueued;
        let j = Job { id: JobId(0), arrival: 0.0, task_durations: vec![1.0], is_long: false };
        assert_eq!(r.route(&j, 0, &mk([5, 2, 9])), 1);
        assert_eq!(r.route(&j, 0, &mk([4, 4, 4])), 0, "ties must break low");
        assert_eq!(r.route(&j, 2, &mk([7, 3, 3])), 1);
    }
}
