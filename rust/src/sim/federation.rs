//! Multi-cluster federation: N independent [`World`]s advanced in
//! **global event-time order**, with a pluggable [`JobRouter`] front end
//! dispatching arrivals across clusters and an optional
//! [`SharedBudget`] coupling their transient fleets.
//!
//! The paper evaluates CloudCoaster on one statically-provisioned
//! cluster; its elasticity argument is strongest when bursts are *not*
//! uniform across clusters (the co-located-workload regime production
//! trace studies report). A `Federation` makes that testable: each
//! member world owns its own cluster, scenario-resolved arrival
//! pipeline, recorder and RNG streams forked off its own seed — so each
//! member is bit-identical to the same world run standalone — while the
//! federation interleaves their event loops by earliest next event and,
//! optionally, lets the members draw transient leases from one pooled
//! budget, so one cluster's quiet period frees headroom for another's
//! burst.
//!
//! Two feed topologies:
//!
//! * **Pass-through** ([`Federation::passthrough`]): every member pulls
//!   from its own source exactly as a standalone [`World::run`] would.
//!   The federation only interleaves `step()`s (and reconciles the
//!   shared budget between them). An N = 1 pass-through federation is
//!   therefore *bit-identical* to the plain world — pinned by
//!   `tests/federation_golden.rs`.
//! * **Routed** ([`Federation::routed`]): the federation owns the
//!   per-cluster sources, merges them into one global arrival stream
//!   (earliest arrival first, ties to the lowest source index), and
//!   asks the [`JobRouter`] — which sees every member's queue state at
//!   the routing instant — where each job executes. Members run on
//!   inbox feeds ([`World::new_inbox`]); a routed arrival is injected
//!   when global time reaches it, so router decisions are a
//!   deterministic function of (sources, seeds, router), independent of
//!   host threading.
//!
//! Determinism: the merge is a strict order on `(time, member index)`,
//! arrivals route before equal-time member events, and every RNG stream
//! forks off per-member config seeds — a federated run is bit-identical
//! across repeats and sweep thread counts.
//!
//! The earliest-next-event merge keys on [`World::next_event_time`]
//! (the engine's O(1) `peek_time` — on the calendar queue the head is
//! restored eagerly after every mutation precisely so this stays a
//! `&self` constant-time read), and the serial merge advances members
//! via the single-event [`World::step`]: routed arrivals must
//! interleave *between* same-timestamp events exactly as the per-event
//! merge dictates. A standalone `World::run` uses batch dispatch, which
//! produces the identical event order — the N = 1 pass-through golden
//! pins stepped-vs-batched equivalence end to end.
//!
//! # Conservative-window PDES
//!
//! [`Federation::run_pdes`] executes the *same* merge with member
//! worlds advancing concurrently on scoped threads. The serial merge
//! order restricted to any window in which no cross-member interaction
//! occurs is just the `(time, member index)`-lexicographic interleaving
//! of the members' own event sequences — and each member's sequence is
//! interleaving-independent, because members only ever touch their own
//! engine, cluster and recorder. Cross-member interaction happens at
//! exactly three points, and each yields a conservative horizon term:
//!
//! 1. **Routed arrivals** — an arrival is injected when global time
//!    reaches it, so the global feed's one-job lookahead (cf.
//!    [`World::pending_arrival`]) lower-bounds the next injection.
//! 2. **Pooled shared budgets** — any event of a member whose
//!    [`SharedBudget`] handle is shared with another member
//!    ([`SharedBudget::same_pool`]) can take or release pool units in
//!    contention-sensitive order, so such a member's own
//!    `next_event_time` is a horizon term: pooled-coupled members only
//!    ever step in the serial boundary phase, which preserves the exact
//!    serial take/release order (and Σ(active + provisioning) ≤ K). A
//!    *split* pool is touched only by its own member plus that member's
//!    release bookkeeping, which runs inside the member's own window —
//!    per-pool operation order is again exactly serial.
//! 3. **The fleet watermarks** — the serial merge samples
//!    Σ fleet / Σ active after *every* member step. Window advance logs
//!    a change point per step whose fleet or active-cost value changed
//!    (bitwise, so `-0.0` vs `0.0` is a change), and the barrier
//!    replays all members' change points in `(time, member index)`
//!    order, recomputing both sums with the serial fold — steps that
//!    changed neither leave the sums bit-identical, so skipping them
//!    cannot move a maximum.
//!
//! Each PDES iteration computes `H = min(next routed arrival, min
//! pooled-coupled next event)`, advances every uncoupled member through
//! events strictly below `H` in parallel (members without a transient
//! manager have identically-zero watermark contributions and drain via
//! the batch path; managed members step per event to sample
//! watermarks), replays the journals at the barrier in member-index
//! order, then runs the ordinary serial merge for everything at `H`
//! (arrivals before equal-time events, lowest member index first).
//! `H = None` — no arrivals pending, no pooled coupling — drains every
//! member to quiescence fully in parallel. Every report field is
//! bit-identical to [`Federation::run`] at any thread count (pinned by
//! `tests/federation_golden.rs`); the serial merge survives as the
//! reference mode, mirroring `Engine::reference`. With *pooled* sharing
//! every member is budget-coupled, so `run_pdes` degenerates to the
//! serial boundary phase — correct by construction, parallel speedup
//! only for `none`/`split` sharing.

use std::sync::Mutex;

use crate::sim::components::TransientManagerComponent;
use crate::sim::{Rng, World};
use crate::trace::{ArrivalSource, Job};
use crate::transient::SharedBudget;
use crate::util::Time;

/// A router's read-only view of one member cluster at a routing instant.
#[derive(Clone, Copy, Debug)]
pub struct MemberView {
    /// Member index (the routing target space).
    pub index: usize,
    /// Tasks materialised but not yet finished on this member.
    pub outstanding_tasks: u64,
    /// Jobs resident (arrived, not fully finished).
    pub resident_jobs: usize,
}

/// Decides which member cluster executes an arriving job.
///
/// `origin` is the index of the per-cluster source that produced the
/// job (the pass-through identity); `members` is indexed by routing
/// target. Implementations must be deterministic functions of their own
/// state and the arguments — no wall clock, no global RNG.
pub trait JobRouter {
    fn name(&self) -> &'static str;
    fn route(&mut self, job: &Job, origin: usize, members: &[MemberView]) -> usize;
}

/// Round-robin over members, ignoring origin and load.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl JobRouter for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&mut self, _job: &Job, _origin: usize, members: &[MemberView]) -> usize {
        let t = self.next % members.len();
        self.next = (self.next + 1) % members.len();
        t
    }
}

/// Least-queued: the member with the fewest outstanding tasks (ties to
/// the lowest index) — the classic join-the-shortest-queue front end.
#[derive(Debug, Default)]
pub struct LeastQueued;

impl JobRouter for LeastQueued {
    fn name(&self) -> &'static str {
        "least-queued"
    }

    fn route(&mut self, _job: &Job, _origin: usize, members: &[MemberView]) -> usize {
        let mut best = 0usize;
        for m in members {
            if m.outstanding_tasks < members[best].outstanding_tasks {
                best = m.index;
            }
        }
        best
    }
}

/// Class-aware short/long split: long jobs round-robin over the first
/// half of the members, short jobs over the second half, so long-job
/// bursts never occupy the short-serving clusters (the federation-level
/// analogue of the paper's short-only partition). With a single member
/// both halves collapse to it.
#[derive(Debug, Default)]
pub struct ClassSplit {
    next_long: usize,
    next_short: usize,
}

impl JobRouter for ClassSplit {
    fn name(&self) -> &'static str {
        "class-split"
    }

    fn route(&mut self, job: &Job, _origin: usize, members: &[MemberView]) -> usize {
        let n = members.len();
        let long_half = n.div_ceil(2); // members [0, long_half) serve longs
        if job.is_long || long_half == n {
            let t = self.next_long % long_half;
            self.next_long = (self.next_long + 1) % long_half;
            t
        } else {
            let shorts = n - long_half;
            let t = long_half + self.next_short % shorts;
            self.next_short = (self.next_short + 1) % shorts;
            t
        }
    }
}

/// The routed-mode global arrival stream: per-cluster sources with one
/// job of lookahead each, merged by earliest arrival.
struct GlobalFeed {
    sources: Vec<Box<dyn ArrivalSource>>,
    /// Per-source arrival RNG: each member's 0xAE stream, forked by the
    /// builder in the member's canonical order so a routed member's
    /// source consumes the identical stream a standalone run would.
    rngs: Vec<Rng>,
    lookahead: Vec<Option<Job>>,
}

impl GlobalFeed {
    /// Earliest pending arrival as `(time, source index)`; ties break to
    /// the lowest source index (strict `<` keeps the first minimum).
    fn earliest(&self) -> Option<(Time, usize)> {
        let mut best: Option<(Time, usize)> = None;
        for (i, slot) in self.lookahead.iter().enumerate() {
            if let Some(job) = slot {
                if best.map_or(true, |(t, _)| job.arrival < t) {
                    best = Some((job.arrival, i));
                }
            }
        }
        best
    }

    fn refill(&mut self, i: usize) {
        debug_assert!(self.lookahead[i].is_none());
        self.lookahead[i] = self.sources[i].next_job(&mut self.rngs[i]);
    }

    fn exhausted(&self) -> bool {
        self.lookahead.iter().all(Option::is_none)
    }
}

/// N member worlds + merge loop + router + shared-budget reconciliation.
///
/// Built by `coordinator::runner::build_federation` (canonical wiring
/// from an `ExperimentConfig` with a `[federation]` block) or manually
/// from wired worlds for custom scenarios.
pub struct Federation<'w> {
    members: Vec<World<'w>>,
    /// `Some` in routed mode; pass-through members own their sources.
    feed: Option<GlobalFeed>,
    router: Option<Box<dyn JobRouter>>,
    /// Per-member shared-budget handles (pooled sharing: clones of one
    /// pool; split sharing: disjoint pools; `None`: uncoupled).
    shareds: Vec<Option<SharedBudget>>,
    /// Total transient units the sharing mode admits across all members
    /// (`None` = uncoupled). Recorded by the builder that sized the
    /// pools, so the reported cap can never drift from the enforced one.
    shared_cap: Option<usize>,
    /// Last reconciled fleet (active + provisioning transients) per
    /// member — the release-side bookkeeping for the shared pools.
    last_fleet: Vec<usize>,
    /// Last observed active-transient cost value per member — the PDES
    /// barrier replays watermark change points against this mirror, so
    /// it must track `rec.cost.active_now()` exactly (maintained by
    /// `reconcile` and the journal replay).
    last_active: Vec<f64>,
    /// High-water mark of the summed fleet across members (the
    /// cross-cluster cap invariant: never exceeds a pooled cap).
    peak_total_fleet: usize,
    /// High-water mark of summed *active* transients (report headline).
    peak_total_active: f64,
    steps: u64,
    /// Reusable routing-view scratch: the router sees every member's
    /// queue state per arrival, rebuilt in place instead of allocated.
    view_scratch: Vec<MemberView>,
}

/// One watermark change point observed inside a parallel window: member
/// state *after* a step whose fleet or active-cost value changed.
#[derive(Clone, Copy, Debug)]
struct FleetChange {
    time: Time,
    fleet: usize,
    active: f64,
}

/// A unit of parallel window work: one member world plus the state its
/// window loop threads through (shared-pool handle for release
/// bookkeeping, last reconciled fleet/active for change detection).
struct WindowTask<'t, 'w> {
    index: usize,
    world: &'t mut World<'w>,
    shared: Option<SharedBudget>,
    managed: bool,
    fleet: usize,
    active_bits: u64,
}

/// What a window hands back to the barrier.
struct WindowOutcome {
    index: usize,
    steps: u64,
    changes: Vec<FleetChange>,
}

/// Advance one member through every event strictly below `horizon`
/// (`None` = to quiescence). Runs on a PDES worker thread; everything
/// it touches is member-local except the member's own shared-pool
/// handle, which in split mode no other thread touches.
///
/// Managed members (wired transient manager) step per event: the serial
/// merge samples the fleet watermarks after every step, so the journal
/// must too. Unmanaged members have identically-zero fleet and active
/// cost for the whole run, so the batch path (one engine head-restore
/// per unique timestamp, bit-identical event order — pinned by the
/// N = 1 pass-through golden) drains them with an empty journal.
fn advance_window(task: WindowTask, horizon: Option<Time>) -> WindowOutcome {
    let world = task.world;
    let before = world.engine.processed();
    let mut changes = Vec::new();
    if task.managed {
        let mut fleet = task.fleet;
        let mut active_bits = task.active_bits;
        loop {
            match (world.next_event_time(), horizon) {
                (None, _) => break,
                (Some(t), Some(h)) if t >= h => break,
                _ => {}
            }
            // lint: allow(panic-surface): next_event_time() returned Some just above and nothing dequeued since
            let t = world.step().expect("peeked event vanished");
            let new_fleet = {
                let c = &world.cluster;
                c.transient_pool.len() + c.provisioning_count()
            };
            if new_fleet < fleet {
                if let Some(shared) = &task.shared {
                    shared.release(fleet - new_fleet);
                }
            }
            let active = world.rec.cost.active_now();
            if new_fleet != fleet || active.to_bits() != active_bits {
                changes.push(FleetChange { time: t, fleet: new_fleet, active });
                fleet = new_fleet;
                active_bits = active.to_bits();
            }
        }
    } else {
        world.run_until(horizon.unwrap_or(f64::INFINITY));
    }
    WindowOutcome { index: task.index, steps: world.engine.processed() - before, changes }
}

impl<'w> Federation<'w> {
    /// Pass-through federation: members own their arrival sources; the
    /// federation interleaves their event loops and (optionally) couples
    /// their transient budgets.
    pub fn passthrough(members: Vec<World<'w>>) -> Self {
        let n = members.len();
        assert!(n > 0, "federation needs at least one member");
        Federation {
            members,
            feed: None,
            router: None,
            shareds: vec![None; n],
            shared_cap: None,
            last_fleet: vec![0; n],
            last_active: vec![0.0; n],
            peak_total_fleet: 0,
            peak_total_active: 0.0,
            steps: 0,
            view_scratch: Vec::new(),
        }
    }

    /// Routed federation: `members` must be inbox-fed
    /// ([`World::new_inbox`]); `sources`/`rngs` are the per-cluster
    /// arrival pipelines and their 0xAE streams (one per member, forked
    /// from the member in canonical order), merged into one global
    /// stream and dispatched by `router`.
    pub fn routed(
        members: Vec<World<'w>>,
        sources: Vec<Box<dyn ArrivalSource>>,
        rngs: Vec<Rng>,
        router: Box<dyn JobRouter>,
    ) -> Self {
        let n = members.len();
        assert!(n > 0, "federation needs at least one member");
        assert_eq!(sources.len(), n, "one source per member");
        assert_eq!(rngs.len(), n, "one arrival stream per member");
        let lookahead = (0..n).map(|_| None).collect();
        Federation {
            members,
            feed: Some(GlobalFeed { sources, rngs, lookahead }),
            router: Some(router),
            shareds: vec![None; n],
            shared_cap: None,
            last_fleet: vec![0; n],
            last_active: vec![0.0; n],
            peak_total_fleet: 0,
            peak_total_active: 0.0,
            steps: 0,
            view_scratch: Vec::new(),
        }
    }

    /// Attach per-member shared-budget handles (same length as members)
    /// and the total cap they enforce together (`Σ` of the pool caps for
    /// split sharing, the one pool's cap for pooled). The same handles
    /// must already be wired into the members' transient managers (the
    /// take side); the federation drives the release side.
    pub fn set_shared_budgets(
        &mut self,
        shareds: Vec<Option<SharedBudget>>,
        total_cap: Option<usize>,
    ) {
        assert_eq!(shareds.len(), self.members.len());
        self.shareds = shareds;
        self.shared_cap = total_cap;
    }

    /// Total transient units the sharing mode admits (`None` =
    /// uncoupled budgets) — the bound [`Federation::peak_total_fleet`]
    /// is checked against.
    pub fn shared_cap(&self) -> Option<usize> {
        self.shared_cap
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Member worlds, for post-run distillation.
    pub fn members(&self) -> &[World<'w>] {
        &self.members
    }

    /// High-water mark of Σ (active + provisioning) transients across
    /// members — with a pooled [`SharedBudget`] of cap K this never
    /// exceeds K (the federation cap invariant, pinned by
    /// `tests/federation_golden.rs`).
    pub fn peak_total_fleet(&self) -> usize {
        self.peak_total_fleet
    }

    /// High-water mark of Σ active transients across members.
    pub fn peak_total_active(&self) -> f64 {
        self.peak_total_active
    }

    /// Events processed across all members.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Consume the federation, handing back the member worlds (call
    /// after [`Federation::run`] to distill results).
    pub fn into_members(self) -> Vec<World<'w>> {
        self.members
    }

    /// Rebuild the per-arrival routing views into `out` — the reusable
    /// federation scratch (no allocation once warm; an associated fn so
    /// the merge loop can borrow the members and the scratch disjointly).
    fn fill_views(members: &[World<'_>], out: &mut Vec<MemberView>) {
        out.clear();
        out.extend(members.iter().enumerate().map(|(index, m)| MemberView {
            index,
            outstanding_tasks: m.outstanding_tasks(),
            resident_jobs: m.resident_jobs(),
        }));
    }

    /// Earliest member event as `(time, member index)` (ties to the
    /// lowest index — strict `<` keeps the first minimum).
    fn earliest_event(&self) -> Option<(Time, usize)> {
        let mut best: Option<(Time, usize)> = None;
        for (i, m) in self.members.iter().enumerate() {
            if let Some(t) = m.next_event_time() {
                if best.map_or(true, |(bt, _)| t < bt) {
                    best = Some((t, i));
                }
            }
        }
        best
    }

    /// Post-step bookkeeping for member `i`: release shared-budget units
    /// for any fleet shrink (revocation, drain, retirement) and advance
    /// the cross-cluster peak watermarks.
    fn reconcile(&mut self, i: usize) {
        let fleet = {
            let c = &self.members[i].cluster;
            c.transient_pool.len() + c.provisioning_count()
        };
        let last = self.last_fleet[i];
        if fleet < last {
            if let Some(shared) = &self.shareds[i] {
                shared.release(last - fleet);
            }
        }
        self.last_fleet[i] = fleet;
        // Keep the active-cost mirror fresh: a member's active value
        // only moves during its own steps, so updating slot `i` here
        // (and the others at window replay) keeps `last_active[j] ==
        // members[j].rec.cost.active_now()` at every merge instant.
        self.last_active[i] = self.members[i].rec.cost.active_now();
        let total: usize = self.last_fleet.iter().sum();
        self.peak_total_fleet = self.peak_total_fleet.max(total);
        let active: f64 = self.members.iter().map(|m| m.rec.cost.active_now()).sum();
        self.peak_total_active = self.peak_total_active.max(active);
    }

    /// Shared run prologue: start every member, prime the global feed's
    /// one-job lookaheads (closing inboxes immediately on a zero-job
    /// stream), and take the initial watermark samples.
    fn start_members(&mut self) {
        for m in &mut self.members {
            m.start();
        }
        if let Some(feed) = &mut self.feed {
            for i in 0..feed.sources.len() {
                feed.refill(i);
            }
            if feed.exhausted() {
                // Zero-job global stream: nothing will ever be routed.
                for m in &mut self.members {
                    m.close_inbox();
                }
            }
        }
        for i in 0..self.members.len() {
            self.reconcile(i);
        }
    }

    fn finish_members(&mut self) {
        for m in &mut self.members {
            m.finish();
        }
    }

    /// One unit of serial-merge progress: route the earliest pending
    /// arrival, or step the member holding the earliest event and
    /// reconcile it. Returns `false` — consuming nothing — when the
    /// federation has quiesced, or when `bound` is set and the earliest
    /// item lies strictly beyond it (the PDES boundary phase drains
    /// items *at* the window horizon with exactly this loop, so the
    /// boundary is the serial merge by construction).
    fn serial_step(&mut self, bound: Option<Time>) -> bool {
        let next_arrival = self.feed.as_ref().and_then(GlobalFeed::earliest);
        let next_event = self.earliest_event();
        if let Some(b) = bound {
            let t = match (next_arrival, next_event) {
                (None, None) => return false,
                (Some((a, _)), Some((e, _))) => a.min(e),
                (Some((a, _)), None) => a,
                (None, Some((e, _))) => e,
            };
            if t > b {
                return false;
            }
        }
        match (next_arrival, next_event) {
            (None, None) => false,
            // Arrivals route when global time reaches them: strictly
            // before later events, and before *equal-time* events so
            // the injected arrival competes inside the target's own
            // engine (a fixed, deterministic order).
            (Some((arrival, si)), ev) if ev.map_or(true, |(te, _)| arrival <= te) => {
                // lint: allow(panic-surface): next_arrival is only Some in routed mode, which constructs feed + router together
                let feed = self.feed.as_mut().expect("arrival without a feed");
                // lint: allow(panic-surface): feed.earliest() reported this slot non-empty within the same &mut self borrow
                let job = feed.lookahead[si].take().expect("earliest() said Some");
                let mut views = std::mem::take(&mut self.view_scratch);
                Self::fill_views(&self.members, &mut views);
                // lint: allow(panic-surface): same routed-mode construction invariant as the feed above
                let router = self.router.as_mut().expect("routed mode has a router");
                let target = router.route(&job, si, &views).min(views.len() - 1);
                self.view_scratch = views;
                self.members[target].inject_job(job);
                // lint: allow(panic-surface): the feed Option was only borrowed, not taken, earlier in this arm
                let feed = self.feed.as_mut().expect("feed still present");
                feed.refill(si);
                if feed.exhausted() {
                    for m in &mut self.members {
                        m.close_inbox();
                    }
                }
                true
            }
            (_, Some((_, i))) => {
                let _ = self.members[i].step();
                self.steps += 1;
                self.reconcile(i);
                true
            }
            // No member event but an arrival exists — handled by the
            // arrival arm above (its guard is true when ev is None).
            (Some(_), None) => unreachable!("arrival arm covers ev == None"),
        }
    }

    /// Drive every member to quiescence in global event-time order —
    /// the serial reference merge ([`Federation::run_pdes`] must match
    /// it bit for bit, as `Engine::reference` anchors the calendar
    /// queue).
    ///
    /// Loop invariant: each iteration consumes exactly one unit of
    /// global progress — either the earliest pending arrival is routed
    /// (and the producing source refilled) or the member holding the
    /// earliest event steps once — so the run terminates whenever the
    /// member sources do.
    pub fn run(&mut self) {
        self.start_members();
        while self.serial_step(None) {}
        self.finish_members();
    }

    /// Which members are *budget-coupled* — holding a [`SharedBudget`]
    /// handle on a pool some other member also draws from? Their events
    /// are horizon events: they only step in the serial boundary phase.
    fn pooled_coupled(&self) -> Vec<bool> {
        let n = self.members.len();
        let mut coupled = vec![false; n];
        for i in 0..n {
            let Some(a) = &self.shareds[i] else { continue };
            for (j, other) in self.shareds.iter().enumerate() {
                if i == j {
                    continue;
                }
                if let Some(b) = other {
                    if a.same_pool(b) {
                        coupled[i] = true;
                        break;
                    }
                }
            }
        }
        coupled
    }

    /// The conservative window horizon: no member advancing strictly
    /// below it can miss a cross-member interaction. `None` means no
    /// interaction can ever happen again — windows may drain to
    /// quiescence.
    fn window_horizon(&self, coupled: &[bool]) -> Option<Time> {
        let mut horizon =
            self.feed.as_ref().and_then(GlobalFeed::earliest).map(|(t, _)| t);
        for (i, m) in self.members.iter().enumerate() {
            if !coupled[i] {
                continue;
            }
            if let Some(t) = m.next_event_time() {
                horizon = Some(match horizon {
                    Some(h) => h.min(t),
                    None => t,
                });
            }
        }
        horizon
    }

    /// Replay the windows' watermark change points in the serial merge
    /// order — ascending `(time, member index)`, FIFO within a member —
    /// recomputing the summed-fleet and summed-active watermarks with
    /// the serial fold at each point. Steps that changed neither value
    /// were skipped by the journal: they contribute bit-identical sums,
    /// so they cannot move a maximum. `outcomes` is sorted by member
    /// index, so the linear scan's first minimum breaks time ties to
    /// the lowest member index, exactly as `earliest_event` does.
    fn replay_changes(&mut self, outcomes: &[WindowOutcome]) {
        let lists: Vec<(usize, &[FleetChange])> = outcomes
            .iter()
            .filter(|o| !o.changes.is_empty())
            .map(|o| (o.index, o.changes.as_slice()))
            .collect();
        if lists.is_empty() {
            return;
        }
        let mut pos = vec![0usize; lists.len()];
        loop {
            let mut best: Option<(Time, usize)> = None; // (time, list slot)
            for (k, (_, changes)) in lists.iter().enumerate() {
                if let Some(c) = changes.get(pos[k]) {
                    if best.map_or(true, |(bt, _)| c.time < bt) {
                        best = Some((c.time, k));
                    }
                }
            }
            let Some((_, k)) = best else { break };
            let (mi, changes) = lists[k];
            let c = changes[pos[k]];
            pos[k] += 1;
            self.last_fleet[mi] = c.fleet;
            self.last_active[mi] = c.active;
            let total: usize = self.last_fleet.iter().sum();
            self.peak_total_fleet = self.peak_total_fleet.max(total);
            let active: f64 = self.last_active.iter().sum();
            self.peak_total_active = self.peak_total_active.max(active);
        }
    }

    /// Advance every uncoupled member with work strictly below `horizon`
    /// (`None` = drain fully), fanned out over at most `threads` scoped
    /// worker threads, then reconcile the outcomes deterministically:
    /// thread completion order is host scheduling noise, so outcomes
    /// sort by member index before the journal replay.
    fn advance_windows(
        &mut self,
        horizon: Option<Time>,
        threads: usize,
        managed: &[bool],
        coupled: &[bool],
    ) {
        let mut tasks: Vec<WindowTask<'_, 'w>> = Vec::new();
        let shareds = &self.shareds;
        let last_fleet = &self.last_fleet;
        let last_active = &self.last_active;
        for (i, m) in self.members.iter_mut().enumerate() {
            if coupled[i] {
                continue;
            }
            let Some(t) = m.next_event_time() else { continue };
            if let Some(h) = horizon {
                if t >= h {
                    continue;
                }
            }
            // A member's own pending arrival is safe to cross: its
            // JobArrival event is already in the engine (so `t` keys on
            // it); only *feed* lookaheads — a horizon term — can inject
            // new events from outside (`World::pending_arrival`
            // documents the lower-bound invariant).
            tasks.push(WindowTask {
                index: i,
                world: m,
                shared: shareds[i].clone(),
                managed: managed[i],
                fleet: last_fleet[i],
                active_bits: last_active[i].to_bits(),
            });
        }
        if tasks.is_empty() {
            return;
        }
        let mut outcomes: Vec<WindowOutcome> = if threads <= 1 || tasks.len() == 1 {
            tasks.into_iter().map(|t| advance_window(t, horizon)).collect()
        } else {
            let workers = threads.min(tasks.len());
            let queue = Mutex::new(tasks);
            let done: Mutex<Vec<WindowOutcome>> = Mutex::new(Vec::new());
            std::thread::scope(|scope| {
                for _ in 0..workers {
                    scope.spawn(|| loop {
                        // lint: allow(panic-surface): Mutex poisoning — a panicked worker already aborts the run; propagating is correct
                        let Some(task) = queue.lock().unwrap().pop() else { break };
                        let outcome = advance_window(task, horizon);
                        // lint: allow(panic-surface): Mutex poisoning — a panicked worker already aborts the run; propagating is correct
                        done.lock().unwrap().push(outcome);
                    });
                }
            });
            // lint: allow(panic-surface): scope joined all workers; poisoning only follows a worker panic that already failed the run
            done.into_inner().unwrap()
        };
        outcomes.sort_by_key(|o| o.index);
        for o in &outcomes {
            self.steps += o.steps;
        }
        self.replay_changes(&outcomes);
    }

    /// Drive every member to quiescence with conservative-window
    /// parallel discrete-event execution — bit-identical to
    /// [`Federation::run`] at any `threads` count (including 1, which
    /// exercises the same windowed code path inline).
    ///
    /// Each iteration: compute the horizon, advance every uncoupled
    /// member below it in parallel, replay the watermark journals, then
    /// run the serial merge for everything *at* the horizon (arrivals
    /// route before equal-time events, ties to the lowest member
    /// index). Progress: a `Some` horizon is witnessed by a pending
    /// arrival or a pooled member's event at exactly that time, so the
    /// boundary always consumes at least one item; a `None` horizon
    /// means the windows just drained everything.
    pub fn run_pdes(&mut self, threads: usize) {
        let threads = threads.max(1);
        self.start_members();
        let managed: Vec<bool> = self
            .members
            .iter()
            .map(|m| m.component::<TransientManagerComponent>().is_some())
            .collect();
        let coupled = self.pooled_coupled();
        loop {
            let horizon = self.window_horizon(&coupled);
            self.advance_windows(horizon, threads, &managed, &coupled);
            let Some(h) = horizon else { break };
            let mut progressed = false;
            while self.serial_step(Some(h)) {
                progressed = true;
            }
            debug_assert!(progressed, "PDES boundary at t={h} consumed nothing");
            if !progressed {
                // Defensive: a horizon no longer witnessed by any item
                // (cannot happen — see above) must not spin forever.
                break;
            }
        }
        self.finish_members();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, QueuePolicy};
    use crate::metrics::Recorder;
    use crate::sched::Hybrid;
    use crate::sim::{SchedulerComponent, SnapshotSampler};
    use crate::trace::synth::{YahooLikeParams, YahooSource};
    use crate::util::JobId;

    fn tiny_params() -> YahooLikeParams {
        let mut p = YahooLikeParams::default();
        p.horizon = 2000.0;
        p
    }

    fn member<'s>(sched: &'s mut Hybrid, seed: u64) -> World<'s> {
        let p = tiny_params();
        let source = Box::new(YahooSource::new(&p, &mut Rng::new(seed)));
        let cluster = Cluster::new(96, 8, QueuePolicy::Fifo);
        let mut w = World::new(source, cluster, Recorder::new(1.0), seed);
        w.add_component(Box::new(SnapshotSampler::new(60.0)));
        w.add_component(Box::new(SchedulerComponent::new(sched)));
        w
    }

    #[test]
    fn n1_passthrough_matches_standalone_run() {
        let mut solo_sched = Hybrid::eagle(2.0);
        let mut solo = member(&mut solo_sched, 7);
        solo.run();

        let mut fed_sched = Hybrid::eagle(2.0);
        let fed_member = member(&mut fed_sched, 7);
        let mut fed = Federation::passthrough(vec![fed_member]);
        fed.run();
        let fed_world = &fed.members()[0];

        assert_eq!(solo.engine.processed(), fed_world.engine.processed());
        assert_eq!(solo.engine.now().to_bits(), fed_world.engine.now().to_bits());
        assert_eq!(solo.rec.tasks_finished, fed_world.rec.tasks_finished);
        assert_eq!(solo.rec.short_delays, fed_world.rec.short_delays);
        assert_eq!(solo.rec.long_delays, fed_world.rec.long_delays);
        assert_eq!(solo.peak_resident_jobs(), fed_world.peak_resident_jobs());
    }

    #[test]
    fn n2_passthrough_runs_both_members_to_completion() {
        let mut s0 = Hybrid::eagle(2.0);
        let mut s1 = Hybrid::eagle(2.0);
        let members = vec![member(&mut s0, 3), member(&mut s1, 4)];
        let mut fed = Federation::passthrough(members);
        fed.run();
        let total: u64 = fed.members().iter().map(|m| m.rec.tasks_finished).sum();
        assert!(total > 0);
        for m in fed.members() {
            assert!(m.rec.tasks_finished > 0, "a member ran no work");
            assert_eq!(m.outstanding_tasks(), 0);
        }
        assert_eq!(
            fed.steps(),
            fed.members().iter().map(|m| m.engine.processed()).sum::<u64>()
        );
    }

    #[test]
    fn routed_round_robin_preserves_and_splits_work() {
        // One real source + one empty-horizon source; round-robin must
        // land half the jobs on each member regardless of origin.
        let run = || {
            let mut s0 = Hybrid::eagle(2.0);
            let mut s1 = Hybrid::eagle(2.0);
            let mut w0 = World::new_inbox(
                Cluster::new(96, 8, QueuePolicy::Fifo),
                Recorder::new(1.0),
                11,
            );
            w0.add_component(Box::new(SnapshotSampler::new(60.0)));
            w0.add_component(Box::new(SchedulerComponent::new(&mut s0)));
            let mut w1 = World::new_inbox(
                Cluster::new(96, 8, QueuePolicy::Fifo),
                Recorder::new(1.0),
                12,
            );
            w1.add_component(Box::new(SnapshotSampler::new(60.0)));
            w1.add_component(Box::new(SchedulerComponent::new(&mut s1)));
            let r0 = w0.fork_rng(crate::util::RNG_ARRIVALS);
            let r1 = w1.fork_rng(crate::util::RNG_ARRIVALS);
            let p = tiny_params();
            let src0: Box<dyn ArrivalSource> =
                Box::new(YahooSource::new(&p, &mut Rng::new(11)));
            let mut empty = p.clone();
            empty.horizon = 0.0;
            let src1: Box<dyn ArrivalSource> =
                Box::new(YahooSource::new(&empty, &mut Rng::new(12)));
            let mut fed = Federation::routed(
                vec![w0, w1],
                vec![src0, src1],
                vec![r0, r1],
                Box::new(RoundRobin::default()),
            );
            fed.run();
            let per: Vec<u64> =
                fed.members().iter().map(|m| m.rec.tasks_finished).collect();
            let jobs: Vec<u64> = fed.members().iter().map(|m| m.jobs_seen()).collect();
            (per, jobs)
        };
        let (per, jobs) = run();
        let total_jobs: u64 = jobs.iter().sum();
        assert!(total_jobs > 1, "source produced too few jobs to split");
        // Round-robin: job counts differ by at most one.
        assert!(
            jobs[0].abs_diff(jobs[1]) <= 1,
            "round-robin split uneven: {jobs:?}"
        );
        assert!(per.iter().all(|&t| t > 0), "a member ran no tasks: {per:?}");
        // Deterministic per seed: a second identical run is identical.
        let (per2, jobs2) = run();
        assert_eq!(per, per2);
        assert_eq!(jobs, jobs2);
    }

    #[test]
    fn class_split_routes_by_job_class() {
        let views: Vec<MemberView> = (0..4)
            .map(|index| MemberView { index, outstanding_tasks: 0, resident_jobs: 0 })
            .collect();
        let mut r = ClassSplit::default();
        let job = |is_long: bool| Job {
            id: JobId(0),
            arrival: 0.0,
            task_durations: vec![1.0],
            is_long,
        };
        // Longs cycle members {0, 1}; shorts cycle members {2, 3}.
        assert_eq!(r.route(&job(true), 0, &views), 0);
        assert_eq!(r.route(&job(true), 0, &views), 1);
        assert_eq!(r.route(&job(true), 0, &views), 0);
        assert_eq!(r.route(&job(false), 0, &views), 2);
        assert_eq!(r.route(&job(false), 0, &views), 3);
        assert_eq!(r.route(&job(false), 0, &views), 2);
        // Single member: everything collapses to it.
        let one = vec![MemberView { index: 0, outstanding_tasks: 0, resident_jobs: 0 }];
        let mut r1 = ClassSplit::default();
        assert_eq!(r1.route(&job(false), 0, &one), 0);
        assert_eq!(r1.route(&job(true), 0, &one), 0);
    }

    /// `World` must be `Send` for the PDES windows to move members onto
    /// scoped worker threads; this fails to compile if any field (or
    /// boxed trait object) loses the bound.
    #[test]
    fn worlds_and_window_state_are_send() {
        fn assert_send<T: Send>() {}
        assert_send::<World<'static>>();
        assert_send::<SharedBudget>();
    }

    fn assert_federations_bit_identical(a: &Federation, b: &Federation) {
        assert_eq!(a.steps(), b.steps());
        assert_eq!(a.peak_total_fleet(), b.peak_total_fleet());
        assert_eq!(
            a.peak_total_active().to_bits(),
            b.peak_total_active().to_bits()
        );
        for (x, y) in a.members().iter().zip(b.members()) {
            assert_eq!(x.engine.processed(), y.engine.processed());
            assert_eq!(x.engine.now().to_bits(), y.engine.now().to_bits());
            assert_eq!(x.jobs_seen(), y.jobs_seen());
            assert_eq!(x.rec.tasks_finished, y.rec.tasks_finished);
            assert_eq!(x.rec.short_delays, y.rec.short_delays);
            assert_eq!(x.rec.long_delays, y.rec.long_delays);
            assert_eq!(x.peak_resident_jobs(), y.peak_resident_jobs());
            assert_eq!(x.peak_resident_tasks(), y.peak_resident_tasks());
        }
    }

    #[test]
    fn pdes_passthrough_matches_serial_merge_at_every_thread_count() {
        let serial = {
            let mut s0 = Hybrid::eagle(2.0);
            let mut s1 = Hybrid::eagle(2.0);
            let mut fed =
                Federation::passthrough(vec![member(&mut s0, 3), member(&mut s1, 4)]);
            fed.run();
            (
                fed.steps(),
                fed.members().iter().map(|m| m.engine.processed()).collect::<Vec<_>>(),
                fed.members()
                    .iter()
                    .map(|m| m.rec.tasks_finished)
                    .collect::<Vec<_>>(),
            )
        };
        for threads in [1, 2, 8] {
            let mut s0 = Hybrid::eagle(2.0);
            let mut s1 = Hybrid::eagle(2.0);
            let mut fed =
                Federation::passthrough(vec![member(&mut s0, 3), member(&mut s1, 4)]);
            fed.run_pdes(threads);
            assert_eq!(fed.steps(), serial.0, "threads={threads}");
            assert_eq!(
                fed.members().iter().map(|m| m.engine.processed()).collect::<Vec<_>>(),
                serial.1,
                "threads={threads}"
            );
            assert_eq!(
                fed.members()
                    .iter()
                    .map(|m| m.rec.tasks_finished)
                    .collect::<Vec<_>>(),
                serial.2,
                "threads={threads}"
            );
        }
    }

    /// Cross-member tie storm: both sources emit jobs at *identical*
    /// timestamps, so routed arrivals and member events collide at the
    /// same instant across members — the PDES boundary must interleave
    /// them exactly as the serial merge (arrivals first, then events,
    /// lowest member index first).
    fn tie_storm_fed<'s>(
        s0: &'s mut Hybrid,
        s1: &'s mut Hybrid,
    ) -> Federation<'s> {
        use crate::trace::VecSource;
        let mk_jobs = || {
            let mut jobs = Vec::new();
            let mut id = 0u32;
            // 40 waves of 4 jobs each, every job in a wave at the same
            // arrival time, waves 25 s apart; task durations collide too.
            for wave in 0..40 {
                for _ in 0..4 {
                    jobs.push(Job {
                        id: JobId(id),
                        arrival: wave as f64 * 25.0,
                        task_durations: vec![10.0, 10.0],
                        is_long: wave % 5 == 0,
                    });
                    id += 1;
                }
            }
            jobs
        };
        let mut w0 =
            World::new_inbox(Cluster::new(64, 8, QueuePolicy::Fifo), Recorder::new(1.0), 21);
        w0.add_component(Box::new(SnapshotSampler::new(60.0)));
        w0.add_component(Box::new(SchedulerComponent::new(s0)));
        let mut w1 =
            World::new_inbox(Cluster::new(64, 8, QueuePolicy::Fifo), Recorder::new(1.0), 22);
        w1.add_component(Box::new(SnapshotSampler::new(60.0)));
        w1.add_component(Box::new(SchedulerComponent::new(s1)));
        let r0 = w0.fork_rng(crate::util::RNG_ARRIVALS);
        let r1 = w1.fork_rng(crate::util::RNG_ARRIVALS);
        let src0: Box<dyn ArrivalSource> = Box::new(VecSource::new(mk_jobs(), 90.0));
        let src1: Box<dyn ArrivalSource> = Box::new(VecSource::new(mk_jobs(), 90.0));
        Federation::routed(
            vec![w0, w1],
            vec![src0, src1],
            vec![r0, r1],
            Box::new(RoundRobin::default()),
        )
    }

    #[test]
    fn pdes_same_timestamp_tie_storm_matches_serial_merge() {
        let mut a0 = Hybrid::eagle(2.0);
        let mut a1 = Hybrid::eagle(2.0);
        let mut serial = tie_storm_fed(&mut a0, &mut a1);
        serial.run();
        let total: u64 = serial.members().iter().map(|m| m.jobs_seen()).sum();
        assert_eq!(total, 320, "both tied sources must drain fully");
        for threads in [1, 2, 8] {
            let mut b0 = Hybrid::eagle(2.0);
            let mut b1 = Hybrid::eagle(2.0);
            let mut pdes = tie_storm_fed(&mut b0, &mut b1);
            pdes.run_pdes(threads);
            assert_federations_bit_identical(&serial, &pdes);
        }
    }

    /// Regression (merge-loop audit): a member whose engine is exhausted
    /// while its inbox is open-but-empty is *idle, not done* — the merge
    /// must keep running on pending feed arrivals and deliver the late
    /// jobs instead of declaring global quiescence. Source 0 has a long
    /// arrival gap during which both members fully quiesce except for
    /// the open inboxes.
    fn gap_fed<'s>(s0: &'s mut Hybrid, s1: &'s mut Hybrid) -> Federation<'s> {
        use crate::trace::VecSource;
        let jobs = vec![
            Job { id: JobId(0), arrival: 1.0, task_durations: vec![5.0], is_long: false },
            Job { id: JobId(1), arrival: 2.0, task_durations: vec![5.0], is_long: false },
            // ... both members drain completely by ~t=10 ...
            Job {
                id: JobId(2),
                arrival: 5000.0,
                task_durations: vec![5.0],
                is_long: false,
            },
            Job {
                id: JobId(3),
                arrival: 5001.0,
                task_durations: vec![5.0],
                is_long: false,
            },
        ];
        let mut w0 =
            World::new_inbox(Cluster::new(16, 4, QueuePolicy::Fifo), Recorder::new(1.0), 31);
        w0.add_component(Box::new(SnapshotSampler::new(60.0)));
        w0.add_component(Box::new(SchedulerComponent::new(s0)));
        let mut w1 =
            World::new_inbox(Cluster::new(16, 4, QueuePolicy::Fifo), Recorder::new(1.0), 32);
        w1.add_component(Box::new(SnapshotSampler::new(60.0)));
        w1.add_component(Box::new(SchedulerComponent::new(s1)));
        let r0 = w0.fork_rng(crate::util::RNG_ARRIVALS);
        let r1 = w1.fork_rng(crate::util::RNG_ARRIVALS);
        let src0: Box<dyn ArrivalSource> = Box::new(VecSource::new(jobs, 90.0));
        let empty: Box<dyn ArrivalSource> = Box::new(VecSource::new(Vec::new(), 90.0));
        Federation::routed(
            vec![w0, w1],
            vec![src0, empty],
            vec![r0, r1],
            Box::new(RoundRobin::default()),
        )
    }

    #[test]
    fn open_but_empty_inbox_is_idle_not_done() {
        let mut a0 = Hybrid::eagle(2.0);
        let mut a1 = Hybrid::eagle(2.0);
        let mut serial = gap_fed(&mut a0, &mut a1);
        serial.run();
        let jobs: Vec<u64> = serial.members().iter().map(|m| m.jobs_seen()).collect();
        assert_eq!(jobs.iter().sum::<u64>(), 4, "late post-gap arrivals were dropped");
        assert_eq!(jobs, vec![2, 2], "round-robin must deliver across the gap");
        for m in serial.members() {
            assert_eq!(m.outstanding_tasks(), 0);
            assert_eq!(m.rec.tasks_finished, 2);
        }
        // The PDES path must honor the same invariant: a `None` horizon
        // (quiescence) is only declared once the feed is drained.
        for threads in [1, 4] {
            let mut b0 = Hybrid::eagle(2.0);
            let mut b1 = Hybrid::eagle(2.0);
            let mut pdes = gap_fed(&mut b0, &mut b1);
            pdes.run_pdes(threads);
            assert_federations_bit_identical(&serial, &pdes);
        }
    }

    #[test]
    fn least_queued_prefers_lowest_loaded_then_lowest_index() {
        let mk = |loads: [u64; 3]| {
            loads
                .iter()
                .enumerate()
                .map(|(index, &outstanding_tasks)| MemberView {
                    index,
                    outstanding_tasks,
                    resident_jobs: 0,
                })
                .collect::<Vec<_>>()
        };
        let mut r = LeastQueued;
        let j = Job { id: JobId(0), arrival: 0.0, task_durations: vec![1.0], is_long: false };
        assert_eq!(r.route(&j, 0, &mk([5, 2, 9])), 1);
        assert_eq!(r.route(&j, 0, &mk([4, 4, 4])), 0, "ties must break low");
        assert_eq!(r.route(&j, 2, &mk([7, 3, 3])), 1);
    }
}
