//! Standard [`Component`]s for composing simulation scenarios:
//!
//! * [`SchedulerComponent`] — adapts any [`Scheduler`] (placement on job
//!   arrival, orphan replacement on revocation).
//! * [`TransientManagerComponent`] — the §3.2 Transient Manager
//!   (resize on long-occupancy changes, provisioning/warning/prewarm
//!   handling).
//! * [`WorkStealer`] — Hawk-lineage randomized task stealing by newly
//!   idle servers.
//! * [`SnapshotSampler`] — the periodic metrics snapshot and (optional)
//!   predictive l_r forecast that feeds the manager's prewarm path.
//!
//! The canonical wirings — Eagle baseline, CloudCoaster, manager-less
//! Sparrow/Centralized — live in `coordinator::runner::build_world`;
//! custom scenarios compose the same pieces differently (see the crate
//! docs for a quickstart).

use crate::cluster::Cluster;
use crate::runtime::Analytics;
use crate::sched::{SchedCtx, Scheduler};
use crate::sim::{Component, Engine, Event, Rng, WorldCtx};
use crate::transient::{ManagerConfig, TransientManager};
use crate::util::{ServerRef, Time};

// ------------------------------------------------------------- scheduler

/// Adapts a [`Scheduler`] to the component interface: places arriving
/// jobs and re-places revocation orphans.
pub struct SchedulerComponent<'s> {
    scheduler: &'s mut dyn Scheduler,
}

impl<'s> SchedulerComponent<'s> {
    pub fn new(scheduler: &'s mut dyn Scheduler) -> Self {
        SchedulerComponent { scheduler }
    }
}

impl Component for SchedulerComponent<'_> {
    fn name(&self) -> &'static str {
        "scheduler"
    }

    fn on_event(&mut self, _now: Time, event: &Event, ctx: &mut WorldCtx) {
        match event {
            Event::JobArrival(_) => {
                let job = ctx.job.expect("JobArrival dispatched without its job"); // lint: allow(panic-surface): World::dispatch_event always stages the job before a JobArrival
                let mut sctx = SchedCtx {
                    cluster: &mut *ctx.cluster,
                    engine: &mut *ctx.engine,
                    rec: &mut *ctx.rec,
                    rng: &mut *ctx.rng,
                };
                self.scheduler.place_job(job, ctx.arrived, &mut sctx);
            }
            Event::Revoked(_) if !ctx.orphans.is_empty() => {
                let mut sctx = SchedCtx {
                    cluster: &mut *ctx.cluster,
                    engine: &mut *ctx.engine,
                    rec: &mut *ctx.rec,
                    rng: &mut *ctx.rng,
                };
                self.scheduler.replace_orphans(ctx.orphans, &mut sctx);
            }
            _ => {}
        }
    }
}

// ------------------------------------------------------------- transient

/// The §3.2 Transient Manager as a component: resizes the dynamic short
/// partition on long-occupancy changes and handles the transient-server
/// lifecycle events.
pub struct TransientManagerComponent {
    pub manager: TransientManager,
}

impl TransientManagerComponent {
    pub fn new(cfg: ManagerConfig, rng: Rng) -> Self {
        TransientManagerComponent { manager: TransientManager::new(cfg, rng) }
    }

    /// Manager wired to a federated cross-cluster transient pool: every
    /// lease request must also take a [`SharedBudget`] unit, so the
    /// federation's pooled cap binds across clusters.
    pub fn with_shared_budget(
        cfg: ManagerConfig,
        rng: Rng,
        shared: crate::transient::SharedBudget,
    ) -> Self {
        let mut c = Self::new(cfg, rng);
        c.manager.set_shared_budget(shared);
        c
    }

    /// `(adds, drains, failed_requests)` — the run-report triple.
    pub fn stats(&self) -> (u64, u64, u64) {
        (self.manager.adds, self.manager.drains, self.manager.failed_requests)
    }
}

impl Component for TransientManagerComponent {
    fn name(&self) -> &'static str {
        "transient-manager"
    }

    fn on_event(&mut self, _now: Time, event: &Event, ctx: &mut WorldCtx) {
        match event {
            Event::TransientReady(sid) => {
                self.manager.on_ready(*sid, &mut *ctx.cluster, &*ctx.engine, &mut *ctx.rec);
            }
            Event::RevocationWarning(sid) => {
                self.manager.on_warning(*sid, &mut *ctx.cluster, &*ctx.engine, &mut *ctx.rec);
            }
            Event::Snapshot => {
                // Forecast published by an upstream SnapshotSampler.
                if let Some(lr) = ctx.take_prewarm() {
                    self.manager.prewarm(lr, &mut *ctx.cluster, &mut *ctx.engine, &mut *ctx.rec);
                }
            }
            _ => {}
        }
    }

    fn on_long_change(&mut self, _now: Time, ctx: &mut WorldCtx) {
        self.manager.maybe_resize(&mut *ctx.cluster, &mut *ctx.engine, &mut *ctx.rec);
    }

    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }
}

// ---------------------------------------------------------- work stealer

/// Hawk-lineage randomized stealing: a newly idle server probes for a
/// busy victim and takes a batch of its queued short tasks.
pub struct WorkStealer {
    /// Probes an idle server sends looking for a victim (0 disables).
    pub probes: usize,
    /// Max queued short tasks moved per steal.
    pub batch: usize,
}

impl Component for WorkStealer {
    fn name(&self) -> &'static str {
        "work-stealer"
    }

    fn on_event(&mut self, _now: Time, event: &Event, ctx: &mut WorldCtx) {
        let Event::TaskFinish { server, .. } = event else { return };
        if self.probes == 0 {
            return;
        }
        let thief = *server;
        {
            // Generation-checked: a drained server was retired by the
            // world core within this event — its slot may already be
            // released (and later recycled), so the stale handle must
            // not dereference. Busy servers don't steal either.
            let Some(s) = ctx.cluster.get_server(thief) else { return };
            if !(s.is_idle() && s.accepting()) {
                return;
            }
        }
        try_steal(
            &mut *ctx.cluster,
            thief,
            self.probes,
            self.batch,
            &mut *ctx.rng,
            &mut *ctx.engine,
            &mut *ctx.rec,
        );
    }
}

/// Steal probes for a newly idle server: sample candidates from the
/// short pools (where load-spike queues live) and the general partition,
/// steal from the first victim with queued work.
fn try_steal(
    cluster: &mut Cluster,
    thief: ServerRef,
    steal_probes: usize,
    steal_batch: usize,
    rng: &mut Rng,
    engine: &mut Engine,
    rec: &mut crate::metrics::Recorder,
) {
    // Long-hosting victims are fine: we only take their *short* tasks.
    for probe in 0..steal_probes {
        // Alternate between short pools and the general partition.
        let victim = if probe % 2 == 0 {
            let shorts = cluster.short_reserved.len() + cluster.transient_pool.len();
            if shorts == 0 {
                continue;
            }
            let k = rng.below(shorts as u64) as usize;
            if k < cluster.short_reserved.len() {
                cluster.short_reserved[k]
            } else {
                cluster.transient_pool[k - cluster.short_reserved.len()]
            }
        } else {
            cluster.general[rng.below(cluster.general.len() as u64) as usize]
        };
        // Dense hot-field read: depth minus running occupancy answers
        // "any queued work?" without touching the victim's struct.
        if !cluster.has_queued(victim) {
            continue;
        }
        if cluster.steal_short_tasks(victim, thief, steal_batch, engine, rec) > 0 {
            return;
        }
    }
}

// -------------------------------------------------------------- sampler

/// Periodic metrics snapshot (l_r and active-transient time series) and,
/// optionally, the predictive l_r forecast (Holt level+trend through the
/// analytics engine) published for the transient manager's prewarm.
pub struct SnapshotSampler<'a> {
    interval: f64,
    predictive: bool,
    /// Forecast horizon in snapshot steps (provisioning delay / interval).
    horizon_steps: f32,
    lr_history: Vec<f32>,
    analytics: Option<&'a mut dyn Analytics>,
}

impl<'a> SnapshotSampler<'a> {
    /// Plain reactive sampler: metrics only.
    pub fn new(interval: f64) -> Self {
        SnapshotSampler {
            interval,
            predictive: false,
            horizon_steps: 1.0,
            lr_history: Vec::new(),
            analytics: None,
        }
    }

    /// Predictive sampler: additionally forecasts l_r `horizon_steps`
    /// snapshots ahead and publishes it via [`WorldCtx::signal_prewarm`].
    pub fn predictive(
        interval: f64,
        horizon_steps: f32,
        analytics: Option<&'a mut dyn Analytics>,
    ) -> Self {
        let window = crate::runtime::artifacts::FORECAST_WINDOW;
        SnapshotSampler {
            interval,
            predictive: true,
            horizon_steps,
            lr_history: Vec::with_capacity(window),
            analytics,
        }
    }
}

impl Component for SnapshotSampler<'_> {
    fn name(&self) -> &'static str {
        "snapshot-sampler"
    }

    fn on_start(&mut self, ctx: &mut WorldCtx) {
        // `work_remaining` at start == "the source has at least one job"
        // (the world primes its lookahead before components start).
        if ctx.work_remaining() {
            ctx.engine.schedule(self.interval, Event::Snapshot);
        }
    }

    fn on_event(&mut self, now: Time, event: &Event, ctx: &mut WorldCtx) {
        if !matches!(event, Event::Snapshot) {
            return;
        }
        let lr = ctx.cluster.long_load_ratio();
        ctx.rec.snapshot(now, lr, ctx.cluster.transient_pool.len() as f64);
        if self.predictive {
            let window = crate::runtime::artifacts::FORECAST_WINDOW;
            if self.lr_history.len() == window {
                self.lr_history.rotate_left(1);
                self.lr_history.pop();
            }
            self.lr_history.push(lr as f32);
            if self.lr_history.len() == window {
                if let Some(eng) = self.analytics.as_mut() {
                    if let Ok((forecast, _, _)) =
                        eng.lr_forecast(&self.lr_history, self.horizon_steps)
                    {
                        ctx.signal_prewarm(forecast as f64);
                    }
                }
            }
        }
        if ctx.work_remaining() {
            // Deferred so the manager's prewarm provisioning events (if
            // any) sort ahead of the next snapshot at equal timestamps —
            // the legacy runner's scheduling order.
            ctx.defer(now + self.interval, Event::Snapshot);
        }
    }
}
