//! Deterministic discrete-event simulation core: the event queue and
//! clock ([`Engine`] — a calendar queue with O(1) amortized push/pop
//! and same-timestamp batch draining; the pre-calendar `BinaryHeap`
//! survives as [`Engine::reference`] for golden comparisons), the
//! event vocabulary ([`Event`]), the reproducible PRNG ([`Rng`]), the
//! composable simulation [`World`] with its pluggable [`Component`]s,
//! and the multi-cluster [`Federation`] that advances several worlds
//! in global event-time order behind a pluggable [`JobRouter`] —
//! serially ([`Federation::run`], the reference merge) or with
//! conservative-window parallel execution ([`Federation::run_pdes`],
//! bit-identical at any thread count). The opt-in hot-path
//! [`Profiler`] rides on the world's dispatch loop and finalises into
//! a [`ProfileReport`] — see the `profiler` module docs for its
//! determinism contract (profiling never perturbs simulation bits).

pub mod components;
mod engine;
mod event;
pub mod federation;
mod profiler;
mod rng;
mod world;

pub use components::{
    SchedulerComponent, SnapshotSampler, TransientManagerComponent, WorkStealer,
};
pub use engine::Engine;
pub use event::Event;
pub use federation::{ClassSplit, Federation, JobRouter, LeastQueued, MemberView, RoundRobin};
pub use profiler::{ProfileReport, Profiler, Stopwatch};
pub use rng::Rng;
pub use world::{Component, World, WorldCtx};
