//! Deterministic discrete-event simulation core: the event queue and
//! clock ([`Engine`]), the event vocabulary ([`Event`]), the
//! reproducible PRNG ([`Rng`]), and the composable simulation
//! [`World`] with its pluggable [`Component`]s.

pub mod components;
mod engine;
mod event;
mod rng;
mod world;

pub use components::{
    SchedulerComponent, SnapshotSampler, TransientManagerComponent, WorkStealer,
};
pub use engine::Engine;
pub use event::Event;
pub use rng::Rng;
pub use world::{Component, World, WorldCtx};
