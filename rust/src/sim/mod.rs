//! Deterministic discrete-event simulation core: the event queue and
//! clock ([`Engine`]), the event vocabulary ([`Event`]), and the
//! reproducible PRNG ([`Rng`]).

mod engine;
mod event;
mod rng;

pub use engine::Engine;
pub use event::Event;
pub use rng::Rng;
