//! Opt-in hot-path profiler: counts events by [`Event::kind`],
//! attributes wall time per event class and per [`Component`], and
//! reports the cluster's allocation-pool hit/miss counters — the
//! in-binary evidence behind the zero-alloc/SoA hot-path claims, so a
//! 1e4-server burst-storm run can be profiled reproducibly instead of
//! once under an external tool.
//!
//! **Determinism contract**: profiling is enabled per run
//! (`SimConfig::profile` / `--profile`) and is *excluded from the
//! bit-identity surface* — the counters never feed back into the
//! simulation, so every simulation observable is bit-identical with
//! profiling on or off (pinned by the streaming goldens). Within the
//! profile itself, event counts and pool counters are pure functions
//! of the run and repeat bit-exactly run to run (CI pins this); wall
//! times are wall clock and are not comparable across runs.
//!
//! [`Component`]: crate::sim::Component

use crate::cluster::PoolStats;
use crate::sim::Event;

/// The sanctioned wall-clock primitive for sim code. `pallas-lint`'s
/// `wall-clock-quarantine` rule bans `std::time::Instant` outside this
/// module (plus the runner and benchkit), so any real-time measurement
/// a sim path needs — today, the world's per-event/per-component
/// profiling — goes through a `Stopwatch`. That keeps the quarantine
/// lexically checkable: a grep for `Instant` finds only timing modules,
/// and every wall-clock read inherits this module's determinism
/// contract (never feeds back into simulation observables).
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    /// Start timing now.
    #[inline]
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }

    /// Wall nanoseconds since [`Stopwatch::start`], saturating at
    /// `u64::MAX` (585 years — plenty for an event handler).
    #[inline]
    pub fn elapsed_ns(&self) -> u64 {
        let nanos = self.0.elapsed().as_nanos();
        if nanos > u64::MAX as u128 {
            u64::MAX
        } else {
            nanos as u64
        }
    }
}

/// Upper bound on profiled components per world (the dispatch loop
/// times into a fixed stack array to stay allocation-free; standard
/// wirings use at most four components).
pub const MAX_PROFILED_COMPONENTS: usize = 16;

/// Live profiling state owned by a `World` while a profiled run is in
/// flight. Finalised into a [`ProfileReport`] by `World::take_profile`.
#[derive(Clone, Debug, Default)]
pub struct Profiler {
    /// Popped events per class, indexed by [`Event::kind_index`]
    /// (stale generation-filtered events count too — they cost a pop).
    pub event_counts: [u64; Event::N_KINDS],
    /// Wall nanoseconds of `dispatch_event` per class (core lifecycle +
    /// component dispatch + completion accounting).
    pub event_nanos: [u64; Event::N_KINDS],
    /// Component names, registered in wiring order at first dispatch.
    pub component_names: Vec<&'static str>,
    /// Wall nanoseconds inside each component's handlers (`on_event` +
    /// `on_long_change`), parallel to `component_names`.
    pub component_nanos: Vec<u64>,
}

impl Profiler {
    /// Account one dispatched event of class `kind_idx`.
    #[inline]
    pub fn record_event(&mut self, kind_idx: usize, nanos: u64) {
        self.event_counts[kind_idx] += 1;
        self.event_nanos[kind_idx] += nanos;
    }

    /// Account handler time for the component at wiring position `i`.
    #[inline]
    pub fn record_component(&mut self, i: usize, name: &'static str, nanos: u64) {
        while self.component_names.len() <= i {
            self.component_names.push("");
            self.component_nanos.push(0);
        }
        self.component_names[i] = name;
        self.component_nanos[i] += nanos;
    }

    /// Finalise into a report, folding in the cluster's pool counters.
    pub fn into_report(self, pools: PoolStats) -> ProfileReport {
        ProfileReport {
            by_kind: Event::KINDS
                .iter()
                .enumerate()
                .map(|(i, &k)| (k, self.event_counts[i], self.event_nanos[i]))
                .collect(),
            by_component: self
                .component_names
                .iter()
                .zip(&self.component_nanos)
                .map(|(&n, &ns)| (n, ns))
                .collect(),
            pools,
        }
    }
}

/// A finished run's hot-path profile. Reported as a separate section
/// (stderr) and a JSON artifact next to the CDF — never on the default
/// stdout surface, which stays byte-identical to an unprofiled run.
#[derive(Clone, Debug)]
pub struct ProfileReport {
    /// `(kind, count, wall_ns)` per event class, in [`Event::KINDS`]
    /// order. Counts are deterministic; wall_ns is not.
    pub by_kind: Vec<(&'static str, u64, u64)>,
    /// `(component, wall_ns)` in wiring order.
    pub by_component: Vec<(&'static str, u64)>,
    /// Allocation-pool hit/miss counters (deterministic).
    pub pools: PoolStats,
}

impl ProfileReport {
    /// Total events popped (sum over classes).
    pub fn events_total(&self) -> u64 {
        self.by_kind.iter().map(|(_, c, _)| c).sum()
    }

    /// Human-readable report section (stderr).
    pub fn render(&self) -> String {
        let mut out = String::from("-- hot-path profile --\n");
        out.push_str(&format!("events: {} total\n", self.events_total()));
        for &(kind, count, ns) in &self.by_kind {
            if count == 0 {
                continue;
            }
            out.push_str(&format!(
                "  {kind:<20} {count:>10}  {:>9.2} ms\n",
                ns as f64 / 1e6
            ));
        }
        out.push_str("components:\n");
        for &(name, ns) in &self.by_component {
            out.push_str(&format!("  {name:<20} {:>9.2} ms\n", ns as f64 / 1e6));
        }
        let p = &self.pools;
        out.push_str(&format!(
            "pools (hit/miss): task slots {}/{}, server slots {}/{}, queue buffers {}/{}\n",
            p.task_slot_hits,
            p.task_slot_misses,
            p.server_slot_hits,
            p.server_slot_misses,
            p.queue_buf_hits,
            p.queue_buf_misses,
        ));
        out
    }

    /// JSON artifact. Deterministic fields (`event_counts`, `pools`)
    /// are separate objects from the wall-clock ones so CI can pin
    /// run-to-run identity on just the counts.
    pub fn to_json(&self) -> String {
        let counts: Vec<String> = self
            .by_kind
            .iter()
            .map(|(k, c, _)| format!("\"{k}\": {c}"))
            .collect();
        let walls: Vec<String> = self
            .by_kind
            .iter()
            .map(|(k, _, ns)| format!("\"{k}\": {ns}"))
            .collect();
        let comps: Vec<String> = self
            .by_component
            .iter()
            .map(|(n, ns)| format!("\"{n}\": {ns}"))
            .collect();
        let p = &self.pools;
        format!(
            "{{\n  \"events_total\": {},\n  \"event_counts\": {{{}}},\n  \
             \"event_wall_ns\": {{{}}},\n  \"component_wall_ns\": {{{}}},\n  \
             \"pools\": {{\"task_slot_hits\": {}, \"task_slot_misses\": {}, \
             \"server_slot_hits\": {}, \"server_slot_misses\": {}, \
             \"queue_buf_hits\": {}, \"queue_buf_misses\": {}}}\n}}\n",
            self.events_total(),
            counts.join(", "),
            walls.join(", "),
            comps.join(", "),
            p.task_slot_hits,
            p.task_slot_misses,
            p.server_slot_hits,
            p.server_slot_misses,
            p.queue_buf_hits,
            p.queue_buf_misses,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_and_serializes() {
        let mut prof = Profiler::default();
        prof.record_event(0, 1500);
        prof.record_event(1, 2500);
        prof.record_event(1, 500);
        prof.record_component(0, "scheduler", 1000);
        prof.record_component(1, "work-stealer", 2000);
        let mut pools = PoolStats::default();
        pools.task_slot_hits = 9;
        pools.queue_buf_misses = 1;
        let rep = prof.into_report(pools);
        assert_eq!(rep.events_total(), 3);
        assert_eq!(rep.by_kind[0], ("job_arrival", 1, 1500));
        assert_eq!(rep.by_kind[1], ("task_finish", 2, 3000));
        let text = rep.render();
        assert!(text.contains("job_arrival"));
        assert!(text.contains("scheduler"));
        assert!(text.contains("queue buffers 0/1"));
        let json = rep.to_json();
        assert!(json.contains("\"events_total\": 3"));
        assert!(json.contains("\"task_finish\": 2"));
        assert!(json.contains("\"task_slot_hits\": 9"));
        // Balanced braces (cheap well-formedness check without a parser).
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }

    #[test]
    fn stopwatch_is_monotonic() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_ns();
        let b = sw.elapsed_ns();
        assert!(b >= a);
    }

    #[test]
    fn kind_tables_agree() {
        // The profiler's fixed arrays rely on KINDS/kind_index agreeing.
        assert_eq!(Event::KINDS.len(), Event::N_KINDS);
        assert_eq!(Event::Snapshot.kind_index(), Event::N_KINDS - 1);
        assert_eq!(Event::KINDS[Event::Snapshot.kind_index()], "snapshot");
    }
}
