//! The composable simulation world.
//!
//! [`World`] owns the discrete-event [`Engine`], the [`Cluster`], the
//! metrics [`Recorder`] and the forked RNG streams, and drives the event
//! loop over a [`Workload`]. Everything *policy* — placement, transient
//! management, work stealing, sampling — lives in an ordered list of
//! pluggable [`Component`]s dispatched per [`Event`]. New scenarios
//! (manager-less baselines, injected burst storms, custom samplers) are
//! component wiring, not new match arms.
//!
//! The world itself keeps only the trace-replay responsibilities that
//! define the simulation's semantics:
//!
//! * materialising each arriving job's tasks and scheduling the next
//!   arrival (after dispatch, so placement-scheduled events keep their
//!   legacy queue order);
//! * cluster lifecycle bookkeeping for `TaskFinish` / `Revoked` /
//!   `DrainComplete` (stale-finish filtering, drain retirement,
//!   revocation orphan collection);
//! * per-job completion accounting and the end-of-run transient
//!   close-out.
//!
//! Determinism: given the same workload, seed and component wiring, the
//! run is bitwise identical to the pre-component monolithic runner —
//! enforced by `tests/golden_determinism.rs`.

use crate::cluster::{Cluster, ServerKind, ServerState, TaskState};
use crate::metrics::Recorder;
use crate::sim::{Engine, Event, Rng};
use crate::trace::Workload;
use crate::util::{JobId, TaskId, Time};

/// Mutable per-event view handed to components.
///
/// Fields are the world's core state; the scratch slices (`arrived`,
/// `orphans`) carry the current event's payload between the world core
/// and the components that act on it.
pub struct WorldCtx<'w> {
    pub cluster: &'w mut Cluster,
    pub engine: &'w mut Engine,
    pub rec: &'w mut Recorder,
    /// The shared scheduler-side RNG stream (probe sampling, stealing) —
    /// fork label 0x5C off the root seed, as in the original runner.
    pub rng: &'w mut Rng,
    pub workload: &'w Workload,
    /// Tasks materialised for the `JobArrival` being dispatched (empty
    /// for other events).
    pub arrived: &'w [TaskId],
    /// Tasks orphaned by the `Revoked` being dispatched (empty
    /// otherwise).
    pub orphans: &'w [TaskId],
    outstanding_tasks: u64,
    next_job: usize,
    prewarm_lr: &'w mut Option<f64>,
    deferred: &'w mut Vec<(Time, Event)>,
}

impl WorldCtx<'_> {
    /// Is there still work in flight or jobs yet to arrive? (Periodic
    /// components use this to decide whether to reschedule themselves.)
    pub fn work_remaining(&self) -> bool {
        self.outstanding_tasks > 0 || self.next_job < self.workload.jobs.len()
    }

    /// Publish a forecast long-load ratio for a downstream component
    /// (the transient manager) to act on within this event.
    pub fn signal_prewarm(&mut self, forecast_lr: f64) {
        *self.prewarm_lr = Some(forecast_lr);
    }

    /// Consume the forecast published earlier in this event, if any.
    pub fn take_prewarm(&mut self) -> Option<f64> {
        self.prewarm_lr.take()
    }

    /// Schedule `event` at `at`, *after* every component has run for the
    /// current event. Use this when the event must sort behind anything
    /// a later component schedules at the same timestamp (e.g. the
    /// snapshot sampler's own reschedule vs. the manager's prewarm
    /// provisioning events).
    pub fn defer(&mut self, at: Time, event: Event) {
        self.deferred.push((at, event));
    }
}

/// A pluggable simulation behaviour, dispatched per event in wiring
/// order. Implementations: the scheduler adapter, the transient manager,
/// the Hawk-lineage work stealer, the snapshot/forecast sampler (see
/// [`crate::sim::components`]).
pub trait Component {
    fn name(&self) -> &'static str {
        "component"
    }

    /// Called once before the first event — schedule initial periodic
    /// events here.
    fn on_start(&mut self, _ctx: &mut WorldCtx) {}

    /// Called for every processed (non-stale) event, in component order.
    fn on_event(&mut self, now: Time, event: &Event, ctx: &mut WorldCtx);

    /// Called after any event that changed long-task occupancy — the
    /// paper's §3.2 recalculation trigger.
    fn on_long_change(&mut self, _now: Time, _ctx: &mut WorldCtx) {}

    /// Downcast hook so callers can extract component-specific stats
    /// after a run (return `Some(self)` from `'static` components).
    fn as_any(&self) -> Option<&dyn std::any::Any> {
        None
    }
}

/// The composed simulation: engine + cluster + recorder + RNG streams +
/// ordered components, run over one workload.
pub struct World<'w> {
    pub cluster: Cluster,
    pub engine: Engine,
    pub rec: Recorder,
    workload: &'w Workload,
    root_rng: Rng,
    sched_rng: Rng,
    components: Vec<Box<dyn Component + 'w>>,
    /// Remaining unfinished tasks per job (response-time accounting).
    job_remaining: Vec<u32>,
    outstanding: u64,
    next_job: usize,
    arrived: Vec<TaskId>,
    orphans: Vec<TaskId>,
    prewarm_lr: Option<f64>,
    deferred: Vec<(Time, Event)>,
}

impl<'w> World<'w> {
    /// Build a world over `workload`. RNG streams fork off `seed` in a
    /// fixed order: the scheduler stream first (label 0x5C), then
    /// whatever the caller forks via [`World::fork_rng`] — matching the
    /// original runner so fixed-seed runs stay bit-identical.
    pub fn new(workload: &'w Workload, cluster: Cluster, rec: Recorder, seed: u64) -> Self {
        let mut root_rng = Rng::new(seed);
        let sched_rng = root_rng.fork(0x5C);
        World {
            cluster,
            engine: Engine::new(),
            rec,
            workload,
            root_rng,
            sched_rng,
            components: Vec::new(),
            job_remaining: workload.jobs.iter().map(|j| j.num_tasks() as u32).collect(),
            outstanding: workload.num_tasks() as u64,
            next_job: 0,
            arrived: Vec::new(),
            orphans: Vec::new(),
            prewarm_lr: None,
            deferred: Vec::new(),
        }
    }

    /// Derive an independent RNG stream for a component (e.g. the
    /// transient market uses label 0x7A).
    pub fn fork_rng(&mut self, label: u64) -> Rng {
        self.root_rng.fork(label)
    }

    /// Append a component; dispatch follows insertion order.
    pub fn add_component(&mut self, component: Box<dyn Component + 'w>) -> &mut Self {
        self.components.push(component);
        self
    }

    pub fn workload(&self) -> &'w Workload {
        self.workload
    }

    /// Find a component by concrete type (post-run stat extraction).
    pub fn component<T: 'static>(&self) -> Option<&T> {
        self.components.iter().find_map(|c| c.as_any()?.downcast_ref::<T>())
    }

    fn ctx(&mut self) -> WorldCtx<'_> {
        WorldCtx {
            cluster: &mut self.cluster,
            engine: &mut self.engine,
            rec: &mut self.rec,
            rng: &mut self.sched_rng,
            workload: self.workload,
            arrived: &self.arrived,
            orphans: &self.orphans,
            outstanding_tasks: self.outstanding,
            next_job: self.next_job,
            prewarm_lr: &mut self.prewarm_lr,
            deferred: &mut self.deferred,
        }
    }

    fn flush_deferred(&mut self) {
        if self.deferred.is_empty() {
            return;
        }
        let mut pending = std::mem::take(&mut self.deferred);
        for (at, event) in pending.drain(..) {
            self.engine.schedule(at, event);
        }
        self.deferred = pending; // keep the allocation
    }

    /// Drive the event loop to quiescence.
    pub fn run(&mut self) {
        let mut components = std::mem::take(&mut self.components);
        if !self.workload.jobs.is_empty() {
            self.engine.schedule(self.workload.jobs[0].arrival, Event::JobArrival(JobId(0)));
        }
        {
            let mut ctx = self.ctx();
            for c in components.iter_mut() {
                c.on_start(&mut ctx);
            }
        }
        self.flush_deferred();

        while let Some((now, event)) = self.engine.pop() {
            // ---- core pre-dispatch: trace replay + cluster lifecycle ----
            self.arrived.clear();
            self.orphans.clear();
            self.prewarm_lr = None;
            match event {
                Event::JobArrival(jid) => {
                    let job = &self.workload.jobs[jid.index()];
                    for &d in &job.task_durations {
                        let tid = self.cluster.add_task(job.id, d, job.is_long, now);
                        self.arrived.push(tid);
                    }
                }
                Event::TaskFinish { server, task } => {
                    // A revocation may have killed this execution after
                    // its finish event was scheduled (the task restarts
                    // elsewhere with a new finish event) — drop the
                    // stale one before any component sees it.
                    {
                        let t = self.cluster.task(task);
                        if t.state != TaskState::Running || t.ran_on != Some(server) {
                            continue;
                        }
                    }
                    let drained =
                        self.cluster.on_task_finish(server, task, &mut self.engine, &mut self.rec);
                    if drained {
                        self.cluster.retire(server, now, &mut self.rec);
                    }
                }
                Event::Revoked(sid) => {
                    let state = self.cluster.server(sid).state;
                    if matches!(state, ServerState::Active | ServerState::Draining) {
                        self.orphans = self.cluster.revoke(sid, now, &mut self.rec);
                    }
                }
                Event::DrainComplete(sid) => {
                    if self.cluster.server(sid).state == ServerState::Draining
                        && self.cluster.server(sid).is_idle()
                    {
                        self.cluster.retire(sid, now, &mut self.rec);
                    }
                }
                Event::TransientReady(_) | Event::RevocationWarning(_) | Event::Snapshot => {}
            }

            // Did this event change long-task occupancy? (`is_long` is
            // immutable, so reading it after the state transition is
            // equivalent to the legacy in-arm flags.)
            let long_change = match event {
                Event::JobArrival(jid) => self.workload.jobs[jid.index()].is_long,
                Event::TaskFinish { task, .. } => self.cluster.task(task).is_long,
                _ => false,
            };

            // ---- dispatch to components, in wiring order ----
            {
                let mut ctx = self.ctx();
                for c in components.iter_mut() {
                    c.on_event(now, &event, &mut ctx);
                }
            }

            // ---- core post-dispatch: arrival cursor + completions ----
            match event {
                Event::JobArrival(jid) => {
                    self.next_job = jid.index() + 1;
                    if self.next_job < self.workload.jobs.len() {
                        self.engine.schedule(
                            self.workload.jobs[self.next_job].arrival,
                            Event::JobArrival(JobId(self.next_job as u32)),
                        );
                    }
                }
                Event::TaskFinish { task, .. } => {
                    self.outstanding -= 1;
                    let jid = self.cluster.task(task).job;
                    let rem = &mut self.job_remaining[jid.index()];
                    *rem -= 1;
                    if *rem == 0 {
                        let job = &self.workload.jobs[jid.index()];
                        self.rec.job_finished(job.is_long, now - job.arrival);
                    }
                }
                _ => {}
            }
            self.flush_deferred();

            if long_change {
                let mut ctx = self.ctx();
                for c in components.iter_mut() {
                    c.on_long_change(now, &mut ctx);
                }
            }
        }

        // ---- run end: close out transients still up ----
        let end_time = self.engine.now();
        let live: Vec<_> = self
            .cluster
            .servers
            .iter()
            .filter(|s| {
                s.kind == ServerKind::Transient
                    && matches!(s.state, ServerState::Active | ServerState::Draining)
            })
            .map(|s| s.id)
            .collect();
        for sid in live {
            self.cluster.retire(sid, end_time, &mut self.rec);
        }
        debug_assert_eq!(self.outstanding, 0, "tasks lost by the simulation");
        #[cfg(debug_assertions)]
        self.cluster.check_invariants();
        self.components = components;
    }
}
