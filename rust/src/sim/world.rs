//! The composable simulation world.
//!
//! [`World`] owns the discrete-event [`Engine`], the [`Cluster`], the
//! metrics [`Recorder`] and the forked RNG streams, and drives the event
//! loop over a streaming [`ArrivalSource`]. Everything *policy* —
//! placement, transient management, work stealing, sampling — lives in
//! an ordered list of pluggable [`Component`]s dispatched per [`Event`].
//! New scenarios (manager-less baselines, injected burst storms, custom
//! samplers) are component wiring plus source combinators, not new match
//! arms.
//!
//! **Streaming arrivals**: the world keeps exactly one job of lookahead
//! pulled from the source — the job whose `JobArrival` event is in the
//! queue. Its task durations are materialised into the cluster arena at
//! dispatch and the `Job` itself is dropped at the end of the event;
//! only a small per-job metadata record (arrival, class, remaining task
//! count) survives until the job completes. Peak resident job count is
//! therefore set by cluster load, not trace length (tracked by
//! [`World::peak_resident_jobs`]); the cluster's generational task and
//! server arenas bound task and server slots the same way
//! ([`World::peak_resident_tasks`] / [`World::peak_resident_servers`]),
//! and the recorder's per-sample delay populations stream through
//! fixed-memory histogram sketches, and the sampled snapshot time
//! series through bounded rebucketing rings — so per-job, per-task,
//! per-transient *and* per-snapshot state is load-bound, not
//! trace-bound.
//!
//! **Stepping**: the event loop is exposed piecewise —
//! [`World::start`] / [`World::step`] / [`World::finish`] — so a
//! [`crate::sim::Federation`] can interleave several worlds in global
//! event-time order without perturbing a single world's event
//! sequence. Externally-routed worlds use an inbox feed
//! ([`World::new_inbox`] / [`World::inject_job`]) instead of pulling
//! from a source they own. [`World::run`] drives the same per-event
//! dispatch through [`World::step_batch`], which drains each maximal
//! run of equal-time events from the engine in one call
//! ([`Engine::pop_batch`]) and dispatches them in seq order — the
//! event-for-event order (and therefore every report bit) is identical
//! to the single-step loop, but the queue is touched once per unique
//! timestamp instead of once per event, which matters under the
//! paper's bursty arrivals where a burst lands hundreds of
//! same-timestamp events.
//!
//! **Borrowed lookahead**: a world built over an eager [`Workload`]
//! ([`World::from_workload`]) borrows each job straight from the
//! workload slice instead of pulling owned clones through a
//! [`crate::trace::WorkloadReplay`] — zero per-job allocation or
//! durations memcpy on
//! the eager/shared-workload path, with pull order, id assignment and
//! RNG usage identical to the streaming path (fixed-seed runs are
//! bit-identical either way).
//!
//! The world core keeps only the trace-replay responsibilities that
//! define the simulation's semantics:
//!
//! * materialising each arriving job's tasks and scheduling the next
//!   arrival (after dispatch, so placement-scheduled events keep their
//!   legacy queue order);
//! * cluster lifecycle bookkeeping for `TaskFinish` / `Revoked` /
//!   `DrainComplete` (stale-finish filtering via the arena's
//!   [`FinishOutcome`], drain retirement, revocation orphan collection);
//! * per-job completion accounting — keyed off fields extracted *at*
//!   the finish event, never read back through a possibly-recycled
//!   [`TaskRef`] — and the end-of-run transient close-out.
//!
//! Determinism: given the same source, seed and component wiring, the
//! run is bitwise identical to the pre-component monolithic runner —
//! enforced by `tests/golden_determinism.rs` (eager replay) and
//! `tests/streaming_golden.rs` (streaming synthesis + combinators +
//! arena recycling on/off).

use std::collections::{HashMap, VecDeque};

use crate::cluster::{Cluster, FinishOutcome, ServerKind, ServerState};
use crate::metrics::Recorder;
use crate::sim::profiler::MAX_PROFILED_COMPONENTS;
use crate::sim::{Engine, Event, ProfileReport, Profiler, Rng, Stopwatch};
use crate::trace::{ArrivalSource, Job, Workload};
use crate::util::{JobId, TaskRef, Time, RNG_ARRIVALS, RNG_SCHED};

/// Mutable per-event view handed to components.
///
/// Fields are the world's core state; the scratch fields (`job`,
/// `arrived`, `orphans`) carry the current event's payload between the
/// world core and the components that act on it.
pub struct WorldCtx<'w> {
    pub cluster: &'w mut Cluster,
    pub engine: &'w mut Engine,
    pub rec: &'w mut Recorder,
    /// The shared scheduler-side RNG stream (probe sampling, stealing) —
    /// fork label [`crate::util::RNG_SCHED`] off the root seed, as in
    /// the original runner.
    pub rng: &'w mut Rng,
    /// The job whose `JobArrival` is being dispatched (`None` for every
    /// other event). Dropped when the event ends — components must copy
    /// what they need.
    pub job: Option<&'w Job>,
    /// Tasks materialised for the `JobArrival` being dispatched (empty
    /// for other events).
    pub arrived: &'w [TaskRef],
    /// Tasks orphaned by the `Revoked` being dispatched (empty
    /// otherwise).
    pub orphans: &'w [TaskRef],
    outstanding_tasks: u64,
    more_jobs: bool,
    prewarm_lr: &'w mut Option<f64>,
    deferred: &'w mut Vec<(Time, Event)>,
}

impl WorldCtx<'_> {
    /// Is there still work in flight or jobs yet to arrive? (Periodic
    /// components use this to decide whether to reschedule themselves.)
    pub fn work_remaining(&self) -> bool {
        self.outstanding_tasks > 0 || self.more_jobs
    }

    /// Publish a forecast long-load ratio for a downstream component
    /// (the transient manager) to act on within this event.
    pub fn signal_prewarm(&mut self, forecast_lr: f64) {
        *self.prewarm_lr = Some(forecast_lr);
    }

    /// Consume the forecast published earlier in this event, if any.
    pub fn take_prewarm(&mut self) -> Option<f64> {
        self.prewarm_lr.take()
    }

    /// Schedule `event` at `at`, *after* every component has run for the
    /// current event. Use this when the event must sort behind anything
    /// a later component schedules at the same timestamp (e.g. the
    /// snapshot sampler's own reschedule vs. the manager's prewarm
    /// provisioning events).
    pub fn defer(&mut self, at: Time, event: Event) {
        self.deferred.push((at, event));
    }
}

/// A pluggable simulation behaviour, dispatched per event in wiring
/// order. Implementations: the scheduler adapter, the transient manager,
/// the Hawk-lineage work stealer, the snapshot/forecast sampler (see
/// [`crate::sim::components`]).
///
/// `Send` (like [`crate::trace::ArrivalSource`] and
/// [`crate::sched::Scheduler`]) so a fully wired `World` can advance on
/// a federation PDES worker thread. Components stay thread-confined —
/// only the world that owns them ever calls in — the bound just lets
/// the owning world migrate between threads at window boundaries.
pub trait Component: Send {
    fn name(&self) -> &'static str {
        "component"
    }

    /// Called once before the first event — schedule initial periodic
    /// events here.
    fn on_start(&mut self, _ctx: &mut WorldCtx) {}

    /// Called for every processed (non-stale) event, in component order.
    fn on_event(&mut self, now: Time, event: &Event, ctx: &mut WorldCtx);

    /// Called after any event that changed long-task occupancy — the
    /// paper's §3.2 recalculation trigger.
    fn on_long_change(&mut self, _now: Time, _ctx: &mut WorldCtx) {}

    /// Downcast hook so callers can extract component-specific stats
    /// after a run (return `Some(self)` from `'static` components).
    fn as_any(&self) -> Option<&dyn std::any::Any> {
        None
    }
}

/// Completion-accounting record for a job with unfinished tasks — all
/// that survives of a job once its arrival event has been dispatched.
struct JobMeta {
    arrival: Time,
    is_long: bool,
    remaining: u32,
}

/// Where arrivals come from: a boxed streaming source, the
/// borrowed-lookahead fast path over an eager workload slice (no
/// per-job clone), or an externally-fed inbox (federation routing:
/// jobs are pushed by [`World::inject_job`] instead of pulled).
enum Feed<'w> {
    Stream(Box<dyn ArrivalSource + 'w>),
    Eager { workload: &'w Workload, next: usize },
    /// Externally fed: an open inbox may be empty *now* yet receive
    /// more jobs later, so exhaustion is only declared once
    /// [`World::close_inbox`] has been called and the queue drained.
    Inbox { queue: VecDeque<Job>, closed: bool },
}

/// One job of lookahead: owned (streamed) or borrowed from an eager
/// workload.
enum JobRef<'w> {
    Owned(Job),
    Borrowed(&'w Job),
}

impl JobRef<'_> {
    #[inline]
    fn job(&self) -> &Job {
        match *self {
            JobRef::Owned(ref j) => j,
            JobRef::Borrowed(j) => j,
        }
    }
}

/// The composed simulation: engine + cluster + recorder + RNG streams +
/// ordered components, run over one streaming arrival source (or an
/// eager workload via the borrowed fast path).
pub struct World<'w> {
    pub cluster: Cluster,
    pub engine: Engine,
    pub rec: Recorder,
    feed: Feed<'w>,
    root_rng: Rng,
    sched_rng: Rng,
    components: Vec<Box<dyn Component + 'w>>,
    /// Per-job completion accounting, keyed by `JobId.0` — entries live
    /// from arrival to last task finish (O(active jobs), not O(trace)).
    // lint: allow(unordered-iter): keyed access only (insert/get_mut/remove/len/is_empty) — never iterated, so randomized order cannot reach an observable
    job_meta: HashMap<u32, JobMeta>,
    /// Tasks materialised but not yet finished.
    outstanding: u64,
    /// Sequential id assigned to the next job pulled from the source.
    next_id: u32,
    /// Arrival of the last pulled job (source-ordering assertion).
    last_arrival: Time,
    /// One-job lookahead: pulled from the feed, arrival event queued.
    lookahead: Option<JobRef<'w>>,
    source_done: bool,
    /// The arrival RNG stream ([`crate::util::RNG_ARRIVALS`]), forked
    /// at [`World::start`].
    /// Held in an `Option` so [`World::step`]'s feed advance can take it
    /// without splitting a borrow of `self`.
    arrivals_rng: Option<Rng>,
    /// The job being dispatched in the current `JobArrival` event.
    current_job: Option<JobRef<'w>>,
    peak_resident: usize,
    /// `(job, is_long)` of the task completed by the `TaskFinish` being
    /// dispatched — extracted at the finish so completion accounting
    /// never dereferences a recycled arena slot.
    finished: Option<(JobId, bool)>,
    arrived: Vec<TaskRef>,
    orphans: Vec<TaskRef>,
    prewarm_lr: Option<f64>,
    deferred: Vec<(Time, Event)>,
    /// Reusable same-timestamp scratch for [`World::step_batch`] (one
    /// allocation for the whole run, not one per batch).
    batch: Vec<Event>,
    /// Opt-in hot-path profiler ([`World::enable_profiler`]). Counters
    /// never feed back into the simulation, so every simulation
    /// observable is bit-identical with profiling on or off.
    profiler: Option<Profiler>,
}

impl<'w> World<'w> {
    /// Build a world over a streaming `source`. RNG streams fork off
    /// `seed` in a fixed order: the scheduler stream first
    /// (`RNG_SCHED`), then whatever the caller forks via
    /// [`World::fork_rng`], then the arrival stream (`RNG_ARRIVALS`,
    /// forked at [`World::run`]) — matching the original runner so
    /// fixed-seed runs stay bit-identical. The label table and
    /// canonical order live in `util/rng_labels.rs`.
    pub fn new(
        source: Box<dyn ArrivalSource + 'w>,
        cluster: Cluster,
        rec: Recorder,
        seed: u64,
    ) -> Self {
        Self::with_feed(Feed::Stream(source), cluster, rec, seed)
    }

    /// Build a world replaying an eager [`Workload`] through the
    /// borrowed-lookahead fast path: jobs are handed to dispatch by
    /// reference, skipping the per-pull clone a
    /// [`crate::trace::WorkloadReplay`] adapter would pay. Bit-identical
    /// to streaming the same jobs.
    pub fn from_workload(
        workload: &'w Workload,
        cluster: Cluster,
        rec: Recorder,
        seed: u64,
    ) -> Self {
        Self::with_feed(Feed::Eager { workload, next: 0 }, cluster, rec, seed)
    }

    /// Build an externally-fed world (federation routing): arrivals are
    /// pushed via [`World::inject_job`] by an outer driver instead of
    /// pulled from a source the world owns. The driver must call
    /// [`World::close_inbox`] once its global stream is exhausted, or
    /// periodic components will keep the run alive forever. RNG fork
    /// order is identical to the other constructors, so member worlds
    /// stay stream-for-stream compatible with standalone ones.
    pub fn new_inbox(cluster: Cluster, rec: Recorder, seed: u64) -> Self {
        Self::with_feed(
            Feed::Inbox { queue: VecDeque::new(), closed: false },
            cluster,
            rec,
            seed,
        )
    }

    fn with_feed(feed: Feed<'w>, cluster: Cluster, rec: Recorder, seed: u64) -> Self {
        let mut root_rng = Rng::new(seed);
        let sched_rng = root_rng.fork(RNG_SCHED);
        // Pending events are dominated by one `TaskFinish` per busy
        // server, so the static fleet is the natural engine pre-size
        // (the runner replaces this with a transient-aware hint when it
        // knows the manager budget; any hint is bit-identical).
        let engine = Engine::with_capacity(cluster.servers.len() + 64);
        World {
            cluster,
            engine,
            rec,
            feed,
            root_rng,
            sched_rng,
            components: Vec::new(),
            // lint: allow(unordered-iter): construction of the keyed-access-only job_meta map
            job_meta: HashMap::new(),
            outstanding: 0,
            next_id: 0,
            last_arrival: f64::NEG_INFINITY,
            lookahead: None,
            source_done: false,
            arrivals_rng: None,
            current_job: None,
            peak_resident: 0,
            finished: None,
            arrived: Vec::new(),
            orphans: Vec::new(),
            prewarm_lr: None,
            deferred: Vec::new(),
            batch: Vec::new(),
            profiler: None,
        }
    }

    /// Turn on hot-path profiling for this run (`--profile`): events
    /// counted and wall-timed by class, wall time per component, and
    /// the cluster's allocation-pool counters at close-out. Profiling
    /// is excluded from the bit-identity surface — it observes the run
    /// without perturbing it.
    pub fn enable_profiler(&mut self) {
        self.profiler = Some(Profiler::default());
    }

    /// Finalise and take this run's profile (`None` when profiling was
    /// never enabled), folding in the cluster's pool counters. Call
    /// after [`World::finish`].
    pub fn take_profile(&mut self) -> Option<ProfileReport> {
        let pools = self.cluster.pool_stats();
        self.profiler.take().map(|p| p.into_report(pools))
    }

    /// Derive an independent RNG stream for a component (e.g. the
    /// transient market uses [`crate::util::RNG_MARKET`]).
    pub fn fork_rng(&mut self, label: u64) -> Rng {
        self.root_rng.fork(label)
    }

    /// Append a component; dispatch follows insertion order.
    pub fn add_component(&mut self, component: Box<dyn Component + 'w>) -> &mut Self {
        self.components.push(component);
        self
    }

    /// Find a component by concrete type (post-run stat extraction).
    pub fn component<T: 'static>(&self) -> Option<&T> {
        self.components.iter().find_map(|c| c.as_any()?.downcast_ref::<T>())
    }

    /// Jobs pulled from the source so far.
    pub fn jobs_seen(&self) -> u64 {
        self.next_id as u64
    }

    /// High-water mark of concurrently-resident job records — bounded by
    /// cluster load, independent of trace length (the streaming-memory
    /// guarantee; pinned by `tests/streaming_golden.rs`).
    pub fn peak_resident_jobs(&self) -> usize {
        self.peak_resident
    }

    /// High-water mark of concurrently-resident task-arena slots — the
    /// arena-recycling twin of [`World::peak_resident_jobs`]: bounded by
    /// cluster load, independent of trace length.
    pub fn peak_resident_tasks(&self) -> usize {
        self.cluster.peak_resident_tasks()
    }

    /// High-water mark of concurrently-resident server-arena slots:
    /// on-demand size + peak concurrent transients — with slot
    /// recycling this bounds the server arena even under heavy
    /// revocation churn, independent of transients ever requested.
    pub fn peak_resident_servers(&self) -> usize {
        self.cluster.peak_resident_servers()
    }

    /// Tasks materialised but not yet finished — the federation's
    /// least-queued router keys on this (O(1), maintained by the core).
    pub fn outstanding_tasks(&self) -> u64 {
        self.outstanding
    }

    /// Jobs currently resident (arrived, not yet fully finished).
    pub fn resident_jobs(&self) -> usize {
        self.job_meta.len()
    }

    /// Can the feed still yield jobs beyond the current lookahead? Only
    /// an *open* inbox can: stream and eager feeds pull eagerly into the
    /// lookahead, so for them `lookahead == None` after an advance means
    /// exhausted.
    fn feed_pending(&self) -> bool {
        match &self.feed {
            Feed::Inbox { queue, closed } => !queue.is_empty() || !*closed,
            Feed::Stream(_) | Feed::Eager { .. } => false,
        }
    }

    fn ctx(&mut self) -> WorldCtx<'_> {
        // Computed before the field borrows below: a method call on
        // `self` inside the struct literal would conflict with them.
        let more_jobs = self.lookahead.is_some() || self.feed_pending();
        WorldCtx {
            cluster: &mut self.cluster,
            engine: &mut self.engine,
            rec: &mut self.rec,
            rng: &mut self.sched_rng,
            job: self.current_job.as_ref().map(|j| j.job()),
            arrived: &self.arrived,
            orphans: &self.orphans,
            outstanding_tasks: self.outstanding,
            more_jobs,
            prewarm_lr: &mut self.prewarm_lr,
            deferred: &mut self.deferred,
        }
    }

    fn flush_deferred(&mut self) {
        if self.deferred.is_empty() {
            return;
        }
        let mut pending = std::mem::take(&mut self.deferred);
        for (at, event) in pending.drain(..) {
            self.engine.schedule(at, event);
        }
        self.deferred = pending; // keep the allocation
    }

    /// Pull the next job into the lookahead slot, assigning it the next
    /// sequential id. Enforces the source's nondecreasing-arrival
    /// contract (a violation would corrupt the event queue). The eager
    /// feed borrows the job in place; streams hand over owned jobs.
    fn advance_source(&mut self, arrivals_rng: &mut Rng) {
        debug_assert!(self.lookahead.is_none(), "lookahead overwritten");
        if self.source_done {
            return;
        }
        let pulled: Option<JobRef<'w>> = match &mut self.feed {
            Feed::Eager { workload, next } => {
                let w: &'w Workload = *workload;
                match w.jobs.get(*next) {
                    Some(job) => {
                        *next += 1;
                        if job.id.0 == self.next_id {
                            Some(JobRef::Borrowed(job))
                        } else {
                            // Non-canonical ids (hand-built Workload):
                            // fall back to an owned, re-id'd clone.
                            let mut j = job.clone();
                            j.id = JobId(self.next_id);
                            Some(JobRef::Owned(j))
                        }
                    }
                    None => None,
                }
            }
            Feed::Stream(source) => match source.next_job(arrivals_rng) {
                Some(mut job) => {
                    job.id = JobId(self.next_id);
                    Some(JobRef::Owned(job))
                }
                None => None,
            },
            Feed::Inbox { queue, closed } => match queue.pop_front() {
                Some(mut job) => {
                    job.id = JobId(self.next_id);
                    Some(JobRef::Owned(job))
                }
                // An open inbox that is empty *now* is not exhausted —
                // the driver may inject more; leave `source_done`
                // untouched and retry at the next inject.
                None if !*closed => return,
                None => None,
            },
        };
        match pulled {
            Some(jobref) => {
                let arrival = jobref.job().arrival;
                assert!(
                    arrival >= self.last_arrival,
                    "ArrivalSource produced out-of-order arrival {} after {}",
                    arrival,
                    self.last_arrival
                );
                self.last_arrival = arrival;
                // lint: allow(panic-surface): job ids are u32 by design; a 4-billion-job trace is out of scope and overflow must not wrap silently
                self.next_id = self.next_id.checked_add(1).expect("more than u32::MAX jobs");
                self.lookahead = Some(jobref);
            }
            None => self.source_done = true,
        }
    }

    /// Advance the feed into the lookahead slot and, if a job arrived
    /// there, schedule its `JobArrival`. The arrival RNG is threaded
    /// through `self.arrivals_rng` (taken/restored so the feed advance
    /// doesn't split a `self` borrow) — state-for-state identical to the
    /// local variable the pre-stepping `run()` threaded by `&mut`.
    fn prime_arrival(&mut self) {
        // lint: allow(panic-surface): start() populates arrivals_rng before any event dispatches; absence is a driver wiring bug
        let mut rng = self.arrivals_rng.take().expect("prime_arrival before start()");
        self.advance_source(&mut rng);
        self.arrivals_rng = Some(rng);
        if let Some(jobref) = &self.lookahead {
            let job = jobref.job();
            self.engine.schedule(job.arrival, Event::JobArrival(job.id));
        }
    }

    /// Push a job into an inbox-fed world (see [`World::new_inbox`]).
    /// Arrivals must be injected in nondecreasing arrival order and
    /// never before the world's clock (the federation routes in global
    /// event-time order, which guarantees both). If the world is idle on
    /// arrivals (no lookahead), the job is primed and its arrival event
    /// scheduled immediately.
    pub fn inject_job(&mut self, job: Job) {
        let Feed::Inbox { queue, closed } = &mut self.feed else {
            // lint: allow(panic-surface): API misuse by the federation driver — injecting into a self-fed world corrupts arrival order, so fail fast
            panic!("inject_job on a world that owns its arrival feed");
        };
        assert!(!*closed, "inject_job after close_inbox");
        queue.push_back(job);
        if self.lookahead.is_none() && !self.source_done {
            self.prime_arrival();
        }
    }

    /// Declare the inbox's upstream exhausted: once the queued jobs
    /// drain, the world treats its source as done (so periodic
    /// components stop rescheduling and the run can quiesce).
    pub fn close_inbox(&mut self) {
        if let Feed::Inbox { queue, closed } = &mut self.feed {
            *closed = true;
            if self.lookahead.is_none() && queue.is_empty() {
                self.source_done = true;
            }
        }
    }

    /// Time of the next queued event, if any (the federation's global
    /// earliest-next-event merge keys on this).
    pub fn next_event_time(&self) -> Option<Time> {
        self.engine.peek_time()
    }

    /// Prepare the event loop: fork the arrival stream, prime the first
    /// lookahead + arrival event, run every component's `on_start`.
    /// Fork order — scheduler stream `RNG_SCHED` at construction,
    /// component streams (e.g. the market's `RNG_MARKET`) while wiring,
    /// `RNG_ARRIVALS` here — matches the original runner, so
    /// fixed-seed runs are bit-identical (table: `util/rng_labels.rs`).
    /// [`World::run`] is exactly `start` + `step`-loop +
    /// `finish`; the pieces are public so a federation can interleave
    /// several worlds in global event-time order.
    pub fn start(&mut self) {
        debug_assert!(self.arrivals_rng.is_none(), "start() called twice");
        // The arrival stream forks off the root *after* the scheduler
        // stream (RNG_SCHED, at construction) and any component streams
        // the caller forked while wiring (e.g. the market's RNG_MARKET)
        // — so the streaming refactor leaves every legacy stream
        // bit-identical.
        self.arrivals_rng = Some(self.root_rng.fork(RNG_ARRIVALS));
        self.prime_arrival();
        let mut components = std::mem::take(&mut self.components);
        {
            let mut ctx = self.ctx();
            for c in components.iter_mut() {
                c.on_start(&mut ctx);
            }
        }
        self.components = components;
        self.flush_deferred();
    }

    /// Process exactly one event, returning its timestamp (`None` once
    /// the engine has quiesced). A stale (generation-filtered) finish
    /// still counts as a processed step. The federation's serial merge
    /// and its PDES windows over budget-managed members step through
    /// this (never [`World::step_batch`]): the global merge interleaves
    /// members *per event* — routed arrivals inject between
    /// same-timestamp events, and the fleet watermark samples after
    /// every event — so batch granularity would be observable.
    pub fn step(&mut self) -> Option<Time> {
        let (now, event) = self.engine.pop()?;
        let mut components = std::mem::take(&mut self.components);
        self.dispatch_event(now, event, &mut components);
        self.components = components;
        Some(now)
    }

    /// Process every event sharing the next timestamp in one call,
    /// returning that timestamp (`None` once the engine has quiesced).
    /// Events dispatch in exactly the `(time, seq)` order of a
    /// [`World::step`] loop — anything a handler schedules *at* the
    /// current timestamp has a higher seq than every drained event, so
    /// it forms the next batch, precisely where a per-event pop would
    /// have placed it. [`World::run`] is built on this; the per-batch
    /// saving is one engine head-restore per unique timestamp instead
    /// of one per event.
    pub fn step_batch(&mut self) -> Option<Time> {
        let mut batch = std::mem::take(&mut self.batch);
        let popped = self.engine.pop_batch(&mut batch);
        let Some(now) = popped else {
            self.batch = batch;
            return None;
        };
        let mut components = std::mem::take(&mut self.components);
        for &event in &batch {
            self.dispatch_event(now, event, &mut components);
        }
        self.components = components;
        self.batch = batch;
        Some(now)
    }

    /// [`World::step_batch`], bounded: drain the next same-timestamp
    /// batch only when it lies strictly *before* `horizon`; otherwise
    /// process nothing and return `None`. The federation's PDES windows
    /// drive unmanaged members through this — events at or past the
    /// conservative horizon must wait for the serial merge boundary,
    /// where routed arrivals and shared-budget interactions reconcile.
    pub fn step_batch_before(&mut self, horizon: Time) -> Option<Time> {
        let mut batch = std::mem::take(&mut self.batch);
        let popped = self.engine.pop_batch_before(horizon, &mut batch);
        let Some(now) = popped else {
            self.batch = batch;
            return None;
        };
        let mut components = std::mem::take(&mut self.components);
        for &event in &batch {
            self.dispatch_event(now, event, &mut components);
        }
        self.components = components;
        self.batch = batch;
        Some(now)
    }

    /// Advance until the next event is at or past `horizon` (or the
    /// engine quiesces), batch-granular; returns events processed. The
    /// scratch buffer behind [`World::step_batch`] is a `World` field,
    /// so repeated bounded runs — like [`World::run`]'s unbounded loop —
    /// allocate nothing in steady state.
    pub fn run_until(&mut self, horizon: Time) -> u64 {
        let before = self.engine.processed();
        while self.step_batch_before(horizon).is_some() {}
        self.engine.processed() - before
    }

    /// Arrival time of the primed one-job lookahead, if any — a lower
    /// bound on this world's next arrival intake. For an inbox-fed
    /// member this (or the feed's own lookahead, for the members still
    /// to be routed to) is what makes the federation's conservative
    /// horizon safe: no arrival can materialise inside a window that
    /// ends at or before every pending arrival's lower bound.
    pub fn pending_arrival(&self) -> Option<Time> {
        self.lookahead.as_ref().map(|j| j.job().arrival)
    }

    /// The per-event entry shared by [`World::step`] and
    /// [`World::step_batch`]. Unprofiled runs fall straight through to
    /// [`World::dispatch_event_core`]; profiled runs wrap it with
    /// wall-clock timing (whole event + per-component) and count every
    /// popped event — stale generation-filtered finishes included, since
    /// they cost a pop and their count is deterministic.
    fn dispatch_event(
        &mut self,
        now: Time,
        event: Event,
        components: &mut [Box<dyn Component + 'w>],
    ) {
        if self.profiler.is_none() {
            self.dispatch_event_core(now, event, components, &mut None);
            return;
        }
        let kind_idx = event.kind_index();
        // Timed into a stack array: `dispatch_event_core` borrows all of
        // `self` (the profiler included), so per-component nanos merge
        // into the profiler only after the core returns.
        let mut comp_nanos = [0u64; MAX_PROFILED_COMPONENTS];
        let started = Stopwatch::start();
        {
            let mut slot = Some(&mut comp_nanos);
            self.dispatch_event_core(now, event, components, &mut slot);
        }
        let total_ns = started.elapsed_ns();
        // lint: allow(panic-surface): checked is_none() above; the profiler is only taken at run end
        let prof = self.profiler.as_mut().expect("profiler vanished mid-event");
        prof.record_event(kind_idx, total_ns);
        for (i, c) in components.iter().enumerate().take(MAX_PROFILED_COMPONENTS) {
            prof.record_component(i, c.name(), comp_nanos[i]);
        }
    }

    /// The per-event core: arrival intake, cluster lifecycle, component
    /// dispatch, completion accounting. A stale (generation-filtered)
    /// finish returns before components see the event. `comp_nanos` is
    /// the profiling wrapper's per-component timing scratch (`None` on
    /// the unprofiled fast path — no timing code runs).
    fn dispatch_event_core(
        &mut self,
        now: Time,
        event: Event,
        components: &mut [Box<dyn Component + 'w>],
        comp_nanos: &mut Option<&mut [u64; MAX_PROFILED_COMPONENTS]>,
    ) {
        // ---- core pre-dispatch: arrival intake + cluster lifecycle ----
        self.arrived.clear();
        self.orphans.clear();
        self.prewarm_lr = None;
        self.current_job = None;
        self.finished = None;
        match event {
            Event::JobArrival(jid) => {
                // lint: allow(panic-surface): prime_arrival schedules JobArrival only after filling the lookahead; an empty slot is a lost-job invariant break
                let jobref =
                    self.lookahead.take().expect("JobArrival without a pulled job");
                {
                    let job = jobref.job();
                    debug_assert_eq!(job.id, jid, "arrival event out of step with source");
                    for &d in &job.task_durations {
                        let tid = self.cluster.add_task(job.id, d, job.is_long, now);
                        self.arrived.push(tid);
                    }
                    let n = job.num_tasks() as u32;
                    if n > 0 {
                        self.outstanding += n as u64;
                        self.job_meta.insert(
                            jid.0,
                            JobMeta {
                                arrival: job.arrival,
                                is_long: job.is_long,
                                remaining: n,
                            },
                        );
                        self.peak_resident = self.peak_resident.max(self.job_meta.len());
                    }
                }
                self.current_job = Some(jobref);
            }
            Event::TaskFinish { server, task } => {
                // The arena consumes the event's liveness ref and
                // filters stale finishes (a revocation killed this
                // execution after its event was scheduled; the task
                // restarted elsewhere with a new finish event).
                // Completion fields come out of the outcome — the
                // slot may recycle any time after this call.
                match self.cluster.on_task_finish(server, task, &mut self.engine, &mut self.rec)
                {
                    FinishOutcome::Stale => {
                        // Filtered pre-dispatch: components never see
                        // the event (the old loop's `continue`).
                        return;
                    }
                    FinishOutcome::Finished { job, is_long, drained } => {
                        if drained {
                            self.cluster.retire(server, now, &mut self.rec);
                        }
                        self.finished = Some((job, is_long));
                    }
                }
            }
            Event::Revoked(sid) => {
                // Generation-checked: a stale Revoked (the server
                // already drained/retired and its slot possibly
                // recycled) must not touch the slot's next tenant.
                let state = self.cluster.get_server(sid).map(|s| s.state);
                if matches!(state, Some(ServerState::Active | ServerState::Draining)) {
                    // Orphans land in the world's reusable scratch —
                    // zero allocation per revocation in steady state.
                    self.cluster.revoke_into(sid, now, &mut self.rec, &mut self.orphans);
                }
            }
            Event::DrainComplete(sid) => {
                let ok = self
                    .cluster
                    .get_server(sid)
                    .is_some_and(|s| s.state == ServerState::Draining && s.is_idle());
                if ok {
                    self.cluster.retire(sid, now, &mut self.rec);
                }
            }
            Event::TransientReady(_) | Event::RevocationWarning(_) | Event::Snapshot => {}
        }

        // Did this event change long-task occupancy? (Extracted
        // payloads, never a task-arena read-back: the finished
        // task's slot may already be recycled.)
        let long_change = match event {
            Event::JobArrival(_) => {
                self.current_job.as_ref().map(|j| j.job().is_long).unwrap_or(false)
            }
            Event::TaskFinish { .. } => {
                self.finished.map(|(_, is_long)| is_long).unwrap_or(false)
            }
            _ => false,
        };

        // ---- dispatch to components, in wiring order ----
        {
            let mut ctx = self.ctx();
            if let Some(nanos) = comp_nanos {
                for (i, c) in components.iter_mut().enumerate() {
                    let t0 = Stopwatch::start();
                    c.on_event(now, &event, &mut ctx);
                    if i < nanos.len() {
                        nanos[i] += t0.elapsed_ns();
                    }
                }
            } else {
                for c in components.iter_mut() {
                    c.on_event(now, &event, &mut ctx);
                }
            }
        }

        // ---- core post-dispatch: arrival lookahead + completions ----
        match event {
            Event::JobArrival(_) => {
                self.prime_arrival();
            }
            Event::TaskFinish { .. } => {
                // lint: allow(panic-surface): pre-dispatch filtered Stale outcomes and returned; a live finish always set self.finished
                let (jid, _) =
                    self.finished.expect("stale finishes are filtered pre-dispatch");
                self.outstanding -= 1;
                let done = {
                    // lint: allow(panic-surface): job_meta entries live from arrival to last finish; a miss means task/job accounting diverged
                    let meta = self
                        .job_meta
                        .get_mut(&jid.0)
                        .expect("task finish for unknown job");
                    meta.remaining -= 1;
                    meta.remaining == 0
                };
                if done {
                    // lint: allow(panic-surface): get_mut above proved the entry exists within this same event
                    let meta = self.job_meta.remove(&jid.0).expect("meta vanished");
                    self.rec.job_finished(meta.is_long, now - meta.arrival);
                }
            }
            _ => {}
        }
        self.flush_deferred();

        if long_change {
            let mut ctx = self.ctx();
            if let Some(nanos) = comp_nanos {
                for (i, c) in components.iter_mut().enumerate() {
                    let t0 = Stopwatch::start();
                    c.on_long_change(now, &mut ctx);
                    if i < nanos.len() {
                        nanos[i] += t0.elapsed_ns();
                    }
                }
            } else {
                for c in components.iter_mut() {
                    c.on_long_change(now, &mut ctx);
                }
            }
        }
    }

    /// Close out the run after the engine quiesces: retire transients
    /// still up, check conservation invariants. Call exactly once, after
    /// [`World::step`] returns `None`.
    pub fn finish(&mut self) {
        // ---- run end: close out transients still up ----
        let end_time = self.engine.now();
        let live: Vec<_> = self
            .cluster
            .servers
            .iter()
            .filter(|s| {
                s.kind == ServerKind::Transient
                    && matches!(s.state, ServerState::Active | ServerState::Draining)
            })
            .map(|s| s.id)
            .collect();
        for sid in live {
            self.cluster.retire(sid, end_time, &mut self.rec);
        }
        debug_assert_eq!(self.outstanding, 0, "tasks lost by the simulation");
        debug_assert!(self.job_meta.is_empty(), "jobs left incomplete");
        debug_assert_eq!(
            self.cluster.resident_tasks(),
            0,
            "task slots still pinned at quiescence"
        );
        #[cfg(debug_assertions)]
        self.cluster.check_invariants();
    }

    /// Drive the event loop to quiescence: [`World::start`] + a
    /// [`World::step_batch`] loop + [`World::finish`]. The batch loop
    /// dispatches events in exactly the order of a [`World::step`]
    /// loop (see [`World::step_batch`]), so a stepped (federated)
    /// world and a plain `run()` stay bit-identical event for event —
    /// pinned by the N=1 federation passthrough golden.
    pub fn run(&mut self) {
        self.start();
        while self.step_batch().is_some() {}
        self.finish();
    }
}
