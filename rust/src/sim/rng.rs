//! Deterministic PRNG for the simulator: xoshiro256++ seeded via splitmix64.
//!
//! The whole evaluation is reproducible from a single `u64` seed; every
//! subsystem (trace synthesis, probe sampling, market revocations) derives
//! an independent stream with [`Rng::fork`] so adding randomness in one
//! subsystem never perturbs another.

/// xoshiro256++ (Blackman & Vigna). Passes BigCrush; 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (label keeps forks distinct even from
    /// identical parent states).
    pub fn fork(&mut self, label: u64) -> Rng {
        let seed = self.next_u64() ^ label.wrapping_mul(0x9E3779B97F4A7C15);
        Rng::new(seed)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Exponential with the given mean (inverse-CDF).
    #[inline]
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal (Box–Muller; one value per call, simple & branchless
    /// enough for trace synthesis which is off the hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal: exp(N(mu, sigma^2)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pareto with scale `xm` and shape `alpha` (heavy-tailed task counts).
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        let u = 1.0 - self.f64();
        xm / u.powf(1.0 / alpha)
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates over a
    /// scratch buffer provided by the caller to keep the hot path
    /// allocation-free). `scratch` must have length `n` and contain
    /// `0..n as u32` in any order; it is left permuted.
    pub fn sample_distinct_into(&mut self, scratch: &mut [u32], k: usize, out: &mut Vec<u32>) {
        let n = scratch.len();
        let k = k.min(n);
        out.clear();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            scratch.swap(i, j);
            out.push(scratch[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent() {
        let mut root = Rng::new(7);
        let mut f1 = root.fork(1);
        let mut f2 = root.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let sum: f64 = (0..n).map(|_| r.exponential(10.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 10.0).abs() < 0.2, "mean={mean}");
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.lognormal(3.0, 1.5) > 0.0);
        }
    }

    #[test]
    fn pareto_exceeds_scale() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            assert!(r.pareto(2.0, 1.5) >= 2.0);
        }
    }

    #[test]
    fn sample_distinct_unique_and_in_range() {
        let mut r = Rng::new(17);
        let mut scratch: Vec<u32> = (0..100).collect();
        let mut out = Vec::new();
        r.sample_distinct_into(&mut scratch, 20, &mut out);
        assert_eq!(out.len(), 20);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(out.iter().all(|&x| x < 100));
    }

    #[test]
    fn sample_distinct_caps_at_n() {
        let mut r = Rng::new(19);
        let mut scratch: Vec<u32> = (0..5).collect();
        let mut out = Vec::new();
        r.sample_distinct_into(&mut scratch, 50, &mut out);
        assert_eq!(out.len(), 5);
    }
}
