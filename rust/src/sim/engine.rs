//! The discrete-event engine: a time-ordered queue with a deterministic
//! tie-break, the simulation clock, and lightweight event accounting.
//!
//! This is the innermost loop of the whole system — every simulated task
//! passes through `push` + `pop` at least twice — so the representation is
//! kept lean: a `BinaryHeap` of 24-byte entries keyed by `(time, seq)`.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

use crate::sim::Event;
use crate::util::{OrderedTime, Time};

#[derive(Debug, Clone, Copy)]
struct Entry {
    at: OrderedTime,
    seq: u64,
    event: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.at.cmp(&other.at).then(self.seq.cmp(&other.seq))
    }
}

/// Time-ordered event queue + simulation clock.
pub struct Engine {
    heap: BinaryHeap<Reverse<Entry>>,
    now: Time,
    seq: u64,
    processed: u64,
}

impl Engine {
    pub fn new() -> Self {
        Engine { heap: BinaryHeap::with_capacity(1 << 16), now: 0.0, seq: 0, processed: 0 }
    }

    /// Current simulation time (seconds).
    #[inline]
    pub fn now(&self) -> Time {
        self.now
    }

    /// Number of events popped so far (throughput metric for §Perf).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Number of events still queued.
    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `event` at absolute time `at`. Panics on NaN or on
    /// scheduling into the past — both are simulator bugs, not runtime
    /// conditions.
    #[inline]
    pub fn schedule(&mut self, at: Time, event: Event) {
        assert!(!at.is_nan(), "NaN event time for {event:?}");
        assert!(
            at >= self.now,
            "scheduling into the past: {at} < {} for {event:?}",
            self.now
        );
        let entry = Entry { at: OrderedTime(at), seq: self.seq, event };
        self.seq += 1;
        self.heap.push(Reverse(entry));
    }

    /// Schedule `event` after `delay` seconds.
    #[inline]
    pub fn schedule_after(&mut self, delay: Time, event: Event) {
        self.schedule(self.now + delay, event);
    }

    /// Pop the next event, advancing the clock. Returns `None` when the
    /// simulation has quiesced.
    #[inline]
    pub fn pop(&mut self) -> Option<(Time, Event)> {
        let Reverse(entry) = self.heap.pop()?;
        debug_assert!(entry.at.0 >= self.now, "time went backwards");
        self.now = entry.at.0;
        self.processed += 1;
        Some((entry.at.0, entry.event))
    }

    /// Peek at the next event time without popping.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|Reverse(e)| e.at.0)
    }
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{JobId, ServerRef, TaskRef};

    #[test]
    fn pops_in_time_order() {
        let mut e = Engine::new();
        e.schedule(3.0, Event::Snapshot);
        e.schedule(1.0, Event::JobArrival(JobId(1)));
        e.schedule(2.0, Event::JobArrival(JobId(2)));
        let times: Vec<f64> = std::iter::from_fn(|| e.pop()).map(|(t, _)| t).collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut e = Engine::new();
        e.schedule(5.0, Event::JobArrival(JobId(1)));
        e.schedule(5.0, Event::JobArrival(JobId(2)));
        e.schedule(5.0, Event::JobArrival(JobId(3)));
        let ids: Vec<u32> = std::iter::from_fn(|| e.pop())
            .map(|(_, ev)| match ev {
                Event::JobArrival(j) => j.0,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut e = Engine::new();
        e.schedule(1.0, Event::Snapshot);
        e.schedule(4.0, Event::Snapshot);
        e.pop();
        assert_eq!(e.now(), 1.0);
        // schedule_after is relative to the advanced clock
        e.schedule_after(1.5, Event::TaskFinish { server: ServerRef::initial(0), task: TaskRef { slot: 0, gen: 0 } });
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, 2.5);
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, 4.0);
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn rejects_past_events() {
        let mut e = Engine::new();
        e.schedule(5.0, Event::Snapshot);
        e.pop();
        e.schedule(1.0, Event::Snapshot);
    }

    #[test]
    fn counts_processed() {
        let mut e = Engine::new();
        for i in 0..10 {
            e.schedule(i as f64, Event::Snapshot);
        }
        while e.pop().is_some() {}
        assert_eq!(e.processed(), 10);
        assert_eq!(e.pending(), 0);
    }
}
