//! The discrete-event engine: a time-ordered queue with a deterministic
//! tie-break, the simulation clock, and lightweight event accounting.
//!
//! This is the innermost loop of the whole system — every simulated task
//! passes through `push` + `pop` at least twice — so the default
//! representation is a **calendar queue**: a two-tier bucketed structure
//! giving O(1) amortized push/pop under the MMPP arrival mix, versus the
//! O(log n) of the [`BinaryHeap`] it replaced (the heap survives as
//! [`Engine::reference`] purely for golden/equivalence comparisons —
//! `SimConfig::reference_engine`, mirroring the arena `recycle_*`
//! pattern).
//!
//! # Calendar queue layout
//!
//! Entries are 24-byte `(time, seq, event)` records. The structure is:
//!
//! * a **year of buckets** — `nb` (a power of two) unsorted `Vec`s
//!   covering the rolling window `[window_start, window_start + nb·w)`,
//!   where `w` is the bucket width. An in-window entry lives in bucket
//!   `⌊(t − window_start)/w⌋`.
//! * the **open bucket** `cur` — the contents of bucket `cur_bucket`,
//!   sorted ascending by `(time, seq)` and consumed front-to-back
//!   through `cur_pos`. Buckets are sorted lazily, each exactly once,
//!   when the drain reaches them.
//! * an **overflow rung** — a min-heap holding far-future events (index
//!   ≥ `nb`: transient MTTF revocation horizons, long forecast
//!   deadlines). Overflow entries are re-bucketed **lazily on
//!   rollover**: only when the window advances onto them, so an event a
//!   simulated year out is touched O(log overflow) times total, not
//!   once per window.
//!
//! # Invariants (why the total order is exact)
//!
//! 1. **Total order preserved.** The bucket index function
//!    `i(t) = clamp(⌊(t − window_start)/w⌋, 0, ∞)` is monotone
//!    nondecreasing in `t` (f64 subtraction and division by a positive
//!    constant are monotone), so a smaller-time entry can never land in
//!    a later bucket than a larger-time one — even under floating-point
//!    rounding at bucket boundaries. Draining buckets in index order
//!    with an in-bucket `(time, seq)` sort therefore yields *exactly*
//!    the global `(time, seq)` order the heap produced; equal-time
//!    entries share one bucket (same index) and sort by insertion seq.
//!    Pinned against the in-tree heap oracle by `tests/engine_props.rs`
//!    and end-to-end by the determinism goldens.
//! 2. **Rollover correctness.** Membership (bucket vs overflow) is
//!    decided by the *same* index function, so the overflow rung only
//!    ever holds entries ordered after every in-window entry. When the
//!    window empties, it jumps to the earliest overflow time and
//!    re-buckets exactly the entries whose new index is in-window; the
//!    remainder stay strictly later. Every bucket belongs to exactly
//!    one window (no modulo wrap-around years).
//! 3. **Head availability.** After every mutation the earliest entry is
//!    at `cur[cur_pos]` (restored eagerly), so [`Engine::peek_time`] is
//!    O(1) — the federation's earliest-next-event merge keys on it once
//!    per member per step.
//!
//! # Self-tuning (no config knob)
//!
//! The bucket width tracks observed inter-event spacing: a decayed mean
//! of the nonzero gaps between consecutively popped timestamps
//! (deterministic — a pure function of the event sequence, never the
//! wall clock). The width is re-derived from it at structural resizes
//! (bucket count doubles when occupancy exceeds 2 entries/bucket,
//! shrinks when it falls below 1/8) and at rollovers, where retuning is
//! free because the window is empty. Capacity hints
//! ([`Engine::with_capacity`]) pre-size the bucket count from expected
//! pending events — one `TaskFinish` per busy server plus transient
//! lifecycle traffic — so an N-member federation no longer pre-pays
//! N × 64Ki heap slots.
//!
//! # Batch dispatch
//!
//! [`Engine::pop_batch`] drains the maximal run of equal-time events in
//! seq order into a caller-owned scratch buffer (the run is contiguous
//! in the open bucket — equal times share one index). `World::step`'s
//! batch path dispatches such runs through the component list with the
//! per-event callback order unchanged, skipping the per-event loop
//! setup; events scheduled *during* a batch at the same timestamp have
//! higher seqs and form the next batch, which is exactly the order a
//! per-event pop loop would produce.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

use crate::sim::Event;
use crate::util::{OrderedTime, Time};

#[derive(Debug, Clone, Copy)]
struct Entry {
    at: OrderedTime,
    seq: u64,
    event: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.at.cmp(&other.at).then(self.seq.cmp(&other.seq))
    }
}

/// Smallest / largest bucket counts the calendar will use. The floor
/// keeps tiny queues cheap; the ceiling bounds the Vec-header footprint
/// (24 B each) at planet scale.
const MIN_BUCKETS: usize = 16;
const MAX_BUCKETS: usize = 1 << 20;
/// Default pre-size for [`Engine::new`] (standalone/test use): modest,
/// grows on demand. Wired runs pass a load-derived hint instead.
const DEFAULT_HINT: usize = 256;
/// Bucket width clamp: keeps the window arithmetic finite and the
/// index function well-defined under degenerate gap estimates.
const MIN_WIDTH: f64 = 1e-9;
const MAX_WIDTH: f64 = 1e12;

/// The calendar/ladder structure behind the default engine. See the
/// module docs for layout and invariants.
struct Calendar {
    /// The year of buckets; `buckets[cur_bucket]` is always empty (its
    /// live contents are `cur`), as are all buckets below it.
    buckets: Vec<Vec<Entry>>,
    /// Bucket width `w` (seconds); finite, in `[MIN_WIDTH, MAX_WIDTH]`.
    width: f64,
    /// Start of the current window; bucket `i` covers
    /// `[window_start + i·w, window_start + (i+1)·w)` modulo the
    /// monotone-clamp at index 0.
    window_start: f64,
    /// The open bucket's contents, ascending `(time, seq)`, consumed
    /// from `cur_pos`. If the queue is nonempty, `cur[cur_pos]` is the
    /// global minimum (head invariant).
    cur: Vec<Entry>,
    cur_pos: usize,
    cur_bucket: usize,
    /// Far-future rung: entries whose index is ≥ `buckets.len()`.
    overflow: BinaryHeap<Reverse<Entry>>,
    /// Live entries in `cur[cur_pos..]` + all `buckets` (excludes the
    /// overflow rung).
    in_window: usize,
    /// Decayed mean of nonzero inter-pop gaps — the spacing estimate
    /// the width self-tunes from. 0.0 until the first nonzero gap.
    gap_ewma: f64,
    /// Timestamp of the most recent pop (−∞ before the first).
    last_pop: f64,
}

impl Calendar {
    fn with_capacity(hint: usize) -> Self {
        let nb = hint.clamp(MIN_BUCKETS, MAX_BUCKETS).next_power_of_two();
        Calendar {
            buckets: std::iter::repeat_with(Vec::new).take(nb).collect(),
            width: 1.0,
            window_start: 0.0,
            cur: Vec::new(),
            cur_pos: 0,
            cur_bucket: 0,
            overflow: BinaryHeap::new(),
            in_window: 0,
            gap_ewma: 0.0,
            last_pop: f64::NEG_INFINITY,
        }
    }

    fn len(&self) -> usize {
        self.in_window + self.overflow.len()
    }

    /// Bucket index of `t` under the current window: monotone
    /// nondecreasing in `t` (the order-exactness keystone — see module
    /// docs). Returns `usize::MAX` for the overflow rung.
    #[inline]
    // lint: hot-path
    fn index_of(&self, t: f64) -> usize {
        let d = (t - self.window_start) / self.width;
        if d <= 0.0 {
            0
        } else if d >= self.buckets.len() as f64 {
            usize::MAX
        } else {
            d as usize
        }
    }

    #[inline]
    fn peek(&self) -> Option<&Entry> {
        self.cur.get(self.cur_pos)
    }

    // lint: hot-path
    fn push(&mut self, e: Entry) {
        if self.len() == 0 {
            // Re-anchor an empty calendar on the incoming event so a
            // sparse queue never walks dead buckets to reach it.
            self.window_start = e.at.0;
            self.cur_bucket = 0;
            self.cur.clear();
            self.cur_pos = 0;
        }
        let i = self.index_of(e.at.0);
        if i >= self.buckets.len() {
            self.overflow.push(Reverse(e));
        } else if i == self.cur_bucket {
            // Into the open bucket: keep the ascending (time, seq)
            // order. Equal-time storms append at the tail (their seq is
            // the running maximum), so tie bursts are O(1) per push.
            let tail = &self.cur[self.cur_pos..];
            let pos = tail.partition_point(|x| x < &e);
            self.cur.insert(self.cur_pos + pos, e);
            self.in_window += 1;
        } else if i < self.cur_bucket {
            // Earlier than the open bucket (the drain skipped empty
            // buckets ahead of a gap, then a near-term event was
            // scheduled behind it): hand the open bucket's unconsumed
            // tail back and reopen bucket `i`. All buckets below
            // `cur_bucket` are empty, so `cur` becomes exactly `[e]`.
            let cb = self.cur_bucket;
            let mut returned = std::mem::take(&mut self.buckets[cb]);
            returned.extend(self.cur.drain(self.cur_pos..));
            self.buckets[cb] = returned;
            self.cur.clear();
            self.cur_pos = 0;
            self.cur.push(e);
            self.cur_bucket = i;
            self.in_window += 1;
        } else {
            self.buckets[i].push(e);
            self.in_window += 1;
        }
        if self.len() > 2 * self.buckets.len() && self.buckets.len() < MAX_BUCKETS {
            self.resize();
        }
        self.ensure_head();
    }

    // lint: hot-path
    fn pop(&mut self) -> Option<Entry> {
        let e = *self.peek()?;
        self.cur_pos += 1;
        self.in_window -= 1;
        self.note_pop(e.at.0);
        self.maybe_shrink();
        self.ensure_head();
        Some(e)
    }

    /// Drain the maximal run of equal-time entries (contiguous in the
    /// open bucket — equal times share one index) into `out`,
    /// returning the shared timestamp. Exactly equivalent to repeated
    /// [`Calendar::pop`] while the head time is unchanged.
    // lint: hot-path
    fn pop_run(&mut self, out: &mut Vec<Event>) -> Option<Time> {
        let t = self.peek()?.at;
        while let Some(e) = self.cur.get(self.cur_pos) {
            if e.at != t {
                break;
            }
            out.push(e.event);
            self.cur_pos += 1;
            self.in_window -= 1;
        }
        self.note_pop(t.0);
        self.maybe_shrink();
        self.ensure_head();
        Some(t.0)
    }

    /// Track inter-pop spacing for the width self-tuner. Zero gaps
    /// (same-timestamp batches) are skipped: bucket width should track
    /// the spacing of *distinct* timestamps, and a pop-batch drain must
    /// tune identically to the per-pop loop it replaces.
    // lint: hot-path
    #[inline]
    fn note_pop(&mut self, t: f64) {
        if t > self.last_pop {
            if self.last_pop.is_finite() {
                let gap = t - self.last_pop;
                self.gap_ewma = if self.gap_ewma > 0.0 {
                    0.875 * self.gap_ewma + 0.125 * gap
                } else {
                    gap
                };
            }
            self.last_pop = t;
        }
    }

    #[inline]
    fn maybe_shrink(&mut self) {
        if self.buckets.len() > MIN_BUCKETS && self.len() * 8 < self.buckets.len() {
            self.resize();
        }
    }

    /// The width the spacing estimate currently suggests: ~2 distinct
    /// timestamps per bucket, clamped to keep the window finite.
    fn tuned_width(&self) -> f64 {
        if self.gap_ewma > 0.0 {
            (2.0 * self.gap_ewma).clamp(MIN_WIDTH, MAX_WIDTH)
        } else {
            self.width
        }
    }

    /// Rebuild the bucket array for the current queue size: new bucket
    /// count ~ live entries (power of two), new width from the spacing
    /// estimate, window re-anchored at the clock floor. O(live
    /// entries), amortized O(1) by the doubling/halving thresholds.
    fn resize(&mut self) {
        let nb = self.len().clamp(MIN_BUCKETS, MAX_BUCKETS).next_power_of_two();
        let mut stash: Vec<Entry> = Vec::with_capacity(self.in_window);
        stash.extend(self.cur.drain(self.cur_pos..));
        self.cur.clear();
        self.cur_pos = 0;
        for b in &mut self.buckets {
            stash.append(b);
        }
        self.buckets.resize_with(nb, Vec::new);
        self.width = self.tuned_width();
        // Every live entry's time is ≥ the engine clock (scheduling
        // into the past panics), so the last pop is a valid window
        // anchor; entries landing at or before it clamp into bucket 0,
        // which the monotone index keeps order-exact.
        if self.last_pop.is_finite() {
            self.window_start = self.last_pop;
        }
        self.cur_bucket = 0;
        self.in_window = 0;
        for e in stash {
            let i = self.index_of(e.at.0);
            if i >= self.buckets.len() {
                self.overflow.push(Reverse(e));
            } else {
                self.buckets[i].push(e);
                self.in_window += 1;
            }
        }
        // A wider window may now cover rung entries; a narrower one
        // pushed some out above. Either way, re-establish invariant 2.
        self.drain_overflow();
        self.ensure_head();
    }

    /// Move overflow entries whose index now falls in-window into their
    /// buckets (stops at the first that doesn't — the rung is a min-
    /// heap, and the index function is monotone in time).
    fn drain_overflow(&mut self) {
        loop {
            let t = match self.overflow.peek() {
                Some(Reverse(e)) => e.at.0,
                None => return,
            };
            let i = self.index_of(t);
            if i >= self.buckets.len() {
                return;
            }
            // lint: allow(panic-surface): peek() returned Some above and nothing else touches the heap between
            let Reverse(e) = self.overflow.pop().expect("peeked entry vanished");
            self.buckets[i].push(e);
            self.in_window += 1;
        }
    }

    /// Restore the head invariant: if the queue is nonempty, the global
    /// minimum sits at `cur[cur_pos]`. Advances the open bucket past
    /// drained ones (sorting each newly opened bucket exactly once) and
    /// performs the lazy rollover — jump the window to the earliest
    /// rung entry and re-bucket what now falls inside — when the whole
    /// window has drained.
    // lint: hot-path
    fn ensure_head(&mut self) {
        if self.cur_pos < self.cur.len() {
            return;
        }
        if self.in_window == 0 {
            if self.overflow.is_empty() {
                return;
            }
            // Rollover: re-anchor on the earliest far-future event
            // (its index becomes 0, so the drain moves at least one
            // entry and terminates). Retuning the width here is free —
            // no in-window entry needs re-bucketing.
            // lint: allow(panic-surface): guarded by the overflow.is_empty() early return just above
            let t0 = self.overflow.peek().expect("overflow nonempty").0.at.0;
            self.window_start = t0;
            self.width = self.tuned_width();
            self.cur_bucket = 0;
            self.drain_overflow();
        }
        // Find the next nonempty bucket. Scanning starts at cur_bucket
        // itself: normally empty (its contents were `cur`), but a
        // rollover or resize restocks it in place.
        let mut i = self.cur_bucket;
        while self.buckets[i].is_empty() {
            i += 1;
            debug_assert!(i < self.buckets.len(), "in_window > 0 but no nonempty bucket");
        }
        // Open bucket i, recycling the retired `cur` allocation as the
        // bucket's (now empty) storage.
        let mut fresh = std::mem::take(&mut self.buckets[i]);
        std::mem::swap(&mut self.cur, &mut fresh);
        fresh.clear();
        self.buckets[i] = fresh;
        self.cur_pos = 0;
        self.cur_bucket = i;
        // Each bucket is sorted exactly once, when opened. Keys are
        // unique (seq), so unstable sort is deterministic.
        self.cur.sort_unstable();
    }
}

/// The two queue representations behind [`Engine`]: the calendar is
/// the default; the heap is the order-oracle reference kept for golden
/// comparisons (`SimConfig::reference_engine`) and the equivalence
/// property suite.
enum Queue {
    Calendar(Calendar),
    Heap(BinaryHeap<Reverse<Entry>>),
}

/// Time-ordered event queue + simulation clock.
pub struct Engine {
    queue: Queue,
    now: Time,
    seq: u64,
    processed: u64,
}

impl Engine {
    /// Calendar-queue engine with the default (modest, growable)
    /// pre-size — the standalone/test constructor. Wired runs size the
    /// engine from expected load via [`Engine::with_capacity`].
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_HINT)
    }

    /// Calendar-queue engine pre-sized for `hint` expected concurrently
    /// pending events (≈ busy servers + transient cap: each running
    /// task holds one `TaskFinish`, plus lifecycle and periodic
    /// events). Purely a performance hint — the structure grows and
    /// shrinks regardless, and results are bit-identical for any hint.
    pub fn with_capacity(hint: usize) -> Self {
        Engine {
            queue: Queue::Calendar(Calendar::with_capacity(hint)),
            now: 0.0,
            seq: 0,
            processed: 0,
        }
    }

    /// The pre-calendar `BinaryHeap` engine, kept as the order oracle
    /// for golden/equivalence comparisons (`SimConfig::reference_engine`
    /// and `tests/engine_props.rs`). Keeps the historical 64Ki
    /// pre-allocation.
    pub fn reference() -> Self {
        Self::reference_with_capacity(1 << 16)
    }

    /// [`Engine::reference`] with an explicit heap pre-allocation.
    pub fn reference_with_capacity(hint: usize) -> Self {
        Engine {
            queue: Queue::Heap(BinaryHeap::with_capacity(hint)),
            now: 0.0,
            seq: 0,
            processed: 0,
        }
    }

    /// Is this the reference `BinaryHeap` engine (true) or the default
    /// calendar queue (false)?
    pub fn is_reference(&self) -> bool {
        matches!(self.queue, Queue::Heap(_))
    }

    /// Current simulation time (seconds).
    #[inline]
    pub fn now(&self) -> Time {
        self.now
    }

    /// Number of events popped so far (throughput metric for §Perf).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Number of events still queued.
    pub fn pending(&self) -> usize {
        match &self.queue {
            Queue::Calendar(c) => c.len(),
            Queue::Heap(h) => h.len(),
        }
    }

    /// Schedule `event` at absolute time `at`. Panics on NaN/infinite
    /// times or on scheduling into the past — all are simulator bugs,
    /// not runtime conditions (and the finiteness bound keeps the
    /// calendar's window arithmetic well-defined).
    // lint: hot-path
    #[inline]
    pub fn schedule(&mut self, at: Time, event: Event) {
        assert!(!at.is_nan(), "NaN event time for {event:?}");
        assert!(at.is_finite(), "non-finite event time {at} for {event:?}");
        assert!(
            at >= self.now,
            "scheduling into the past: {at} < {} for {event:?}",
            self.now
        );
        let entry = Entry { at: OrderedTime(at), seq: self.seq, event };
        self.seq += 1;
        match &mut self.queue {
            Queue::Calendar(c) => c.push(entry),
            Queue::Heap(h) => h.push(Reverse(entry)),
        }
    }

    /// Schedule `event` after `delay` seconds.
    #[inline]
    pub fn schedule_after(&mut self, delay: Time, event: Event) {
        self.schedule(self.now + delay, event);
    }

    /// Pop the next event, advancing the clock. Returns `None` when the
    /// simulation has quiesced.
    // lint: hot-path
    #[inline]
    pub fn pop(&mut self) -> Option<(Time, Event)> {
        let entry = match &mut self.queue {
            Queue::Calendar(c) => c.pop()?,
            Queue::Heap(h) => h.pop()?.0,
        };
        debug_assert!(entry.at.0 >= self.now, "time went backwards");
        self.now = entry.at.0;
        self.processed += 1;
        Some((entry.at.0, entry.event))
    }

    /// Drain the maximal run of equal-time events, in seq order, into
    /// the reusable scratch `out` (cleared first), advancing the clock
    /// to their shared timestamp. Equivalent to calling [`Engine::pop`]
    /// while the head time is unchanged — `World`'s batch dispatch path
    /// is built on this. Returns `None` when the queue is empty.
    // lint: hot-path
    pub fn pop_batch(&mut self, out: &mut Vec<Event>) -> Option<Time> {
        out.clear();
        let t = match &mut self.queue {
            Queue::Calendar(c) => c.pop_run(out)?,
            Queue::Heap(h) => {
                let Reverse(first) = h.pop()?;
                out.push(first.event);
                while let Some(Reverse(e)) = h.peek() {
                    if e.at != first.at {
                        break;
                    }
                    // lint: allow(panic-surface): peek() returned Some in this loop iteration; single-threaded access
                    out.push(h.pop().expect("peeked entry vanished").0.event);
                }
                first.at.0
            }
        };
        debug_assert!(t >= self.now, "time went backwards");
        self.now = t;
        self.processed += out.len() as u64;
        Some(t)
    }

    /// [`Engine::pop_batch`], bounded: drain the next equal-time run
    /// only when its timestamp lies strictly *before* `horizon`;
    /// otherwise pop nothing and return `None` (`out` is untouched, the
    /// clock does not advance). The federation's conservative-window
    /// PDES drains member engines through this — events at or past the
    /// window horizon belong to the serial merge boundary.
    pub fn pop_batch_before(&mut self, horizon: Time, out: &mut Vec<Event>) -> Option<Time> {
        match self.peek_time() {
            Some(t) if t < horizon => self.pop_batch(out),
            _ => None,
        }
    }

    /// Time of the next event without popping — O(1) on both
    /// representations (the federation merge calls this once per member
    /// per step, and the PDES horizon computation keys on it).
    // lint: hot-path
    pub fn peek_time(&self) -> Option<Time> {
        match &self.queue {
            Queue::Calendar(c) => c.peek().map(|e| e.at.0),
            Queue::Heap(h) => h.peek().map(|Reverse(e)| e.at.0),
        }
    }
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{JobId, ServerRef, TaskRef};

    fn engines() -> Vec<Engine> {
        // Both representations plus a degenerate capacity that forces
        // early calendar resizes.
        vec![Engine::new(), Engine::with_capacity(1), Engine::reference()]
    }

    #[test]
    fn pops_in_time_order() {
        for mut e in engines() {
            e.schedule(3.0, Event::Snapshot);
            e.schedule(1.0, Event::JobArrival(JobId(1)));
            e.schedule(2.0, Event::JobArrival(JobId(2)));
            let times: Vec<f64> = std::iter::from_fn(|| e.pop()).map(|(t, _)| t).collect();
            assert_eq!(times, vec![1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn ties_break_by_insertion_order() {
        for mut e in engines() {
            e.schedule(5.0, Event::JobArrival(JobId(1)));
            e.schedule(5.0, Event::JobArrival(JobId(2)));
            e.schedule(5.0, Event::JobArrival(JobId(3)));
            let ids: Vec<u32> = std::iter::from_fn(|| e.pop())
                .map(|(_, ev)| match ev {
                    Event::JobArrival(j) => j.0,
                    _ => unreachable!(),
                })
                .collect();
            assert_eq!(ids, vec![1, 2, 3]);
        }
    }

    #[test]
    fn pop_batch_before_respects_horizon() {
        for mut e in engines() {
            e.schedule(1.0, Event::JobArrival(JobId(1)));
            e.schedule(1.0, Event::JobArrival(JobId(2)));
            e.schedule(2.0, Event::JobArrival(JobId(3)));
            let mut out = Vec::new();
            // Horizon at the head time: strictly-before means no drain,
            // no clock movement.
            assert_eq!(e.pop_batch_before(1.0, &mut out), None);
            assert_eq!(e.now(), 0.0);
            assert_eq!(e.processed(), 0);
            // Horizon past the head: drains exactly the equal-time run.
            assert_eq!(e.pop_batch_before(1.5, &mut out), Some(1.0));
            assert_eq!(out.len(), 2);
            assert_eq!(e.now(), 1.0);
            // The 2.0 event sits at the next horizon, so again nothing.
            assert_eq!(e.pop_batch_before(2.0, &mut out), None);
            assert_eq!(e.pop_batch_before(f64::INFINITY, &mut out), Some(2.0));
            assert_eq!(e.pop_batch_before(f64::INFINITY, &mut out), None);
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        for mut e in engines() {
            e.schedule(1.0, Event::Snapshot);
            e.schedule(4.0, Event::Snapshot);
            e.pop();
            assert_eq!(e.now(), 1.0);
            // schedule_after is relative to the advanced clock
            e.schedule_after(
                1.5,
                Event::TaskFinish {
                    server: ServerRef::initial(0),
                    task: TaskRef { slot: 0, gen: 0 },
                },
            );
            let (t, _) = e.pop().unwrap();
            assert_eq!(t, 2.5);
            let (t, _) = e.pop().unwrap();
            assert_eq!(t, 4.0);
        }
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn rejects_past_events() {
        let mut e = Engine::new();
        e.schedule(5.0, Event::Snapshot);
        e.pop();
        e.schedule(1.0, Event::Snapshot);
    }

    #[test]
    #[should_panic(expected = "NaN event time")]
    fn rejects_nan_times() {
        let mut e = Engine::new();
        e.schedule(f64::NAN, Event::Snapshot);
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn rejects_infinite_times() {
        let mut e = Engine::new();
        e.schedule(f64::INFINITY, Event::Snapshot);
    }

    #[test]
    fn counts_processed() {
        for mut e in engines() {
            e.schedule(0.0, Event::Snapshot);
            for i in 0..10 {
                e.schedule(i as f64, Event::Snapshot);
            }
            // 0.0 twice: equal-time entries count individually.
            while e.pop().is_some() {}
            assert_eq!(e.processed(), 11);
            assert_eq!(e.pending(), 0);
        }
    }

    #[test]
    fn far_future_overflow_pops_in_order() {
        for mut e in engines() {
            // A revocation-horizon shape: near-term churn plus events
            // far beyond any initial window.
            e.schedule(5.0, Event::JobArrival(JobId(0)));
            e.schedule(2.0e9, Event::JobArrival(JobId(1)));
            e.schedule(1.0e9, Event::JobArrival(JobId(2)));
            e.schedule(7.0, Event::JobArrival(JobId(3)));
            let order: Vec<u32> = std::iter::from_fn(|| e.pop())
                .map(|(_, ev)| match ev {
                    Event::JobArrival(j) => j.0,
                    _ => unreachable!(),
                })
                .collect();
            assert_eq!(order, vec![0, 3, 2, 1]);
        }
    }

    #[test]
    fn reopens_earlier_buckets_after_skip_ahead() {
        for mut e in engines() {
            e.schedule(1.0, Event::JobArrival(JobId(0)));
            e.schedule(1000.0, Event::JobArrival(JobId(1)));
            let (t, _) = e.pop().unwrap();
            assert_eq!(t, 1.0);
            // The drain has skipped far ahead to reach 1000.0's bucket;
            // a near-term event must still pop first.
            e.schedule(2.0, Event::JobArrival(JobId(2)));
            assert_eq!(e.peek_time(), Some(2.0));
            let (t, _) = e.pop().unwrap();
            assert_eq!(t, 2.0);
            let (t, _) = e.pop().unwrap();
            assert_eq!(t, 1000.0);
        }
    }

    #[test]
    fn pop_batch_drains_maximal_equal_time_runs() {
        for mut e in engines() {
            e.schedule(1.0, Event::JobArrival(JobId(0)));
            e.schedule(2.0, Event::JobArrival(JobId(1)));
            e.schedule(2.0, Event::JobArrival(JobId(2)));
            e.schedule(2.0, Event::JobArrival(JobId(3)));
            e.schedule(3.0, Event::JobArrival(JobId(4)));
            let mut batch = Vec::new();
            assert_eq!(e.pop_batch(&mut batch), Some(1.0));
            assert_eq!(batch.len(), 1);
            assert_eq!(e.pop_batch(&mut batch), Some(2.0));
            assert_eq!(batch.len(), 3);
            assert_eq!(
                batch,
                vec![
                    Event::JobArrival(JobId(1)),
                    Event::JobArrival(JobId(2)),
                    Event::JobArrival(JobId(3)),
                ]
            );
            assert_eq!(e.pop_batch(&mut batch), Some(3.0));
            assert_eq!(batch.len(), 1);
            assert_eq!(e.pop_batch(&mut batch), None);
            assert!(batch.is_empty(), "empty pop_batch must leave the scratch clear");
            assert_eq!(e.processed(), 5);
            assert_eq!(e.now(), 3.0);
        }
    }
}
