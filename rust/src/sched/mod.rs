//! Scheduler family: the [`Scheduler`] trait, shared probe machinery, and
//! the four policies the evaluation compares — fully centralized,
//! Sparrow-style decentralized, the Eagle hybrid baseline, and
//! CloudCoaster's placement (Eagle + on-demand duplication; the dynamic
//! partition itself lives in [`crate::transient`]).

mod centralized;
mod hybrid;
pub mod probe;
mod sparrow;
mod types;

pub use centralized::Centralized;
pub use hybrid::Hybrid;
pub use sparrow::Sparrow;
pub use types::{SchedCtx, Scheduler};
