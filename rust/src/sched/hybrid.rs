//! The hybrid scheduler (Eagle, SoCC'16) — the paper's baseline and the
//! placement engine inside CloudCoaster.
//!
//! * **Long jobs** go through the centralized scheduler: exact
//!   least-loaded placement over the general partition (it alone may run
//!   long tasks).
//! * **Short jobs** go through decentralized schedulers: batch-sampling
//!   probes over the whole cluster, *filtered by the long-server bitmap*
//!   (succinct state) so shorts never queue behind longs ("divide"), with
//!   the short-only partition as the guaranteed fallback when the cluster
//!   is crowded with longs ("stick to your probes").
//!
//! CloudCoaster reuses this placement unchanged (§3: "utilizes the same
//! centralized/decentralized paradigm") — the dynamic short partition
//! just grows the fallback pool with transient servers. When
//! `duplicate_to_ondemand` is set (§3.3), any short task whose chosen
//! server is transient also enqueues a copy on an on-demand short server
//! so revocation can never lose work.

use crate::sched::probe::{assign_least_loaded, filter_long, sample_from_pool, ProbeBuffers};
use crate::sched::{SchedCtx, Scheduler};
use crate::trace::Job;
use crate::util::{ServerRef, TaskRef};

/// Eagle-style hybrid placement (also CloudCoaster's placement engine).
pub struct Hybrid {
    /// Probes per short task (Eagle/Sparrow default: 2).
    pub probe_ratio: f64,
    /// §3.3: mirror transient-placed shorts onto an on-demand server.
    pub duplicate_to_ondemand: bool,
    /// Eagle's succinct state: filter probe candidates by the long-server
    /// bitmap. `false` recovers Hawk (probes land blindly; only the short
    /// partition and stealing protect shorts).
    pub use_succinct_state: bool,
    name: &'static str,
    buf: ProbeBuffers,
    out: Vec<ServerRef>,
    pool: Vec<ServerRef>,
}

impl Hybrid {
    pub fn eagle(probe_ratio: f64) -> Self {
        Hybrid {
            probe_ratio,
            duplicate_to_ondemand: false,
            use_succinct_state: true,
            name: "eagle",
            buf: ProbeBuffers::new(),
            out: Vec::new(),
            pool: Vec::new(),
        }
    }

    /// Hawk (ATC'15): Eagle's predecessor — same hybrid split and short
    /// partition, but no succinct state, so short probes can land behind
    /// long tasks. Here as the lineage baseline for the abl-scheduler
    /// comparison.
    pub fn hawk(probe_ratio: f64) -> Self {
        Hybrid { use_succinct_state: false, name: "hawk", ..Hybrid::eagle(probe_ratio) }
    }

    /// CloudCoaster placement: Eagle + on-demand duplication for
    /// transient-placed short tasks.
    pub fn cloudcoaster(probe_ratio: f64) -> Self {
        Hybrid { duplicate_to_ondemand: true, name: "cloudcoaster", ..Hybrid::eagle(probe_ratio) }
    }

    fn place_long(&mut self, task_ids: &[TaskRef], ctx: &mut SchedCtx) {
        for &tid in task_ids {
            let target = ctx.cluster.least_loaded_general();
            ctx.cluster.enqueue(tid, target, ctx.engine, ctx.rec);
        }
    }

    fn place_short(&mut self, job: &Job, task_ids: &[TaskRef], ctx: &mut SchedCtx) {
        let m = task_ids.len();
        let probes = ((m as f64 * self.probe_ratio).ceil() as usize).max(1);

        // Probe the whole cluster (general + short partitions)...
        self.pool.clear();
        self.pool.extend_from_slice(&ctx.cluster.general);
        self.pool.extend_from_slice(&ctx.cluster.short_reserved);
        self.pool.extend_from_slice(&ctx.cluster.transient_pool);
        self.buf.candidates.clear();
        sample_from_pool(&self.pool, probes, ctx.cluster, ctx.rng, &mut self.buf);
        // ...and discard servers hosting long tasks (succinct state —
        // Eagle's addition over Hawk).
        if self.use_succinct_state {
            filter_long(ctx.cluster, &mut self.buf);
        }

        // Crowded cluster: fall back to the short-only partition, which by
        // construction never hosts longs. This is where CloudCoaster's
        // dynamic partition pays off — the pool below grows with l_r.
        if self.buf.candidates.len() < m {
            self.pool.clear();
            self.pool.extend_from_slice(&ctx.cluster.short_reserved);
            self.pool.extend_from_slice(&ctx.cluster.transient_pool);
            let extra = (2 * (m - self.buf.candidates.len())).max(2);
            sample_from_pool(&self.pool, extra, ctx.cluster, ctx.rng, &mut self.buf);
        }
        if self.buf.candidates.is_empty() {
            // Pathological: every probe hit a non-accepting server. Place
            // on the least-loaded on-demand short server directly (via
            // the short-pool index; the old code grabbed the *first*
            // short server regardless of load).
            if let Some(od) = ctx.cluster.least_loaded_short_reserved() {
                self.buf.candidates.push(od);
            } else {
                self.buf.candidates.push(ctx.cluster.least_loaded_general());
            }
        }

        assign_least_loaded(ctx.cluster, &job.task_durations, &mut self.buf, &mut self.out);
        for (&tid, &sid) in task_ids.iter().zip(&self.out) {
            ctx.cluster.enqueue(tid, sid, ctx.engine, ctx.rec);
            // §3.3: at least one copy of every short task on on-demand.
            // The duplication target is an O(log n) short-pool index
            // query, not a partition scan.
            if self.duplicate_to_ondemand
                && ctx.cluster.is_transient(sid)
                && ctx.cluster.task(tid).copies > 0
            {
                if let Some(od) = ctx.cluster.least_loaded_short_reserved() {
                    ctx.cluster.enqueue(tid, od, ctx.engine, ctx.rec);
                }
            }
        }
    }
}

impl Scheduler for Hybrid {
    fn name(&self) -> &'static str {
        self.name
    }

    fn place_job(&mut self, job: &Job, task_ids: &[TaskRef], ctx: &mut SchedCtx) {
        if job.is_long {
            self.place_long(task_ids, ctx);
        } else {
            self.place_short(job, task_ids, ctx);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, Pool, QueuePolicy, TaskState};
    use crate::metrics::Recorder;
    use crate::sim::{Engine, Rng};
    use crate::util::JobId;

    fn ctx_parts(general: usize, short: usize) -> (Cluster, Engine, Recorder, Rng) {
        (
            Cluster::new(general, short, QueuePolicy::Fifo),
            Engine::new(),
            Recorder::new(3.0),
            Rng::new(7),
        )
    }

    fn short_job(n: usize, dur: f64) -> Job {
        Job { id: JobId(0), arrival: 0.0, task_durations: vec![dur; n], is_long: false }
    }

    fn long_job(n: usize, dur: f64) -> Job {
        Job { id: JobId(0), arrival: 0.0, task_durations: vec![dur; n], is_long: true }
    }

    fn add_tasks(cluster: &mut Cluster, job: &Job) -> Vec<TaskRef> {
        job.task_durations
            .iter()
            .map(|&d| cluster.add_task(job.id, d, job.is_long, 0.0))
            .collect()
    }

    #[test]
    fn long_jobs_stay_in_general_partition() {
        let (mut cluster, mut engine, mut rec, mut rng) = ctx_parts(8, 4);
        let mut sched = Hybrid::eagle(2.0);
        let job = long_job(8, 500.0);
        let tids = add_tasks(&mut cluster, &job);
        let mut ctx = SchedCtx {
            cluster: &mut cluster,
            engine: &mut engine,
            rec: &mut rec,
            rng: &mut rng,
        };
        sched.place_job(&job, &tids, &mut ctx);
        for sid in &cluster.short_reserved {
            assert!(cluster.server(*sid).is_idle(), "long task leaked into short partition");
        }
        assert_eq!(cluster.n_long_servers(), 8);
        cluster.check_invariants();
    }

    #[test]
    fn shorts_avoid_long_servers() {
        let (mut cluster, mut engine, mut rec, mut rng) = ctx_parts(8, 4);
        let mut sched = Hybrid::eagle(2.0);
        // Fill half the general partition with longs.
        let lj = long_job(4, 1000.0);
        let ltids = add_tasks(&mut cluster, &lj);
        {
            let mut ctx = SchedCtx {
                cluster: &mut cluster,
                engine: &mut engine,
                rec: &mut rec,
                rng: &mut rng,
            };
            sched.place_job(&lj, &ltids, &mut ctx);
        }
        // Now a burst of short jobs; none may land behind a long.
        for _ in 0..20 {
            let sj = short_job(3, 10.0);
            let stids = add_tasks(&mut cluster, &sj);
            let mut ctx = SchedCtx {
                cluster: &mut cluster,
                engine: &mut engine,
                rec: &mut rec,
                rng: &mut rng,
            };
            sched.place_job(&sj, &stids, &mut ctx);
            for &tid in &stids {
                if let Some(sid) = cluster.task(tid).ran_on {
                    assert!(!cluster.has_long(sid) || cluster.task(tid).is_long);
                }
            }
        }
        // Every queued short task sits on a long-free server.
        for s in &cluster.servers {
            if s.long_tasks > 0 {
                for &tid in &s.queue {
                    assert!(cluster.task(tid).is_long, "short queued behind long");
                }
            }
        }
        cluster.check_invariants();
    }

    #[test]
    fn crowded_cluster_falls_back_to_short_partition() {
        let (mut cluster, mut engine, mut rec, mut rng) = ctx_parts(4, 2);
        let mut sched = Hybrid::eagle(2.0);
        // Saturate ALL general servers with longs.
        let lj = long_job(4, 10_000.0);
        let ltids = add_tasks(&mut cluster, &lj);
        {
            let mut ctx = SchedCtx {
                cluster: &mut cluster,
                engine: &mut engine,
                rec: &mut rec,
                rng: &mut rng,
            };
            sched.place_job(&lj, &ltids, &mut ctx);
        }
        let sj = short_job(4, 5.0);
        let stids = add_tasks(&mut cluster, &sj);
        let mut ctx = SchedCtx {
            cluster: &mut cluster,
            engine: &mut engine,
            rec: &mut rec,
            rng: &mut rng,
        };
        sched.place_job(&sj, &stids, &mut ctx);
        // All shorts must be on the short partition.
        for &tid in &stids {
            let t = cluster.task(tid);
            let on_short = cluster.short_reserved.iter().any(|&sid| {
                cluster.server(sid).running == Some(tid)
                    || cluster.server(sid).queue.contains(&tid)
                    || t.ran_on == Some(sid)
            });
            assert!(on_short, "short task escaped to a long-crowded server");
        }
        cluster.check_invariants();
    }

    #[test]
    fn cloudcoaster_duplicates_transient_placed_shorts() {
        let (mut cluster, mut engine, mut rec, mut rng) = ctx_parts(4, 2);
        let mut sched = Hybrid::cloudcoaster(2.0);
        // Saturate general with longs so shorts go to the short pool.
        let lj = long_job(4, 10_000.0);
        let ltids = add_tasks(&mut cluster, &lj);
        {
            let mut ctx = SchedCtx {
                cluster: &mut cluster,
                engine: &mut engine,
                rec: &mut rec,
                rng: &mut rng,
            };
            sched.place_job(&lj, &ltids, &mut ctx);
        }
        // Bring up transient servers and occupy the short partition so
        // placements favour transients.
        for _ in 0..4 {
            let sid = cluster.request_transient(0.0);
            cluster.transient_ready(sid, 0.0, &mut rec);
        }
        for &sid in &cluster.short_reserved.clone() {
            let b = cluster.add_task(JobId(9), 500.0, false, 0.0);
            cluster.enqueue(b, sid, &mut engine, &mut rec);
        }
        let sj = short_job(6, 5.0);
        let stids = add_tasks(&mut cluster, &sj);
        let mut ctx = SchedCtx {
            cluster: &mut cluster,
            engine: &mut engine,
            rec: &mut rec,
            rng: &mut rng,
        };
        sched.place_job(&sj, &stids, &mut ctx);
        // Any task queued (not yet running) on a transient must hold a
        // second copy on an on-demand server.
        for &tid in &stids {
            let t = cluster.task(tid);
            if t.state == TaskState::Queued {
                let on_transient = cluster
                    .transient_pool
                    .iter()
                    .any(|&sid| cluster.server(sid).queue.contains(&tid));
                if on_transient {
                    assert!(t.copies >= 2, "transient-queued short lacks on-demand copy");
                    let on_od = cluster
                        .short_reserved
                        .iter()
                        .any(|&sid| cluster.server(sid).queue.contains(&tid));
                    assert!(on_od);
                }
            }
        }
        cluster.check_invariants();
    }

    #[test]
    fn transient_pool_grows_short_candidates() {
        let (mut cluster, mut engine, mut rec, mut rng) = ctx_parts(4, 1);
        let mut sched = Hybrid::cloudcoaster(2.0);
        // Saturate general.
        let lj = long_job(4, 10_000.0);
        let ltids = add_tasks(&mut cluster, &lj);
        {
            let mut ctx = SchedCtx {
                cluster: &mut cluster,
                engine: &mut engine,
                rec: &mut rec,
                rng: &mut rng,
            };
            sched.place_job(&lj, &ltids, &mut ctx);
        }
        for _ in 0..8 {
            let sid = cluster.request_transient(0.0);
            cluster.transient_ready(sid, 0.0, &mut rec);
        }
        let sj = short_job(8, 10.0);
        let stids = add_tasks(&mut cluster, &sj);
        let mut ctx = SchedCtx {
            cluster: &mut cluster,
            engine: &mut engine,
            rec: &mut rec,
            rng: &mut rng,
        };
        sched.place_job(&sj, &stids, &mut ctx);
        let transient_running = cluster
            .transient_pool
            .iter()
            .filter(|&&sid| cluster.server(sid).running.is_some())
            .count();
        assert!(transient_running > 0, "transients unused despite crowded cluster");
        cluster.check_invariants();
    }

    #[test]
    fn pools_are_disjoint() {
        let (cluster, ..) = ctx_parts(8, 4);
        for sid in &cluster.short_reserved {
            assert_eq!(cluster.server(*sid).pool, Pool::ShortReserved);
            assert!(!cluster.general.contains(sid));
        }
    }
}
