//! Fully centralized scheduler (YARN-like, §2.1): every task of every job
//! is placed with global knowledge on the least-loaded general-partition
//! server. Optimal placement, but short jobs inherit the same queues as
//! long ones — the head-of-line-blocking baseline the hybrid designs beat.

use crate::sched::{SchedCtx, Scheduler};
use crate::trace::Job;
use crate::util::TaskRef;

/// Global least-loaded placement over the general partition.
#[derive(Default)]
pub struct Centralized;

impl Scheduler for Centralized {
    fn name(&self) -> &'static str {
        "centralized"
    }

    fn place_job(&mut self, _job: &Job, task_ids: &[TaskRef], ctx: &mut SchedCtx) {
        for &tid in task_ids {
            let target = ctx.cluster.least_loaded_general();
            ctx.cluster.enqueue(tid, target, ctx.engine, ctx.rec);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, QueuePolicy};
    use crate::metrics::Recorder;
    use crate::sim::{Engine, Rng};
    use crate::util::JobId;

    #[test]
    fn spreads_tasks_across_least_loaded() {
        let mut cluster = Cluster::new(4, 0, QueuePolicy::Fifo);
        let mut engine = Engine::new();
        let mut rec = Recorder::new(1.0);
        let mut rng = Rng::new(1);
        let job = Job { id: JobId(0), arrival: 0.0, task_durations: vec![10.0; 4], is_long: false };
        let tids: Vec<_> =
            (0..4).map(|_| cluster.add_task(JobId(0), 10.0, false, 0.0)).collect();
        let mut ctx = SchedCtx {
            cluster: &mut cluster,
            engine: &mut engine,
            rec: &mut rec,
            rng: &mut rng,
        };
        Centralized.place_job(&job, &tids, &mut ctx);
        // Equal tasks over 4 idle servers -> one each, all running.
        assert!(cluster.servers.iter().all(|s| s.running.is_some()));
        cluster.check_invariants();
    }
}
