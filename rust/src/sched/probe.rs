//! Shared probe machinery for decentralized (Sparrow-style) placement:
//! random candidate sampling, long-bitmap filtering, and greedy
//! least-estimated-wait assignment.
//!
//! Buffers are owned by the caller and reused across jobs — the probe
//! path runs once per job and must not allocate in steady state.

use crate::cluster::Cluster;
use crate::sim::Rng;
use crate::util::ServerRef;

/// Reusable scratch buffers for probe-based placement.
#[derive(Default)]
pub struct ProbeBuffers {
    pub candidates: Vec<ServerRef>,
    pub loads: Vec<f64>,
}

impl ProbeBuffers {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Sample `k` servers (with replacement, as Sparrow probes do) from
/// `pool`, keeping only servers that are currently accepting work, and
/// append them to `buf.candidates`.
pub fn sample_from_pool(
    pool: &[ServerRef],
    k: usize,
    cluster: &Cluster,
    rng: &mut Rng,
    buf: &mut ProbeBuffers,
) {
    if pool.is_empty() {
        return;
    }
    for _ in 0..k {
        let sid = pool[rng.below(pool.len() as u64) as usize];
        // Dense hot-field read: the sampling loop is the single
        // hottest read path, so it must not drag Server structs
        // through cache.
        if cluster.is_accepting(sid) {
            buf.candidates.push(sid);
        }
    }
}

/// Drop candidates currently hosting a long task (Eagle's "divide" rule:
/// succinct-state filtering avoids head-of-line blocking behind longs).
pub fn filter_long(cluster: &Cluster, buf: &mut ProbeBuffers) {
    buf.candidates.retain(|&sid| !cluster.has_long(sid));
}

/// Greedily assign `m` tasks to the least-loaded candidates: repeatedly
/// pick the candidate with the smallest estimated wait, bump its local
/// load estimate by `task_cost`, repeat. Writes the chosen server per
/// task into `out`.
///
/// This mirrors batch-sampling placement: the probe response is the
/// estimated wait (est_work), and each placed task updates the local
/// estimate so a single job spreads over its probe set.
pub fn assign_least_loaded(
    cluster: &Cluster,
    task_costs: &[f64],
    buf: &mut ProbeBuffers,
    out: &mut Vec<ServerRef>,
) {
    out.clear();
    buf.loads.clear();
    buf.loads
        .extend(buf.candidates.iter().map(|&sid| cluster.est_work_of(sid)));
    for &cost in task_costs {
        // Linear argmin over the probe set (probe sets are O(2m), small).
        let (mut best, mut best_load) = (0usize, f64::INFINITY);
        for (i, &load) in buf.loads.iter().enumerate() {
            if load < best_load {
                best = i;
                best_load = load;
            }
        }
        out.push(buf.candidates[best]);
        buf.loads[best] += cost;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::QueuePolicy;
    use crate::metrics::Recorder;
    use crate::sim::Engine;
    use crate::util::JobId;

    fn cluster_with_load() -> (Cluster, Engine, Recorder) {
        let mut c = Cluster::new(8, 2, QueuePolicy::Fifo);
        let mut e = Engine::new();
        let mut r = Recorder::new(1.0);
        // Server 0 busy with a long task; server 1 busy with a short one.
        let t0 = c.add_task(JobId(0), 1000.0, true, 0.0);
        c.enqueue(t0, ServerRef::initial(0), &mut e, &mut r);
        let t1 = c.add_task(JobId(0), 10.0, false, 0.0);
        c.enqueue(t1, ServerRef::initial(1), &mut e, &mut r);
        (c, e, r)
    }

    #[test]
    fn sampling_respects_pool_and_accepting() {
        let (c, _, _) = cluster_with_load();
        let mut rng = Rng::new(1);
        let mut buf = ProbeBuffers::new();
        let pool: Vec<ServerRef> = c.general.clone();
        sample_from_pool(&pool, 64, &c, &mut rng, &mut buf);
        assert!(!buf.candidates.is_empty());
        assert!(buf.candidates.iter().all(|s| c.general.contains(s)));
    }

    #[test]
    fn long_filter_removes_long_servers() {
        let (c, _, _) = cluster_with_load();
        let mut buf = ProbeBuffers::new();
        buf.candidates = c.general.clone();
        filter_long(&c, &mut buf);
        assert!(!buf.candidates.contains(&ServerRef::initial(0)));
        assert!(buf.candidates.contains(&ServerRef::initial(1)));
    }

    #[test]
    fn least_loaded_spreads_over_probe_set() {
        let (c, _, _) = cluster_with_load();
        let mut buf = ProbeBuffers::new();
        buf.candidates = vec![ServerRef::initial(2), ServerRef::initial(3)];
        let mut out = Vec::new();
        // Four equal tasks over two idle candidates -> 2 each.
        assign_least_loaded(&c, &[5.0, 5.0, 5.0, 5.0], &mut buf, &mut out);
        let on2 = out.iter().filter(|&&s| s == ServerRef::initial(2)).count();
        let on3 = out.iter().filter(|&&s| s == ServerRef::initial(3)).count();
        assert_eq!(on2, 2);
        assert_eq!(on3, 2);
    }

    #[test]
    fn least_loaded_prefers_idle_over_busy() {
        let (c, _, _) = cluster_with_load();
        let mut buf = ProbeBuffers::new();
        buf.candidates = vec![ServerRef::initial(1), ServerRef::initial(2)]; // 1 busy, 2 idle
        let mut out = Vec::new();
        assign_least_loaded(&c, &[1.0], &mut buf, &mut out);
        assert_eq!(out, vec![ServerRef::initial(2)]);
    }

    #[test]
    fn empty_pool_produces_no_candidates() {
        let (c, _, _) = cluster_with_load();
        let mut rng = Rng::new(2);
        let mut buf = ProbeBuffers::new();
        sample_from_pool(&[], 16, &c, &mut rng, &mut buf);
        assert!(buf.candidates.is_empty());
    }
}
