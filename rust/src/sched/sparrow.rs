//! Sparrow-style fully decentralized scheduler (§2.1): batch sampling
//! with power-of-d probes, no global state, no short/long awareness.
//! Fast for shorts, but long tasks land blindly — the other end of the
//! design space the hybrid schedulers interpolate.

use crate::sched::probe::{assign_least_loaded, sample_from_pool, ProbeBuffers};
use crate::sched::{SchedCtx, Scheduler};
use crate::trace::Job;
use crate::util::{ServerRef, TaskRef};

/// Batch-sampling decentralized placement over the whole cluster.
pub struct Sparrow {
    /// Probes per task (d in power-of-d; Sparrow uses 2).
    pub probe_ratio: f64,
    buf: ProbeBuffers,
    out: Vec<ServerRef>,
    pool: Vec<ServerRef>,
}

impl Sparrow {
    pub fn new(probe_ratio: f64) -> Self {
        Sparrow { probe_ratio, buf: ProbeBuffers::new(), out: Vec::new(), pool: Vec::new() }
    }
}

impl Scheduler for Sparrow {
    fn name(&self) -> &'static str {
        "sparrow"
    }

    fn place_job(&mut self, job: &Job, task_ids: &[TaskRef], ctx: &mut SchedCtx) {
        // Whole cluster is fair game: general + short partitions.
        self.pool.clear();
        self.pool.extend_from_slice(&ctx.cluster.general);
        self.pool.extend_from_slice(&ctx.cluster.short_reserved);
        self.pool.extend_from_slice(&ctx.cluster.transient_pool);
        let m = task_ids.len();
        let probes = ((m as f64 * self.probe_ratio).ceil() as usize).max(1);
        self.buf.candidates.clear();
        sample_from_pool(&self.pool, probes, ctx.cluster, ctx.rng, &mut self.buf);
        if self.buf.candidates.is_empty() {
            // Degenerate fallback: probe set entirely non-accepting.
            self.buf.candidates.push(ctx.cluster.least_loaded_general());
        }
        assign_least_loaded(ctx.cluster, &job.task_durations, &mut self.buf, &mut self.out);
        for (&tid, &sid) in task_ids.iter().zip(&self.out) {
            ctx.cluster.enqueue(tid, sid, ctx.engine, ctx.rec);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, QueuePolicy, TaskState};
    use crate::metrics::Recorder;
    use crate::sim::{Engine, Rng};
    use crate::util::JobId;

    #[test]
    fn places_every_task() {
        let mut cluster = Cluster::new(16, 4, QueuePolicy::Fifo);
        let mut engine = Engine::new();
        let mut rec = Recorder::new(1.0);
        let mut rng = Rng::new(5);
        let mut sched = Sparrow::new(2.0);
        let durs = vec![5.0; 10];
        let job = Job { id: JobId(0), arrival: 0.0, task_durations: durs.clone(), is_long: false };
        let tids: Vec<_> =
            durs.iter().map(|&d| cluster.add_task(JobId(0), d, false, 0.0)).collect();
        let mut ctx = SchedCtx {
            cluster: &mut cluster,
            engine: &mut engine,
            rec: &mut rec,
            rng: &mut rng,
        };
        sched.place_job(&job, &tids, &mut ctx);
        for tid in tids {
            assert_ne!(cluster.task(tid).state, TaskState::Finished);
            assert!(cluster.task(tid).copies == 1 || cluster.task(tid).state == TaskState::Running);
        }
        cluster.check_invariants();
    }
}
