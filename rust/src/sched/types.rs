//! The scheduler abstraction: placement policy invoked by the simulation
//! runner on job arrival (and on revocation-orphaned tasks).

use crate::cluster::Cluster;
use crate::metrics::Recorder;
use crate::sim::{Engine, Rng};
use crate::trace::Job;
use crate::util::TaskRef;

/// Mutable simulation context handed to schedulers.
pub struct SchedCtx<'a> {
    pub cluster: &'a mut Cluster,
    pub engine: &'a mut Engine,
    pub rec: &'a mut Recorder,
    pub rng: &'a mut Rng,
}

/// A job-placement policy. Schedulers only *place* tasks onto server
/// queues; execution, queue discipline and metrics are the cluster's job.
///
/// `Send` so a member world (which borrows its scheduler exclusively)
/// can advance on a federation PDES worker thread; schedulers are plain
/// policy state, so the bound costs implementors nothing.
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;

    /// Place all tasks of `job` (already materialised in the task arena as
    /// `task_ids`) onto server queues.
    fn place_job(&mut self, job: &Job, task_ids: &[TaskRef], ctx: &mut SchedCtx);

    /// Re-place tasks orphaned by a transient revocation (tasks whose only
    /// queue copy lived on the revoked server). Default: least-loaded
    /// on-demand short-partition server — the §3.3 on-demand fallback —
    /// answered by the short-pool index in O(log n).
    fn replace_orphans(&mut self, orphans: &[TaskRef], ctx: &mut SchedCtx) {
        for &tid in orphans {
            ctx.rec.tasks_rescheduled += 1;
            let target = ctx
                .cluster
                .least_loaded_short_reserved()
                .or_else(|| ctx.cluster.general.first().copied())
                .expect("cluster has no on-demand servers"); // lint: allow(panic-surface): build() guarantees at least one on-demand server
            ctx.cluster.enqueue(tid, target, ctx.engine, ctx.rec);
        }
    }
}
