//! Budget arithmetic from §3.1: how many transient servers a fixed
//! short-partition budget buys.
//!
//! With `N` on-demand short servers, replacing fraction `p` of them with
//! transients at cost ratio `r` yields `K = r·N·p` transient servers and
//! a managed short partition of up to `T = N((r-1)p + 1)` servers.
//!
//! [`SharedBudget`] extends the arithmetic across a federation: one
//! counted pool of transient leases that several clusters' managers draw
//! from, so one cluster's quiet period frees headroom another cluster's
//! burst can use (pooled sharing), or a hard per-cluster slice of the
//! same total (split sharing).

use std::sync::{Arc, Mutex};

/// Short-partition budget: the paper's (N, p, r) triple.
#[derive(Clone, Copy, Debug)]
pub struct Budget {
    /// On-demand servers in a purely static short partition (paper: 80).
    pub n_static: usize,
    /// Fraction replaced with transients (paper: 0.5).
    pub p: f64,
    /// Cost ratio r = c_static / c_trans (paper: 1..3).
    pub r: f64,
}

impl Budget {
    pub fn new(n_static: usize, p: f64, r: f64) -> Self {
        assert!((0.0..=1.0).contains(&p), "p must be in [0,1]");
        assert!(r >= 1.0, "cost ratio must be >= 1");
        Budget { n_static, p, r }
    }

    /// On-demand short servers kept as the §3.1 buffer: (1-p)·N.
    pub fn ondemand_short(&self) -> usize {
        ((1.0 - self.p) * self.n_static as f64).round() as usize
    }

    /// Max transient servers the budget buys: K = ⌊r·N·p⌋.
    pub fn max_transients(&self) -> usize {
        (self.r * self.n_static as f64 * self.p).floor() as usize
    }

    /// Max managed short-partition size: T = N((r-1)p + 1).
    pub fn max_partition(&self) -> usize {
        self.ondemand_short() + self.max_transients()
    }
}

/// Interior state of a [`SharedBudget`] pool.
#[derive(Debug)]
struct SharedPool {
    cap: usize,
    in_use: usize,
    peak: usize,
}

/// A counted transient-lease pool shared across clusters in a
/// federation. Managers [`SharedBudget::try_take`] one unit per
/// transient request; the federation driver releases units as it
/// observes each cluster's fleet (active + provisioning) shrink after a
/// step. The `peak` watermark records the most units ever
/// simultaneously taken — the cross-cluster cap test pins `peak <= cap`.
///
/// `Arc<Mutex>`-shared so member worlds can advance on the federation's
/// PDES worker threads. The lock is uncontended by construction: a pool
/// shared across members (pooled sharing) makes those members
/// horizon events — they only ever step in the serial boundary phase —
/// while a per-member slice (split sharing) is touched only by its own
/// member's thread, so take/release order on any one pool is exactly
/// the serial merge order.
#[derive(Clone, Debug)]
pub struct SharedBudget(Arc<Mutex<SharedPool>>);

impl SharedBudget {
    pub fn new(cap: usize) -> Self {
        SharedBudget(Arc::new(Mutex::new(SharedPool { cap, in_use: 0, peak: 0 })))
    }

    /// Do these two handles draw from the same pool? The federation's
    /// PDES scheduler uses this to detect budget coupling: members
    /// sharing a pool must synchronize at the merge boundary, members
    /// with disjoint pools may advance concurrently.
    pub fn same_pool(&self, other: &SharedBudget) -> bool {
        Arc::ptr_eq(&self.0, &other.0)
    }

    /// Total units in the pool.
    pub fn cap(&self) -> usize {
        self.0.lock().unwrap().cap // lint: allow(panic-surface): poisoning requires a panic inside these few-line critical sections, which contain none
    }

    /// Units currently taken across every sharing cluster.
    pub fn in_use(&self) -> usize {
        self.0.lock().unwrap().in_use // lint: allow(panic-surface): poisoning requires a panic inside these few-line critical sections, which contain none
    }

    /// High-water mark of simultaneously taken units.
    pub fn peak(&self) -> usize {
        self.0.lock().unwrap().peak // lint: allow(panic-surface): poisoning requires a panic inside these few-line critical sections, which contain none
    }

    /// Take one unit if headroom remains; `false` when the pool is
    /// exhausted (the caller treats it like a failed market request).
    pub fn try_take(&self) -> bool {
        let mut p = self.0.lock().unwrap(); // lint: allow(panic-surface): poisoning requires a panic inside these few-line critical sections, which contain none
        if p.in_use >= p.cap {
            return false;
        }
        p.in_use += 1;
        p.peak = p.peak.max(p.in_use);
        true
    }

    /// Return `n` units to the pool (saturating: a release can never
    /// underflow even if the driver reconciles conservatively).
    pub fn release(&self, n: usize) {
        let mut p = self.0.lock().unwrap(); // lint: allow(panic-surface): poisoning requires a panic inside these few-line critical sections, which contain none
        p.in_use = p.in_use.saturating_sub(n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_r3_p05() {
        // §3.1: "convert 50% ... r=3 ... T = 2N"
        let b = Budget::new(80, 0.5, 3.0);
        assert_eq!(b.ondemand_short(), 40);
        assert_eq!(b.max_transients(), 120);
        assert_eq!(b.max_partition(), 160); // = 2N
    }

    #[test]
    fn paper_sweep_k_values() {
        // §4: "CloudCoaster can use up to 40, 80 and 120 transient
        // servers" for r = 1, 2, 3 with N=80, p=0.5.
        for (r, k) in [(1.0, 40), (2.0, 80), (3.0, 120)] {
            assert_eq!(Budget::new(80, 0.5, r).max_transients(), k);
        }
    }

    #[test]
    fn p_zero_disables_transients() {
        let b = Budget::new(80, 0.0, 3.0);
        assert_eq!(b.max_transients(), 0);
        assert_eq!(b.ondemand_short(), 80);
        assert_eq!(b.max_partition(), 80);
    }

    #[test]
    fn formula_t_matches_closed_form() {
        for &(n, p, r) in &[(80usize, 0.5, 3.0), (100, 0.25, 2.0), (64, 0.75, 4.0)] {
            let b = Budget::new(n, p, r);
            let t_closed = (n as f64 * ((r - 1.0) * p + 1.0)).round() as i64;
            assert!((b.max_partition() as i64 - t_closed).abs() <= 1);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_bad_p() {
        Budget::new(80, 1.5, 3.0);
    }

    #[test]
    fn shared_budget_counts_and_caps() {
        let s = SharedBudget::new(3);
        let t = s.clone(); // a second cluster's handle on the same pool
        assert!(s.try_take());
        assert!(t.try_take());
        assert!(s.try_take());
        assert!(!t.try_take(), "took past the pooled cap");
        assert_eq!(s.in_use(), 3);
        assert_eq!(t.peak(), 3);
        s.release(2);
        assert_eq!(t.in_use(), 1);
        assert!(t.try_take(), "released headroom not reusable");
        assert_eq!(s.peak(), 3, "peak is a high-water mark, not current");
        // Saturating release never underflows.
        s.release(100);
        assert_eq!(s.in_use(), 0);
        // Zero-cap pool: every take fails, nothing panics.
        let z = SharedBudget::new(0);
        assert!(!z.try_take());
        assert_eq!(z.peak(), 0);
    }

    #[test]
    fn same_pool_identity_tracks_clones_not_caps() {
        let a = SharedBudget::new(4);
        let b = a.clone();
        let c = SharedBudget::new(4); // equal cap, distinct pool
        assert!(a.same_pool(&b));
        assert!(b.same_pool(&a));
        assert!(!a.same_pool(&c));
    }

    #[test]
    fn shared_budget_handles_are_send() {
        fn assert_send<T: Send + Sync>() {}
        assert_send::<SharedBudget>();
    }
}
