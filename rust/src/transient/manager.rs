//! The Transient Manager — the paper's §3.2 contribution.
//!
//! Monitors the long-load ratio `l_r = N_long / N_total` after every
//! long-task enter/exit and resizes the dynamic short partition:
//!
//! * `l_r > L_r^T` → **aggressively** lease transient servers (repeat
//!   until the *projected* ratio — counting servers still provisioning —
//!   drops to the threshold, or the budget cap `K = r·N·p` binds).
//! * `l_r < L_r^T` → **conservatively** release (default: at most one
//!   server per recalculation), and only by graceful drain: the server
//!   finishes its queue before shutting down.
//!
//! The asymmetry is §3.3's design choice: fast growth protects short jobs
//! during long-job bursts; slow shrink avoids thrashing through the
//! non-negligible provisioning delay.

use crate::cluster::{Cluster, ServerState};
use crate::metrics::Recorder;
use crate::sim::{Engine, Event, Rng};
use crate::transient::{Budget, Market, MarketConfig, SharedBudget};
use crate::util::ServerRef;

/// Resize-policy configuration.
#[derive(Clone, Debug)]
pub struct ManagerConfig {
    /// The replacement threshold `L_r^T` (paper: 0.95).
    pub threshold: f64,
    /// Budget triple (N, p, r) bounding the transient fleet.
    pub budget: Budget,
    /// Market behaviour (provisioning delay, MTTF, availability).
    pub market: MarketConfig,
    /// Max servers released per recalculation (1 = paper's conservative
    /// policy; usize::MAX = symmetric aggressive policy, for the
    /// abl-policy ablation).
    pub max_removals_per_recalc: usize,
    /// If false, add at most one server per recalculation too (ablation).
    pub aggressive_add: bool,
    /// Minimum seconds between releases. Recalculations fire on *every*
    /// long-task enter/exit (several per second at paper scale); "remove
    /// one per recalculation" taken literally drains the whole fleet in
    /// under a minute and thrashes against the 120 s provisioning delay.
    /// We rate-limit drains to one per provisioning delay — releasing no
    /// faster than we could re-acquire — as the concrete reading of the
    /// paper's "more conservatively decreasing" (§3.3). Set to 0 for the
    /// literal policy (abl-policy ablation).
    pub drain_cooldown: f64,
    /// Predictive resizing (extension, abl-forecast): pre-provision when
    /// the *forecast* l_r one provisioning-delay ahead crosses the
    /// threshold, hiding the 120 s provisioning lag behind the trend.
    pub predictive: bool,
}

impl ManagerConfig {
    /// Paper defaults: L_r^T = 0.95, 120 s provisioning, never revoked.
    pub fn paper(budget: Budget) -> Self {
        ManagerConfig {
            threshold: 0.95,
            market: MarketConfig { cost_ratio: budget.r, ..Default::default() },
            budget,
            max_removals_per_recalc: 1,
            aggressive_add: true,
            drain_cooldown: 120.0,
            predictive: false,
        }
    }
}

/// Runtime state of the transient manager.
pub struct TransientManager {
    pub cfg: ManagerConfig,
    market: Market,
    /// Servers requested but not yet ready.
    pending: usize,
    /// Time of the most recent drain (cooldown bookkeeping).
    last_drain: f64,
    /// Federated budget sharing: a counted cross-cluster lease pool this
    /// manager must take a unit from before each request (`None` =
    /// standalone cluster, local `budget` cap only). Released by the
    /// federation driver as it observes the fleet shrink.
    shared: Option<SharedBudget>,
    pub adds: u64,
    pub drains: u64,
    pub failed_requests: u64,
}

impl TransientManager {
    pub fn new(cfg: ManagerConfig, rng: Rng) -> Self {
        let market = Market::new(cfg.market.clone(), rng);
        TransientManager {
            cfg,
            market,
            pending: 0,
            last_drain: f64::NEG_INFINITY,
            shared: None,
            adds: 0,
            drains: 0,
            failed_requests: 0,
        }
    }

    /// Attach a federated [`SharedBudget`] pool (see the field docs).
    pub fn set_shared_budget(&mut self, shared: SharedBudget) {
        self.shared = Some(shared);
    }

    pub fn pending(&self) -> usize {
        self.pending
    }

    /// Fleet size counted against the budget cap (active + provisioning).
    fn fleet(&self, cluster: &Cluster) -> usize {
        cluster.transient_pool.len() + self.pending
    }

    /// `l_r` as it will look once provisioning servers arrive — the
    /// add-loop must use this or it would request the entire budget in
    /// one recalculation (provisioned servers don't move `N_total` for
    /// 120 s).
    fn projected_lr(&self, cluster: &Cluster, extra_pending: usize) -> f64 {
        let denom = cluster.n_total() + self.pending + extra_pending;
        if denom == 0 {
            0.0
        } else {
            cluster.n_long_servers() as f64 / denom as f64
        }
    }

    /// Lease transient servers while the projected ratio (with the given
    /// effective long-server count as numerator) stays above threshold.
    fn grow(
        &mut self,
        n_long_eff: f64,
        cluster: &mut Cluster,
        engine: &mut Engine,
        rec: &mut Recorder,
    ) {
        let now = engine.now();
        let cap = self.cfg.budget.max_transients();
        let mut requested = 0usize;
        let proj = |mgr: &Self, cluster: &Cluster| {
            let denom = (cluster.n_total() + mgr.pending) as f64;
            if denom == 0.0 {
                0.0
            } else {
                n_long_eff / denom
            }
        };
        while self.fleet(cluster) < cap
            && proj(self, cluster) > self.cfg.threshold
            && (self.cfg.aggressive_add || requested == 0)
        {
            // Federated sharing: take a unit from the cross-cluster pool
            // first — an exhausted pool counts as a failed request, just
            // like unavailable market capacity (retry at next recalc,
            // when another cluster may have released headroom).
            if let Some(shared) = &self.shared {
                if !shared.try_take() {
                    self.failed_requests += 1;
                    break;
                }
            }
            let Some(lease) = self.market.try_acquire(now) else {
                // Return the pool unit the failed request reserved.
                if let Some(shared) = &self.shared {
                    shared.release(1);
                }
                self.failed_requests += 1;
                break; // capacity unavailable; retry at next recalc
            };
            let sid = cluster.request_transient(now);
            engine.schedule(lease.ready_at, Event::TransientReady(sid));
            if let Some(revoke_at) = lease.revoke_at {
                let warn_at =
                    (revoke_at - self.cfg.market.revocation_warning).max(lease.ready_at);
                engine.schedule(warn_at, Event::RevocationWarning(sid));
                engine.schedule(revoke_at, Event::Revoked(sid));
            }
            self.pending += 1;
            self.adds += 1;
            rec.transients_requested += 1;
            requested += 1;
        }
    }

    /// Predictive pre-provisioning: grow the fleet as if `forecast_lr`
    /// were the current ratio (never shrinks — drains stay reactive).
    pub fn prewarm(
        &mut self,
        forecast_lr: f64,
        cluster: &mut Cluster,
        engine: &mut Engine,
        rec: &mut Recorder,
    ) {
        if forecast_lr > self.cfg.threshold {
            let n_long_eff = forecast_lr * (cluster.n_total() + self.pending) as f64;
            self.grow(n_long_eff, cluster, engine, rec);
        }
    }

    /// Recalculate `l_r` and resize (the paper triggers this on every
    /// long-task enter/exit; the runner calls it after each such event).
    pub fn maybe_resize(&mut self, cluster: &mut Cluster, engine: &mut Engine, rec: &mut Recorder) {
        let now = engine.now();
        if self.projected_lr(cluster, 0) > self.cfg.threshold {
            let n_long = cluster.n_long_servers() as f64;
            self.grow(n_long, cluster, engine, rec);
        } else {
            // Conservative shrink: graceful drain, bounded per recalc, and
            // never overshooting the threshold (removing a server *raises*
            // l_r; stop while the post-removal ratio stays below it).
            if now - self.last_drain < self.cfg.drain_cooldown {
                return;
            }
            for _ in 0..self.cfg.max_removals_per_recalc {
                if cluster.transient_pool.is_empty() {
                    break;
                }
                let post_total = cluster.n_total() + self.pending - 1;
                let post_lr = if post_total == 0 {
                    0.0
                } else {
                    cluster.n_long_servers() as f64 / post_total as f64
                };
                if post_lr > self.cfg.threshold {
                    break;
                }
                let victim = self.pick_victim(cluster);
                self.drains += 1;
                self.last_drain = now;
                if cluster.begin_drain(victim) {
                    // Already idle: retire on the spot.
                    cluster.retire(victim, now, rec);
                }
            }
        }
    }

    /// Drain victim: an idle transient if one exists, else the one with
    /// the least estimated remaining work (fastest to free). Answered by
    /// the cluster's transient-pool index — an O(log n) argmin over the
    /// lexicographic `(depth, est_work)` key with the same first-minimal
    /// tie-break as the scan it replaced.
    fn pick_victim(&self, cluster: &Cluster) -> ServerRef {
        cluster.transient_drain_victim().expect("pick_victim on empty pool") // lint: allow(panic-surface): callers check transient_pool_len() > 0 before draining
    }

    /// `TransientReady` arrived: the server joins the pool. The handle
    /// is generation-checked — a Provisioning server is never retired,
    /// so a stale ready event cannot happen with the current lifecycle,
    /// but the check keeps the slot's next tenant safe regardless.
    pub fn on_ready(&mut self, sid: ServerRef, cluster: &mut Cluster, engine: &Engine, rec: &mut Recorder) {
        self.pending = self.pending.saturating_sub(1);
        if cluster.get_server(sid).map(|s| s.state) == Some(ServerState::Provisioning) {
            cluster.transient_ready(sid, engine.now(), rec);
        }
    }

    /// `RevocationWarning` arrived: stop accepting work; try to finish.
    /// Generation-checked: the lease may have been drained and retired
    /// (and its slot recycled) before the warning popped — a stale
    /// warning must not drain the slot's next tenant.
    pub fn on_warning(&mut self, sid: ServerRef, cluster: &mut Cluster, engine: &Engine, rec: &mut Recorder) {
        if cluster.get_server(sid).map(|s| s.state) == Some(ServerState::Active) {
            if cluster.begin_drain(sid) {
                cluster.retire(sid, engine.now(), rec);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::QueuePolicy;
    use crate::util::JobId;

    fn setup(threshold: f64, n_general: usize) -> (Cluster, Engine, Recorder, TransientManager) {
        let cluster = Cluster::new(n_general, 2, QueuePolicy::Fifo);
        let engine = Engine::new();
        let rec = Recorder::new(3.0);
        let cfg = ManagerConfig {
            threshold,
            drain_cooldown: 0.0, // policy-logic tests exercise raw recalcs
            ..ManagerConfig::paper(Budget::new(8, 0.5, 3.0)) // K = 12
        };
        let mgr = TransientManager::new(cfg, Rng::new(1));
        (cluster, engine, rec, mgr)
    }

    fn saturate_with_longs(cluster: &mut Cluster, engine: &mut Engine, rec: &mut Recorder) {
        for sid in cluster.general.clone() {
            let t = cluster.add_task(JobId(0), 10_000.0, true, 0.0);
            cluster.enqueue(t, sid, engine, rec);
        }
    }

    #[test]
    fn adds_when_lr_above_threshold() {
        let (mut cluster, mut engine, mut rec, mut mgr) = setup(0.5, 8);
        saturate_with_longs(&mut cluster, &mut engine, &mut rec);
        assert!(cluster.long_load_ratio() > 0.5);
        mgr.maybe_resize(&mut cluster, &mut engine, &mut rec);
        assert!(mgr.pending() > 0);
        // Projected ratio at or below threshold, or budget exhausted.
        let proj = cluster.n_long_servers() as f64 / (cluster.n_total() + mgr.pending()) as f64;
        assert!(proj <= 0.5 || mgr.pending() + cluster.transient_pool.len() == 12);
        cluster.check_invariants();
    }

    #[test]
    fn respects_budget_cap() {
        let (mut cluster, mut engine, mut rec, mut mgr) = setup(0.01, 64);
        // 64 general servers: threshold so low the manager would add
        // forever — the K = 12 cap must bind.
        for sid in cluster.general.clone() {
            let t = cluster.add_task(JobId(0), 10_000.0, true, 0.0);
            cluster.enqueue(t, sid, &mut engine, &mut rec);
        }
        mgr.maybe_resize(&mut cluster, &mut engine, &mut rec);
        assert_eq!(mgr.pending(), 12);
        assert_eq!(rec.transients_requested, 12);
    }

    #[test]
    fn ready_moves_pending_into_pool() {
        let (mut cluster, mut engine, mut rec, mut mgr) = setup(0.5, 8);
        saturate_with_longs(&mut cluster, &mut engine, &mut rec);
        mgr.maybe_resize(&mut cluster, &mut engine, &mut rec);
        let before_total = cluster.n_total();
        // Drain the provisioning events.
        let mut readied = 0;
        while let Some((_, ev)) = engine.pop() {
            match ev {
                Event::TransientReady(sid) => {
                    mgr.on_ready(sid, &mut cluster, &engine, &mut rec);
                    readied += 1;
                }
                Event::TaskFinish { server, task } => {
                    cluster.on_task_finish(server, task, &mut engine, &mut rec);
                }
                _ => {}
            }
        }
        assert!(readied > 0);
        assert_eq!(mgr.pending(), 0);
        assert_eq!(cluster.transient_pool.len(), readied);
        assert_eq!(cluster.n_total(), before_total + readied);
        cluster.check_invariants();
    }

    #[test]
    fn removes_conservatively_when_lr_low() {
        let (mut cluster, mut engine, mut rec, mut mgr) = setup(0.95, 8);
        // Bring up 5 transients manually.
        for _ in 0..5 {
            let sid = cluster.request_transient(0.0);
            cluster.transient_ready(sid, 0.0, &mut rec);
        }
        // l_r = 0 < threshold -> exactly one removal per recalc.
        mgr.maybe_resize(&mut cluster, &mut engine, &mut rec);
        assert_eq!(cluster.transient_pool.len(), 4);
        mgr.maybe_resize(&mut cluster, &mut engine, &mut rec);
        assert_eq!(cluster.transient_pool.len(), 3);
        assert_eq!(mgr.drains, 2);
        cluster.check_invariants();
    }

    #[test]
    fn symmetric_policy_drains_faster() {
        let (mut cluster, mut engine, mut rec, _) = setup(0.95, 8);
        let cfg = ManagerConfig {
            max_removals_per_recalc: usize::MAX,
            ..ManagerConfig::paper(Budget::new(8, 0.5, 3.0))
        };
        let mut mgr = TransientManager::new(cfg, Rng::new(2));
        for _ in 0..5 {
            let sid = cluster.request_transient(0.0);
            cluster.transient_ready(sid, 0.0, &mut rec);
        }
        mgr.maybe_resize(&mut cluster, &mut engine, &mut rec);
        assert_eq!(cluster.transient_pool.len(), 0);
        assert_eq!(mgr.drains, 5);
    }

    #[test]
    fn drain_waits_for_queue_to_empty() {
        let (mut cluster, mut engine, mut rec, mut mgr) = setup(0.95, 8);
        let sid = cluster.request_transient(0.0);
        cluster.transient_ready(sid, 0.0, &mut rec);
        let t = cluster.add_task(JobId(1), 50.0, false, 0.0);
        cluster.enqueue(t, sid, &mut engine, &mut rec);
        mgr.maybe_resize(&mut cluster, &mut engine, &mut rec);
        // Busy server: draining but not retired.
        assert_eq!(cluster.server(sid).state, ServerState::Draining);
        assert_eq!(cluster.n_total(), 11); // still counted
        // Finish the task -> caller notices drain completion.
        let (_, ev) = engine.pop().unwrap();
        if let Event::TaskFinish { server, task } = ev {
            let out = cluster.on_task_finish(server, task, &mut engine, &mut rec);
            assert!(matches!(
                out,
                crate::cluster::FinishOutcome::Finished { drained: true, .. }
            ));
            cluster.retire(server, engine.now(), &mut rec);
        }
        // Retired -> the arena slot released, so the handle is dead
        // (generation-checked), not merely pointing at a Retired state.
        assert!(cluster.get_server(sid).is_none(), "retired slot not released");
        assert_eq!(rec.cost.lifetimes.len(), 1);
        cluster.check_invariants();
    }

    #[test]
    fn never_overshoots_threshold_on_removal() {
        let (mut cluster, mut engine, mut rec, mut mgr) = setup(0.6, 8);
        // 6 of 8 general servers long; with 2 transients l_r = 6/12 = 0.5.
        for sid in cluster.general.clone().into_iter().take(6) {
            let t = cluster.add_task(JobId(0), 10_000.0, true, 0.0);
            cluster.enqueue(t, sid, &mut engine, &mut rec);
        }
        for _ in 0..2 {
            let sid = cluster.request_transient(0.0);
            cluster.transient_ready(sid, 0.0, &mut rec);
        }
        assert!((cluster.long_load_ratio() - 0.5).abs() < 1e-9);
        // Removing one gives 6/11 = 0.545 < 0.6 -> allowed.
        mgr.maybe_resize(&mut cluster, &mut engine, &mut rec);
        assert_eq!(cluster.transient_pool.len(), 1);
        // Removing the last gives 6/10 = 0.6 <= 0.6 -> allowed (not >).
        mgr.maybe_resize(&mut cluster, &mut engine, &mut rec);
        assert_eq!(cluster.transient_pool.len(), 0);
        // Nothing left to remove; no panic, no change.
        mgr.maybe_resize(&mut cluster, &mut engine, &mut rec);
        cluster.check_invariants();
    }

    #[test]
    fn shared_budget_binds_before_local_cap() {
        // Local cap K = 12, but a shared pool of 5 units (as if other
        // federated clusters hold the rest): the add loop must stop at
        // 5 and count the exhausted pool as a failed request.
        let (mut cluster, mut engine, mut rec, mut mgr) = setup(0.01, 64);
        let shared = crate::transient::SharedBudget::new(5);
        mgr.set_shared_budget(shared.clone());
        saturate_with_longs(&mut cluster, &mut engine, &mut rec);
        mgr.maybe_resize(&mut cluster, &mut engine, &mut rec);
        assert_eq!(mgr.pending(), 5);
        assert_eq!(shared.in_use(), 5);
        assert_eq!(shared.peak(), 5);
        assert!(mgr.failed_requests >= 1, "exhausted pool not counted as failure");
        // Headroom released by the (federation) driver is usable again.
        shared.release(2);
        mgr.maybe_resize(&mut cluster, &mut engine, &mut rec);
        assert_eq!(mgr.pending(), 7);
        assert!(shared.peak() <= shared.cap(), "pool overshot its cap");
        cluster.check_invariants();
    }

    #[test]
    fn unavailable_market_counts_failures() {
        let (mut cluster, mut engine, mut rec, _) = setup(0.5, 8);
        let mut cfg = ManagerConfig::paper(Budget::new(8, 0.5, 3.0));
        cfg.threshold = 0.5;
        cfg.market.unavailable_p = 1.0;
        let mut mgr = TransientManager::new(cfg, Rng::new(3));
        saturate_with_longs(&mut cluster, &mut engine, &mut rec);
        mgr.maybe_resize(&mut cluster, &mut engine, &mut rec);
        assert_eq!(mgr.pending(), 0);
        assert!(mgr.failed_requests > 0);
    }
}
