//! Transient-server substrate: the market model (pricing, provisioning,
//! revocations), the §3.1 budget arithmetic, and the §3.2 Transient
//! Manager that drives CloudCoaster's dynamic short partition.

mod budget;
mod manager;
mod market;
mod price;

pub use budget::{Budget, SharedBudget};
pub use manager::{ManagerConfig, TransientManager};
pub use market::{Lease, Market, MarketConfig, PricingConfig};
pub use price::{PriceModel, PriceTrace};
