//! The transient-server market substrate (§2.4, §3.3).
//!
//! Models the provider-side behaviour the paper depends on: discounted
//! price (the cost ratio `r`), a provisioning delay, occasional request
//! failures ("some types of transient servers might not be available upon
//! being requested" [22]), and MTTF-driven revocations with a short
//! warning window (EC2 gives ~30 s; historical spot MTTF ≫ 18 h per
//! Flint [25], which is why the paper's simulations never lose a server).

use crate::sim::Rng;
use crate::transient::price::{PriceModel, PriceTrace};
use crate::util::Time;

/// Bid-based dynamic pricing (Amazon-style, §2.4): the customer bids a
/// fraction of the on-demand price; requests fail while the market is
/// above the bid, and running servers are revoked when it crosses.
#[derive(Clone, Debug)]
pub struct PricingConfig {
    pub model: PriceModel,
    /// Bid, fraction of the on-demand price.
    pub bid: f64,
    /// Horizon of the simulated price trace, seconds.
    pub horizon: f64,
}

impl Default for PricingConfig {
    fn default() -> Self {
        PricingConfig { model: PriceModel::default(), bid: 0.5, horizon: 7.0 * 86_400.0 }
    }
}

/// Market configuration.
#[derive(Clone, Debug)]
pub struct MarketConfig {
    /// Cost ratio r = c_static / c_trans (paper sweeps 1..3).
    pub cost_ratio: f64,
    /// Seconds from request to usable server (paper: 120 s).
    pub provisioning_delay: f64,
    /// Mean time to (involuntary) revocation; `None` = never revoked —
    /// the paper's observed regime (lifetimes ≤ 12.8 h ≪ MTTF > 18 h).
    pub mttf: Option<f64>,
    /// Warning lead time before a revocation lands (EC2: 30 s... [§3.3]).
    pub revocation_warning: f64,
    /// Probability a request fails outright (capacity unavailable).
    pub unavailable_p: f64,
    /// Bid-based dynamic pricing; `None` = fixed 1/r pricing (the
    /// paper's model). When set, price crossings add revocations and
    /// request failures on top of `mttf`/`unavailable_p`.
    pub pricing: Option<PricingConfig>,
}

impl Default for MarketConfig {
    fn default() -> Self {
        MarketConfig {
            cost_ratio: 3.0,
            provisioning_delay: 120.0,
            mttf: None,
            revocation_warning: 30.0,
            unavailable_p: 0.0,
            pricing: None,
        }
    }
}

/// Outcome of a successful acquisition.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Lease {
    /// When the server becomes usable.
    pub ready_at: Time,
    /// Absolute revocation time, if this lease will be revoked.
    pub revoke_at: Option<Time>,
}

/// The market: answers acquisition requests, samples revocations.
#[derive(Clone, Debug)]
pub struct Market {
    pub config: MarketConfig,
    trace: Option<PriceTrace>,
    rng: Rng,
}

impl Market {
    pub fn new(config: MarketConfig, mut rng: Rng) -> Self {
        let trace = config
            .pricing
            .as_ref()
            .map(|p| PriceTrace::simulate(&p.model, p.horizon, &mut rng));
        Market { config, trace, rng }
    }

    /// Current market price (fraction of on-demand); `1/r` flat when
    /// dynamic pricing is disabled.
    pub fn price_at(&self, t: Time) -> f64 {
        match &self.trace {
            Some(trace) => trace.at(t),
            None => 1.0 / self.config.cost_ratio,
        }
    }

    /// Effective mean price paid for a server held over `[a, b)`.
    pub fn effective_price(&self, a: Time, b: Time) -> f64 {
        match &self.trace {
            Some(trace) => trace.mean_over(a, b),
            None => 1.0 / self.config.cost_ratio,
        }
    }

    /// Try to lease one transient server at time `now`.
    pub fn try_acquire(&mut self, now: Time) -> Option<Lease> {
        if self.config.unavailable_p > 0.0 && self.rng.f64() < self.config.unavailable_p {
            return None;
        }
        let bid = self.config.pricing.as_ref().map(|p| p.bid);
        if let (Some(trace), Some(bid)) = (&self.trace, bid) {
            if trace.at(now) > bid {
                return None; // market above our bid: no capacity at this price
            }
        }
        let ready_at = now + self.config.provisioning_delay;
        // Revocation clock starts when the server is up; the earlier of
        // the MTTF sample and the next price crossing wins.
        let mttf_revoke = self.config.mttf.map(|mttf| ready_at + self.rng.exponential(mttf));
        let price_revoke = match (&self.trace, bid) {
            (Some(trace), Some(bid)) => trace.next_crossing(ready_at, bid),
            _ => None,
        };
        let revoke_at = match (mttf_revoke, price_revoke) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        Some(Lease { ready_at, revoke_at })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_has_provisioning_delay() {
        let mut m = Market::new(MarketConfig::default(), Rng::new(1));
        let lease = m.try_acquire(100.0).unwrap();
        assert_eq!(lease.ready_at, 220.0);
        assert_eq!(lease.revoke_at, None); // default: never revoked
    }

    #[test]
    fn mttf_samples_revocations_with_right_mean() {
        let cfg = MarketConfig { mttf: Some(10_000.0), ..Default::default() };
        let mut m = Market::new(cfg, Rng::new(2));
        let n = 20_000;
        let mean: f64 = (0..n)
            .map(|_| m.try_acquire(0.0).unwrap().revoke_at.unwrap() - 120.0)
            .sum::<f64>()
            / n as f64;
        assert!((mean - 10_000.0).abs() / 10_000.0 < 0.05, "mean={mean}");
    }

    #[test]
    fn unavailability_rate_respected() {
        let cfg = MarketConfig { unavailable_p: 0.3, ..Default::default() };
        let mut m = Market::new(cfg, Rng::new(3));
        let fails = (0..10_000).filter(|_| m.try_acquire(0.0).is_none()).count();
        let rate = fails as f64 / 10_000.0;
        assert!((rate - 0.3).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn revocation_after_ready() {
        let cfg = MarketConfig { mttf: Some(100.0), ..Default::default() };
        let mut m = Market::new(cfg, Rng::new(4));
        for _ in 0..1000 {
            let lease = m.try_acquire(50.0).unwrap();
            assert!(lease.revoke_at.unwrap() >= lease.ready_at);
        }
    }

    #[test]
    fn fixed_pricing_is_one_over_r() {
        let m = Market::new(MarketConfig::default(), Rng::new(5));
        assert!((m.price_at(0.0) - 1.0 / 3.0).abs() < 1e-12);
        assert!((m.effective_price(0.0, 1e4) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn bid_pricing_revokes_on_crossing() {
        let cfg = MarketConfig {
            pricing: Some(PricingConfig { bid: 0.35, ..Default::default() }),
            ..Default::default()
        };
        let mut m = Market::new(cfg, Rng::new(6));
        // Across a week-long trace, a tight bid must produce at least one
        // acquirable window with a finite revocation time.
        let mut revoked = false;
        for hour in 0..24 * 7 {
            if let Some(lease) = m.try_acquire(hour as f64 * 3600.0) {
                if lease.revoke_at.is_some() {
                    revoked = true;
                    assert!(lease.revoke_at.unwrap() >= lease.ready_at);
                }
            }
        }
        assert!(revoked, "tight bid never crossed by a price spike");
    }

    #[test]
    fn high_bid_rarely_fails_low_bid_often_fails() {
        let mk = |bid: f64, seed: u64| {
            let cfg = MarketConfig {
                pricing: Some(PricingConfig { bid, ..Default::default() }),
                ..Default::default()
            };
            let mut m = Market::new(cfg, Rng::new(seed));
            (0..1000)
                .filter(|i| m.try_acquire(*i as f64 * 600.0).is_none())
                .count()
        };
        assert!(mk(2.0, 7) <= mk(0.31, 7), "higher bid should fail no more often");
    }

    #[test]
    fn price_revocation_combines_with_mttf() {
        let cfg = MarketConfig {
            mttf: Some(10.0), // extremely short MTTF dominates
            pricing: Some(PricingConfig { bid: 5.0, ..Default::default() }),
            ..Default::default()
        };
        let mut m = Market::new(cfg, Rng::new(8));
        let lease = m.try_acquire(0.0).unwrap();
        // bid=5.0 is never crossed, so the MTTF sample must be the cause.
        assert!(lease.revoke_at.is_some());
    }
}
