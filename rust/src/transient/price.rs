//! Spot-market price process (§2.4): Amazon-style dynamic pricing where
//! customers bid and are revoked when the market price crosses their bid.
//!
//! The process is a regime-switching mean-reverting walk, the standard
//! shape reported by spot-market measurement studies (e.g. Spotlight
//! [22], SpotCheck [27]): long quiet stretches near a base discount with
//! occasional demand spikes that can exceed the on-demand price. Prices
//! are normalised to the on-demand price (1.0 = on-demand).

use crate::sim::Rng;
use crate::util::Time;

/// Regime-switching mean-reverting price model.
#[derive(Clone, Debug)]
pub struct PriceModel {
    /// Base (quiet-regime) price, fraction of on-demand (e.g. 0.3 = 70% off).
    pub base: f64,
    /// Spike-regime mean price (can exceed 1.0 = on-demand).
    pub spike: f64,
    /// Mean-reversion strength per step (0..1).
    pub reversion: f64,
    /// Per-step noise amplitude.
    pub noise: f64,
    /// Mean dwell in the quiet regime, seconds.
    pub quiet_dwell: f64,
    /// Mean dwell in the spike regime, seconds.
    pub spike_dwell: f64,
    /// Price update period, seconds.
    pub step: f64,
}

impl Default for PriceModel {
    fn default() -> Self {
        PriceModel {
            base: 0.30, // "effective average cost of only 30%" [25]
            spike: 1.10,
            reversion: 0.15,
            noise: 0.02,
            quiet_dwell: 6.0 * 3600.0,
            spike_dwell: 20.0 * 60.0,
            step: 60.0,
        }
    }
}

/// A realised price trace: step function sampled on a fixed grid.
#[derive(Clone, Debug)]
pub struct PriceTrace {
    pub step: f64,
    pub prices: Vec<f64>,
}

impl PriceTrace {
    /// Simulate a trace over `[0, horizon)`.
    pub fn simulate(model: &PriceModel, horizon: Time, rng: &mut Rng) -> PriceTrace {
        let n = (horizon / model.step).ceil() as usize + 1;
        let mut prices = Vec::with_capacity(n);
        let mut price = model.base;
        let mut in_spike = false;
        let mut regime_left = rng.exponential(model.quiet_dwell);
        for _ in 0..n {
            let target = if in_spike { model.spike } else { model.base };
            price += model.reversion * (target - price) + model.noise * rng.normal();
            price = price.clamp(0.05, 5.0);
            prices.push(price);
            regime_left -= model.step;
            if regime_left <= 0.0 {
                in_spike = !in_spike;
                regime_left = rng
                    .exponential(if in_spike { model.spike_dwell } else { model.quiet_dwell });
            }
        }
        PriceTrace { step: model.step, prices }
    }

    /// Market price at time `t` (clamped to the trace).
    #[inline]
    pub fn at(&self, t: Time) -> f64 {
        let idx = ((t / self.step) as usize).min(self.prices.len() - 1);
        self.prices[idx]
    }

    /// First time strictly after `t` at which the price exceeds `bid`,
    /// or None if it never does within the trace.
    pub fn next_crossing(&self, t: Time, bid: f64) -> Option<Time> {
        let start = ((t / self.step) as usize + 1).min(self.prices.len());
        for (i, &p) in self.prices.iter().enumerate().skip(start) {
            if p > bid {
                return Some(i as f64 * self.step);
            }
        }
        None
    }

    /// Time-average price over `[a, b)` — the effective cost of a server
    /// held over that interval.
    pub fn mean_over(&self, a: Time, b: Time) -> f64 {
        if b <= a {
            return self.at(a);
        }
        let i0 = (a / self.step) as usize;
        let i1 = (((b / self.step).ceil() as usize).max(i0 + 1)).min(self.prices.len());
        let slice = &self.prices[i0.min(self.prices.len() - 1)..i1];
        slice.iter().sum::<f64>() / slice.len() as f64
    }

    /// Fraction of time the price stays at or below `bid`.
    pub fn availability(&self, bid: f64) -> f64 {
        let below = self.prices.iter().filter(|&&p| p <= bid).count();
        below as f64 / self.prices.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(seed: u64) -> PriceTrace {
        PriceTrace::simulate(&PriceModel::default(), 86_400.0, &mut Rng::new(seed))
    }

    #[test]
    fn prices_positive_and_bounded() {
        let t = trace(1);
        assert!(t.prices.iter().all(|&p| (0.05..=5.0).contains(&p)));
        assert_eq!(t.prices.len(), (86_400.0 / 60.0) as usize + 1);
    }

    #[test]
    fn quiet_regime_dominates() {
        // Most of the day should sit near the base discount.
        let t = trace(2);
        let near_base = t.prices.iter().filter(|&&p| p < 0.5).count() as f64;
        assert!(near_base / t.prices.len() as f64 > 0.7);
    }

    #[test]
    fn spikes_exist_and_cross_reasonable_bids() {
        // Across seeds, some spike should exceed a 0.6 bid.
        let crossed = (0..10).any(|s| trace(s).next_crossing(0.0, 0.6).is_some());
        assert!(crossed, "no price spike in 10 seeded days");
    }

    #[test]
    fn crossing_is_after_query_time() {
        let t = trace(3);
        if let Some(c) = t.next_crossing(10_000.0, 0.4) {
            assert!(c > 10_000.0);
        }
    }

    #[test]
    fn availability_monotone_in_bid() {
        let t = trace(4);
        assert!(t.availability(0.2) <= t.availability(0.5));
        assert!(t.availability(0.5) <= t.availability(2.0));
        assert!((t.availability(5.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn mean_over_interval_sane() {
        let t = trace(5);
        let m = t.mean_over(0.0, 86_400.0);
        assert!(m > 0.05 && m < 1.5, "mean price {m}");
        // Degenerate interval falls back to the spot value.
        assert_eq!(t.mean_over(100.0, 100.0), t.at(100.0));
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(trace(7).prices, trace(7).prices);
        assert_ne!(trace(7).prices, trace(8).prices);
    }
}
