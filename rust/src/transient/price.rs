//! Spot-market price process (§2.4): Amazon-style dynamic pricing where
//! customers bid and are revoked when the market price crosses their bid.
//!
//! The process is a regime-switching mean-reverting walk, the standard
//! shape reported by spot-market measurement studies (e.g. Spotlight
//! [22], SpotCheck [27]): long quiet stretches near a base discount with
//! occasional demand spikes that can exceed the on-demand price. Prices
//! are normalised to the on-demand price (1.0 = on-demand).

use crate::sim::Rng;
use crate::util::Time;

/// Regime-switching mean-reverting price model.
#[derive(Clone, Debug)]
pub struct PriceModel {
    /// Base (quiet-regime) price, fraction of on-demand (e.g. 0.3 = 70% off).
    pub base: f64,
    /// Spike-regime mean price (can exceed 1.0 = on-demand).
    pub spike: f64,
    /// Mean-reversion strength per step (0..1).
    pub reversion: f64,
    /// Per-step noise amplitude.
    pub noise: f64,
    /// Mean dwell in the quiet regime, seconds.
    pub quiet_dwell: f64,
    /// Mean dwell in the spike regime, seconds.
    pub spike_dwell: f64,
    /// Price update period, seconds.
    pub step: f64,
}

impl Default for PriceModel {
    fn default() -> Self {
        PriceModel {
            base: 0.30, // "effective average cost of only 30%" [25]
            spike: 1.10,
            reversion: 0.15,
            noise: 0.02,
            quiet_dwell: 6.0 * 3600.0,
            spike_dwell: 20.0 * 60.0,
            step: 60.0,
        }
    }
}

/// A realised price trace: step function sampled on a fixed grid.
#[derive(Clone, Debug)]
pub struct PriceTrace {
    pub step: f64,
    pub prices: Vec<f64>,
}

impl PriceTrace {
    /// Simulate a trace over `[0, horizon)`.
    pub fn simulate(model: &PriceModel, horizon: Time, rng: &mut Rng) -> PriceTrace {
        let n = (horizon / model.step).ceil() as usize + 1;
        let mut prices = Vec::with_capacity(n);
        let mut price = model.base;
        let mut in_spike = false;
        let mut regime_left = rng.exponential(model.quiet_dwell);
        for _ in 0..n {
            let target = if in_spike { model.spike } else { model.base };
            price += model.reversion * (target - price) + model.noise * rng.normal();
            price = price.clamp(0.05, 5.0);
            prices.push(price);
            regime_left -= model.step;
            if regime_left <= 0.0 {
                in_spike = !in_spike;
                regime_left = rng
                    .exponential(if in_spike { model.spike_dwell } else { model.quiet_dwell });
            }
        }
        PriceTrace { step: model.step, prices }
    }

    /// Market price at time `t`.
    ///
    /// Boundary contract (pinned by unit tests): queries at or past the
    /// trace end clamp to the final sampled price (the trace's last
    /// regime persists); negative `t` clamps to the first sample (the
    /// `as usize` cast saturates at 0); a **zero-length trace** answers
    /// the on-demand parity price 1.0 — defined, no panic, no wrap.
    /// `PriceTrace::simulate` always produces at least one sample, so
    /// the empty case only arises for hand-built traces.
    #[inline]
    pub fn at(&self, t: Time) -> f64 {
        match self.prices.len() {
            0 => 1.0,
            n => self.prices[((t / self.step) as usize).min(n - 1)],
        }
    }

    /// First time strictly after `t` at which the price **strictly
    /// exceeds** `bid`, or None if it never does within the trace.
    ///
    /// Boundary contract: a sampled price exactly equal to `bid` is NOT
    /// a crossing (`p > bid`, matching [`PriceTrace::availability`]'s
    /// `p <= bid` — a bidder at exactly the market price keeps the
    /// server); queries at or past the trace end return None; the
    /// returned time is always `> t`; empty traces return None.
    pub fn next_crossing(&self, t: Time, bid: f64) -> Option<Time> {
        // A pre-trace query time must still see bucket 0 (its start,
        // 0.0, is strictly after any negative t); the saturating cast
        // below would otherwise skip it.
        let start = if t < 0.0 {
            0
        } else {
            ((t / self.step) as usize + 1).min(self.prices.len())
        };
        for (i, &p) in self.prices.iter().enumerate().skip(start) {
            if p > bid {
                return Some(i as f64 * self.step);
            }
        }
        None
    }

    /// Time-average price over `[a, b)` — the effective cost of a server
    /// held over that interval.
    ///
    /// Boundary contract: a degenerate interval (`b <= a`) answers the
    /// spot price [`PriceTrace::at`]`(a)`; intervals extending past the
    /// trace end average only the sampled prefix (the last sample is
    /// not extrapolated); intervals entirely past the end answer the
    /// final sampled price; empty traces answer 1.0 (on-demand parity,
    /// via `at`).
    pub fn mean_over(&self, a: Time, b: Time) -> f64 {
        if b <= a || self.prices.is_empty() {
            return self.at(a);
        }
        let i0 = ((a / self.step) as usize).min(self.prices.len() - 1);
        // i0 <= len-1, so i1 ∈ [i0+1, len]: the slice is never empty.
        let i1 = (((b / self.step).ceil() as usize).max(i0 + 1)).min(self.prices.len());
        let slice = &self.prices[i0..i1];
        slice.iter().sum::<f64>() / slice.len() as f64
    }

    /// Fraction of sampled time the price stays at or below `bid` (a
    /// price exactly at `bid` counts as available, the complement of
    /// [`PriceTrace::next_crossing`]'s strict crossing). Empty traces
    /// answer 0.0 — defined, never 0/0 = NaN.
    pub fn availability(&self, bid: f64) -> f64 {
        if self.prices.is_empty() {
            return 0.0;
        }
        let below = self.prices.iter().filter(|&&p| p <= bid).count();
        below as f64 / self.prices.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(seed: u64) -> PriceTrace {
        PriceTrace::simulate(&PriceModel::default(), 86_400.0, &mut Rng::new(seed))
    }

    #[test]
    fn prices_positive_and_bounded() {
        let t = trace(1);
        assert!(t.prices.iter().all(|&p| (0.05..=5.0).contains(&p)));
        assert_eq!(t.prices.len(), (86_400.0 / 60.0) as usize + 1);
    }

    #[test]
    fn quiet_regime_dominates() {
        // Most of the day should sit near the base discount.
        let t = trace(2);
        let near_base = t.prices.iter().filter(|&&p| p < 0.5).count() as f64;
        assert!(near_base / t.prices.len() as f64 > 0.7);
    }

    #[test]
    fn spikes_exist_and_cross_reasonable_bids() {
        // Across seeds, some spike should exceed a 0.6 bid.
        let crossed = (0..10).any(|s| trace(s).next_crossing(0.0, 0.6).is_some());
        assert!(crossed, "no price spike in 10 seeded days");
    }

    #[test]
    fn crossing_is_after_query_time() {
        let t = trace(3);
        if let Some(c) = t.next_crossing(10_000.0, 0.4) {
            assert!(c > 10_000.0);
        }
    }

    #[test]
    fn availability_monotone_in_bid() {
        let t = trace(4);
        assert!(t.availability(0.2) <= t.availability(0.5));
        assert!(t.availability(0.5) <= t.availability(2.0));
        assert!((t.availability(5.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn mean_over_interval_sane() {
        let t = trace(5);
        let m = t.mean_over(0.0, 86_400.0);
        assert!(m > 0.05 && m < 1.5, "mean price {m}");
        // Degenerate interval falls back to the spot value.
        assert_eq!(t.mean_over(100.0, 100.0), t.at(100.0));
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(trace(7).prices, trace(7).prices);
        assert_ne!(trace(7).prices, trace(8).prices);
    }

    // ---- boundary-behaviour audit (pinned: no panic, no silent wrap) ----

    fn hand_trace(prices: &[f64]) -> PriceTrace {
        PriceTrace { step: 60.0, prices: prices.to_vec() }
    }

    #[test]
    fn at_clamps_past_trace_end_and_below_zero() {
        let t = hand_trace(&[0.3, 0.5, 0.9]);
        // Exactly at the last sample's start, far past the end, and at
        // the f64 end boundary: all clamp to the final sampled price.
        assert_eq!(t.at(120.0), 0.9);
        assert_eq!(t.at(180.0), 0.9);
        assert_eq!(t.at(1e12), 0.9);
        // Negative times clamp to the first sample (saturating cast).
        assert_eq!(t.at(-5.0), 0.3);
        assert_eq!(t.at(0.0), 0.3);
    }

    #[test]
    fn empty_trace_is_defined_everywhere() {
        let t = hand_trace(&[]);
        assert_eq!(t.at(0.0), 1.0); // on-demand parity, not a panic
        assert_eq!(t.at(1e9), 1.0);
        assert_eq!(t.next_crossing(0.0, 0.5), None);
        assert_eq!(t.mean_over(0.0, 1000.0), 1.0);
        let a = t.availability(0.5);
        assert_eq!(a, 0.0);
        assert!(a.is_finite(), "empty availability must not be 0/0 NaN");
    }

    #[test]
    fn bid_exactly_at_price_is_not_a_crossing() {
        // Price rises to exactly the bid, then above it: the equal
        // sample must NOT revoke (strict >), the higher one must.
        let t = hand_trace(&[0.3, 0.5, 0.5, 0.6]);
        assert_eq!(t.next_crossing(0.0, 0.5), Some(180.0));
        // A bid the trace only ever equals never crosses.
        let flat = hand_trace(&[0.5, 0.5, 0.5]);
        assert_eq!(flat.next_crossing(0.0, 0.5), None);
        // availability is the complement: equal prices count available.
        assert_eq!(flat.availability(0.5), 1.0);
    }

    #[test]
    fn next_crossing_at_or_past_trace_end_is_none() {
        let t = hand_trace(&[0.3, 0.9, 0.3]);
        // Query inside the trace but after the last spike: None.
        assert_eq!(t.next_crossing(120.0, 0.5), None);
        // Query exactly at / far past the end: None, no wraparound to
        // the spike at index 1.
        assert_eq!(t.next_crossing(180.0, 0.5), None);
        assert_eq!(t.next_crossing(1e12, 0.5), None);
    }

    #[test]
    fn next_crossing_from_pre_trace_times_sees_bucket_zero() {
        // Negative query times are in-contract (at() clamps them); the
        // first bucket's start 0.0 is strictly after any t < 0, so a
        // crossing there must be reported, not skipped.
        let t = hand_trace(&[0.9, 0.3]);
        assert_eq!(t.next_crossing(-1.0, 0.5), Some(0.0));
        assert_eq!(t.next_crossing(-1e9, 0.5), Some(0.0));
        // At t = 0 exactly, bucket 0 is not strictly after: skip to 1.
        assert_eq!(t.next_crossing(0.0, 0.5), None);
    }

    #[test]
    fn next_crossing_is_strictly_after_query_even_mid_bucket() {
        let t = hand_trace(&[0.3, 0.9, 0.9]);
        // Query mid-bucket 0: the crossing is bucket 1's start, > t.
        let c = t.next_crossing(30.0, 0.5).unwrap();
        assert_eq!(c, 60.0);
        assert!(c > 30.0);
        // Query exactly on the crossing bucket's start: skip to the next.
        let c = t.next_crossing(60.0, 0.5).unwrap();
        assert_eq!(c, 120.0);
    }

    #[test]
    fn mean_over_boundary_intervals() {
        let t = hand_trace(&[0.2, 0.4, 0.6]);
        // Interval extending past the end averages the sampled prefix
        // only (no extrapolation of the last sample).
        assert!((t.mean_over(0.0, 1e9) - 0.4).abs() < 1e-12);
        // Interval entirely past the end: final sampled price.
        assert_eq!(t.mean_over(500.0, 900.0), 0.6);
        // Degenerate interval: spot price at `a`.
        assert_eq!(t.mean_over(70.0, 70.0), 0.4);
        assert_eq!(t.mean_over(90.0, 70.0), 0.4); // b < a, same contract
    }
}
