//! Server model: one execution slot + a work queue, in the style of the
//! Eagle/Hawk simulators the paper builds on.

use std::collections::VecDeque;

use crate::cluster::{Task, TaskState};
use crate::util::{ServerRef, TaskRef, Time};

/// Purchase class of a server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServerKind {
    /// Statically provisioned, always available.
    OnDemand,
    /// Cheap, revocable, provisioned on demand (§2.4).
    Transient,
}

/// Which partition a server belongs to (§3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pool {
    /// Static partition: runs both long and short tasks.
    General,
    /// On-demand short-only partition ("buffer" servers).
    ShortReserved,
    /// Dynamic short-only partition of transient servers.
    TransientPool,
}

/// Server lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServerState {
    /// Transient server requested but not yet usable (provisioning delay).
    Provisioning,
    /// Accepting and executing tasks.
    Active,
    /// Finishing its queue, accepting no new tasks (graceful release §3.2,
    /// or a revocation warning §3.3).
    Draining,
    /// Gone (drained out or revoked).
    Retired,
}

/// How a server picks the next task from its queue.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QueuePolicy {
    /// Strict arrival order.
    Fifo,
    /// Eagle's discipline: shortest-remaining-processing-time among queued
    /// short tasks (longs yield to shorts), bounded by a starvation limit —
    /// any task queued longer than the limit runs first, in FIFO order.
    Srpt { starvation_limit: f64 },
}

/// One simulated server: a single execution slot plus a queue.
///
/// Servers live in a slot arena owned by [`crate::cluster::Cluster`]:
/// `id` is the slot's *current identity* (slot index + generation), the
/// server twin of the task arena — a retired transient's slot is
/// released and its generation bumped, so stale [`ServerRef`]s from
/// already-popped lifecycle events fail the generation check instead of
/// acting on the slot's next tenant.
#[derive(Clone, Debug)]
pub struct Server {
    pub id: ServerRef,
    pub kind: ServerKind,
    pub pool: Pool,
    pub state: ServerState,
    pub running: Option<TaskRef>,
    pub queue: VecDeque<TaskRef>,
    /// Long tasks on this server (running + queued). `> 0` marks the
    /// server in the long-bitmap Eagle shares with distributed schedulers,
    /// and feeds the cluster's incremental `N_long` for `l_r`.
    pub long_tasks: u32,
    /// Estimated queued work (sum of durations of queued entries + the
    /// running task's full duration) — the probe-placement load signal.
    pub est_work: f64,
    /// Provisioning request time (transient lifetime accounting).
    pub requested_at: Time,
    /// When the server became Active.
    pub active_at: Time,
    /// When the server retired.
    pub retired_at: Time,
    /// Global activation order (assigned at `TransientReady`): the
    /// transient drain-victim tie-break. Unique per activation, so the
    /// pool index's argmin is independent of slot reuse and reproduces
    /// the historical "first-minimal in ready order" scan bit-exactly.
    pub ready_seq: u64,
}

impl Server {
    pub fn new(id: ServerRef, kind: ServerKind, pool: Pool, state: ServerState, now: Time) -> Self {
        Server {
            id,
            kind,
            pool,
            state,
            running: None,
            queue: VecDeque::new(),
            long_tasks: 0,
            est_work: 0.0,
            requested_at: now,
            active_at: now,
            retired_at: 0.0,
            ready_seq: 0,
        }
    }

    /// Can the scheduler place new work here?
    #[inline]
    pub fn accepting(&self) -> bool {
        self.state == ServerState::Active
    }

    #[inline]
    pub fn is_idle(&self) -> bool {
        self.running.is_none() && self.queue.is_empty()
    }

    /// Queue length including the running slot.
    #[inline]
    pub fn depth(&self) -> usize {
        self.queue.len() + self.running.is_some() as usize
    }

    /// Select the next runnable task index in `queue` under `policy`,
    /// skipping stale copies (tasks already running/finished elsewhere,
    /// or — defensively — entries whose generation no longer matches the
    /// slot). Returns the queue index to pop, or None if the queue has
    /// no runnable entry. Stale entries pruned off the front are pushed
    /// to `pruned` so the cluster can settle their liveness accounting.
    pub fn select_next(
        &mut self,
        tasks: &[Task],
        policy: QueuePolicy,
        now: Time,
        pruned: &mut Vec<TaskRef>,
    ) -> Option<usize> {
        // Prune stale copies from the front first — cheap and keeps FIFO
        // semantics exact for the common case.
        while let Some(&front) = self.queue.front() {
            let t = &tasks[front.index()];
            if t.id == front && t.state == TaskState::Queued {
                break;
            }
            pruned.push(front);
            self.queue.pop_front();
        }
        if self.queue.is_empty() {
            return None;
        }
        match policy {
            QueuePolicy::Fifo => Some(0),
            QueuePolicy::Srpt { starvation_limit } => {
                let mut best: Option<(usize, f64)> = None;
                let mut starved: Option<usize> = None;
                for (i, &tid) in self.queue.iter().enumerate() {
                    let t = &tasks[tid.index()];
                    if t.id != tid || t.state != TaskState::Queued {
                        continue; // stale copy, skipped (pruned on pop)
                    }
                    if now - t.enqueued_at > starvation_limit && starved.is_none() {
                        starved = Some(i);
                    }
                    let key = if t.is_long { f64::INFINITY } else { t.duration };
                    if best.map_or(true, |(_, k)| key < k) {
                        best = Some((i, key));
                    }
                }
                starved.or(best.map(|(i, _)| i))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::JobId;

    fn tref(slot: u32) -> TaskRef {
        TaskRef { slot, gen: 0 }
    }

    fn mk_task(id: u32, duration: f64, is_long: bool, enq: f64) -> Task {
        Task::new(tref(id), JobId(0), duration, is_long, enq)
    }

    fn mk_server() -> Server {
        Server::new(
            ServerRef::initial(0),
            ServerKind::OnDemand,
            Pool::General,
            ServerState::Active,
            0.0,
        )
    }

    #[test]
    fn fifo_picks_front() {
        let tasks = vec![mk_task(0, 10.0, false, 0.0), mk_task(1, 1.0, false, 0.0)];
        let mut s = mk_server();
        s.queue.push_back(tref(0));
        s.queue.push_back(tref(1));
        assert_eq!(s.select_next(&tasks, QueuePolicy::Fifo, 5.0, &mut vec![]), Some(0));
    }

    #[test]
    fn srpt_prefers_shortest_short() {
        let tasks = vec![
            mk_task(0, 50.0, false, 0.0),
            mk_task(1, 5.0, false, 0.0),
            mk_task(2, 20.0, false, 0.0),
        ];
        let mut s = mk_server();
        for i in 0..3 {
            s.queue.push_back(tref(i));
        }
        let policy = QueuePolicy::Srpt { starvation_limit: 1e9 };
        assert_eq!(s.select_next(&tasks, policy, 1.0, &mut vec![]), Some(1));
    }

    #[test]
    fn srpt_longs_yield_to_shorts() {
        let tasks = vec![mk_task(0, 1000.0, true, 0.0), mk_task(1, 30.0, false, 0.0)];
        let mut s = mk_server();
        s.queue.push_back(tref(0));
        s.queue.push_back(tref(1));
        let policy = QueuePolicy::Srpt { starvation_limit: 1e9 };
        assert_eq!(s.select_next(&tasks, policy, 1.0, &mut vec![]), Some(1));
    }

    #[test]
    fn srpt_starvation_guard_restores_fifo() {
        let tasks = vec![mk_task(0, 1000.0, true, 0.0), mk_task(1, 30.0, false, 400.0)];
        let mut s = mk_server();
        s.queue.push_back(tref(0));
        s.queue.push_back(tref(1));
        // Long task has waited 500 s > limit, so it runs despite SRPT.
        let policy = QueuePolicy::Srpt { starvation_limit: 300.0 };
        assert_eq!(s.select_next(&tasks, policy, 500.0, &mut vec![]), Some(0));
    }

    #[test]
    fn stale_copies_skipped() {
        let mut tasks = vec![mk_task(0, 10.0, false, 0.0), mk_task(1, 10.0, false, 0.0)];
        tasks[0].state = TaskState::Running; // copy started elsewhere
        let mut s = mk_server();
        s.queue.push_back(tref(0));
        s.queue.push_back(tref(1));
        let mut pruned = Vec::new();
        assert_eq!(s.select_next(&tasks, QueuePolicy::Fifo, 0.0, &mut pruned), Some(0));
        // After pruning, front is task 1 and the stale copy is reported.
        assert_eq!(s.queue.front(), Some(&tref(1)));
        assert_eq!(pruned, vec![tref(0)]);
    }

    #[test]
    fn empty_after_all_stale() {
        let mut tasks = vec![mk_task(0, 10.0, false, 0.0)];
        tasks[0].state = TaskState::Finished;
        let mut s = mk_server();
        s.queue.push_back(tref(0));
        let mut pruned = Vec::new();
        assert_eq!(s.select_next(&tasks, QueuePolicy::Fifo, 0.0, &mut pruned), None);
        assert!(s.queue.is_empty());
        assert_eq!(pruned.len(), 1);
    }

    #[test]
    fn generation_mismatch_is_pruned_as_stale() {
        // A queue entry whose slot was recycled (generation bumped, new
        // Queued payload) must be treated as stale, not run: the entry
        // refers to the *old* task, not the slot's new tenant.
        let mut tasks = vec![mk_task(0, 10.0, false, 0.0), mk_task(1, 10.0, false, 0.0)];
        tasks[0].id.gen = 3; // slot 0 recycled under a later generation
        let mut s = mk_server();
        s.queue.push_back(tref(0)); // stale handle: gen 0
        s.queue.push_back(tref(1));
        let mut pruned = Vec::new();
        assert_eq!(s.select_next(&tasks, QueuePolicy::Fifo, 0.0, &mut pruned), Some(0));
        assert_eq!(pruned, vec![tref(0)]);
        assert_eq!(s.queue.front(), Some(&tref(1)));
        // SRPT skips mismatched entries in the scan as well.
        let mut s2 = mk_server();
        s2.queue.push_back(tref(1));
        s2.queue.push_back(tref(0)); // stale, not at front
        let policy = QueuePolicy::Srpt { starvation_limit: 1e9 };
        assert_eq!(s2.select_next(&tasks, policy, 1.0, &mut vec![]), Some(0));
    }
}
