//! The cluster: the **generational server arena**, the **generational
//! task arena**, partitions, and the incremental state the schedulers
//! and the transient manager read (`N_long`, `N_total`, the long-load
//! ratio).
//!
//! All mutation goes through methods here so the invariants hold by
//! construction:
//!
//! * `n_long_servers` == number of Active/Draining servers with
//!   `long_tasks > 0` (the paper's `N_long`).
//! * `n_total` == number of Active/Draining servers (the paper's
//!   `N_total`).
//! * a server's `running` task is always in state `Running` with
//!   `ran_on == server`.
//!
//! ## The task arena
//!
//! Tasks are slot-allocated; a [`TaskRef`] (slot + generation) is the
//! only way to address one. A slot is pushed onto the free list — and
//! its generation bumped — exactly when the task is `Finished` *and*
//! its liveness count (queue copies + pending `TaskFinish` events) hits
//! zero, so the *task arena* is O(peak active tasks), not O(trace).
//! Every settle site ([`Cluster::try_start_next`] pruning,
//! [`Cluster::on_task_finish`], [`Cluster::revoke`]) releases its ref
//! through [`Cluster::maybe_free`]. Recycling can be disabled
//! ([`Cluster::set_task_recycling`]) for golden comparisons; liveness
//! accounting is identical in both modes, so every simulation
//! observable — including `peak_resident_tasks` — is bit-identical
//! with recycling on or off.
//!
//! ## The server arena
//!
//! Servers get the same treatment through [`ServerRef`]
//! (slot + generation). The on-demand prefix (general +
//! short-reserved) is permanent — those slots never recycle and keep
//! generation 0 for the whole run. A **retired transient's** slot is
//! released immediately at [`Cluster::retire`]: its generation bumps
//! and the slot joins a free list, so a revocation-heavy run's server
//! arena is bounded by the on-demand size plus *peak concurrent*
//! transients, not by transients ever requested. Unlike tasks, no
//! liveness count is needed: every lifecycle event that can outlive
//! its server (`Revoked`, `RevocationWarning`, `DrainComplete`, a
//! revoked execution's `TaskFinish`) is generation-checked at pop
//! ([`Cluster::get_server`]) and resolves to "stale, skip" — it can
//! never act on the slot's next tenant. The transient pool index
//! recycles its tree slots in lockstep (`index.rs`), with the
//! `ready_seq` key component preserving the historical ready-order
//! tie-break bit-exactly. Recycling is toggleable
//! ([`Cluster::set_server_recycling`]) for golden comparisons;
//! `peak_resident_servers` accounting is mode-independent, so every
//! simulation observable is bit-identical either way.
//!
//! ## Struct-of-arrays hot fields
//!
//! The placement/argmin paths (probe sampling, `least_loaded_*`,
//! Eagle's succinct-state filter) touch four per-server fields on
//! every event: `est_work`, queue depth, the accepting/long state
//! bits, and `ready_seq`. Walking `Vec<Server>` for those reads drags
//! the cold remainder (the queue `VecDeque`, lifecycle timestamps)
//! through cache. [`HotFields`] keeps a dense parallel-array mirror,
//! **indexed by arena slot** — the generation discipline is unchanged:
//! handles are still validated against `Server::id` (hot-field
//! accessors `debug_assert` it), and slot reuse overwrites the arrays
//! in lockstep with the struct. The arrays are maintained
//! *unconditionally* by every mutator ([`Cluster::sync_hot`], called
//! from `sync_index` and every state transition);
//! [`Cluster::set_soa_hot_fields`] only switches which representation
//! the read accessors consult, so SoA-vs-struct bit-identity is
//! testable the same way the recycling toggles are, and
//! [`Cluster::check_invariants`] pins array == struct in both modes.
//!
//! ## Steady-state allocation pooling
//!
//! The event loop's mutation paths allocate nothing once warm:
//! `try_start_next` pruning and `steal_short_tasks` run on pooled
//! scratch buffers, [`Cluster::revoke_into`] fills a caller-passed
//! orphan buffer (the [`Engine::pop_batch`] idiom), and retired
//! transients donate their queue `VecDeque` buffers to a free pool
//! that [`Cluster::request_transient`] reinstalls on the slot's next
//! tenant. [`PoolStats`] counts hits/misses for every pool (task
//! slots, server slots, queue buffers) — deterministic counters the
//! opt-in profiler reports as the zero-alloc evidence.

use std::collections::VecDeque;

use crate::cluster::{
    Pool, PoolIndex, QueuePolicy, Server, ServerKind, ServerState, Task, TaskState,
};
use crate::metrics::Recorder;
use crate::sim::{Engine, Event};
use crate::util::{JobId, ServerRef, TaskRef, Time};

/// What a popped `TaskFinish` event resolved to.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FinishOutcome {
    /// The event outlived its execution (the §3.3 revocation race): the
    /// task was revoked mid-run and restarted — or already finished —
    /// elsewhere. Its liveness ref has been consumed; skip the event.
    Stale,
    /// The running task completed. Fields are extracted *here*, before
    /// the slot can be recycled — callers must not read them back
    /// through the (possibly freed) `TaskRef`.
    Finished {
        job: JobId,
        is_long: bool,
        /// The server is draining and has gone idle — the caller should
        /// retire it.
        drained: bool,
    },
}

/// Dense parallel arrays of the per-server fields the placement,
/// probe and argmin paths touch every event (see the module docs).
/// Indexed by **arena slot**; maintained in lockstep with the
/// `Server` structs by every mutator, whether or not the SoA read
/// path is enabled.
#[derive(Clone, Debug, Default)]
pub struct HotFields {
    /// Estimated queued + running work (the probe-score field).
    pub est_work: Vec<f64>,
    /// Queue length + running occupancy — the transient-index depth key.
    pub depth: Vec<u32>,
    /// State tag collapsed to the one bit placement cares about
    /// (`state == Active`).
    pub accepting: Vec<bool>,
    /// Eagle's succinct state: does the server host any long task?
    pub has_long: Vec<bool>,
    /// Kind tag collapsed to the bit the §3.3 duplication check reads.
    pub is_transient: Vec<bool>,
    /// Activation order — the transient drain-victim tie-break key.
    pub ready_seq: Vec<u64>,
}

impl HotFields {
    /// Extend every array by one default slot (new arena slot appended).
    fn push_slot(&mut self) {
        self.est_work.push(0.0);
        self.depth.push(0);
        self.accepting.push(false);
        self.has_long.push(false);
        self.is_transient.push(false);
        self.ready_seq.push(0);
    }
}

/// Hit/miss counters for the steady-state allocation pools. A *hit*
/// reuses pooled capacity; a *miss* allocates fresh. Pure event-driven
/// counts — deterministic for a fixed config, so the profiler reports
/// them and CI pins run-to-run identity. Not part of the bit-identity
/// surface (reference modes legitimately miss more).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Task arena: slot popped from the free list vs fresh append.
    pub task_slot_hits: u64,
    pub task_slot_misses: u64,
    /// Server arena: retired-transient slot reused vs fresh append.
    pub server_slot_hits: u64,
    pub server_slot_misses: u64,
    /// Transient queue `VecDeque` buffers reinstalled from the pool vs
    /// freshly allocated with the new tenant.
    pub queue_buf_hits: u64,
    pub queue_buf_misses: u64,
}

/// Full simulated-cluster state.
pub struct Cluster {
    /// Server arena slots. Addressed through generation-checked
    /// [`ServerRef`]s ([`Cluster::server`] / [`Cluster::get_server`]);
    /// retired transient slots recycle.
    pub servers: Vec<Server>,
    /// Task arena slots. Addressed only through generation-checked
    /// [`TaskRef`]s ([`Cluster::task`] / [`Cluster::get_task`]).
    tasks: Vec<Task>,
    /// Recycled task-slot indices awaiting reuse (LIFO).
    free_slots: Vec<u32>,
    /// Recycle freed task slots (default). Off = append-only reference
    /// mode for the recycling-vs-not golden pin.
    recycle: bool,
    /// Slots currently holding a live (not yet released) task.
    resident_tasks: usize,
    /// High-water mark of `resident_tasks` — the arena-memory headline.
    peak_resident_tasks: usize,
    /// Recycled server-slot indices awaiting reuse (LIFO).
    free_server_slots: Vec<u32>,
    /// Recycle retired server slots (default). Off = append-only
    /// reference mode for the recycling-vs-not golden pin.
    recycle_servers: bool,
    /// Server slots currently live (on-demand prefix + transients not
    /// yet released). Accounting is recycling-mode independent.
    resident_servers: usize,
    /// High-water mark of `resident_servers` — the server-arena memory
    /// headline, bounded by on-demand size + peak concurrent transients.
    peak_resident_servers: usize,
    /// Global transient-activation counter: the drain-victim tie-break
    /// (see `Server::ready_seq`).
    next_ready_seq: u64,
    /// Transient servers requested but not yet ready — incremental twin
    /// of the Provisioning-state scan, kept O(1) because the federation
    /// reads it after every member step.
    n_provisioning: usize,
    pub policy: QueuePolicy,
    /// Servers (Active or Draining) currently hosting >= 1 long task.
    n_long_servers: usize,
    /// Servers currently Active or Draining.
    n_total: usize,
    /// On-demand general partition (long + short), fixed.
    pub general: Vec<ServerRef>,
    /// On-demand short-only partition, fixed ("buffer", §3.1).
    pub short_reserved: Vec<ServerRef>,
    /// Active transient servers (dynamic short-only partition).
    pub transient_pool: Vec<ServerRef>,
    /// Per-pool argmin indexes (general / short-reserved / transient) —
    /// O(log N) exact least-loaded queries for every placement path.
    index: PoolIndex,
    /// Dense SoA mirror of the hot fields, indexed by arena slot.
    /// Always maintained; [`Cluster::set_soa_hot_fields`] picks the
    /// read path.
    hot: HotFields,
    /// Serve hot-field reads from the dense arrays (default). Off =
    /// read back through the `Server` structs — the reference layout
    /// for the SoA-vs-struct golden pin.
    soa_hot_fields: bool,
    /// Retired transients' queue buffers awaiting reuse: the buffer
    /// recycles alongside the arena slot so steady-state provisioning
    /// churn reuses capacity instead of allocating per tenant.
    free_queue_bufs: Vec<VecDeque<TaskRef>>,
    /// Allocation-pool hit/miss counters (profiler evidence).
    pool_stats: PoolStats,
    /// Pooled scratch for `try_start_next` pruning — taken/restored
    /// around the dequeue loop; never allocates once warm.
    scratch_pruned: Vec<TaskRef>,
    /// Pooled scratch for `steal_short_tasks` (same discipline).
    scratch_stolen: Vec<TaskRef>,
}

impl Cluster {
    /// Build the static cluster: `n_general` general servers plus
    /// `n_short_reserved` on-demand short-only servers.
    pub fn new(n_general: usize, n_short_reserved: usize, policy: QueuePolicy) -> Self {
        let mut servers = Vec::with_capacity(n_general + n_short_reserved);
        let mut general = Vec::with_capacity(n_general);
        let mut short_reserved = Vec::with_capacity(n_short_reserved);
        for i in 0..n_general + n_short_reserved {
            let id = ServerRef::initial(i as u32);
            let pool = if i < n_general { Pool::General } else { Pool::ShortReserved };
            servers.push(Server::new(id, ServerKind::OnDemand, pool, ServerState::Active, 0.0));
            if i < n_general {
                general.push(id);
            } else {
                short_reserved.push(id);
            }
        }
        let mut cluster = Cluster {
            n_total: servers.len(),
            resident_servers: servers.len(),
            peak_resident_servers: servers.len(),
            servers,
            tasks: Vec::new(),
            free_slots: Vec::new(),
            recycle: true,
            resident_tasks: 0,
            peak_resident_tasks: 0,
            free_server_slots: Vec::new(),
            recycle_servers: true,
            next_ready_seq: 0,
            n_provisioning: 0,
            policy,
            n_long_servers: 0,
            general,
            short_reserved,
            transient_pool: Vec::new(),
            index: PoolIndex::new(n_general, n_short_reserved),
            hot: HotFields::default(),
            soa_hot_fields: true,
            free_queue_bufs: Vec::new(),
            pool_stats: PoolStats::default(),
            scratch_pruned: Vec::new(),
            scratch_stolen: Vec::new(),
        };
        for slot in 0..cluster.servers.len() {
            cluster.hot.push_slot();
            cluster.sync_hot(slot);
        }
        cluster
    }

    /// Toggle task-slot recycling. Off keeps the arena append-only (the
    /// pre-arena reference behaviour) while leaving every simulation
    /// observable — including liveness accounting and
    /// `peak_resident_tasks` — bit-identical; the golden tests pin that.
    pub fn set_task_recycling(&mut self, on: bool) {
        self.recycle = on;
    }

    /// Toggle server-slot recycling (same golden-comparison role as
    /// [`Cluster::set_task_recycling`]): off keeps one slot per
    /// transient ever requested, on bounds the arena by peak concurrent
    /// transients. `peak_resident_servers` accounting and every
    /// simulation observable are identical in both modes.
    pub fn set_server_recycling(&mut self, on: bool) {
        self.recycle_servers = on;
    }

    /// Toggle the SoA read path for the hot fields (default on). The
    /// dense arrays are maintained by every mutator in both modes —
    /// this only picks which representation the read accessors
    /// ([`Cluster::est_work_of`], [`Cluster::is_accepting`],
    /// [`Cluster::has_long`], [`Cluster::has_queued`],
    /// [`Cluster::is_transient`]) consult, so every simulation
    /// observable is bit-identical either way; the golden tests pin it.
    pub fn set_soa_hot_fields(&mut self, on: bool) {
        self.soa_hot_fields = on;
    }

    /// Allocation-pool hit/miss counters (see [`PoolStats`]).
    #[inline]
    pub fn pool_stats(&self) -> PoolStats {
        self.pool_stats
    }

    /// Refresh the dense hot-field mirror for one arena slot from its
    /// `Server` struct. Called from [`Cluster::sync_index`] (every load
    /// change) and from every state transition that bypasses it.
    // lint: hot-path
    #[inline]
    fn sync_hot(&mut self, slot: usize) {
        let s = &self.servers[slot];
        self.hot.est_work[slot] = s.est_work;
        self.hot.depth[slot] = s.depth() as u32;
        self.hot.accepting[slot] = s.accepting();
        self.hot.has_long[slot] = s.long_tasks > 0;
        self.hot.is_transient[slot] = s.kind == ServerKind::Transient;
        self.hot.ready_seq[slot] = s.ready_seq;
    }

    /// Keep the per-pool argmin indexes in sync after any load change on
    /// `sid` (est_work, queue depth, or running slot).
    // lint: hot-path
    #[inline]
    fn sync_index(&mut self, sid: ServerRef) {
        let (pool, est_work, depth, seq) = {
            let s = &self.servers[sid.index()];
            debug_assert_eq!(s.id, sid, "sync_index through a stale ServerRef");
            (s.pool, s.est_work, s.depth() as u32, s.ready_seq)
        };
        self.sync_hot(sid.index());
        match pool {
            Pool::General => self.index.update_general(sid.index(), est_work),
            Pool::ShortReserved => {
                self.index.update_short(sid.index() - self.general.len(), est_work)
            }
            // No-op unless the server is indexed (i.e. Active).
            Pool::TransientPool => self.index.update_transient(sid, (depth, est_work, seq)),
        }
    }

    /// The general-partition server with the least estimated wait — the
    /// centralized scheduler's placement target for long tasks.
    #[inline]
    pub fn least_loaded_general(&self) -> ServerRef {
        let slot = self.index.least_loaded_general_slot().expect("empty general partition"); // lint: allow(panic-surface): build() rejects clusters with an empty general partition
        self.general[slot]
    }

    /// The least-loaded on-demand short-partition server (always
    /// accepting — on-demand servers never drain). `None` only when the
    /// short partition has size zero. The §3.3 duplication target and
    /// the revocation-orphan fallback.
    #[inline]
    pub fn least_loaded_short_reserved(&self) -> Option<ServerRef> {
        self.index.least_loaded_short_slot().map(|slot| self.short_reserved[slot])
    }

    /// The Active transient server minimizing
    /// `(depth, est_work, ready_seq)` — the transient manager's drain
    /// victim (fastest to free, earliest-activated on load ties).
    #[inline]
    pub fn transient_drain_victim(&self) -> Option<ServerRef> {
        self.index.transient_argmin()
    }

    /// Read-only view of the per-pool load indexes (tests, tooling).
    #[inline]
    pub fn pool_index(&self) -> &PoolIndex {
        &self.index
    }

    // ------------------------------------------------------------ queries

    #[inline]
    pub fn n_long_servers(&self) -> usize {
        self.n_long_servers
    }

    #[inline]
    pub fn n_total(&self) -> usize {
        self.n_total
    }

    /// The paper's long-load ratio `l_r = N_long / N_total` (§3.2).
    #[inline]
    pub fn long_load_ratio(&self) -> f64 {
        if self.n_total == 0 {
            0.0
        } else {
            self.n_long_servers as f64 / self.n_total as f64
        }
    }

    /// Dereference a server handle. Panics if the slot was recycled —
    /// holding a `ServerRef` across a retire/release point is a caller
    /// bug; use [`Cluster::get_server`] when staleness is an expected
    /// outcome (lifecycle events racing a revocation).
    #[inline]
    pub fn server(&self, id: ServerRef) -> &Server {
        let s = &self.servers[id.index()];
        assert_eq!(s.id, id, "stale ServerRef {id:?}: slot was recycled (now {:?})", s.id);
        s
    }

    /// Generation-checked dereference: `None` iff the slot has been
    /// released (and possibly reused) since `id` was issued — i.e. the
    /// transient retired and its slot recycled. The lifecycle-event
    /// handlers (`Revoked`, `RevocationWarning`, `DrainComplete`, the
    /// work stealer's thief check) route through this, so a stale event
    /// can never act on the slot's next tenant.
    #[inline]
    pub fn get_server(&self, id: ServerRef) -> Option<&Server> {
        let s = self.servers.get(id.index())?;
        (s.id == id).then_some(s)
    }

    /// Dereference a task handle. Panics if the slot was recycled —
    /// holding a `TaskRef` across a release point is a caller bug; use
    /// [`Cluster::get_task`] when staleness is an expected outcome.
    #[inline]
    pub fn task(&self, r: TaskRef) -> &Task {
        let t = &self.tasks[r.index()];
        assert_eq!(t.id, r, "stale TaskRef {r:?}: slot was recycled (now {:?})", t.id);
        t
    }

    /// Generation-checked dereference: `None` iff the slot has been
    /// released (and possibly reused) since `r` was issued — i.e. the
    /// task finished and all its liveness refs settled.
    #[inline]
    pub fn get_task(&self, r: TaskRef) -> Option<&Task> {
        let t = self.tasks.get(r.index())?;
        (t.id == r).then_some(t)
    }

    /// Tasks currently resident in the arena (allocated, not released).
    #[inline]
    pub fn resident_tasks(&self) -> usize {
        self.resident_tasks
    }

    /// High-water mark of resident tasks — with recycling on this also
    /// bounds the arena's slot count, so it is the O(active) memory
    /// headline reported next to `peak_resident_jobs`.
    #[inline]
    pub fn peak_resident_tasks(&self) -> usize {
        self.peak_resident_tasks
    }

    /// Arena slots ever allocated (== `peak_resident_tasks` with
    /// recycling on; == total tasks with recycling off).
    #[inline]
    pub fn task_slots(&self) -> usize {
        self.tasks.len()
    }

    /// Server slots currently live (on-demand prefix + unreleased
    /// transients). Mode-independent, like `resident_tasks`.
    #[inline]
    pub fn resident_servers(&self) -> usize {
        self.resident_servers
    }

    /// High-water mark of resident server slots — with recycling on
    /// this also bounds the server arena's slot count: on-demand size +
    /// peak concurrent transients, reported next to
    /// `peak_resident_tasks` as the second arena-memory headline.
    #[inline]
    pub fn peak_resident_servers(&self) -> usize {
        self.peak_resident_servers
    }

    /// Server-arena slots ever allocated (== `peak_resident_servers`
    /// with recycling on; on-demand + transients ever requested with
    /// recycling off).
    #[inline]
    pub fn server_slots(&self) -> usize {
        self.servers.len()
    }

    /// Does this server currently host any long task? (The "succinct
    /// state" bit Eagle's distributed schedulers use to dodge
    /// head-of-line blocking.)
    #[inline]
    pub fn has_long(&self, id: ServerRef) -> bool {
        debug_assert_eq!(self.servers[id.index()].id, id, "has_long through a stale ServerRef");
        if self.soa_hot_fields {
            self.hot.has_long[id.index()]
        } else {
            self.servers[id.index()].long_tasks > 0
        }
    }

    /// Estimated queued + running work on `id` — the probe-score read
    /// every placement path makes. Dense-array read by default.
    #[inline]
    pub fn est_work_of(&self, id: ServerRef) -> f64 {
        debug_assert_eq!(self.servers[id.index()].id, id, "est_work_of through a stale ServerRef");
        if self.soa_hot_fields {
            self.hot.est_work[id.index()]
        } else {
            self.servers[id.index()].est_work
        }
    }

    /// Is `id` accepting new work (state == Active)? The probe-sampling
    /// filter. Dense-array read by default.
    #[inline]
    pub fn is_accepting(&self, id: ServerRef) -> bool {
        debug_assert_eq!(self.servers[id.index()].id, id, "is_accepting through a stale ServerRef");
        if self.soa_hot_fields {
            self.hot.accepting[id.index()]
        } else {
            self.servers[id.index()].accepting()
        }
    }

    /// Is `id` a transient server? The §3.3 duplication check's kind
    /// read. Dense-array read by default.
    #[inline]
    pub fn is_transient(&self, id: ServerRef) -> bool {
        debug_assert_eq!(self.servers[id.index()].id, id, "is_transient through a stale ServerRef");
        if self.soa_hot_fields {
            self.hot.is_transient[id.index()]
        } else {
            self.servers[id.index()].kind == ServerKind::Transient
        }
    }

    /// Does `id` have any *queued* (not running) task? The work
    /// stealer's victim filter. The dense path derives queue length
    /// from the depth array (depth = queue length + running occupancy).
    #[inline]
    pub fn has_queued(&self, id: ServerRef) -> bool {
        debug_assert_eq!(self.servers[id.index()].id, id, "has_queued through a stale ServerRef");
        if self.soa_hot_fields {
            self.hot.depth[id.index()] > self.servers[id.index()].running.is_some() as u32
        } else {
            !self.servers[id.index()].queue.is_empty()
        }
    }

    // ---------------------------------------------------------- tasks

    /// Create a task in the arena (does not enqueue it), reusing a
    /// recycled slot when one is free.
    // lint: hot-path
    pub fn add_task(&mut self, job: JobId, duration: f64, is_long: bool, now: Time) -> TaskRef {
        self.resident_tasks += 1;
        self.peak_resident_tasks = self.peak_resident_tasks.max(self.resident_tasks);
        if let Some(slot) = self.free_slots.pop() {
            // The generation was bumped at release; reuse it as-is so
            // every pre-release handle stays invalid.
            self.pool_stats.task_slot_hits += 1;
            let gen = self.tasks[slot as usize].id.gen;
            let id = TaskRef { slot, gen };
            self.tasks[slot as usize] = Task::new(id, job, duration, is_long, now);
            id
        } else {
            self.pool_stats.task_slot_misses += 1;
            let id = TaskRef { slot: self.tasks.len() as u32, gen: 0 };
            self.tasks.push(Task::new(id, job, duration, is_long, now));
            id
        }
    }

    /// Release one liveness ref's worth of bookkeeping: if the task is
    /// finished and no queue copy or pending finish event pins it, the
    /// slot is released (and, with recycling on, its generation bumped
    /// and the slot queued for reuse). Safe to call speculatively after
    /// any ref drop; no-ops while any ref remains.
    fn maybe_free(&mut self, r: TaskRef) {
        let t = &mut self.tasks[r.index()];
        if t.id != r {
            debug_assert!(false, "maybe_free on already-recycled {r:?}");
            return;
        }
        if t.state != TaskState::Finished || t.copies != 0 || t.pending_finishes != 0 {
            return;
        }
        self.resident_tasks -= 1;
        if self.recycle {
            t.id.gen = t.id.gen.wrapping_add(1);
            self.free_slots.push(r.slot);
        }
    }

    /// Enqueue (a copy of) `task` on `server`; starts it immediately if
    /// the server is idle. Panics if the server is not accepting work.
    // lint: hot-path
    pub fn enqueue(
        &mut self,
        task_id: TaskRef,
        server_id: ServerRef,
        engine: &mut Engine,
        rec: &mut Recorder,
    ) {
        let is_long;
        {
            let task = &mut self.tasks[task_id.index()];
            debug_assert_eq!(task.id, task_id, "enqueue through a stale TaskRef");
            debug_assert_eq!(task.state, TaskState::Queued, "enqueue of non-queued task");
            task.copies += 1;
            task.add_location(server_id);
            is_long = task.is_long;
        }
        let dur = self.tasks[task_id.index()].duration;
        let server = &mut self.servers[server_id.index()];
        assert!(server.accepting(), "enqueue on non-accepting server {server_id:?}");
        server.queue.push_back(task_id);
        server.est_work += dur;
        if is_long {
            server.long_tasks += 1;
            if server.long_tasks == 1 {
                self.n_long_servers += 1;
            }
        }
        self.sync_index(server_id);
        if self.servers[server_id.index()].running.is_none() {
            self.try_start_next(server_id, engine, rec);
        }
    }

    /// Pop the next runnable task (per policy) and start it. No-op if the
    /// slot is busy or the queue has no runnable entry.
    // lint: hot-path
    pub fn try_start_next(
        &mut self,
        server_id: ServerRef,
        engine: &mut Engine,
        rec: &mut Recorder,
    ) {
        let now = engine.now();
        if self.servers[server_id.index()].running.is_some() {
            return;
        }
        // Pooled scratch: taken for the loop, restored (cleared) on
        // every exit — the dequeue path never allocates once warm.
        let mut pruned: Vec<TaskRef> = std::mem::take(&mut self.scratch_pruned);
        loop {
            let idx = {
                let server = &mut self.servers[server_id.index()];
                pruned.clear();
                let idx = server.select_next(&self.tasks, self.policy, now, &mut pruned);
                idx
            };
            for &tid in &pruned {
                // Settle the stale copy: its est_work contribution was
                // already discounted when the live copy started. Dropping
                // the copy may release the slot (a §3.3 shadow whose twin
                // already finished).
                let t = &mut self.tasks[tid.index()];
                debug_assert_eq!(t.id, tid, "queue entry outlived its slot");
                t.copies -= 1;
                t.remove_location(server_id);
                rec.stale_copies_skipped += 1;
                self.maybe_free(tid);
            }
            let Some(idx) = idx else {
                // Pruning may have shortened the queue — resync depth.
                self.sync_index(server_id);
                break;
            };
            let server = &mut self.servers[server_id.index()];
            let task_id = server.queue.remove(idx).expect("index from select_next"); // lint: allow(panic-surface): idx came from select_next over this same queue one line up
            let task = &mut self.tasks[task_id.index()];
            debug_assert_eq!(task.id, task_id, "queue entry outlived its slot");
            if task.state != TaskState::Queued {
                // Stale copy (non-front selection path): settle like the
                // pruned entries above.
                task.copies -= 1;
                task.remove_location(server_id);
                rec.stale_copies_skipped += 1;
                self.maybe_free(task_id);
                continue;
            }
            task.state = TaskState::Running;
            task.started_at = now;
            task.ran_on = Some(server_id);
            task.copies -= 1;
            // The execution's finish event becomes the liveness ref that
            // replaces the consumed queue copy.
            task.pending_finishes += 1;
            task.remove_location(server_id);
            let other = task.other_location(server_id);
            let dur = task.duration;
            let is_long = task.is_long;
            let delay = task.queueing_delay();
            server.running = Some(task_id);
            // est_work keeps the running task's full duration as the
            // occupancy estimate (matches the probe-score convention) —
            // the queued contribution simply becomes the running one.
            rec.task_started(is_long, delay);
            engine.schedule_after(dur, Event::TaskFinish { server: server_id, task: task_id });
            // Discount the §3.3 shadow copy from its host's load estimate
            // right away so probe placement sees true load; the stale
            // queue entry itself is pruned lazily at dequeue.
            if let Some(other_sid) = other {
                let o = &mut self.servers[other_sid.index()];
                o.est_work = (o.est_work - dur).max(0.0);
                self.sync_index(other_sid);
            }
            self.sync_index(server_id);
            break;
        }
        pruned.clear();
        self.scratch_pruned = pruned;
    }

    /// Consume a popped `TaskFinish` event: drop its liveness ref, filter
    /// stale finishes (a revocation killed the execution after the event
    /// was scheduled), and on a live finish run the completion
    /// bookkeeping. Completion fields are extracted into the returned
    /// [`FinishOutcome`] *before* the slot can be recycled — never read
    /// them back through the `TaskRef`.
    // lint: hot-path
    pub fn on_task_finish(
        &mut self,
        server_id: ServerRef,
        task_id: TaskRef,
        engine: &mut Engine,
        rec: &mut Recorder,
    ) -> FinishOutcome {
        let (live, job, is_long) = {
            let task = &mut self.tasks[task_id.index()];
            // The pending-finish ref pins the slot, so a popped event's
            // generation always matches; a mismatch is a refcount bug.
            debug_assert_eq!(task.id, task_id, "TaskFinish outlived its arena slot");
            debug_assert!(task.pending_finishes > 0, "unaccounted TaskFinish");
            task.pending_finishes -= 1;
            (
                task.state == TaskState::Running && task.ran_on == Some(server_id),
                task.job,
                task.is_long,
            )
        };
        if !live {
            // Execution superseded (revocation) or generation drift: the
            // ref drop above may have been the last pin.
            self.maybe_free(task_id);
            return FinishOutcome::Stale;
        }
        self.tasks[task_id.index()].state = TaskState::Finished;
        let dur = self.tasks[task_id.index()].duration;
        {
            let server = &mut self.servers[server_id.index()];
            debug_assert_eq!(server.running, Some(task_id));
            server.running = None;
            server.est_work = (server.est_work - dur).max(0.0);
            if is_long {
                debug_assert!(server.long_tasks > 0);
                server.long_tasks -= 1;
                if server.long_tasks == 0 {
                    self.n_long_servers -= 1;
                }
            }
        }
        rec.tasks_finished += 1;
        self.sync_index(server_id);
        self.try_start_next(server_id, engine, rec);
        // A §3.3 shadow copy may still pin the slot; it settles when its
        // host dequeues (or revokes) it.
        self.maybe_free(task_id);
        let server = &self.servers[server_id.index()];
        let drained = server.state == ServerState::Draining && server.is_idle();
        FinishOutcome::Finished { job, is_long, drained }
    }

    /// Hawk/Eagle-style randomized task stealing: move up to `max_n`
    /// *queued short* tasks from `victim`'s queue to `thief` (which must
    /// be idle and accepting). Returns how many were moved.
    ///
    /// This is how the Hawk lineage (which Eagle and therefore
    /// CloudCoaster build on) drains deep queues left behind by load
    /// spikes: an idle server probes random busy ones and takes a batch
    /// of their pending shorts.
    // lint: hot-path
    pub fn steal_short_tasks(
        &mut self,
        victim: ServerRef,
        thief: ServerRef,
        max_n: usize,
        engine: &mut Engine,
        rec: &mut Recorder,
    ) -> usize {
        if victim == thief || !self.servers[thief.index()].accepting() {
            return 0;
        }
        // Pooled scratch (same discipline as `try_start_next`).
        let mut stolen: Vec<TaskRef> = std::mem::take(&mut self.scratch_stolen);
        {
            let queue = &mut self.servers[victim.index()].queue;
            let mut i = 0;
            while i < queue.len() && stolen.len() < max_n {
                let tid = queue[i];
                let t = &self.tasks[tid.index()];
                if t.id == tid && !t.is_long && t.state == TaskState::Queued {
                    queue.remove(i);
                    stolen.push(tid);
                } else {
                    i += 1;
                }
            }
        }
        let mut freed = 0.0;
        for &tid in &stolen {
            freed += self.tasks[tid.index()].duration;
            // The queue entry moves servers; `copies` nets out against the
            // re-enqueue below (a Queued task is never releasable, so the
            // transient zero-copies state cannot free the slot).
            self.tasks[tid.index()].copies -= 1;
            self.tasks[tid.index()].remove_location(victim);
        }
        {
            let server = &mut self.servers[victim.index()];
            server.est_work = (server.est_work - freed).max(0.0);
        }
        self.sync_index(victim);
        let n = stolen.len();
        for i in 0..n {
            let tid = stolen[i];
            self.enqueue(tid, thief, engine, rec);
        }
        stolen.clear();
        self.scratch_stolen = stolen;
        n
    }

    // ------------------------------------------------- transient servers

    /// Request a new transient server (Provisioning until
    /// `TransientReady`), reusing a recycled arena slot when one is
    /// free. The returned handle carries the slot's live generation;
    /// stale handles from earlier tenants no longer dereference.
    pub fn request_transient(&mut self, now: Time) -> ServerRef {
        self.n_provisioning += 1;
        self.resident_servers += 1;
        self.peak_resident_servers = self.peak_resident_servers.max(self.resident_servers);
        let id = if let Some(slot) = self.free_server_slots.pop() {
            // The generation was bumped at release; reuse it as-is so
            // every pre-release handle stays invalid.
            self.pool_stats.server_slot_hits += 1;
            let gen = self.servers[slot as usize].id.gen;
            ServerRef { slot, gen }
        } else {
            self.pool_stats.server_slot_misses += 1;
            ServerRef::initial(self.servers.len() as u32)
        };
        let mut server =
            Server::new(id, ServerKind::Transient, Pool::TransientPool, ServerState::Provisioning, now);
        // Reinstall a recycled queue buffer (harvested at retire) so
        // steady-state provisioning churn reuses capacity.
        if let Some(buf) = self.free_queue_bufs.pop() {
            debug_assert!(buf.is_empty(), "pooled queue buffer not drained");
            self.pool_stats.queue_buf_hits += 1;
            server.queue = buf;
        } else {
            self.pool_stats.queue_buf_misses += 1;
        }
        if id.index() == self.servers.len() {
            self.servers.push(server);
            self.hot.push_slot();
        } else {
            self.servers[id.index()] = server;
        }
        self.sync_hot(id.index());
        id
    }

    /// Number of transient servers still provisioning. O(1): the only
    /// Provisioning entry is [`Cluster::request_transient`] and the only
    /// exit is [`Cluster::transient_ready`]; `check_invariants` pins the
    /// counter to the arena scan.
    pub fn provisioning_count(&self) -> usize {
        self.n_provisioning
    }

    /// Provisioning finished: the server joins the dynamic short pool
    /// (and the transient load index), stamped with the next global
    /// activation number — the index's ready-order tie-break.
    pub fn transient_ready(&mut self, id: ServerRef, now: Time, rec: &mut Recorder) {
        debug_assert!(self.n_provisioning > 0, "ready without a pending request");
        self.n_provisioning -= 1;
        let seq = self.next_ready_seq;
        self.next_ready_seq += 1;
        let key = {
            let server = &mut self.servers[id.index()];
            debug_assert_eq!(server.id, id, "transient_ready through a stale ServerRef");
            debug_assert_eq!(server.state, ServerState::Provisioning);
            server.state = ServerState::Active;
            server.active_at = now;
            server.ready_seq = seq;
            (server.depth() as u32, server.est_work, seq)
        };
        self.sync_hot(id.index());
        self.transient_pool.push(id);
        self.index.insert_transient(id, key);
        self.n_total += 1;
        rec.cost.transient_up(now);
    }

    /// Begin graceful release: stop accepting, finish queued work (§3.2).
    /// Returns true if the server was already idle (caller retires it).
    pub fn begin_drain(&mut self, id: ServerRef) -> bool {
        let server = &mut self.servers[id.index()];
        debug_assert_eq!(server.state, ServerState::Active);
        debug_assert_eq!(server.kind, ServerKind::Transient);
        server.state = ServerState::Draining;
        self.sync_hot(id.index());
        // Remove from the probe-candidate pool and load index immediately.
        self.transient_pool.retain(|&s| s != id);
        self.index.remove_transient(id);
        self.servers[id.index()].is_idle()
    }

    /// Final shutdown of a drained/revoked transient server. The arena
    /// slot is released here: generation bumped (recycling on) and the
    /// slot queued for reuse, so pending lifecycle events addressed to
    /// this incarnation resolve as stale via the generation check.
    pub fn retire(&mut self, id: ServerRef, now: Time, rec: &mut Recorder) {
        let server = &mut self.servers[id.index()];
        debug_assert_eq!(server.id, id, "retire through a stale ServerRef");
        debug_assert!(matches!(server.state, ServerState::Draining | ServerState::Active));
        debug_assert_eq!(server.kind, ServerKind::Transient);
        debug_assert!(server.is_idle(), "retire of a busy server");
        if server.long_tasks > 0 {
            self.n_long_servers -= 1; // should not happen: transients are short-only
        }
        server.state = ServerState::Retired;
        server.retired_at = now;
        let lifetime = now - server.active_at;
        // Harvest the (empty) queue buffer: its capacity recycles
        // through the free pool to the next provisioned transient.
        let buf = std::mem::take(&mut server.queue);
        debug_assert!(buf.is_empty(), "retire harvested a non-empty queue");
        self.free_queue_bufs.push(buf);
        self.sync_hot(id.index());
        self.transient_pool.retain(|&s| s != id);
        self.index.remove_transient(id); // no-op if drain already removed it
        self.n_total -= 1;
        rec.cost.transient_down(now, lifetime);
        // Release the arena slot. Mode-independent residency accounting;
        // only the generation bump + free-list push depend on the mode.
        self.resident_servers -= 1;
        if self.recycle_servers {
            self.servers[id.index()].id.gen = id.gen.wrapping_add(1);
            self.free_server_slots.push(id.slot);
        }
    }

    /// Revoke a transient server immediately (provider reclaim, §3.3).
    ///
    /// Queued copies on it become stale; tasks whose *only* copy lived
    /// here (including a task mid-execution) are appended to `orphans`
    /// (cleared first) for rescheduling — a caller-passed scratch
    /// buffer, like [`Engine::pop_batch`], so the revocation path
    /// allocates nothing at steady state. The interrupted execution's
    /// already-scheduled `TaskFinish` event stays in the queue as a
    /// liveness ref — it pops later, resolves [`FinishOutcome::Stale`],
    /// and only then can the slot recycle.
    // lint: hot-path
    pub fn revoke_into(
        &mut self,
        id: ServerRef,
        now: Time,
        rec: &mut Recorder,
        orphans: &mut Vec<TaskRef>,
    ) {
        orphans.clear();
        // Take the queue instead of collecting it into a fresh Vec: it
        // is emptied below anyway, and the drained buffer goes back on
        // the slot so `retire` harvests its capacity into the pool.
        let mut queue = std::mem::take(&mut self.servers[id.index()].queue);
        let running = self.servers[id.index()].running;
        for tid in queue.drain(..) {
            let task = &mut self.tasks[tid.index()];
            debug_assert_eq!(task.id, tid, "queue entry outlived its slot");
            if task.state == TaskState::Queued {
                task.copies -= 1;
                task.remove_location(id);
                if task.copies == 0 {
                    orphans.push(tid);
                }
            } else {
                // Stale entry on the revoked server: settle it here since
                // its queue is being destroyed. May release the slot.
                task.copies -= 1;
                task.remove_location(id);
                self.maybe_free(tid);
            }
        }
        self.servers[id.index()].queue = queue;
        if let Some(tid) = running {
            // Mid-execution work is lost; the task restarts elsewhere.
            // (Its pending finish event keeps the slot pinned until it
            // pops as Stale.)
            let task = &mut self.tasks[tid.index()];
            debug_assert_eq!(task.id, tid, "running slot outlived its arena slot");
            task.state = TaskState::Queued;
            task.ran_on = None;
            if task.copies > 0 {
                // §3.3 payoff: a shadow copy still sits queued on an
                // on-demand server — the task resurrects there. Restore
                // the load-estimate contribution discounted at start.
                // (`placed_on` is a fixed two-slot array; copy it out
                // instead of collecting a Vec.)
                let dur = task.duration;
                let locs = task.placed_on;
                for loc in locs.into_iter().flatten() {
                    self.servers[loc.index()].est_work += dur;
                    self.sync_index(loc);
                }
            } else {
                orphans.push(tid);
            }
        }
        {
            let server = &mut self.servers[id.index()];
            server.running = None;
            server.est_work = 0.0;
            // Settle the N_long counter here (retire() sees 0 below).
            if server.long_tasks > 0 {
                server.long_tasks = 0;
                self.n_long_servers -= 1;
            }
        }
        self.sync_hot(id.index());
        rec.transients_revoked += 1;
        self.retire(id, now, rec);
    }

    /// [`Cluster::revoke_into`] returning a fresh orphan Vec — the
    /// allocating convenience wrapper (tests, tooling); the event loop
    /// threads its pooled scratch through `revoke_into` instead.
    pub fn revoke(&mut self, id: ServerRef, now: Time, rec: &mut Recorder) -> Vec<TaskRef> {
        let mut orphans = Vec::new();
        self.revoke_into(id, now, rec, &mut orphans);
        orphans
    }

    // ------------------------------------------------------- validation

    /// Exhaustive invariant check (tests / debug builds only — O(cluster)).
    pub fn check_invariants(&self) {
        use std::collections::HashSet;
        let free: HashSet<u32> = self.free_slots.iter().copied().collect(); // lint: allow(unordered-iter): duplicate detection via len() only, never iterated
        assert_eq!(free.len(), self.free_slots.len(), "duplicate slots on the free list");
        if self.recycle {
            assert_eq!(
                self.resident_tasks + self.free_slots.len(),
                self.tasks.len(),
                "resident/free accounting drift"
            );
        } else {
            assert!(self.free_slots.is_empty(), "free list populated with recycling off");
            assert!(self.resident_tasks <= self.tasks.len());
        }
        assert!(self.peak_resident_tasks >= self.resident_tasks);
        // Server-arena accounting (the server twin of the task checks).
        let free_servers: HashSet<u32> = self.free_server_slots.iter().copied().collect(); // lint: allow(unordered-iter): duplicate detection via len() only, never iterated
        assert_eq!(
            free_servers.len(),
            self.free_server_slots.len(),
            "duplicate slots on the server free list"
        );
        if self.recycle_servers {
            assert_eq!(
                self.resident_servers + self.free_server_slots.len(),
                self.servers.len(),
                "server resident/free accounting drift"
            );
        } else {
            assert!(
                self.free_server_slots.is_empty(),
                "server free list populated with recycling off"
            );
            assert!(self.resident_servers <= self.servers.len());
        }
        assert!(self.peak_resident_servers >= self.resident_servers);
        assert!(
            self.resident_servers >= self.general.len() + self.short_reserved.len(),
            "on-demand prefix released"
        );
        // The O(1) provisioning counter tracks the arena scan exactly.
        let provisioning_scan = self
            .servers
            .iter()
            .enumerate()
            .filter(|(i, s)| {
                !free_servers.contains(&(*i as u32))
                    && s.kind == ServerKind::Transient
                    && s.state == ServerState::Provisioning
            })
            .count();
        assert_eq!(self.n_provisioning, provisioning_scan, "provisioning counter drift");
        // SoA mirror: the dense hot-field arrays track the structs
        // exactly — for every slot, in both read modes, freed or live
        // (retire refreshes the arrays before releasing the slot).
        assert_eq!(self.hot.est_work.len(), self.servers.len(), "hot-array length drift");
        assert_eq!(self.hot.depth.len(), self.servers.len(), "hot-array length drift");
        assert_eq!(self.hot.ready_seq.len(), self.servers.len(), "hot-array length drift");
        let mut n_long = 0;
        let mut n_total = 0;
        for (i, s) in self.servers.iter().enumerate() {
            assert_eq!(s.id.index(), i, "server id/slot drift at {i}");
            assert_eq!(
                self.hot.est_work[i].to_bits(),
                s.est_work.to_bits(),
                "SoA est_work drift at slot {i}"
            );
            assert_eq!(self.hot.depth[i] as usize, s.depth(), "SoA depth drift at slot {i}");
            assert_eq!(self.hot.accepting[i], s.accepting(), "SoA accepting drift at slot {i}");
            assert_eq!(self.hot.has_long[i], s.long_tasks > 0, "SoA has_long drift at slot {i}");
            assert_eq!(
                self.hot.is_transient[i],
                s.kind == ServerKind::Transient,
                "SoA is_transient drift at slot {i}"
            );
            assert_eq!(self.hot.ready_seq[i], s.ready_seq, "SoA ready_seq drift at slot {i}");
            if free_servers.contains(&(i as u32)) {
                // Released slot awaiting reuse: payload is the retired
                // previous tenant; no live invariants apply.
                assert_eq!(s.state, ServerState::Retired, "freed server slot not Retired");
                continue;
            }
            if i < self.general.len() {
                assert!(
                    (self.index.general_key(i) - s.est_work).abs() < 1e-9,
                    "general index drift on server {i}"
                );
            } else if i < self.general.len() + self.short_reserved.len() {
                assert!(
                    (self.index.short_key(i - self.general.len()) - s.est_work).abs() < 1e-9,
                    "short index drift on server {i}"
                );
            }
            if s.kind == ServerKind::Transient {
                // Indexed iff Active; key mirrors (depth, est_work, seq).
                let indexed = self.index.contains_transient(s.id);
                assert_eq!(
                    indexed,
                    s.state == ServerState::Active,
                    "transient index membership drift on {:?} ({:?})",
                    s.id,
                    s.state
                );
                if let Some((depth, est, seq)) = self.index.transient_key(s.id) {
                    assert_eq!(depth as usize, s.depth(), "transient depth drift on {:?}", s.id);
                    assert!(
                        (est - s.est_work).abs() < 1e-9,
                        "transient est_work drift on {:?}",
                        s.id
                    );
                    assert_eq!(seq, s.ready_seq, "transient ready_seq drift on {:?}", s.id);
                }
            }
            if matches!(s.state, ServerState::Active | ServerState::Draining) {
                n_total += 1;
                if s.long_tasks > 0 {
                    n_long += 1;
                }
            }
            if let Some(tid) = s.running {
                // lint: allow(panic-surface): check_invariants is a diagnostic-only walk; a recycled ref here IS the bug it reports
                let t = self
                    .get_task(tid)
                    .expect("running slot references a recycled task");
                assert_eq!(t.state, TaskState::Running, "running slot holds non-running task");
                assert_eq!(t.ran_on, Some(s.id));
                assert!(t.pending_finishes > 0, "running task without a pending finish");
            }
            assert!(s.est_work >= -1e-9, "negative est_work on {:?}", s.id);
            // est_work == running duration + live queued entries (stale
            // copies were discounted when their live twin started).
            let mut expect = s.running.map(|t| self.task(t).duration).unwrap_or(0.0);
            for &tid in &s.queue {
                // lint: allow(panic-surface): check_invariants is a diagnostic-only walk; a recycled ref here IS the bug it reports
                let t = self
                    .get_task(tid)
                    .expect("server queue references a recycled task");
                if t.state == TaskState::Queued {
                    expect += t.duration;
                }
            }
            assert!(
                (s.est_work - expect).abs() < 1e-6 * expect.max(1.0),
                "est_work drift on {:?}: {} vs {}",
                s.id,
                s.est_work,
                expect
            );
        }
        for (slot, t) in self.tasks.iter().enumerate() {
            if free.contains(&(slot as u32)) {
                continue; // recycled payload, no invariants
            }
            assert_eq!(t.id.index(), slot, "task id/slot drift at {slot}");
            let locs = t.placed_on.iter().flatten().count() as u8;
            assert_eq!(t.copies, locs, "copies/placed_on drift on {:?}", t.id);
            if self.recycle {
                // Eager-release invariant: a finished task with no
                // liveness refs never lingers.
                assert!(
                    t.state != TaskState::Finished || t.copies > 0 || t.pending_finishes > 0,
                    "releasable task {:?} not released",
                    t.id
                );
            }
        }
        assert_eq!(n_long, self.n_long_servers, "N_long drift");
        assert_eq!(n_total, self.n_total, "N_total drift");
        assert_eq!(
            self.index.transient_len(),
            self.transient_pool.len(),
            "transient index / pool size drift"
        );
        let lr = self.long_load_ratio();
        assert!((0.0..=1.0).contains(&lr), "l_r out of [0,1]: {lr}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Generation-0 handle for the fixed on-demand prefix (and the
    /// first incarnation of transient slots).
    fn sref(slot: u32) -> ServerRef {
        ServerRef::initial(slot)
    }

    fn setup() -> (Cluster, Engine, Recorder) {
        let cluster = Cluster::new(4, 2, QueuePolicy::Fifo);
        // Exact delay backend: these unit tests inspect raw samples.
        (cluster, Engine::new(), Recorder::new_exact(3.0))
    }

    fn drain_events(c: &mut Cluster, e: &mut Engine, r: &mut Recorder) {
        while let Some((_, ev)) = e.pop() {
            if let Event::TaskFinish { server, task } = ev {
                c.on_task_finish(server, task, e, r);
            }
        }
    }

    #[test]
    fn new_cluster_layout() {
        let (c, _, _) = setup();
        assert_eq!(c.servers.len(), 6);
        assert_eq!(c.general.len(), 4);
        assert_eq!(c.short_reserved.len(), 2);
        assert_eq!(c.n_total(), 6);
        assert_eq!(c.long_load_ratio(), 0.0);
        c.check_invariants();
    }

    #[test]
    fn enqueue_starts_immediately_when_idle() {
        let (mut c, mut e, mut r) = setup();
        let t = c.add_task(JobId(0), 10.0, false, 0.0);
        c.enqueue(t, sref(0), &mut e, &mut r);
        assert_eq!(c.task(t).state, TaskState::Running);
        assert_eq!(c.server(sref(0)).running, Some(t));
        // TaskFinish scheduled at t=10
        assert_eq!(e.peek_time(), Some(10.0));
        assert_eq!(r.short_delays.len(), 1);
        assert_eq!(r.short_delays.samples().unwrap()[0], 0.0);
        c.check_invariants();
    }

    #[test]
    fn queueing_delay_measured_from_enqueue_to_start() {
        let (mut c, mut e, mut r) = setup();
        let t1 = c.add_task(JobId(0), 10.0, false, 0.0);
        let t2 = c.add_task(JobId(0), 5.0, false, 0.0);
        c.enqueue(t1, sref(0), &mut e, &mut r);
        c.enqueue(t2, sref(0), &mut e, &mut r);
        let (_, ev) = e.pop().unwrap(); // t1 finish at 10.0
        match ev {
            Event::TaskFinish { server, task } => {
                let out = c.on_task_finish(server, task, &mut e, &mut r);
                assert!(matches!(out, FinishOutcome::Finished { drained: false, .. }));
            }
            _ => panic!(),
        }
        assert_eq!(c.task(t2).state, TaskState::Running);
        assert!((c.task(t2).queueing_delay() - 10.0).abs() < 1e-12);
        c.check_invariants();
    }

    #[test]
    fn finished_slots_recycle_and_peak_tracks_active() {
        let (mut c, mut e, mut r) = setup();
        // Three sequential waves of one task each: the arena should
        // recycle a single slot, not grow per task.
        let mut refs = Vec::new();
        for wave in 0..3 {
            let t = c.add_task(JobId(wave), 5.0, false, 0.0);
            refs.push(t);
            c.enqueue(t, sref(0), &mut e, &mut r);
            drain_events(&mut c, &mut e, &mut r);
            c.check_invariants();
        }
        assert_eq!(r.tasks_finished, 3);
        assert_eq!(c.task_slots(), 1, "slots grew despite recycling");
        assert_eq!(c.peak_resident_tasks(), 1);
        assert_eq!(c.resident_tasks(), 0);
        // All handles are stale now; generations distinguish the waves.
        for t in refs {
            assert!(c.get_task(t).is_none(), "recycled slot still dereferences");
        }
    }

    #[test]
    fn recycling_off_keeps_arena_append_only() {
        let (mut c, mut e, mut r) = setup();
        c.set_task_recycling(false);
        for wave in 0..3 {
            let t = c.add_task(JobId(wave), 5.0, false, 0.0);
            c.enqueue(t, sref(0), &mut e, &mut r);
            drain_events(&mut c, &mut e, &mut r);
            c.check_invariants();
        }
        assert_eq!(c.task_slots(), 3);
        // Liveness accounting is mode-independent: same peak, same
        // post-run residency.
        assert_eq!(c.peak_resident_tasks(), 1);
        assert_eq!(c.resident_tasks(), 0);
    }

    #[test]
    fn long_load_ratio_tracks_long_tasks() {
        let (mut c, mut e, mut r) = setup();
        let t = c.add_task(JobId(0), 100.0, true, 0.0);
        c.enqueue(t, sref(1), &mut e, &mut r);
        assert_eq!(c.n_long_servers(), 1);
        assert!((c.long_load_ratio() - 1.0 / 6.0).abs() < 1e-12);
        // Second long task on the same server doesn't double count.
        let t2 = c.add_task(JobId(0), 100.0, true, 0.0);
        c.enqueue(t2, sref(1), &mut e, &mut r);
        assert_eq!(c.n_long_servers(), 1);
        // Finish both -> ratio back to 0.
        drain_events(&mut c, &mut e, &mut r);
        assert_eq!(c.n_long_servers(), 0);
        assert_eq!(c.long_load_ratio(), 0.0);
        c.check_invariants();
    }

    #[test]
    fn transient_lifecycle_changes_n_total() {
        let (mut c, mut e, mut r) = setup();
        let sid = c.request_transient(0.0);
        assert_eq!(c.n_total(), 6); // provisioning doesn't count
        assert_eq!(c.provisioning_count(), 1);
        c.transient_ready(sid, 120.0, &mut r);
        assert_eq!(c.n_total(), 7);
        assert_eq!(c.transient_pool.len(), 1);
        // Graceful drain of idle server retires immediately via caller.
        let idle = c.begin_drain(sid);
        assert!(idle);
        e.schedule(200.0, Event::Snapshot);
        e.pop();
        c.retire(sid, 200.0, &mut r);
        assert_eq!(c.n_total(), 6);
        assert!(c.transient_pool.is_empty());
        assert_eq!(r.cost.lifetimes.len(), 1);
        assert!((r.cost.lifetimes.samples().unwrap()[0] - 80.0).abs() < 1e-12);
        c.check_invariants();
    }

    #[test]
    fn duplicate_copies_first_start_wins() {
        let (mut c, mut e, mut r) = setup();
        // Occupy server 0 so the copy there queues.
        let blocker = c.add_task(JobId(0), 50.0, false, 0.0);
        c.enqueue(blocker, sref(0), &mut e, &mut r);
        let t = c.add_task(JobId(1), 10.0, false, 0.0);
        c.enqueue(t, sref(0), &mut e, &mut r); // queued copy
        c.enqueue(t, sref(1), &mut e, &mut r); // starts immediately
        assert_eq!(c.task(t).state, TaskState::Running);
        assert_eq!(c.task(t).ran_on, Some(sref(1)));
        assert_eq!(c.task(t).copies, 1); // stale copy still queued on 0
        // Run the world; the stale copy must be skipped, not re-run.
        drain_events(&mut c, &mut e, &mut r);
        assert_eq!(r.tasks_finished, 2);
        assert!(r.stale_copies_skipped >= 1);
        // Both slots released once the shadow copy settled.
        assert_eq!(c.resident_tasks(), 0);
        assert!(c.get_task(t).is_none());
        c.check_invariants();
    }

    #[test]
    fn stale_finish_event_cannot_resurrect_recycled_slot() {
        let (mut c, mut e, mut r) = setup();
        let sid = c.request_transient(0.0);
        c.transient_ready(sid, 0.0, &mut r);
        // A task running on the transient, with no shadow copy.
        let t = c.add_task(JobId(0), 30.0, false, 0.0);
        c.enqueue(t, sid, &mut e, &mut r);
        assert_eq!(c.task(t).state, TaskState::Running);
        // Revoke mid-run: the finish event at t=30 is now stale, and the
        // orphan is re-placed on an on-demand server.
        let orphans = c.revoke(sid, 10.0, &mut r);
        assert_eq!(orphans, vec![t]);
        assert_eq!(c.task(t).pending_finishes, 1, "stale finish must pin the slot");
        c.enqueue(t, sref(0), &mut e, &mut r);
        // Drain: the stale finish pops first (t=30), then the real one
        // (t=40). The task finishes exactly once, and only after the
        // stale event settles can the slot recycle.
        let mut finishes = 0;
        let mut stales = 0;
        while let Some((_, ev)) = e.pop() {
            if let Event::TaskFinish { server, task } = ev {
                match c.on_task_finish(server, task, &mut e, &mut r) {
                    FinishOutcome::Stale => stales += 1,
                    FinishOutcome::Finished { .. } => finishes += 1,
                }
            }
        }
        assert_eq!((stales, finishes), (1, 1));
        assert_eq!(r.tasks_finished, 1);
        assert!(c.get_task(t).is_none(), "slot still pinned after all refs settled");
        // A new task may now reuse the slot under a fresh generation.
        let t2 = c.add_task(JobId(1), 5.0, false, 50.0);
        assert_eq!(t2.slot, t.slot);
        assert_ne!(t2.gen, t.gen);
        assert!(c.get_task(t).is_none());
        assert!(c.get_task(t2).is_some());
        c.check_invariants();
    }

    #[test]
    fn revoke_returns_orphans_only() {
        let (mut c, mut e, mut r) = setup();
        let sid = c.request_transient(0.0);
        c.transient_ready(sid, 0.0, &mut r);
        // Task A: copy on transient + copy on on-demand (safe).
        let a = c.add_task(JobId(0), 30.0, false, 0.0);
        // Occupy both so copies stay queued.
        let b0 = c.add_task(JobId(0), 100.0, false, 0.0);
        let b1 = c.add_task(JobId(0), 100.0, false, 0.0);
        c.enqueue(b0, sref(4), &mut e, &mut r);
        c.enqueue(b1, sid, &mut e, &mut r);
        c.enqueue(a, sid, &mut e, &mut r);
        c.enqueue(a, sref(4), &mut e, &mut r);
        // Task C: only copy on the transient (unsafe).
        let cc = c.add_task(JobId(0), 30.0, false, 0.0);
        c.enqueue(cc, sid, &mut e, &mut r);
        let orphans = c.revoke(sid, 10.0, &mut r);
        // b1 was running on the transient -> orphaned; c queued only there
        // -> orphaned; a survives through its on-demand copy.
        assert!(orphans.contains(&cc));
        assert!(orphans.contains(&b1));
        assert!(!orphans.contains(&a));
        assert_eq!(r.transients_revoked, 1);
        c.check_invariants();
    }

    #[test]
    #[should_panic(expected = "non-accepting")]
    fn cannot_enqueue_on_draining() {
        let (mut c, mut e, mut r) = setup();
        let sid = c.request_transient(0.0);
        c.transient_ready(sid, 0.0, &mut r);
        // Make it non-idle so drain keeps it alive.
        let t0 = c.add_task(JobId(0), 50.0, false, 0.0);
        c.enqueue(t0, sid, &mut e, &mut r);
        c.begin_drain(sid);
        let t = c.add_task(JobId(0), 10.0, false, 0.0);
        c.enqueue(t, sid, &mut e, &mut r);
    }

    #[test]
    fn retired_server_slots_recycle_and_peak_tracks_active() {
        let (mut c, _, mut r) = setup();
        // Three sequential transient lifecycles: the arena should
        // recycle a single slot, not grow per request.
        let mut refs = Vec::new();
        for wave in 0..3 {
            let sid = c.request_transient(wave as f64 * 100.0);
            refs.push(sid);
            c.transient_ready(sid, wave as f64 * 100.0 + 10.0, &mut r);
            assert!(c.begin_drain(sid), "idle transient should drain instantly");
            c.retire(sid, wave as f64 * 100.0 + 20.0, &mut r);
            c.check_invariants();
        }
        assert_eq!(c.server_slots(), 7, "server slots grew despite recycling");
        assert_eq!(c.peak_resident_servers(), 7); // 6 on-demand + 1 transient
        assert_eq!(c.resident_servers(), 6);
        // All three incarnations shared one slot under distinct gens.
        assert_eq!(refs[0].slot, refs[1].slot);
        assert_eq!(refs[1].slot, refs[2].slot);
        assert_ne!(refs[0].gen, refs[1].gen);
        for sid in refs {
            assert!(c.get_server(sid).is_none(), "released server handle still dereferences");
        }
        // The transient index recycled its tree slot in lockstep.
        assert_eq!(c.pool_index().transient_tree_slots(), 1);
        assert_eq!(r.cost.lifetimes.len(), 3);
    }

    #[test]
    fn server_recycling_off_keeps_arena_append_only() {
        let (mut c, _, mut r) = setup();
        c.set_server_recycling(false);
        for wave in 0..3 {
            let sid = c.request_transient(wave as f64 * 100.0);
            c.transient_ready(sid, wave as f64 * 100.0 + 10.0, &mut r);
            assert!(c.begin_drain(sid));
            c.retire(sid, wave as f64 * 100.0 + 20.0, &mut r);
            c.check_invariants();
        }
        assert_eq!(c.server_slots(), 9); // 6 on-demand + 3 appended
        // Residency accounting is mode-independent: same peak, same
        // post-run residency as the recycling run.
        assert_eq!(c.peak_resident_servers(), 7);
        assert_eq!(c.resident_servers(), 6);
    }

    #[test]
    fn stale_server_handles_fail_generation_checks_after_reuse() {
        let (mut c, mut e, mut r) = setup();
        let first = c.request_transient(0.0);
        c.transient_ready(first, 0.0, &mut r);
        let orphans = c.revoke(first, 5.0, &mut r);
        assert!(orphans.is_empty());
        assert!(c.get_server(first).is_none(), "revoked server slot still live");
        // The slot's next tenant must be invisible through the old ref.
        let second = c.request_transient(10.0);
        assert_eq!(second.slot, first.slot);
        assert_ne!(second.gen, first.gen);
        c.transient_ready(second, 10.0, &mut r);
        assert!(c.get_server(first).is_none());
        assert_eq!(c.get_server(second).map(|s| s.state), Some(ServerState::Active));
        // A task placed on the new incarnation runs there; the old ref
        // never aliases it.
        let t = c.add_task(JobId(0), 5.0, false, 10.0);
        c.enqueue(t, second, &mut e, &mut r);
        assert_eq!(c.task(t).ran_on, Some(second));
        assert_ne!(c.task(t).ran_on, Some(first));
        c.check_invariants();
    }

    #[test]
    fn drain_victim_tiebreak_follows_activation_order_across_reuse() {
        let (mut c, _, mut r) = setup();
        // a, b active; retire a (frees the lower arena slot), then c
        // reuses it. All idle: the victim must be b (earlier activation),
        // not c, even though c occupies the lower slot.
        let a = c.request_transient(0.0);
        c.transient_ready(a, 0.0, &mut r);
        let b = c.request_transient(0.0);
        c.transient_ready(b, 1.0, &mut r);
        assert!(c.begin_drain(a));
        c.retire(a, 2.0, &mut r);
        let cc = c.request_transient(3.0);
        assert_eq!(cc.slot, a.slot);
        c.transient_ready(cc, 4.0, &mut r);
        assert_eq!(c.transient_drain_victim(), Some(b));
        c.check_invariants();
    }

    #[test]
    fn dense_accessors_match_struct_reads_in_both_modes() {
        let (mut c, mut e, mut r) = setup();
        let sid = c.request_transient(0.0);
        c.transient_ready(sid, 1.0, &mut r);
        let blocker = c.add_task(JobId(0), 50.0, false, 0.0);
        c.enqueue(blocker, sref(0), &mut e, &mut r);
        let t = c.add_task(JobId(0), 10.0, false, 0.0);
        c.enqueue(t, sref(0), &mut e, &mut r); // queued behind blocker
        let tl = c.add_task(JobId(0), 99.0, true, 0.0);
        c.enqueue(tl, sref(1), &mut e, &mut r);
        for soa in [true, false] {
            c.set_soa_hot_fields(soa);
            for s in [sref(0), sref(1), sref(4), sid] {
                assert_eq!(c.est_work_of(s).to_bits(), c.server(s).est_work.to_bits());
                assert_eq!(c.is_accepting(s), c.server(s).accepting());
                assert_eq!(c.has_long(s), c.server(s).long_tasks > 0);
                assert_eq!(c.has_queued(s), !c.server(s).queue.is_empty());
                assert_eq!(c.is_transient(s), c.server(s).kind == ServerKind::Transient);
            }
        }
        assert!(c.has_queued(sref(0)));
        assert!(!c.has_queued(sref(1))); // running, nothing queued
        assert!(c.has_long(sref(1)));
        assert!(c.is_transient(sid));
        assert!(!c.is_transient(sref(0)));
        c.check_invariants();
    }

    #[test]
    fn queue_buffers_recycle_through_the_pool() {
        let (mut c, mut e, mut r) = setup();
        // Three sequential transient lifecycles with queued work: the
        // first tenant's buffer misses the pool, the next two hit.
        for wave in 0..3 {
            let now = wave as f64 * 100.0;
            let sid = c.request_transient(now);
            c.transient_ready(sid, now + 1.0, &mut r);
            let t = c.add_task(JobId(wave), 5.0, false, now + 1.0);
            c.enqueue(t, sid, &mut e, &mut r);
            drain_events(&mut c, &mut e, &mut r);
            assert!(c.begin_drain(sid), "drained transient should be idle");
            c.retire(sid, now + 50.0, &mut r);
            c.check_invariants();
        }
        let ps = c.pool_stats();
        assert_eq!(ps.queue_buf_misses, 1, "only the first tenant allocates");
        assert_eq!(ps.queue_buf_hits, 2, "later tenants reuse the pooled buffer");
        assert_eq!(ps.server_slot_hits, 2);
        assert_eq!(ps.server_slot_misses, 1);
    }

    #[test]
    fn revoke_into_matches_revoke_and_reuses_scratch() {
        // Two identical clusters; one revokes through the allocating
        // wrapper, the other through the pooled-scratch entry point.
        let build = |c: &mut Cluster, e: &mut Engine, r: &mut Recorder| {
            let sid = c.request_transient(0.0);
            c.transient_ready(sid, 0.0, r);
            let b = c.add_task(JobId(0), 100.0, false, 0.0);
            c.enqueue(b, sid, e, r);
            let only = c.add_task(JobId(0), 30.0, false, 0.0);
            c.enqueue(only, sid, e, r);
            (sid, only, b)
        };
        let (mut c1, mut e1, mut r1) = setup();
        let (sid1, only1, b1) = build(&mut c1, &mut e1, &mut r1);
        let via_wrapper = c1.revoke(sid1, 10.0, &mut r1);
        let (mut c2, mut e2, mut r2) = setup();
        let (sid2, _, _) = build(&mut c2, &mut e2, &mut r2);
        let mut scratch = vec![TaskRef { slot: 999, gen: 7 }]; // stale junk: must be cleared
        c2.revoke_into(sid2, 10.0, &mut r2, &mut scratch);
        assert_eq!(via_wrapper.len(), scratch.len());
        assert!(via_wrapper.contains(&only1));
        assert!(via_wrapper.contains(&b1));
        c1.check_invariants();
        c2.check_invariants();
    }
}
