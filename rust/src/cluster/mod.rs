//! Cluster substrate: servers (on-demand + transient), per-server queues
//! with Eagle-style SRPT discipline, partitions, and the two
//! **generational slot arenas** that make resident memory load-bound:
//! the task arena (a finished slot recycles once its queue copies and
//! pending finish events settle) and the server arena (a retired
//! transient's slot recycles immediately; stale lifecycle events fail
//! the generation check). Plus the incrementally-maintained
//! long-load-ratio state and the per-pool argmin indexes.
//!
//! **SoA layout invariant:** the per-server hot fields the placement
//! and argmin paths read every event (`est_work`, queue depth, the
//! accepting/long/transient state bits, `ready_seq`) are mirrored into
//! dense parallel arrays ([`HotFields`]) indexed by **arena slot**.
//! The generation discipline is unchanged — handles still validate
//! against the slot's live generation, and slot reuse overwrites the
//! arrays in lockstep with the struct — and the mirror is maintained
//! unconditionally, so toggling the SoA read path
//! ([`Cluster::set_soa_hot_fields`]) cannot change any simulation
//! observable. Steady-state mutators allocate nothing: revocation
//! fills a caller-passed scratch ([`Cluster::revoke_into`]), pruning
//! and stealing run on pooled buffers, and retired transients' queue
//! buffers recycle through a free pool ([`PoolStats`] counts the
//! hits/misses).

#[allow(clippy::module_inception)]
mod cluster;
mod index;
mod server;
mod task;

pub use cluster::{Cluster, FinishOutcome, HotFields, PoolStats};
pub use index::{PoolIndex, TransientKey};
pub use server::{Pool, QueuePolicy, Server, ServerKind, ServerState};
pub use task::{Task, TaskState};
