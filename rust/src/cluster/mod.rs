//! Cluster substrate: servers (on-demand + transient), per-server queues
//! with Eagle-style SRPT discipline, partitions, the **generational task
//! arena** (finished slots recycle once their queue copies and pending
//! finish events settle, so memory is O(active tasks)), and the
//! incrementally-maintained long-load-ratio state.

#[allow(clippy::module_inception)]
mod cluster;
mod index;
mod server;
mod task;

pub use cluster::{Cluster, FinishOutcome};
pub use index::{PoolIndex, TransientKey};
pub use server::{Pool, QueuePolicy, Server, ServerKind, ServerState};
pub use task::{Task, TaskState};
