//! Cluster substrate: servers (on-demand + transient), per-server queues
//! with Eagle-style SRPT discipline, partitions, and the two
//! **generational slot arenas** that make resident memory load-bound:
//! the task arena (a finished slot recycles once its queue copies and
//! pending finish events settle) and the server arena (a retired
//! transient's slot recycles immediately; stale lifecycle events fail
//! the generation check). Plus the incrementally-maintained
//! long-load-ratio state and the per-pool argmin indexes.

#[allow(clippy::module_inception)]
mod cluster;
mod index;
mod server;
mod task;

pub use cluster::{Cluster, FinishOutcome};
pub use index::{PoolIndex, TransientKey};
pub use server::{Pool, QueuePolicy, Server, ServerKind, ServerState};
pub use task::{Task, TaskState};
