//! Per-pool least-loaded indexes.
//!
//! One [`MinTree`]-backed argmin index per partition (§3.1's three pools:
//! general, on-demand short-only, transient), kept incrementally up to
//! date by the cluster's mutators. Every least-loaded query the
//! schedulers and the transient manager used to answer with an O(n)
//! scan is O(log n) here, with tie-breaking identical to the scans they
//! replace (`Iterator::min_by` first-minimal == lowest slot index):
//!
//! * **general** — keyed by `est_work`; slot = position in
//!   `Cluster::general` (== the server slot for the id-compact prefix).
//!   Serves the centralized long-task placement and the degenerate
//!   probe fallbacks.
//! * **short-reserved** — keyed by `est_work`; slot = position in
//!   `Cluster::short_reserved`. Serves the §3.3 on-demand duplication
//!   target and revocation-orphan replacement.
//! * **transient** — keyed by lexicographic
//!   `(depth, est_work, ready_seq)`; `ready_seq` is the server's global
//!   activation number, unique per activation, so exact ties are
//!   impossible and the argmin reproduces the manager's first-minimal
//!   "ready order" scan regardless of how tree slots are laid out.
//!   That independence is what lets tree slots be **recycled** through
//!   a free list when a transient drains or retires: the index is
//!   bounded by peak *concurrent* Active transients, not by transients
//!   ever requested — the index-side half of the server-arena
//!   recycling story (see `cluster.rs`).

use crate::util::{IndexKey, MinTree, ServerRef};

const NO_SLOT: u32 = u32::MAX;

/// Transient-tree key: `(queue depth, est_work, ready_seq)` —
/// "fastest to free", activation order on exact load ties.
pub type TransientKey = (u32, f64, u64);

/// The cluster's three per-pool argmin indexes.
#[derive(Clone, Debug)]
pub struct PoolIndex {
    n_general: usize,
    n_short: usize,
    general: MinTree<f64>,
    short: MinTree<f64>,
    transient: MinTree<TransientKey>,
    /// First transient server slot (= n_general + n_short at construction).
    t_base: usize,
    /// `server.slot - t_base` -> slot in the transient tree. Entries
    /// are per server-arena slot, so this stays bounded by the server
    /// arena (which recycles), not by transients ever requested.
    t_slot: Vec<u32>,
    /// Tree slot -> server handle of the current occupant.
    t_server: Vec<ServerRef>,
    /// Recycled tree slots awaiting reuse (LIFO).
    t_free: Vec<u32>,
    /// Occupied (non-tombstoned) transient slots.
    t_len: usize,
}

impl PoolIndex {
    pub fn new(n_general: usize, n_short: usize) -> Self {
        PoolIndex {
            n_general,
            n_short,
            // Live slots start at ZERO (an idle server has est_work 0);
            // `.max(1)` keeps the tree non-empty for degenerate configs
            // (queries are gated on the real pool size below).
            general: MinTree::new(n_general.max(1)),
            short: MinTree::new(n_short.max(1)),
            transient: tombstoned_tree(8),
            t_base: n_general + n_short,
            t_slot: Vec::new(),
            t_server: Vec::new(),
            t_free: Vec::new(),
            t_len: 0,
        }
    }

    // ------------------------------------------------------------ general

    // lint: hot-path
    #[inline]
    pub fn update_general(&mut self, slot: usize, est_work: f64) {
        debug_assert!(slot < self.n_general);
        self.general.update(slot, est_work);
    }

    /// Slot (== position in `Cluster::general`) of the least-loaded
    /// general server. `None` only for an empty general partition.
    // lint: hot-path
    #[inline]
    pub fn least_loaded_general_slot(&self) -> Option<usize> {
        (self.n_general > 0).then(|| self.general.argmin())
    }

    #[inline]
    pub fn general_key(&self, slot: usize) -> f64 {
        self.general.key(slot)
    }

    // ------------------------------------------------------ short-reserved

    // lint: hot-path
    #[inline]
    pub fn update_short(&mut self, slot: usize, est_work: f64) {
        debug_assert!(slot < self.n_short);
        self.short.update(slot, est_work);
    }

    /// Slot (== position in `Cluster::short_reserved`) of the
    /// least-loaded on-demand short server.
    // lint: hot-path
    #[inline]
    pub fn least_loaded_short_slot(&self) -> Option<usize> {
        (self.n_short > 0).then(|| self.short.argmin())
    }

    #[inline]
    pub fn short_key(&self, slot: usize) -> f64 {
        self.short.key(slot)
    }

    // ----------------------------------------------------------- transient

    /// Register a transient server that just became Active, reusing a
    /// recycled tree slot when one is free.
    // lint: hot-path
    pub fn insert_transient(&mut self, sid: ServerRef, key: TransientKey) {
        let rel = sid.index() - self.t_base;
        if rel >= self.t_slot.len() {
            self.t_slot.resize(rel + 1, NO_SLOT);
        }
        debug_assert_eq!(self.t_slot[rel], NO_SLOT, "double insert of {sid:?}");
        let slot = match self.t_free.pop() {
            Some(slot) => {
                self.t_server[slot as usize] = sid;
                slot as usize
            }
            None => {
                let slot = self.t_server.len();
                if slot == self.transient.len() {
                    self.grow_transient();
                }
                self.t_server.push(sid);
                slot
            }
        };
        self.t_slot[rel] = slot as u32;
        self.transient.update(slot, key);
        self.t_len += 1;
    }

    /// Drop a transient server from the index (drain begun, retired or
    /// revoked), releasing its tree slot for reuse. Idempotent (the
    /// drain and retire paths may both call it), and generation-guarded
    /// like the read paths: a stale handle whose arena slot has been
    /// recycled must not tombstone — or double-free the tree slot of —
    /// the slot's new tenant.
    // lint: hot-path
    pub fn remove_transient(&mut self, sid: ServerRef) {
        let Some(rel) = sid.index().checked_sub(self.t_base) else { return };
        let Some(&slot) = self.t_slot.get(rel) else { return };
        if slot == NO_SLOT || self.t_server[slot as usize] != sid {
            return;
        }
        self.t_slot[rel] = NO_SLOT;
        self.transient.update(slot as usize, TransientKey::MAX_KEY);
        self.t_free.push(slot);
        self.t_len -= 1;
    }

    /// Refresh a transient server's key; no-op if it is not indexed
    /// (provisioning, draining or retired). Generation-guarded: a stale
    /// handle must not re-key the slot's new tenant.
    // lint: hot-path
    #[inline]
    pub fn update_transient(&mut self, sid: ServerRef, key: TransientKey) {
        let Some(rel) = sid.index().checked_sub(self.t_base) else { return };
        if let Some(&slot) = self.t_slot.get(rel) {
            if slot != NO_SLOT && self.t_server[slot as usize] == sid {
                self.transient.update(slot as usize, key);
            }
        }
    }

    /// Is this transient server currently indexed?
    #[inline]
    pub fn contains_transient(&self, sid: ServerRef) -> bool {
        sid.index()
            .checked_sub(self.t_base)
            .and_then(|rel| self.t_slot.get(rel))
            .is_some_and(|&slot| slot != NO_SLOT && self.t_server[slot as usize] == sid)
    }

    /// Number of indexed (Active) transient servers.
    #[inline]
    pub fn transient_len(&self) -> usize {
        self.t_len
    }

    /// Tree slots ever allocated — bounded by peak concurrent Active
    /// transients (tree slots recycle), the index-memory headline.
    #[inline]
    pub fn transient_tree_slots(&self) -> usize {
        self.t_server.len()
    }

    /// The Active transient server minimizing
    /// `(depth, est_work, ready_seq)` — the manager's drain victim
    /// ("fastest to free"), earliest-activated on load ties, exactly
    /// like the scan it replaced.
    // lint: hot-path
    #[inline]
    pub fn transient_argmin(&self) -> Option<ServerRef> {
        (self.t_len > 0).then(|| self.t_server[self.transient.argmin()])
    }

    #[inline]
    pub fn transient_key(&self, sid: ServerRef) -> Option<TransientKey> {
        let rel = sid.index().checked_sub(self.t_base)?;
        let &slot = self.t_slot.get(rel)?;
        (slot != NO_SLOT && self.t_server[slot as usize] == sid)
            .then(|| self.transient.key(slot as usize))
    }

    /// Double the transient tree, carrying over live keys and tombstones
    /// (slot positions are preserved; with seq-tagged keys the argmin
    /// never depends on slot order anyway).
    fn grow_transient(&mut self) {
        let old_cap = self.transient.len();
        let mut bigger = tombstoned_tree(old_cap * 2);
        for slot in 0..old_cap {
            bigger.update(slot, self.transient.key(slot));
        }
        self.transient = bigger;
    }
}

/// A tree whose every slot starts as a tombstone (MAX_KEY).
fn tombstoned_tree(cap: usize) -> MinTree<TransientKey> {
    let mut t = MinTree::new(cap.max(1));
    for i in 0..t.len() {
        t.update(i, TransientKey::MAX_KEY);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sid(i: usize) -> ServerRef {
        ServerRef::initial(i as u32)
    }

    /// Key helper: idle server activated as the `seq`-th transient.
    fn idle(seq: u64) -> TransientKey {
        (0, 0.0, seq)
    }

    #[test]
    fn general_and_short_argmin() {
        let mut idx = PoolIndex::new(4, 2);
        assert_eq!(idx.least_loaded_general_slot(), Some(0)); // all zero -> first
        idx.update_general(0, 10.0);
        idx.update_general(1, 3.0);
        idx.update_general(2, 7.0);
        assert_eq!(idx.least_loaded_general_slot(), Some(3)); // still 0.0
        idx.update_general(3, 4.0);
        assert_eq!(idx.least_loaded_general_slot(), Some(1));
        idx.update_short(0, 5.0);
        assert_eq!(idx.least_loaded_short_slot(), Some(1));
        idx.update_short(1, 2.0);
        assert_eq!(idx.least_loaded_short_slot(), Some(1));
    }

    #[test]
    fn empty_pools_answer_none() {
        let idx = PoolIndex::new(2, 0);
        assert_eq!(idx.least_loaded_short_slot(), None);
        assert_eq!(idx.transient_argmin(), None);
        let idx2 = PoolIndex::new(0, 0);
        assert_eq!(idx2.least_loaded_general_slot(), None);
    }

    #[test]
    fn transient_lifecycle_and_tiebreak() {
        let mut idx = PoolIndex::new(3, 1); // transients start at slot 4
        idx.insert_transient(sid(4), idle(0));
        idx.insert_transient(sid(5), idle(1));
        idx.insert_transient(sid(6), idle(2));
        // Load tie -> earliest activation (lowest seq).
        assert_eq!(idx.transient_argmin(), Some(sid(4)));
        idx.update_transient(sid(4), (2, 40.0, 0));
        idx.update_transient(sid(5), (1, 99.0, 1));
        idx.update_transient(sid(6), (1, 98.0, 2));
        // depth dominates est_work; 6 beats 5 on est_work.
        assert_eq!(idx.transient_argmin(), Some(sid(6)));
        idx.remove_transient(sid(6));
        assert_eq!(idx.transient_argmin(), Some(sid(5)));
        assert_eq!(idx.transient_len(), 2);
        // Removal is idempotent; keys of removed servers are gone.
        idx.remove_transient(sid(6));
        assert_eq!(idx.transient_len(), 2);
        assert_eq!(idx.transient_key(sid(6)), None);
        assert!(!idx.contains_transient(sid(6)));
        assert!(idx.contains_transient(sid(5)));
        // Updates to removed servers are no-ops.
        idx.update_transient(sid(6), idle(9));
        assert_eq!(idx.transient_argmin(), Some(sid(5)));
    }

    #[test]
    fn tree_slots_recycle_and_stay_bounded() {
        let mut idx = PoolIndex::new(1, 1); // transients start at slot 2
        // Sequential lifecycle: never more than one Active at a time,
        // so the tree must stay at one allocated slot.
        for i in 0..40u64 {
            let s = sid(2); // server arena would also recycle slot 2
            idx.insert_transient(s, idle(i));
            assert_eq!(idx.transient_argmin(), Some(s));
            idx.remove_transient(s);
            assert_eq!(idx.transient_len(), 0);
        }
        assert_eq!(idx.transient_tree_slots(), 1, "tree slots grew past peak-active");
    }

    #[test]
    fn seq_ties_are_slot_order_independent() {
        // Deliberately interleave removals so reused tree slots hold
        // later activations: the argmin must still follow seq.
        let mut idx = PoolIndex::new(1, 1);
        idx.insert_transient(sid(2), idle(0));
        idx.insert_transient(sid(3), idle(1));
        idx.remove_transient(sid(2)); // frees tree slot 0
        idx.insert_transient(sid(4), idle(2)); // lands in tree slot 0
        // All idle: seq 1 (server 3) precedes seq 2 (server 4) even
        // though server 4 occupies the lower tree slot.
        assert_eq!(idx.transient_argmin(), Some(sid(3)));
        idx.remove_transient(sid(3));
        assert_eq!(idx.transient_argmin(), Some(sid(4)));
        assert_eq!(idx.transient_tree_slots(), 2);
    }

    #[test]
    fn stale_handles_cannot_mutate_a_recycled_slots_new_tenant() {
        // Arena slot 2 recycles: the old-generation handle must be a
        // no-op on BOTH mutating paths, not just the reads.
        let mut idx = PoolIndex::new(1, 1);
        let old = ServerRef { slot: 2, gen: 0 };
        idx.insert_transient(old, idle(0));
        idx.remove_transient(old);
        let new = ServerRef { slot: 2, gen: 1 };
        idx.insert_transient(new, idle(1));
        // Stale remove: the new tenant stays indexed, no double-free.
        idx.remove_transient(old);
        assert_eq!(idx.transient_len(), 1);
        assert!(idx.contains_transient(new));
        assert_eq!(idx.transient_argmin(), Some(new));
        // Stale update: the new tenant's key is untouched.
        idx.update_transient(old, (9, 9.0, 9));
        assert_eq!(idx.transient_key(new), Some(idle(1)));
        assert_eq!(idx.transient_key(old), None);
        // Live mutations still work.
        idx.update_transient(new, (1, 2.0, 1));
        assert_eq!(idx.transient_key(new), Some((1, 2.0, 1)));
        idx.remove_transient(new);
        assert_eq!(idx.transient_len(), 0);
    }
}
