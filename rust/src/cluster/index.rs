//! Per-pool least-loaded indexes.
//!
//! One [`MinTree`]-backed argmin index per partition (§3.1's three pools:
//! general, on-demand short-only, transient), kept incrementally up to
//! date by the cluster's mutators. Every least-loaded query the
//! schedulers and the transient manager used to answer with an O(n)
//! scan is O(log n) here, with tie-breaking identical to the scans they
//! replace (`Iterator::min_by` first-minimal == lowest slot index):
//!
//! * **general** — keyed by `est_work`; slot = position in
//!   `Cluster::general` (== the server id for the id-compact prefix).
//!   Serves the centralized long-task placement and the degenerate
//!   probe fallbacks.
//! * **short-reserved** — keyed by `est_work`; slot = position in
//!   `Cluster::short_reserved`. Serves the §3.3 on-demand duplication
//!   target and revocation-orphan replacement.
//! * **transient** — keyed by lexicographic `(depth, est_work)`; slots
//!   are assigned append-only in `TransientReady` order and tombstoned
//!   on drain/retire (never reused), so the argmin's lowest-slot
//!   tie-break reproduces the manager's first-minimal scan over
//!   `transient_pool` exactly. Serves the drain-victim query.

use crate::util::{IndexKey, MinTree, ServerId};

const NO_SLOT: u32 = u32::MAX;

/// Transient-tree key: `(queue depth, est_work)` — "fastest to free".
pub type TransientKey = (u32, f64);

/// The cluster's three per-pool argmin indexes.
#[derive(Clone, Debug)]
pub struct PoolIndex {
    n_general: usize,
    n_short: usize,
    general: MinTree<f64>,
    short: MinTree<f64>,
    transient: MinTree<TransientKey>,
    /// First transient server id (= n_general + n_short at construction).
    t_base: usize,
    /// `server_id.index() - t_base` -> slot in the transient tree.
    t_slot: Vec<u32>,
    /// slot -> server id (grows append-only with inserts).
    t_server: Vec<ServerId>,
    /// Occupied (non-tombstoned) transient slots.
    t_len: usize,
}

impl PoolIndex {
    pub fn new(n_general: usize, n_short: usize) -> Self {
        PoolIndex {
            n_general,
            n_short,
            // Live slots start at ZERO (an idle server has est_work 0);
            // `.max(1)` keeps the tree non-empty for degenerate configs
            // (queries are gated on the real pool size below).
            general: MinTree::new(n_general.max(1)),
            short: MinTree::new(n_short.max(1)),
            transient: tombstoned_tree(8),
            t_base: n_general + n_short,
            t_slot: Vec::new(),
            t_server: Vec::new(),
            t_len: 0,
        }
    }

    // ------------------------------------------------------------ general

    #[inline]
    pub fn update_general(&mut self, slot: usize, est_work: f64) {
        debug_assert!(slot < self.n_general);
        self.general.update(slot, est_work);
    }

    /// Slot (== position in `Cluster::general`) of the least-loaded
    /// general server. `None` only for an empty general partition.
    #[inline]
    pub fn least_loaded_general_slot(&self) -> Option<usize> {
        (self.n_general > 0).then(|| self.general.argmin())
    }

    #[inline]
    pub fn general_key(&self, slot: usize) -> f64 {
        self.general.key(slot)
    }

    // ------------------------------------------------------ short-reserved

    #[inline]
    pub fn update_short(&mut self, slot: usize, est_work: f64) {
        debug_assert!(slot < self.n_short);
        self.short.update(slot, est_work);
    }

    /// Slot (== position in `Cluster::short_reserved`) of the
    /// least-loaded on-demand short server.
    #[inline]
    pub fn least_loaded_short_slot(&self) -> Option<usize> {
        (self.n_short > 0).then(|| self.short.argmin())
    }

    #[inline]
    pub fn short_key(&self, slot: usize) -> f64 {
        self.short.key(slot)
    }

    // ----------------------------------------------------------- transient

    /// Register a transient server that just became Active.
    pub fn insert_transient(&mut self, sid: ServerId, key: TransientKey) {
        let rel = sid.index() - self.t_base;
        if rel >= self.t_slot.len() {
            self.t_slot.resize(rel + 1, NO_SLOT);
        }
        debug_assert_eq!(self.t_slot[rel], NO_SLOT, "double insert of {sid:?}");
        let slot = self.t_server.len();
        if slot == self.transient.len() {
            self.grow_transient();
        }
        self.t_slot[rel] = slot as u32;
        self.t_server.push(sid);
        self.transient.update(slot, key);
        self.t_len += 1;
    }

    /// Drop a transient server from the index (drain begun, retired or
    /// revoked). Idempotent: the drain and retire paths may both call it.
    pub fn remove_transient(&mut self, sid: ServerId) {
        let Some(rel) = sid.index().checked_sub(self.t_base) else { return };
        let Some(&slot) = self.t_slot.get(rel) else { return };
        if slot == NO_SLOT {
            return;
        }
        self.t_slot[rel] = NO_SLOT;
        self.transient.update(slot as usize, TransientKey::MAX_KEY);
        self.t_len -= 1;
    }

    /// Refresh a transient server's key; no-op if it is not indexed
    /// (provisioning, draining or retired).
    #[inline]
    pub fn update_transient(&mut self, sid: ServerId, key: TransientKey) {
        let Some(rel) = sid.index().checked_sub(self.t_base) else { return };
        if let Some(&slot) = self.t_slot.get(rel) {
            if slot != NO_SLOT {
                self.transient.update(slot as usize, key);
            }
        }
    }

    /// Is this transient server currently indexed?
    #[inline]
    pub fn contains_transient(&self, sid: ServerId) -> bool {
        sid.index()
            .checked_sub(self.t_base)
            .and_then(|rel| self.t_slot.get(rel))
            .is_some_and(|&slot| slot != NO_SLOT)
    }

    /// Number of indexed (Active) transient servers.
    #[inline]
    pub fn transient_len(&self) -> usize {
        self.t_len
    }

    /// The Active transient server minimizing `(depth, est_work)` — the
    /// manager's drain victim ("fastest to free"). First-minimal in
    /// `TransientReady` order on exact ties, like the scan it replaces.
    #[inline]
    pub fn transient_argmin(&self) -> Option<ServerId> {
        (self.t_len > 0).then(|| self.t_server[self.transient.argmin()])
    }

    #[inline]
    pub fn transient_key(&self, sid: ServerId) -> Option<TransientKey> {
        let rel = sid.index().checked_sub(self.t_base)?;
        let &slot = self.t_slot.get(rel)?;
        (slot != NO_SLOT).then(|| self.transient.key(slot as usize))
    }

    /// Double the transient tree, carrying over live keys and tombstones
    /// (slot order — and therefore tie-breaking — is preserved).
    fn grow_transient(&mut self) {
        let old_cap = self.transient.len();
        let mut bigger = tombstoned_tree(old_cap * 2);
        for slot in 0..old_cap {
            bigger.update(slot, self.transient.key(slot));
        }
        self.transient = bigger;
    }
}

/// A tree whose every slot starts as a tombstone (MAX_KEY).
fn tombstoned_tree(cap: usize) -> MinTree<TransientKey> {
    let mut t = MinTree::new(cap.max(1));
    for i in 0..t.len() {
        t.update(i, TransientKey::MAX_KEY);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sid(i: usize) -> ServerId {
        ServerId(i as u32)
    }

    #[test]
    fn general_and_short_argmin() {
        let mut idx = PoolIndex::new(4, 2);
        assert_eq!(idx.least_loaded_general_slot(), Some(0)); // all zero -> first
        idx.update_general(0, 10.0);
        idx.update_general(1, 3.0);
        idx.update_general(2, 7.0);
        assert_eq!(idx.least_loaded_general_slot(), Some(3)); // still 0.0
        idx.update_general(3, 4.0);
        assert_eq!(idx.least_loaded_general_slot(), Some(1));
        idx.update_short(0, 5.0);
        assert_eq!(idx.least_loaded_short_slot(), Some(1));
        idx.update_short(1, 2.0);
        assert_eq!(idx.least_loaded_short_slot(), Some(1));
    }

    #[test]
    fn empty_pools_answer_none() {
        let idx = PoolIndex::new(2, 0);
        assert_eq!(idx.least_loaded_short_slot(), None);
        assert_eq!(idx.transient_argmin(), None);
        let idx2 = PoolIndex::new(0, 0);
        assert_eq!(idx2.least_loaded_general_slot(), None);
    }

    #[test]
    fn transient_lifecycle_and_tiebreak() {
        let mut idx = PoolIndex::new(3, 1); // transients start at id 4
        idx.insert_transient(sid(4), (0, 0.0));
        idx.insert_transient(sid(5), (0, 0.0));
        idx.insert_transient(sid(6), (0, 0.0));
        // Exact tie -> first in ready order.
        assert_eq!(idx.transient_argmin(), Some(sid(4)));
        idx.update_transient(sid(4), (2, 40.0));
        idx.update_transient(sid(5), (1, 99.0));
        idx.update_transient(sid(6), (1, 98.0));
        // depth dominates est_work; 6 beats 5 on est_work.
        assert_eq!(idx.transient_argmin(), Some(sid(6)));
        idx.remove_transient(sid(6));
        assert_eq!(idx.transient_argmin(), Some(sid(5)));
        assert_eq!(idx.transient_len(), 2);
        // Removal is idempotent; keys of removed servers are gone.
        idx.remove_transient(sid(6));
        assert_eq!(idx.transient_len(), 2);
        assert_eq!(idx.transient_key(sid(6)), None);
        assert!(!idx.contains_transient(sid(6)));
        assert!(idx.contains_transient(sid(5)));
        // Updates to removed servers are no-ops.
        idx.update_transient(sid(6), (0, 0.0));
        assert_eq!(idx.transient_argmin(), Some(sid(5)));
    }

    #[test]
    fn transient_slots_are_never_reused() {
        let mut idx = PoolIndex::new(1, 1); // transients start at id 2
        for i in 0..40 {
            idx.insert_transient(sid(2 + i), (0, i as f64));
            if i % 2 == 0 {
                idx.remove_transient(sid(2 + i));
            }
        }
        assert_eq!(idx.transient_len(), 20);
        // Lowest surviving (depth, est_work) is id 3 (est 1.0).
        assert_eq!(idx.transient_argmin(), Some(sid(3)));
        // Growth preserved every live key.
        for i in 0..40 {
            let key = idx.transient_key(sid(2 + i));
            if i % 2 == 0 {
                assert_eq!(key, None);
            } else {
                assert_eq!(key, Some((0, i as f64)));
            }
        }
    }
}
