//! Task lifecycle: the unit of scheduled work.
//!
//! Tasks live in a **generational slot arena** owned by
//! [`crate::cluster::Cluster`] and are referenced by [`TaskRef`]
//! (slot + generation) everywhere — no per-event allocation on the hot
//! path, and resident memory is O(active tasks), not O(trace).
//!
//! ## Liveness and recycling
//!
//! A slot is recycled only when the task's *liveness count* drops to
//! zero. Liveness has two components, both tracked on the task itself:
//!
//! * [`Task::copies`] — outstanding queue entries across all servers
//!   (mirrored exactly by [`Task::placed_on`]);
//! * [`Task::pending_finishes`] — `TaskFinish` events scheduled but not
//!   yet popped. A transient revocation can kill an execution *after*
//!   its finish event entered the queue; that stale event must keep the
//!   slot pinned until it pops, or it would dereference a recycled slot.
//!
//! A task therefore frees exactly when `state == Finished`,
//! `copies == 0` and `pending_finishes == 0` — which is how a §3.3
//! shadow copy that outlives its finished twin, or a stale finish event
//! from a revoked run, resolves to "stale, skip" instead of resurrecting
//! whatever task reuses the slot. On free the slot's generation is
//! bumped, so any handle that escaped the refcount (a bug) fails the
//! generation check loudly rather than aliasing.
//!
//! ## Copies (§3.3)
//!
//! A short task may be enqueued on *multiple* servers at once:
//! CloudCoaster guarantees at least one copy of every short task lives
//! on an on-demand server so transient revocation can never lose work
//! (paper §3.3). The first copy a server dequeues wins; stale copies are
//! skipped (and their liveness refs settled) at dequeue.

use crate::util::{JobId, ServerRef, TaskRef, Time};

/// Where a task is in its lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    /// Created and placed on one or more server queues.
    Queued,
    /// Executing on exactly one server.
    Running,
    /// Completed. The slot frees once all liveness refs settle.
    Finished,
}

/// A schedulable task (one arena slot's payload).
#[derive(Clone, Debug)]
pub struct Task {
    /// The slot's current identity: `id.slot` is this slot's index,
    /// `id.gen` its live generation. A [`TaskRef`] is valid iff it
    /// equals `id`.
    pub id: TaskRef,
    pub job: JobId,
    pub duration: f64,
    pub is_long: bool,
    pub state: TaskState,
    /// When the task was enqueued (== job arrival; placement is immediate).
    pub enqueued_at: Time,
    /// When the task started executing (valid once `state >= Running`).
    pub started_at: Time,
    /// Server executing / having executed the task.
    pub ran_on: Option<ServerRef>,
    /// Outstanding queue entries across all servers (copies, §3.3).
    pub copies: u8,
    /// `TaskFinish` events scheduled for this task and not yet popped.
    /// Each pins the slot: a revoked execution's finish event stays in
    /// the queue after the task restarts elsewhere.
    pub pending_finishes: u16,
    /// Where the outstanding queue entries live (at most two: the primary
    /// placement plus the §3.3 on-demand shadow copy). Kept exact so a
    /// task's start can immediately discount its other copy from that
    /// server's load estimate.
    pub placed_on: [Option<ServerRef>; 2],
}

impl Task {
    pub fn new(id: TaskRef, job: JobId, duration: f64, is_long: bool, now: Time) -> Self {
        Task {
            id,
            job,
            duration,
            is_long,
            state: TaskState::Queued,
            enqueued_at: now,
            started_at: 0.0,
            ran_on: None,
            copies: 0,
            pending_finishes: 0,
            placed_on: [None, None],
        }
    }

    /// Record a queue-entry location. Panics beyond two live copies —
    /// the §3.3 invariant (primary + one on-demand shadow).
    pub fn add_location(&mut self, sid: ServerRef) {
        for slot in &mut self.placed_on {
            if slot.is_none() {
                *slot = Some(sid);
                return;
            }
        }
        panic!("task {:?} placed on more than two servers", self.id); // lint: allow(panic-surface): enforces the two-copy placement invariant (paper 3.3); a third copy is a scheduler bug
    }

    /// Forget a queue-entry location (entry consumed, stolen or revoked).
    ///
    /// A miss means `copies`/`placed_on` accounting drifted (e.g. a
    /// double-remove masked by a steal/revocation race) — every queue
    /// entry records its location at enqueue, so exactly one matching
    /// removal must exist.
    pub fn remove_location(&mut self, sid: ServerRef) {
        for slot in &mut self.placed_on {
            if *slot == Some(sid) {
                *slot = None;
                return;
            }
        }
        debug_assert!(
            false,
            "remove_location miss: task {:?} has no queue entry on {:?} (placed_on {:?})",
            self.id, sid, self.placed_on
        );
    }

    /// The other live copy's server, if any.
    pub fn other_location(&self, not: ServerRef) -> Option<ServerRef> {
        self.placed_on.iter().flatten().copied().find(|&s| s != not)
    }

    /// Queueing delay (start - enqueue); the paper's headline metric.
    /// Extracted into the recorder the moment the task starts — nothing
    /// reads delay samples back through a (possibly recycled) slot.
    pub fn queueing_delay(&self) -> f64 {
        debug_assert!(self.state != TaskState::Queued);
        self.started_at - self.enqueued_at
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tref(slot: u32) -> TaskRef {
        TaskRef { slot, gen: 0 }
    }

    #[test]
    fn queueing_delay_from_timestamps() {
        let mut t = Task::new(tref(0), JobId(0), 30.0, false, 100.0);
        t.state = TaskState::Running;
        t.started_at = 160.0;
        assert!((t.queueing_delay() - 60.0).abs() < 1e-12);
    }

    #[test]
    fn locations_roundtrip() {
        let mut t = Task::new(tref(1), JobId(0), 5.0, false, 0.0);
        t.add_location(ServerRef::initial(3));
        t.add_location(ServerRef::initial(7));
        assert_eq!(t.other_location(ServerRef::initial(3)), Some(ServerRef::initial(7)));
        t.remove_location(ServerRef::initial(3));
        assert_eq!(t.placed_on, [None, Some(ServerRef::initial(7))]);
        t.remove_location(ServerRef::initial(7));
        assert_eq!(t.placed_on, [None, None]);
    }

    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "remove_location miss"))]
    fn remove_location_miss_is_a_bug() {
        let mut t = Task::new(tref(2), JobId(0), 5.0, false, 0.0);
        t.add_location(ServerRef::initial(1));
        t.remove_location(ServerRef::initial(9));
        // Release builds skip the debug_assert; nothing changed.
        assert_eq!(t.placed_on, [Some(ServerRef::initial(1)), None]);
    }
}
