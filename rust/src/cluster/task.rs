//! Task lifecycle: the unit of scheduled work.
//!
//! Tasks are arena-allocated in [`crate::cluster::Cluster`] and referenced
//! by [`TaskId`] everywhere — no per-event allocation on the hot path.
//!
//! A short task may be enqueued on *multiple* servers at once: CloudCoaster
//! guarantees at least one copy of every short task lives on an on-demand
//! server so transient revocation can never lose work (paper §3.3). The
//! first copy a server dequeues wins; stale copies are skipped at dequeue.

use crate::util::{JobId, ServerId, TaskId, Time};

/// Where a task is in its lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    /// Created and placed on one or more server queues.
    Queued,
    /// Executing on exactly one server.
    Running,
    /// Completed.
    Finished,
}

/// A schedulable task.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: TaskId,
    pub job: JobId,
    pub duration: f64,
    pub is_long: bool,
    pub state: TaskState,
    /// When the task was enqueued (== job arrival; placement is immediate).
    pub enqueued_at: Time,
    /// When the task started executing (valid once `state >= Running`).
    pub started_at: Time,
    /// Server executing / having executed the task.
    pub ran_on: Option<ServerId>,
    /// Outstanding queue entries across all servers (copies, §3.3).
    pub copies: u8,
    /// Where the outstanding queue entries live (at most two: the primary
    /// placement plus the §3.3 on-demand shadow copy). Kept exact so a
    /// task's start can immediately discount its other copy from that
    /// server's load estimate.
    pub placed_on: [Option<ServerId>; 2],
}

impl Task {
    pub fn new(id: TaskId, job: JobId, duration: f64, is_long: bool, now: Time) -> Self {
        Task {
            id,
            job,
            duration,
            is_long,
            state: TaskState::Queued,
            enqueued_at: now,
            started_at: 0.0,
            ran_on: None,
            copies: 0,
            placed_on: [None, None],
        }
    }

    /// Record a queue-entry location. Panics beyond two live copies —
    /// the §3.3 invariant (primary + one on-demand shadow).
    pub fn add_location(&mut self, sid: ServerId) {
        for slot in &mut self.placed_on {
            if slot.is_none() {
                *slot = Some(sid);
                return;
            }
        }
        panic!("task {:?} placed on more than two servers", self.id);
    }

    /// Forget a queue-entry location (entry consumed, stolen or revoked).
    pub fn remove_location(&mut self, sid: ServerId) {
        for slot in &mut self.placed_on {
            if *slot == Some(sid) {
                *slot = None;
                return;
            }
        }
    }

    /// The other live copy's server, if any.
    pub fn other_location(&self, not: ServerId) -> Option<ServerId> {
        self.placed_on.iter().flatten().copied().find(|&s| s != not)
    }

    /// Queueing delay (start - enqueue); the paper's headline metric.
    pub fn queueing_delay(&self) -> f64 {
        debug_assert!(self.state != TaskState::Queued);
        self.started_at - self.enqueued_at
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queueing_delay_from_timestamps() {
        let mut t = Task::new(TaskId(0), JobId(0), 30.0, false, 100.0);
        t.state = TaskState::Running;
        t.started_at = 160.0;
        assert!((t.queueing_delay() - 60.0).abs() < 1e-12);
    }
}
