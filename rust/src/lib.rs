//! # CloudCoaster
//!
//! Production-grade reproduction of *"CloudCoaster: Transient-aware Bursty
//! Datacenter Workload Scheduling"* (Ogden & Guo, 2019).
//!
//! CloudCoaster is a hybrid datacenter scheduler that dynamically resizes
//! the short-job-only cluster partition with cheap **transient servers**
//! (spot / preemptible instances), driven by the **long-load ratio**
//! `l_r = N_long / N_total` (paper §3.2). This crate contains the complete
//! system: a deterministic discrete-event cluster simulator, the scheduler
//! family (centralized, Sparrow, Hawk, Eagle, CloudCoaster), the
//! transient-market substrate (pricing, provisioning delay, revocations,
//! budget), synthetic workload generators calibrated to the Yahoo/Google
//! traces the paper uses, a metrics pipeline, and (behind the `xla`
//! feature) a PJRT runtime that executes the AOT-compiled JAX/Pallas
//! analytics artifacts from `artifacts/`.
//!
//! ## Architecture
//!
//! The simulator is composed from four layers:
//!
//! * **sim** — the deterministic core: event queue + clock
//!   ([`sim::Engine`] — a self-tuning calendar queue: O(1) amortized
//!   push/pop under bursty arrivals with an overflow rung for
//!   far-future events, popping in exactly the `(time, seq)` order of
//!   the `BinaryHeap` it replaced, which survives as
//!   `Engine::reference` for golden/equivalence checks; `pop_batch`
//!   drains whole same-timestamp runs for the world's batch dispatch),
//!   forked PRNG streams ([`sim::Rng`]), and the
//!   composable [`sim::World`]. A `World` owns engine, cluster, recorder
//!   and RNG streams, pulls arrivals lazily from a streaming
//!   [`trace::ArrivalSource`] (one job of lookahead; eager workloads
//!   replay through a borrowed-lookahead fast path with no per-job
//!   clone), and dispatches every [`sim::Event`] through an ordered list
//!   of pluggable [`sim::Component`]s — the scheduler adapter, transient
//!   manager, work stealer and snapshot/forecast sampler are all
//!   components ([`sim::components`]), so new scenarios are component
//!   wiring plus source combinators, not runner changes. The event
//!   loop is exposed piecewise (`World::start`/`step`/`finish`;
//!   `run()` is exactly their composition), which is what the
//!   multi-cluster [`sim::Federation`] builds on: N member worlds —
//!   each with its own cluster, scenario pipeline, recorder and
//!   seed-forked RNG streams — advanced in global event-time order,
//!   with a pluggable [`sim::JobRouter`] (pass-through / round-robin /
//!   least-queued / class-split) dispatching arrivals across clusters
//!   and an optional [`transient::SharedBudget`] pooling one transient
//!   budget across all of them. The federation runs two ways:
//!   `Federation::run` is the serial reference — an
//!   earliest-next-event merge stepping one member event at a time —
//!   and `Federation::run_pdes(threads)` is conservative-window
//!   parallel discrete-event execution over the same members: each
//!   round computes a safe horizon (the min over the routers' next
//!   feed-arrival lower bound and pooled-coupled members' next event
//!   times), advances every uncoupled member's events strictly below
//!   it concurrently on scoped threads, then drains the boundary
//!   through the exact serial merge loop. Members only touch their own
//!   engine/cluster/recorder inside a window, cross-member state
//!   (fleet and cost watermarks) is replayed from per-step change
//!   journals in the serial `(time, member index)` order, and pooled
//!   members never advance inside windows — so every report field is
//!   bit-identical to the serial merge at any thread count, and the
//!   serial path survives as the golden reference (mirroring
//!   `Engine::reference`). An N = 1 pass-through federation is
//!   bit-identical to the plain world. Together with the cluster's
//!   generational
//!   task and server arenas and the recorder's fixed-memory delay
//!   sketches, job records, task slots, server slots and per-sample
//!   metrics are all O(active), not O(trace) (`peak_resident_jobs` /
//!   `peak_resident_tasks` / `peak_resident_servers` report the
//!   high-water marks), and the sampled snapshot series ride a
//!   fixed-capacity rebucketing ring (`metrics::TimeSeries::bounded`:
//!   2x stride coarsening when full) — no per-run structure grows
//!   with the horizon.
//! * **trace** — workloads, eager and streaming: synthetic generators
//!   calibrated to the paper's traces (eager `yahoo_like` /
//!   `google_like` are collectors over their streaming twins
//!   [`trace::synth::YahooSource`] / [`trace::synth::GoogleSource`], so
//!   the two paths are bit-identical per seed), a CSV persistence layer
//!   whose floats round-trip bit-exactly, an O(1)-memory CSV replayer
//!   ([`trace::CsvStream`]), and the [`trace::ArrivalSource`] combinator
//!   algebra — [`trace::BurstStorm`], [`trace::RateScale`],
//!   [`trace::TimeWindow`], [`trace::Splice`], [`trace::Merge`],
//!   [`trace::Take`] — for composing arrival patterns declaratively.
//! * **cluster** — servers, partitions, queue disciplines, the twin
//!   **generational slot arenas**: tasks addressed by [`util::TaskRef`]
//!   (a finished slot recycles once its liveness count — §3.3 queue
//!   copies plus pending `TaskFinish` events — hits zero) and servers
//!   addressed by [`util::ServerRef`] (a retired transient's slot
//!   recycles immediately; stale lifecycle events fail the generation
//!   check at pop), so stale events and shadow copies resolve to
//!   "stale, skip" instead of aliasing a reused slot; and the
//!   [`cluster::PoolIndex`]: one MinTree-backed least-loaded index per
//!   pool (general / short-reserved / transient) kept incrementally up
//!   to date by every mutator, so all placement and drain-victim
//!   queries are O(log n) with scan-identical tie-breaking — the
//!   transient index recycles its tree slots too, with a `ready_seq`
//!   key component pinning the historical ready-order tie-break. The
//!   hot per-server fields (est. work, queue depth, accepting/long
//!   flags, ready sequence) are additionally mirrored into dense
//!   struct-of-arrays columns ([`cluster::HotFields`], synced by every
//!   mutator) so the probe-sampling and least-loaded read paths touch
//!   contiguous memory instead of striding across `Server` structs;
//!   `SimConfig::soa_hot_fields` (default on) switches only the read
//!   path, so the struct reads survive as the bit-identity reference.
//!   Steady-state churn allocates nothing: revocation drains into a
//!   caller-owned scratch ([`cluster::Cluster::revoke_into`]), retired
//!   transients donate their queue buffers to a capacity pool that the
//!   next provisioned server reuses, and the scheduler/steal scratch
//!   Vecs are pooled — [`cluster::PoolStats`] counts the hits/misses
//!   as structural evidence.
//! * **coordinator** — experiment configuration
//!   ([`coordinator::ExperimentConfig`]), the declarative scenario
//!   registry ([`coordinator::scenario`]: a `[scenario]` TOML block or
//!   the CLI `--scenario` names resolve to a source + combinator stack +
//!   optional manager-less override), the canonical component wiring
//!   ([`coordinator::runner::build_world`] / `simulate_with` /
//!   [`coordinator::runner::simulate_source`]), reports, and sweeps:
//!   every evaluation grid is a list of [`coordinator::GridPoint`]s run
//!   through one generic driver, either serially or fanned out across
//!   cores by [`coordinator::run_sweep_parallel`] — scenario parameters
//!   (storm intensity, splice points) and federation axes (router,
//!   budget sharing) sweep like any other grid axis. A `[federation]`
//!   TOML block or `--clusters N` / `--scenario federated-burst`
//!   resolves to a [`coordinator::FederationSpec`]
//!   (`pdes_threads` / `--pdes-threads N` selects the
//!   conservative-window parallel path, 0 the serial reference merge —
//!   reports are bit-identical either way); the canonical
//!   member wiring is [`coordinator::build_federation`] /
//!   [`coordinator::run_federation`], distilled into per-cluster
//!   reports plus a merged aggregate
//!   ([`coordinator::FederatedReport`]: delay histograms merge
//!   bucket-wise exactly, cost ledgers sum). Runs derive all
//!   randomness from their own config seed, so every simulation field
//!   of a sweep report is bit-identical at any thread count (only
//!   wall-clock timing fields vary).
//! * **runtime / metrics / transient** — analytics engines (pure-rust
//!   [`runtime::NativeAnalytics`] by default; PJRT/XLA under
//!   `--features xla`), the recorder + cost ledger behind every paper
//!   number — per-sample populations (queueing delays, transient
//!   lifetimes) stream through the fixed-memory log-bucketed
//!   [`metrics::DelayHistogram`] by default (count/mean/min/max exact,
//!   quantiles within a documented ≤1% bound; the exact-Vec backend
//!   survives behind `SimConfig::exact_delay_samples` for golden
//!   comparisons) — and the §3.2 transient manager + market model.
//!
//! Determinism is load-bearing: `tests/federation_golden.rs` pins the
//! N = 1 pass-through federation bit-exactly to the plain world (plus
//! N = 2 determinism, sweep-thread invariance, the pooled-budget
//! cap invariant, and the conservative-window PDES path bit-exactly
//! to the serial merge at 1/2/8 worker threads for every router and
//! budget-sharing mode), `tests/golden_determinism.rs` pins the
//! `World` decomposition bit-exactly to the original monolithic runner,
//! `tests/streaming_golden.rs` pins the streaming arrival path
//! bit-exactly to the eager replay (and the combinators to fixed
//! seeds), plus task/server-arena recycling and the histogram backend
//! bit-exactly to the append-only / exact-Vec reference builds with
//! `peak_resident_tasks`, `peak_resident_servers` and delay-structure
//! bytes flat under 10x trace scaling, `tests/arena_props.rs`
//! stress-tests both arenas under randomized
//! enqueue/steal/revoke/drain interleavings (no resurrection, slots <=
//! peak-active, all four recycling-mode combinations observationally
//! identical), `tests/pool_index_props.rs` pins every indexed
//! least-loaded answer to the naive linear scan it replaced, and
//! `tests/engine_props.rs` pins the calendar queue to the reference
//! `BinaryHeap` under randomized push/pop interleavings, tie storms,
//! far-future overflow and rollover boundaries (plus a full-run
//! bit-identity check via `SimConfig::reference_engine`). The SoA
//! hot-field mirror is held to the same standard: `check_invariants`
//! pins the dense columns bitwise to the `Server` structs after every
//! transition, and `tests/streaming_golden.rs` pins full reports
//! across `soa_hot_fields` on/off. The opt-in hot-path profiler
//! (`--profile true` / `profile = true`, reported on stderr and via
//! `--profile-out` JSON) is deliberately outside the bit-identity
//! surface: its event/pool counts are deterministic per config —
//! golden-checked — but its wall-time splits are machine noise, so
//! stdout and every report field stay byte-identical with profiling
//! on or off.
//!
//! ## Static invariants
//!
//! The golden and property suites above catch determinism drift *after*
//! it happens; the first-party [`lint`] pass (`pallas-lint`) rejects
//! the code shapes that cause it *before* a run exists. Six rules, each
//! one file under `src/lint/`: wall-clock reads quarantined to the
//! coordinator/benchkit/profiler edges, unordered `HashMap`/`HashSet`
//! iteration banned from report-shaping modules, every RNG fork label
//! forced through the [`util`] registry (`RNG_*` constants — no raw
//! hex at call sites), raw `TaskId`/`ServerId` construction confined
//! to [`util`], allocation banned inside `// lint: hot-path`-marked
//! functions, and `unwrap`/`expect`/`panic!` in library simulation
//! paths required to carry a written justification.
//!
//! A second tier, [`lint::check`] (`pallas-check`, or
//! `pallas-lint --deep` for both tiers at once), goes crate-wide: it
//! builds a symbol table of the whole crate — module tree, fn
//! signatures, struct fields, enum variants, trait surfaces, impl
//! blocks, imports — and resolves every path, call, struct literal,
//! and `self.` access against it. Seven `check-*` rules catch the
//! cross-module drift rustc only reports at compile time (renamed fns
//! still called by old names, arity drift, vanished fields, `Event`
//! dispatch tables out of sync with the variant list, impl blocks
//! diverging from their trait, duplicate definitions, dead `pub`
//! API). Its recall is pinned by a 29-crate seeded-defect corpus under
//! `tests/fixtures/check/`.
//!
//! Violations in either tier are suppressed line-by-line with
//! `// lint: allow(<rule>): <reason>`; unused suppressions fail the
//! run. `tests/lint_clean.rs` gates `cargo test` on a strictly clean
//! tree, and both JSON reports (`--json`) are byte-deterministic for
//! CI diffing. See `rust/LINTS.md` for the full rule catalogue.
//!
//! ## Quickstart
//!
//! ```no_run
//! use cloudcoaster::coordinator::{ExperimentConfig, run_experiment};
//!
//! let cfg = ExperimentConfig::paper_defaults();
//! let report = run_experiment(&cfg).unwrap();
//! println!("avg short queueing delay: {:.1}s", report.short_delay.mean());
//! ```
//!
//! Composing a custom scenario (an Eagle run with stealing disabled and
//! a custom snapshot cadence) is component wiring on a [`sim::World`]:
//!
//! ```no_run
//! use cloudcoaster::cluster::{Cluster, QueuePolicy};
//! use cloudcoaster::metrics::Recorder;
//! use cloudcoaster::sched::Hybrid;
//! use cloudcoaster::sim::{SchedulerComponent, SnapshotSampler, World};
//! use cloudcoaster::trace::synth::{YahooLikeParams, YahooSource};
//! use cloudcoaster::sim::Rng;
//!
//! // Streaming source: the trace is synthesized lazily as the
//! // simulation advances — nothing is materialised up front.
//! let source = YahooSource::new(&YahooLikeParams::default(), &mut Rng::new(42));
//! let mut sched = Hybrid::eagle(2.0);
//! let cluster = Cluster::new(512, 16, QueuePolicy::Fifo);
//! let mut world = World::new(Box::new(source), cluster, Recorder::new(1.0), 42);
//! world.add_component(Box::new(SnapshotSampler::new(30.0)));
//! world.add_component(Box::new(SchedulerComponent::new(&mut sched)));
//! world.run();
//! println!("{} events, {} tasks, peak {} resident jobs",
//!     world.engine.processed(), world.rec.tasks_finished, world.peak_resident_jobs());
//! ```
//!
//! Declaratively, the same ideas are a `[scenario]` block in a config
//! file (or `--scenario NAME` on the CLI):
//!
//! ```toml
//! [workload]
//! csv = "trace.csv"              # replayed in O(1) memory
//!
//! [scenario]
//! name = "storm-replay"
//! storm_windows = [3600, 7200]   # start,end pairs (seconds)
//! storm_intensity = 3.0          # arrival-rate multiplier in-window
//! manager = "none"               # manager-less baseline wiring
//! ```
//!
//! Sweeping a grid across all cores:
//!
//! ```no_run
//! use cloudcoaster::coordinator::{ExperimentConfig, run_sweep_parallel};
//! use cloudcoaster::coordinator::sweep::paper_points;
//!
//! let cfg = ExperimentConfig::paper_defaults();
//! let points = paper_points(&cfg, &[1.0, 2.0, 3.0]);
//! let reports = run_sweep_parallel(&cfg, &points, 8).unwrap();
//! assert_eq!(reports.len(), 4);
//! ```

pub mod benchkit;
pub mod cluster;
pub mod coordinator;
pub mod lint;
pub mod metrics;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod testkit;
pub mod trace;
pub mod transient;
pub mod util;

pub use coordinator::{run_experiment, run_sweep_parallel, ExperimentConfig};
