//! # CloudCoaster
//!
//! Production-grade reproduction of *"CloudCoaster: Transient-aware Bursty
//! Datacenter Workload Scheduling"* (Ogden & Guo, 2019).
//!
//! CloudCoaster is a hybrid datacenter scheduler that dynamically resizes
//! the short-job-only cluster partition with cheap **transient servers**
//! (spot / preemptible instances), driven by the **long-load ratio**
//! `l_r = N_long / N_total` (paper §3.2). This crate contains the complete
//! system: a deterministic discrete-event cluster simulator, the scheduler
//! family (centralized, Sparrow, Eagle, CloudCoaster), the transient-market
//! substrate (pricing, provisioning delay, revocations, budget), synthetic
//! workload generators calibrated to the Yahoo/Google traces the paper
//! uses, a metrics pipeline, and a PJRT runtime that executes the
//! AOT-compiled JAX/Pallas analytics artifacts from `artifacts/`.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — event loop, cluster state, schedulers, transient
//!   manager, experiment coordinator. Python-free at runtime.
//! * **L2/L1 (python/compile)** — JAX cluster-state analytics + Pallas
//!   kernels, AOT-lowered to HLO text and executed through
//!   [`runtime::XlaAnalytics`].
//!
//! ## Quickstart
//!
//! ```no_run
//! use cloudcoaster::coordinator::{ExperimentConfig, run_experiment};
//!
//! let cfg = ExperimentConfig::paper_defaults();
//! let report = run_experiment(&cfg).unwrap();
//! println!("avg short queueing delay: {:.1}s", report.short_delay.mean());
//! ```

pub mod benchkit;
pub mod cluster;
pub mod coordinator;
pub mod metrics;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod testkit;
pub mod trace;
pub mod transient;
pub mod util;

pub use coordinator::{run_experiment, ExperimentConfig};

