//! `cloudcoaster` — CLI launcher for the CloudCoaster reproduction.
//!
//! ```text
//! cloudcoaster run      [--config FILE] [--scheduler KIND] [--r R] [--seed N]
//!                       [--scenario default|managerless|burst-storm|federated-burst]
//!                       [--clusters N] [--router KIND] [--budget-sharing MODE]
//!                       [--pdes-threads N] [--reference-engine true|false]
//!                       [--soa-hot-fields true|false] [--profile true]
//!                       [--profile-out FILE]
//! cloudcoaster sweep    [--config FILE] [--ratios 1,2,3] [--threads N]
//! cloudcoaster ablate   [--config FILE] --what threshold|revocation|policy|scheduler|storm|router|budget [--threads N]
//! cloudcoaster trace    [--out FILE] [--kind yahoo|google] [--horizon SECS]
//! cloudcoaster replicate [--seeds N]   # headline across N seeds
//! cloudcoaster version
//! ```
//!
//! `--scenario` resolves a registry scenario against the loaded config
//! (manager-less baseline wiring, injected burst storms over whatever
//! `[workload]` selects — including CSV trace replay;
//! `federated-burst` adds a two-cluster federation under staggered
//! storms and one pooled transient budget). Fully custom pipelines go
//! in the config file's `[scenario]` / `[federation]` sections; either
//! way the workload streams through the simulation in O(active-jobs)
//! memory, so trace length is not capped by RAM.
//!
//! `--clusters N` federates N clusters (pass-through router unless
//! `--router round-robin|least-queued|class-split` picks a front end;
//! `--budget-sharing none|split|pooled` couples the transient budgets).
//! A federated run prints one summary line per cluster plus the
//! aggregate (merged delay histograms, summed cost ledgers).
//! `--pdes-threads N` advances the member worlds with
//! conservative-window parallel execution on N worker threads inside
//! the one run; 0 (the default) keeps the serial reference merge.
//! Reports are bit-identical either way — only wall-clock changes.
//!
//! `--profile true` turns on the hot-path profiler: per-event-class
//! counts and wall time, per-component wall time, and allocation-pool
//! hit/miss counters, reported on stderr (and as JSON via
//! `--profile-out FILE`). Stdout stays byte-identical to an unprofiled
//! run. `--soa-hot-fields false` serves hot per-server reads from the
//! reference struct layout instead of the dense SoA mirror —
//! bit-identical results either way (the CI smoke diffs them).
//!
//! Sweeps and ablations fan their runs out across `--threads` OS threads
//! (default: all cores). Simulation results are bit-identical at any
//! thread count — every run's RNG streams fork off its own config seed;
//! only wall-clock timing fields vary.

use std::path::Path;

use anyhow::{bail, Context, Result};

use cloudcoaster::coordinator::config::{ExperimentConfig, SchedulerKind, WorkloadSource};
use cloudcoaster::coordinator::report::{
    fig3_cdf_csv, fig3_markdown, run_experiment, summary_line, table1_markdown,
    workload_summary,
};
use cloudcoaster::coordinator::sweep;
use cloudcoaster::sim::Rng;
use cloudcoaster::trace::synth::{google_like, yahoo_like, GoogleLikeParams, YahooLikeParams};
use cloudcoaster::trace::{write_csv, TraceStats};

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    cmd: String,
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse() -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let Some(cmd) = argv.first() else {
            bail!("usage: cloudcoaster <run|sweep|ablate|trace|version> [--flag value ...]");
        };
        let mut flags = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let key = argv[i]
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {:?}", argv[i]))?;
            let value = argv.get(i + 1).with_context(|| format!("--{key} needs a value"))?;
            flags.push((key.to_string(), value.clone()));
            i += 2;
        }
        Ok(Args { cmd: cmd.clone(), flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

fn load_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_toml_file(Path::new(path))?,
        None => ExperimentConfig::paper_defaults(),
    };
    if let Some(s) = args.get("scheduler") {
        cfg.scheduler = SchedulerKind::parse(s)?;
    }
    if let Some(r) = args.get("r") {
        cfg.r = r.parse().context("--r")?;
    }
    if let Some(seed) = args.get("seed") {
        cfg.seed = seed.parse().context("--seed")?;
    }
    if let Some(t) = args.get("threshold") {
        cfg.threshold = t.parse().context("--threshold")?;
    }
    if let Some(h) = args.get("horizon") {
        let horizon: f64 = h.parse().context("--horizon")?;
        if let WorkloadSource::YahooLike(p) = &mut cfg.workload {
            p.horizon = horizon;
        }
    }
    if let Some(n) = args.get("servers") {
        cfg.cluster_size = n.parse().context("--servers")?;
    }
    if let Some(n) = args.get("short-partition") {
        cfg.short_partition = n.parse().context("--short-partition")?;
    }
    if let Some(v) = args.get("reference-engine") {
        // The pre-calendar BinaryHeap engine — bit-identical results;
        // the CI engine-equivalence smoke diffs the two.
        cfg.reference_engine = v.parse().context("--reference-engine")?;
    }
    if let Some(v) = args.get("soa-hot-fields") {
        // `false` reads hot server fields through the reference struct
        // layout instead of the dense SoA mirror — bit-identical
        // results; the CI SoA-equivalence smoke diffs the two.
        cfg.soa_hot_fields = v.parse().context("--soa-hot-fields")?;
    }
    if let Some(v) = args.get("profile") {
        // Hot-path profiler: report goes to stderr (and --profile-out
        // as JSON) so stdout stays byte-identical to an unprofiled run.
        cfg.profile = v.parse().context("--profile")?;
    }
    if let Some(name) = args.get("scenario") {
        // Registry scenarios compose with the configured workload (so
        // `--scenario burst-storm` over a CSV workload is a burst-storm
        // trace replay). A `[scenario]` section in the config file is
        // replaced by the named one; `federated-burst` also installs
        // its registry federation (clusters still overridable below).
        cfg.scenario = Some(
            cloudcoaster::coordinator::scenario::named(name, &cfg).with_context(|| {
                format!(
                    "known scenarios: {}",
                    cloudcoaster::coordinator::scenario::SCENARIO_NAMES.join(", ")
                )
            })?,
        );
        if let Some(fed) = cloudcoaster::coordinator::scenario::named_federation(name, &cfg)? {
            cfg.federation = Some(fed);
        }
    }
    // An explicit cluster count — from the config file's [federation]
    // block, a registry federation, or --clusters — is never second-
    // guessed; only when --router/--budget-sharing conjure a federation
    // from nothing do they default to two clusters (there is nothing to
    // route across with one).
    let had_explicit_clusters = cfg.federation.is_some() || args.get("clusters").is_some();
    if let Some(n) = args.get("clusters") {
        let clusters: usize = n.parse().context("--clusters")?;
        let mut fed = cfg.federation.clone().unwrap_or_default();
        fed.clusters = clusters;
        cfg.federation = Some(fed);
    }
    if let Some(r) = args.get("router") {
        let mut fed = cfg.federation.clone().unwrap_or_default();
        fed.router = cloudcoaster::coordinator::RouterKind::parse(r)?;
        if !had_explicit_clusters {
            fed.clusters = 2;
        }
        cfg.federation = Some(fed);
    }
    if let Some(b) = args.get("budget-sharing") {
        let mut fed = cfg.federation.clone().unwrap_or_default();
        fed.budget_sharing = cloudcoaster::coordinator::BudgetSharing::parse(b)?;
        if !had_explicit_clusters {
            fed.clusters = 2;
        }
        cfg.federation = Some(fed);
    }
    if let Some(n) = args.get("pdes-threads") {
        let mut fed = cfg.federation.clone().unwrap_or_default();
        fed.pdes_threads = n.parse().context("--pdes-threads")?;
        if !had_explicit_clusters {
            fed.clusters = 2;
        }
        cfg.federation = Some(fed);
    }
    cfg.validate()?;
    Ok(cfg)
}

fn parse_ratios(s: &str) -> Result<Vec<f64>> {
    s.split(',').map(|x| x.trim().parse::<f64>().context("ratio list")).collect()
}

/// Worker threads for grid execution: `--threads N`, default all cores.
fn parse_threads(args: &Args) -> Result<usize> {
    Ok(match args.get("threads") {
        Some(n) => n.parse().context("--threads")?,
        None => sweep::default_threads(),
    })
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    eprintln!("workload: {}", workload_summary(&cfg)?);
    // Profiles collected per run (per member for a federation) and
    // reported after the stdout summary — on stderr and via
    // --profile-out, never stdout, so the default surface stays
    // byte-identical to an unprofiled run.
    let mut profiles: Vec<(String, cloudcoaster::sim::ProfileReport)> = Vec::new();
    let rep = if cfg.federation.is_some() {
        // Federated run: one line per member cluster, then the
        // aggregate (merged delay histograms, summed cost ledgers) —
        // which also feeds --cdf-out and the memory headlines below.
        let fed = cloudcoaster::coordinator::run_federated_experiment(&cfg)?;
        for (i, rep) in fed.per_cluster.iter().enumerate() {
            println!("cluster {i}: {}", summary_line(rep));
            if let Some(p) = &rep.profile {
                profiles.push((format!("cluster {i}"), p.clone()));
            }
        }
        match fed.shared_cap {
            Some(cap) => println!(
                "federation transient peak (active+provisioning): {} / shared cap {}",
                fed.peak_total_fleet, cap
            ),
            None => println!(
                "federation transient peak (active+provisioning): {} (uncoupled budgets)",
                fed.peak_total_fleet
            ),
        }
        fed.aggregate
    } else {
        run_experiment(&cfg)?
    };
    if let Some(p) = &rep.profile {
        profiles.push(("run".to_string(), p.clone()));
    }
    println!("{}", summary_line(&rep));
    if cfg.scenario.as_ref().map(|s| s.reshapes_workload()).unwrap_or(false) {
        eprintln!("peak resident jobs (streaming): {}", rep.peak_resident_jobs);
    }
    // The arena-memory headlines: finished task slots and retired
    // server slots recycle, delay samples stream through fixed-size
    // histogram sketches, and the snapshot series ride a bounded
    // rebucketing ring — all bounded by cluster load, not trace length
    // (CI pins each flat under 10x trace scaling).
    println!("peak resident tasks (arena): {}", rep.peak_resident_tasks);
    println!("peak resident servers (arena): {}", rep.peak_resident_servers);
    println!("delay structures (bytes): {}", rep.delay_struct_bytes);
    println!("snapshot series (bytes): {}", rep.snapshot_series_bytes);
    if let Some(out) = args.get("cdf-out") {
        std::fs::write(out, rep.cdf.to_csv())?;
        eprintln!("wrote CDF to {out}");
    }
    for (label, p) in &profiles {
        eprintln!("profile [{label}]");
        eprint!("{}", p.render());
    }
    if let Some(out) = args.get("profile-out") {
        match profiles.as_slice() {
            [] => eprintln!("--profile-out given but profiling was off (pass --profile true)"),
            [(_, p)] => {
                std::fs::write(out, p.to_json())?;
                eprintln!("wrote profile to {out}");
            }
            many => {
                // Federated run: one JSON object per member, in order.
                let parts: Vec<String> =
                    many.iter().map(|(_, p)| p.to_json().trim_end().to_string()).collect();
                std::fs::write(out, format!("[\n{}\n]\n", parts.join(",\n")))?;
                eprintln!("wrote {} member profiles to {out}", many.len());
            }
        }
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let ratios = match args.get("ratios") {
        Some(s) => parse_ratios(s)?,
        None => vec![1.0, 2.0, 3.0],
    };
    let threads = parse_threads(args)?;
    eprintln!("workload: {}", workload_summary(&cfg)?);
    let reports = sweep::run_sweep_parallel(&cfg, &sweep::paper_points(&cfg, &ratios), threads)?;
    println!("\n== Figure 3: short-task queueing delay ==\n{}", fig3_markdown(&reports));
    println!("== Table 1: transient lifetimes & counts ==\n{}", table1_markdown(&reports));
    if let Some(out) = args.get("cdf-out") {
        std::fs::write(out, fig3_cdf_csv(&reports))?;
        eprintln!("wrote CDF series to {out}");
    }
    Ok(())
}

fn cmd_ablate(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let what = args.get("what").unwrap_or("threshold");
    let threads = parse_threads(args)?;
    let points = match what {
        "threshold" => sweep::threshold_points(&cfg, &[0.5, 0.75, 0.9, 0.95, 0.99]),
        "revocation" => {
            sweep::revocation_points(&cfg, &[None, Some(4.0 * 3600.0), Some(3600.0)])
        }
        "policy" => sweep::policy_points(&cfg),
        "scheduler" => sweep::scheduler_points(&cfg),
        "market" => sweep::bid_points(&cfg, &[None, Some(2.0), Some(0.5), Some(0.35)]),
        "forecast" => sweep::forecast_points(&cfg),
        "storm" => sweep::storm_intensity_points(&cfg, &[1.0, 2.0, 3.0, 5.0])?,
        "splice" => {
            // Regime-switch axis: replay a CSV tail from progressively
            // earlier fractions of the synthetic horizon.
            let csv = args.get("csv").context("--what splice needs --csv FILE")?;
            let horizon = match &cfg.workload {
                WorkloadSource::YahooLike(p) => p.horizon,
                WorkloadSource::GoogleLike(p) => p.horizon,
                WorkloadSource::Csv(_) => {
                    bail!("--what splice needs a synthetic base workload (yahoo/google)")
                }
            };
            sweep::splice_points(&cfg, csv, horizon, &[0.25, 0.5, 0.75])
        }
        "router" => sweep::router_points(
            &cfg,
            &[
                cloudcoaster::coordinator::RouterKind::PassThrough,
                cloudcoaster::coordinator::RouterKind::RoundRobin,
                cloudcoaster::coordinator::RouterKind::LeastQueued,
                cloudcoaster::coordinator::RouterKind::ClassSplit,
            ],
        ),
        "budget" => sweep::budget_sharing_points(&cfg),
        other => bail!(
            "unknown ablation {other:?} \
             (threshold|revocation|policy|scheduler|market|forecast|storm|splice|router|budget)"
        ),
    };
    let reports = sweep::run_sweep_parallel(&cfg, &points, threads)?;
    println!("\n== ablation: {what} ==\n{}", fig3_markdown(&reports));
    println!("{}", table1_markdown(&reports));
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let kind = args.get("kind").unwrap_or("yahoo");
    let out = args.get("out").unwrap_or("trace.csv");
    let seed: u64 = args.get("seed").unwrap_or("42").parse()?;
    let mut rng = Rng::new(seed);
    let workload = match kind {
        "yahoo" => {
            let mut p = YahooLikeParams::default();
            if let Some(h) = args.get("horizon") {
                p.horizon = h.parse()?;
            }
            yahoo_like(&p, &mut rng)
        }
        "google" => {
            let mut p = GoogleLikeParams::default();
            if let Some(h) = args.get("horizon") {
                p.horizon = h.parse()?;
            }
            google_like(&p, &mut rng)
        }
        other => bail!("unknown trace kind {other:?} (yahoo|google)"),
    };
    println!("{}", TraceStats::of(&workload).summary());
    write_csv(&workload, Path::new(out))?;
    println!("wrote {out}");
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "run" => cmd_run(&args),
        "replicate" => {
            let cfg = load_config(&args)?;
            let n: u64 = args.get("seeds").unwrap_or("5").parse()?;
            let seeds: Vec<u64> = (0..n).map(|i| cfg.seed + i).collect();
            let rep = cloudcoaster::coordinator::replicate::replicate(&cfg, &seeds)?;
            println!("{}", rep.summary());
            Ok(())
        }
        "sweep" => cmd_sweep(&args),
        "ablate" => cmd_ablate(&args),
        "trace" => cmd_trace(&args),
        "version" => {
            println!("cloudcoaster {} (paper reproduction)", env!("CARGO_PKG_VERSION"));
            Ok(())
        }
        other => bail!("unknown command {other:?} (run|sweep|ablate|trace|replicate|version)"),
    }
}
