//! Empirical CDF construction — the representation behind the paper's
//! Figure 3 ("CDFs of short tasks queueing delay").

/// An empirical CDF evaluated at a fixed set of edges.
#[derive(Clone, Debug)]
pub struct Cdf {
    pub edges: Vec<f64>,
    /// P(X <= edge) for each edge.
    pub values: Vec<f64>,
    pub n_samples: usize,
}

impl Cdf {
    /// Build from samples at `n_edges` points spanning [0, max(sample)].
    pub fn from_samples(samples: &[f64], n_edges: usize) -> Cdf {
        let max = samples.iter().copied().fold(0.0, f64::max).max(1e-9);
        let edges: Vec<f64> =
            (0..n_edges).map(|i| max * i as f64 / (n_edges - 1) as f64).collect();
        Cdf::from_samples_at(samples, edges)
    }

    /// Build from samples evaluated at the given (sorted) edges.
    ///
    /// Empty-input convention (audited for zero-short-task runs, e.g. a
    /// manager-less replay of a long-only trace): with no samples every
    /// value is a well-defined **0.0** — the `len().max(1)` divisor
    /// exists precisely so the empty CDF is all-zeros rather than NaN.
    /// Downstream consumers ([`Cdf::quantile`], the report tables)
    /// treat an all-zero CDF as "no population" and render zeros.
    pub fn from_samples_at(samples: &[f64], edges: Vec<f64>) -> Cdf {
        debug_assert!(edges.windows(2).all(|w| w[0] <= w[1]), "edges must be sorted");
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let n = sorted.len().max(1);
        let values = edges
            .iter()
            .map(|&e| sorted.partition_point(|&s| s <= e) as f64 / n as f64)
            .collect();
        Cdf { edges, values, n_samples: samples.len() }
    }

    /// Inverse CDF: the smallest edge with CDF >= q. An empty CDF (no
    /// samples: every value 0.0) answers 0.0 for all q — not the last
    /// edge, which the all-zero fallthrough would otherwise hit.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n_samples == 0 {
            return 0.0;
        }
        for (e, v) in self.edges.iter().zip(&self.values) {
            if *v >= q {
                return *e;
            }
        }
        *self.edges.last().unwrap_or(&0.0)
    }

    /// Render as `edge,value` CSV rows (for plotting).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("edge,cdf\n");
        for (e, v) in self.edges.iter().zip(&self.values) {
            out.push_str(&format!("{e:.4},{v:.6}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_monotone_and_complete() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let cdf = Cdf::from_samples(&samples, 51);
        assert!(cdf.values.windows(2).all(|w| w[0] <= w[1]));
        assert!((cdf.values.last().unwrap() - 1.0).abs() < 1e-12);
        assert_eq!(cdf.n_samples, 100);
    }

    #[test]
    fn quantile_inverts() {
        let samples: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let cdf = Cdf::from_samples(&samples, 1001);
        let median = cdf.quantile(0.5);
        assert!((median - 500.0).abs() < 2.0, "median={median}");
    }

    #[test]
    fn custom_edges() {
        let cdf = Cdf::from_samples_at(&[5.0, 15.0, 25.0], vec![0.0, 10.0, 20.0, 30.0]);
        assert_eq!(cdf.values, vec![0.0, 1.0 / 3.0, 2.0 / 3.0, 1.0]);
    }

    #[test]
    fn empty_samples() {
        let cdf = Cdf::from_samples(&[], 10);
        assert!(cdf.values.iter().all(|&v| v == 0.0));
        assert!(cdf.values.iter().all(|v| v.is_finite()), "empty CDF must never be NaN");
        assert_eq!(cdf.n_samples, 0);
        // Quantiles of an empty population are defined zeros, not the
        // top edge.
        assert_eq!(cdf.quantile(0.5), 0.0);
        assert_eq!(cdf.quantile(1.0), 0.0);
        assert!(cdf.to_csv().lines().count() == 11);
    }

    #[test]
    fn csv_renders() {
        let cdf = Cdf::from_samples_at(&[1.0], vec![0.0, 2.0]);
        let csv = cdf.to_csv();
        assert!(csv.starts_with("edge,cdf\n"));
        assert_eq!(csv.lines().count(), 3);
    }
}
