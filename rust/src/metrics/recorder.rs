//! The simulation-wide metrics recorder: every number in the paper's
//! evaluation (Figure 3, Table 1, headline ratios) is derived from what
//! this collects.
//!
//! Arena contract: the recorder holds **values, never `TaskRef`s**.
//! Per-task queueing delays are pushed at task start
//! ([`Recorder::task_started`]) and per-job responses at the last task's
//! finish ([`Recorder::job_finished`]), with every field extracted at
//! the state transition — so recycling a finished task's arena slot can
//! never invalidate a recorded sample, and nothing here reads back
//! through the task arena.
//!
//! Memory contract: every per-sample population streams through a
//! [`DelayDist`] — by default the fixed-memory log-bucketed
//! [`crate::metrics::DelayHistogram`] sketch, so recorder memory is
//! **constant**, independent of trace length. The exact-Vec backend
//! ([`Recorder::new_exact`], `SimConfig::exact_delay_samples`) is kept
//! purely for golden comparisons; count/mean/min/max are bit-identical
//! across backends, quantiles within the documented ≤1% bucket bound.

use crate::metrics::{Cdf, CostLedger, DelayDist, StreamingStats, TimeSeries};
use crate::util::Time;

/// Collects per-task delays, cluster time series and transient cost
/// accounting for one simulation run.
#[derive(Clone, Debug)]
pub struct Recorder {
    /// Queueing delay of every *short* task (Figure 3's variable).
    pub short_delays: DelayDist,
    /// Queueing delay of every long task ("maintains long job
    /// performance", §Abstract).
    pub long_delays: DelayDist,
    /// Per-job makespan-style stats (arrival -> last task finish).
    pub short_job_response: StreamingStats,
    pub long_job_response: StreamingStats,
    /// Sampled long-load ratio trajectory.
    pub lr_series: TimeSeries,
    /// Sampled active transient count (for plots; exact average comes from
    /// the cost ledger's integrator).
    pub transient_series: TimeSeries,
    /// Transient cost accounting (Table 1).
    pub cost: CostLedger,
    /// Tasks that finished.
    pub tasks_finished: u64,
    /// Tasks rescheduled due to revocation (should stay 0 with §3.3
    /// duplicate copies enabled).
    pub tasks_rescheduled: u64,
    /// Stale duplicate-copy queue entries skipped at dequeue.
    pub stale_copies_skipped: u64,
    /// Transient servers ever requested / revoked.
    pub transients_requested: u64,
    pub transients_revoked: u64,
}

impl Recorder {
    /// Recorder with the default fixed-memory delay sketches.
    pub fn new(r: f64) -> Self {
        Self::with_backend(r, false)
    }

    /// Recorder with exact-Vec delay samples (reference mode for golden
    /// comparisons; memory grows with the run).
    pub fn new_exact(r: f64) -> Self {
        Self::with_backend(r, true)
    }

    pub fn with_backend(r: f64, exact_delay_samples: bool) -> Self {
        // The exact-delay reference mode keeps the snapshot series exact
        // too (the fully-exact golden build); defaults bound them.
        let cap = if exact_delay_samples {
            0
        } else {
            crate::metrics::DEFAULT_SNAPSHOT_POINTS
        };
        Self::with_options(r, exact_delay_samples, cap)
    }

    /// Full-control constructor: delay backend and snapshot-series point
    /// capacity (`0` = unbounded exact series) chosen independently.
    pub fn with_options(r: f64, exact_delay_samples: bool, snapshot_points: usize) -> Self {
        let series = || {
            if snapshot_points == 0 {
                TimeSeries::new()
            } else {
                TimeSeries::bounded(snapshot_points)
            }
        };
        Recorder {
            short_delays: DelayDist::new(exact_delay_samples),
            long_delays: DelayDist::new(exact_delay_samples),
            short_job_response: StreamingStats::new(),
            long_job_response: StreamingStats::new(),
            lr_series: series(),
            transient_series: series(),
            cost: CostLedger::with_backend(r, exact_delay_samples),
            tasks_finished: 0,
            tasks_rescheduled: 0,
            stale_copies_skipped: 0,
            transients_requested: 0,
            transients_revoked: 0,
        }
    }

    /// Record a task start (the moment queueing delay becomes known).
    #[inline]
    pub fn task_started(&mut self, is_long: bool, delay: f64) {
        debug_assert!(delay >= 0.0, "negative queueing delay {delay}");
        if is_long {
            self.long_delays.push(delay);
        } else {
            self.short_delays.push(delay);
        }
    }

    pub fn job_finished(&mut self, is_long: bool, response: f64) {
        if is_long {
            self.long_job_response.push(response);
        } else {
            self.short_job_response.push(response);
        }
    }

    pub fn snapshot(&mut self, t: Time, l_r: f64, active_transients: f64) {
        self.lr_series.push(t, l_r);
        self.transient_series.push(t, active_transients);
    }

    /// Resident bytes of the per-sample delay structures (short + long
    /// delays + lifetimes). Constant on the sketch backends; O(samples)
    /// in exact mode — the CI memory smoke pins the default flat.
    pub fn delay_struct_bytes(&self) -> usize {
        self.short_delays.memory_bytes()
            + self.long_delays.memory_bytes()
            + self.cost.lifetimes.memory_bytes()
    }

    /// Resident bytes of the sampled snapshot series (l_r + active
    /// transients). Bounded by the ring capacity on the default path —
    /// the last per-run structure that used to grow with the horizon;
    /// O(horizon) only in the exact reference mode.
    pub fn snapshot_series_bytes(&self) -> usize {
        self.lr_series.memory_bytes() + self.transient_series.memory_bytes()
    }

    /// Merge another run's recorder into this one for cross-cluster
    /// aggregation (federation reports): delay populations and transient
    /// lifetimes merge exactly (bucket-wise on the sketch backend,
    /// sample-concatenation on the exact backend), counters sum. The
    /// snapshot time series and the step-integrated cost curves are
    /// per-cluster trajectories with no meaningful pointwise merge —
    /// they stay as-is on `self`; aggregate cost numbers are recombined
    /// from the per-run ledgers by the report layer instead.
    pub fn absorb(&mut self, other: &Recorder) {
        self.short_delays.merge_from(&other.short_delays);
        self.long_delays.merge_from(&other.long_delays);
        self.cost.lifetimes.merge_from(&other.cost.lifetimes);
        self.short_job_response.merge_from(&other.short_job_response);
        self.long_job_response.merge_from(&other.long_job_response);
        self.tasks_finished += other.tasks_finished;
        self.tasks_rescheduled += other.tasks_rescheduled;
        self.stale_copies_skipped += other.stale_copies_skipped;
        self.transients_requested += other.transients_requested;
        self.transients_revoked += other.transients_revoked;
    }

    /// Figure 3: CDF of short-task queueing delay at `n_edges` uniform
    /// edges spanning `[0, max]` — works from either backend (exact on
    /// the Vec path, bucket-approximate on the sketch). Library-side
    /// convenience on f64 edges; the report pipeline builds its own
    /// (f32, analytics-engine-compatible) grid in `coordinator::report`.
    pub fn short_delay_cdf(&mut self, n_edges: usize) -> Cdf {
        let max = self.short_delays.max().max(1e-9);
        // n_edges < 2 degenerates to the single edge at max (the old
        // `max * 0/0` formulation produced a NaN edge).
        let edges: Vec<f64> = if n_edges < 2 {
            vec![max; n_edges]
        } else {
            (0..n_edges).map(|i| max * i as f64 / (n_edges - 1) as f64).collect()
        };
        if self.short_delays.is_exact() {
            let s = self.short_delays.samples().expect("exact backend has samples"); // lint: allow(panic-surface): guarded by is_exact() one line up
            Cdf::from_samples_at(s, edges)
        } else {
            let n = self.short_delays.len();
            let values = edges.iter().map(|&e| self.short_delays.cdf_at(e)).collect();
            Cdf { edges, values, n_samples: n }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_delays_by_class() {
        let mut r = Recorder::new(3.0);
        r.task_started(false, 10.0);
        r.task_started(false, 30.0);
        r.task_started(true, 100.0);
        assert_eq!(r.short_delays.len(), 2);
        assert_eq!(r.long_delays.len(), 1);
        assert!((r.short_delays.mean() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn cdf_export_from_both_backends() {
        for exact in [true, false] {
            let mut r = Recorder::with_backend(1.0, exact);
            for i in 0..100 {
                r.task_started(false, i as f64);
            }
            let cdf = r.short_delay_cdf(11);
            assert_eq!(cdf.edges.len(), 11);
            assert_eq!(cdf.n_samples, 100);
            assert!(cdf.values.windows(2).all(|w| w[0] <= w[1]), "CDF not monotone");
            assert!(
                (cdf.values.last().unwrap() - 1.0).abs() < 1e-12,
                "CDF must reach 1.0 (exact={exact})"
            );
        }
    }

    #[test]
    fn snapshots_accumulate() {
        let mut r = Recorder::new(2.0);
        r.snapshot(0.0, 0.5, 3.0);
        r.snapshot(60.0, 0.9, 10.0);
        assert_eq!(r.lr_series.len(), 2);
        assert_eq!(r.transient_series.len(), 2);
    }

    #[test]
    fn snapshot_series_bounded_by_default_unbounded_in_exact_mode() {
        // Default: the ring caps retained points, so bytes stay bounded
        // no matter how many snapshots the horizon produces.
        let mut r = Recorder::new(1.0);
        for i in 0..20_000 {
            r.snapshot(i as f64 * 60.0, 0.5, 1.0);
        }
        assert!(r.lr_series.len() <= crate::metrics::DEFAULT_SNAPSHOT_POINTS);
        assert!(
            r.snapshot_series_bytes()
                <= 2 * (crate::metrics::DEFAULT_SNAPSHOT_POINTS * 16 + 128)
        );
        // Exact reference mode keeps every point.
        let mut rx = Recorder::new_exact(1.0);
        for i in 0..20_000 {
            rx.snapshot(i as f64 * 60.0, 0.5, 1.0);
        }
        assert_eq!(rx.lr_series.len(), 20_000);
        assert_eq!(rx.transient_series.len(), 20_000);
        // Both series decimate in lockstep (same offer counts), so
        // parallel indexing stays valid for plots.
        assert_eq!(r.lr_series.len(), r.transient_series.len());
    }

    #[test]
    fn absorb_merges_populations_and_counters() {
        let mut a = Recorder::new(3.0);
        let mut b = Recorder::new(3.0);
        a.task_started(false, 10.0);
        a.task_started(true, 50.0);
        a.tasks_finished = 2;
        a.transients_requested = 1;
        b.task_started(false, 30.0);
        b.tasks_finished = 1;
        b.transients_revoked = 4;
        a.absorb(&b);
        assert_eq!(a.short_delays.len(), 2);
        assert_eq!(a.long_delays.len(), 1);
        assert!((a.short_delays.mean() - 20.0).abs() < 1e-12);
        assert_eq!(a.tasks_finished, 3);
        assert_eq!(a.transients_requested, 1);
        assert_eq!(a.transients_revoked, 4);
    }

    #[test]
    fn default_backend_is_fixed_memory() {
        let mut r = Recorder::new(1.0);
        let before = r.delay_struct_bytes();
        for i in 0..10_000 {
            r.task_started(i % 7 == 0, (i % 313) as f64);
        }
        assert_eq!(r.delay_struct_bytes(), before, "sketch recorder memory grew");
        let mut rx = Recorder::new_exact(1.0);
        let b0 = rx.delay_struct_bytes();
        for i in 0..1000 {
            rx.task_started(false, i as f64);
        }
        assert!(rx.delay_struct_bytes() > b0);
    }
}
