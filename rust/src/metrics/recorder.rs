//! The simulation-wide metrics recorder: every number in the paper's
//! evaluation (Figure 3, Table 1, headline ratios) is derived from what
//! this collects.
//!
//! Arena contract: the recorder holds **values, never `TaskRef`s**.
//! Per-task queueing delays are pushed at task start
//! ([`Recorder::task_started`]) and per-job responses at the last task's
//! finish ([`Recorder::job_finished`]), with every field extracted at
//! the state transition — so recycling a finished task's arena slot can
//! never invalidate a recorded sample, and nothing here reads back
//! through the task arena.

use crate::metrics::{Cdf, CostLedger, DelaySamples, StreamingStats, TimeSeries};
use crate::util::Time;

/// Collects per-task delays, cluster time series and transient cost
/// accounting for one simulation run.
#[derive(Clone, Debug)]
pub struct Recorder {
    /// Queueing delay of every *short* task (Figure 3's variable).
    pub short_delays: DelaySamples,
    /// Queueing delay of every long task ("maintains long job
    /// performance", §Abstract).
    pub long_delays: DelaySamples,
    /// Per-job makespan-style stats (arrival -> last task finish).
    pub short_job_response: StreamingStats,
    pub long_job_response: StreamingStats,
    /// Sampled long-load ratio trajectory.
    pub lr_series: TimeSeries,
    /// Sampled active transient count (for plots; exact average comes from
    /// the cost ledger's integrator).
    pub transient_series: TimeSeries,
    /// Transient cost accounting (Table 1).
    pub cost: CostLedger,
    /// Tasks that finished.
    pub tasks_finished: u64,
    /// Tasks rescheduled due to revocation (should stay 0 with §3.3
    /// duplicate copies enabled).
    pub tasks_rescheduled: u64,
    /// Stale duplicate-copy queue entries skipped at dequeue.
    pub stale_copies_skipped: u64,
    /// Transient servers ever requested / revoked.
    pub transients_requested: u64,
    pub transients_revoked: u64,
}

impl Recorder {
    pub fn new(r: f64) -> Self {
        Recorder {
            short_delays: DelaySamples::new(),
            long_delays: DelaySamples::new(),
            short_job_response: StreamingStats::new(),
            long_job_response: StreamingStats::new(),
            lr_series: TimeSeries::new(),
            transient_series: TimeSeries::new(),
            cost: CostLedger::new(r),
            tasks_finished: 0,
            tasks_rescheduled: 0,
            stale_copies_skipped: 0,
            transients_requested: 0,
            transients_revoked: 0,
        }
    }

    /// Record a task start (the moment queueing delay becomes known).
    #[inline]
    pub fn task_started(&mut self, is_long: bool, delay: f64) {
        debug_assert!(delay >= 0.0, "negative queueing delay {delay}");
        if is_long {
            self.long_delays.push(delay);
        } else {
            self.short_delays.push(delay);
        }
    }

    pub fn job_finished(&mut self, is_long: bool, response: f64) {
        if is_long {
            self.long_job_response.push(response);
        } else {
            self.short_job_response.push(response);
        }
    }

    pub fn snapshot(&mut self, t: Time, l_r: f64, active_transients: f64) {
        self.lr_series.push(t, l_r);
        self.transient_series.push(t, active_transients);
    }

    /// Figure 3: CDF of short-task queueing delay.
    pub fn short_delay_cdf(&self, n_edges: usize) -> Cdf {
        Cdf::from_samples(self.short_delays.as_slice(), n_edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_delays_by_class() {
        let mut r = Recorder::new(3.0);
        r.task_started(false, 10.0);
        r.task_started(false, 30.0);
        r.task_started(true, 100.0);
        assert_eq!(r.short_delays.len(), 2);
        assert_eq!(r.long_delays.len(), 1);
        assert!((r.short_delays.mean() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn cdf_export() {
        let mut r = Recorder::new(1.0);
        for i in 0..100 {
            r.task_started(false, i as f64);
        }
        let cdf = r.short_delay_cdf(11);
        assert_eq!(cdf.edges.len(), 11);
        assert!((cdf.values.last().unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn snapshots_accumulate() {
        let mut r = Recorder::new(2.0);
        r.snapshot(0.0, 0.5, 3.0);
        r.snapshot(60.0, 0.9, 10.0);
        assert_eq!(r.lr_series.len(), 2);
        assert_eq!(r.transient_series.len(), 2);
    }
}
