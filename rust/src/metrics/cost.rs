//! Cost accounting for the short-only partition (paper §4.2, Table 1).
//!
//! On-demand servers cost 1 unit/hour; transient servers cost `1/r`
//! units/hour where `r = c_static / c_trans` (§3.1). The paper's headline
//! cost metric is the *r-normalized average on-demand equivalent*: the
//! time-weighted average number of active transient servers divided by r,
//! compared against the `N_s * p` on-demand servers the static baseline
//! dedicates to the same role.
//!
//! All time-averaged quantities are measured **from t = 0** (simulation
//! start) to the caller-supplied `end` — there is no configurable
//! measurement-window start. (An earlier revision carried a dead
//! `start` field that was initialized to 0.0 and never written; it has
//! been removed rather than wired, since every caller and every Table 1
//! number wants whole-run averages.)

use crate::metrics::{DelayDist, StepIntegrator};
use crate::util::Time;

/// Ledger of transient-server usage + derived cost numbers.
#[derive(Clone, Debug)]
pub struct CostLedger {
    /// Cost ratio r = c_static / c_trans.
    pub r: f64,
    /// Active transient count as an exact step function of time.
    active: StepIntegrator,
    /// Completed transient lifetimes (active -> retired), seconds.
    /// Streams through the fixed-memory histogram by default (one
    /// sample per retired transient used to make this O(trace));
    /// `CostLedger::new_exact` keeps the reference Vec backend.
    pub lifetimes: DelayDist,
}

impl CostLedger {
    /// Ledger with the default fixed-memory lifetime sketch.
    pub fn new(r: f64) -> Self {
        Self::with_backend(r, false)
    }

    /// Ledger with the exact-Vec lifetime backend (golden comparisons).
    pub fn new_exact(r: f64) -> Self {
        Self::with_backend(r, true)
    }

    pub fn with_backend(r: f64, exact_samples: bool) -> Self {
        CostLedger {
            r,
            active: StepIntegrator::new(0.0, 0.0),
            lifetimes: DelayDist::new(exact_samples),
        }
    }

    /// A transient server became active at `t`.
    pub fn transient_up(&mut self, t: Time) {
        self.active.add(t, 1.0);
    }

    /// A transient server retired at `t` after `lifetime` seconds active.
    pub fn transient_down(&mut self, t: Time, lifetime: f64) {
        self.active.add(t, -1.0);
        self.lifetimes.push(lifetime);
    }

    pub fn active_now(&self) -> f64 {
        self.active.value()
    }

    pub fn max_active(&self) -> f64 {
        self.active.max()
    }

    /// Time-weighted average active transient count over `[0, end]`
    /// (Table 1 "Average transient"); averages always start at t = 0.
    pub fn avg_active(&self, end: Time) -> f64 {
        self.active.mean_to(0.0, end)
    }

    /// Table 1 "r-normalized avg. on-demand": average transients / r.
    pub fn r_normalized_avg(&self, end: Time) -> f64 {
        self.avg_active(end) / self.r
    }

    /// Transient server-hours consumed up to `end`.
    pub fn transient_hours(&self, end: Time) -> f64 {
        self.active.integral_to(end) / 3600.0
    }

    /// Cost (in on-demand-server-hour units) of the dynamic partition.
    pub fn transient_cost(&self, end: Time) -> f64 {
        self.transient_hours(end) / self.r
    }

    /// Mean / max lifetime of retired transient servers, hours (Table 1
    /// "Active time"). Servers still active at `end` are not included —
    /// callers should retire them at simulation end first. Exact on
    /// both lifetime backends (mean and max are exact in the sketch).
    pub fn mean_lifetime_hours(&self) -> f64 {
        self.lifetimes.mean() / 3600.0
    }

    pub fn max_lifetime_hours(&self) -> f64 {
        self.lifetimes.max() / 3600.0
    }

    /// Cost saving vs. a static baseline that keeps `baseline_servers`
    /// on-demand servers running for the whole interval: the paper's
    /// "29.5% reduction in short partition budget".
    pub fn saving_vs_static(&self, baseline_servers: f64, end: Time) -> f64 {
        if baseline_servers <= 0.0 {
            return 0.0;
        }
        (baseline_servers - self.r_normalized_avg(end)) / baseline_servers
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_accounting() {
        let mut c = CostLedger::new(3.0);
        c.transient_up(0.0);
        c.transient_up(0.0);
        c.transient_down(3600.0, 3600.0);
        // One server for the second hour.
        assert!((c.avg_active(7200.0) - 1.5).abs() < 1e-12);
        assert!((c.r_normalized_avg(7200.0) - 0.5).abs() < 1e-12);
        assert!((c.transient_hours(7200.0) - 3.0).abs() < 1e-12);
        assert!((c.transient_cost(7200.0) - 1.0).abs() < 1e-12);
        assert_eq!(c.max_active(), 2.0);
        assert!((c.mean_lifetime_hours() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn paper_scenario_saving() {
        // r=3, avg 84.5 transients -> 28.2 normalized vs 40 baseline
        // => 29.5% saving (Table 1).
        let mut c2 = CostLedger::new(3.0);
        for _ in 0..845 {
            c2.transient_up(0.0);
        }
        for _ in 0..845 {
            c2.transient_down(36_000.0, 36_000.0);
        }
        let avg = c2.avg_active(36_000.0 * 10.0);
        assert!((avg - 84.5).abs() < 1e-9, "avg={avg}");
        let saving = c2.saving_vs_static(40.0, 36_000.0 * 10.0);
        assert!((saving - (40.0 - 84.5 / 3.0) / 40.0).abs() < 1e-9);
    }

    #[test]
    fn zero_usage_full_saving() {
        let c = CostLedger::new(2.0);
        assert_eq!(c.saving_vs_static(40.0, 1000.0), 1.0);
        assert_eq!(c.mean_lifetime_hours(), 0.0);
        assert_eq!(c.max_lifetime_hours(), 0.0);
    }

    #[test]
    fn lifetime_backends_agree_on_exact_fields() {
        let mut sketch = CostLedger::new(3.0);
        let mut exact = CostLedger::new_exact(3.0);
        for (i, life) in [120.0, 3600.0, 777.5, 0.0, 46_000.0].iter().enumerate() {
            let t = i as f64 * 10.0;
            sketch.transient_up(t);
            exact.transient_up(t);
            sketch.transient_down(t + 50.0, *life);
            exact.transient_down(t + 50.0, *life);
        }
        assert_eq!(sketch.lifetimes.len(), exact.lifetimes.len());
        assert_eq!(
            sketch.mean_lifetime_hours().to_bits(),
            exact.mean_lifetime_hours().to_bits()
        );
        assert_eq!(
            sketch.max_lifetime_hours().to_bits(),
            exact.max_lifetime_hours().to_bits()
        );
        assert!(exact.lifetimes.samples().is_some());
        assert!(sketch.lifetimes.samples().is_none());
    }
}
