//! Streaming delay distributions: a fixed-width log-bucketed quantile
//! histogram ([`DelayHistogram`]) and the backend-selecting
//! [`DelayDist`] the recorder and cost ledger store their per-task /
//! per-transient populations in.
//!
//! ## Why
//!
//! The paper's headline numbers (Figure 3's short-delay CDF, Table 1's
//! transient lifetimes) were computed from unbounded `Vec<f64>`s — one
//! push per task / per retired transient — so whole-run memory scaled
//! with trace length even after jobs and tasks became O(active). The
//! histogram makes every per-run delay structure **load-independent and
//! trace-independent**: a fixed array of [`N_BUCKETS`] counters
//! (~9 KiB) regardless of how many samples stream through.
//!
//! ## Bucket scheme and error bound
//!
//! Geometric buckets with ratio [`GAMMA`] = 1.02 spanning
//! [`MIN_TRACKED`] = 1 ms to [`MAX_TRACKED`] = 10^7 s: bucket `i`
//! covers `[MIN·γ^i, MIN·γ^(i+1))` and reports its midpoint
//! `MIN·γ^i·(1+γ)/2`, clamped into the exact observed `[min, max]`.
//! For any sample `v` inside a bucket the reported value `rep`
//! satisfies `rep/v ∈ [(1+γ)/(2γ), (1+γ)/2]`, i.e. **relative quantile
//! error ≤ (γ−1)/2 = 1%** (double-sided). Samples below 1 ms (queueing
//! delays of exactly 0.0 dominate here) collapse into a dedicated
//! low bucket reported as the exact observed minimum — absolute error
//! < 1 ms. Samples above 10^7 s (~115 days — beyond any simulated
//! delay or transient lifetime) clamp into the top bucket; the exact
//! max is tracked separately, so `percentile(1.0)` is always exact.
//!
//! `count`, `sum` (and therefore `mean`), `min` and `max` are **exact**
//! and — because `sum` accumulates in push order exactly like summing
//! the equivalent `Vec` — bit-identical to the exact-Vec backend.
//! Quantiles (`percentile`, `cdf_at`) are the only approximate fields,
//! within the bound above. Both backends share the crate-wide
//! ceil-based nearest-rank quantile convention
//! ([`crate::util::nearest_rank_index`]).
//!
//! Histograms with identical bucketing are mergeable
//! ([`DelayHistogram::merge`]) for cross-run aggregation.

use crate::metrics::stats::DelaySamples;

/// Geometric bucket ratio: 2% wide buckets, ≤1% quantile error.
pub const GAMMA: f64 = 1.02;
/// Lower edge of bucket 0; smaller samples land in the low bucket.
pub const MIN_TRACKED: f64 = 1e-3;
/// Upper range of the bucket array; larger samples clamp to the top.
pub const MAX_TRACKED: f64 = 1e7;
/// Bucket count: `ceil(ln(MAX/MIN)/ln(GAMMA))` = 1163, +1 slack.
pub const N_BUCKETS: usize = 1164;

/// Fixed-memory streaming quantile histogram (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct DelayHistogram {
    count: u64,
    /// Running sum in push order — mean is exact and bit-identical to
    /// summing the equivalent Vec.
    sum: f64,
    min: f64,
    max: f64,
    /// Samples below [`MIN_TRACKED`] (typically exact-zero delays).
    low: u64,
    buckets: Vec<u64>,
}

impl Default for DelayHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl DelayHistogram {
    pub fn new() -> Self {
        DelayHistogram {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            low: 0,
            buckets: vec![0u64; N_BUCKETS],
        }
    }

    #[inline]
    fn bucket_index(x: f64) -> usize {
        debug_assert!(x >= MIN_TRACKED);
        let i = ((x / MIN_TRACKED).ln() / GAMMA.ln()).floor();
        (i.max(0.0) as usize).min(N_BUCKETS - 1)
    }

    /// Midpoint representative of bucket `i` (pre-clamping).
    #[inline]
    fn bucket_rep(i: usize) -> f64 {
        MIN_TRACKED * GAMMA.powi(i as i32) * (1.0 + GAMMA) / 2.0
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        debug_assert!(x.is_finite(), "non-finite delay sample {x}");
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if x < MIN_TRACKED {
            self.low += 1;
        } else {
            self.buckets[Self::bucket_index(x)] += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact mean (0.0 when empty; never NaN).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact maximum (0.0 when empty, matching the Vec backend).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Exact minimum (0.0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Approximate quantile, ceil-based nearest-rank convention:
    /// the bucket holding rank `clamp(ceil(q·n), 1, n)` reports its
    /// midpoint clamped into the exact `[min, max]`. Relative error
    /// ≤ 1% (see module docs); 0.0 when empty.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        // Same rank as the exact backend: the crate-wide ceil-based
        // convention, via the shared helper so the two can never drift.
        let rank = crate::util::nearest_rank_index(self.count as usize, q) as u64 + 1;
        // The extreme ranks are tracked exactly — this also covers
        // samples clamped into the top bucket from beyond MAX_TRACKED.
        if rank >= self.count {
            return self.max;
        }
        if rank == 1 {
            return self.min;
        }
        let mut cum = self.low;
        if rank <= cum {
            // Low bucket: every sample here is < 1 ms; the exact min is
            // within 1 ms of any quantile that lands here.
            return self.min;
        }
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if rank <= cum {
                return Self::bucket_rep(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Approximate empirical CDF at `x`: the fraction of samples in
    /// buckets whose (clamped) representative is ≤ `x`. Monotone in
    /// `x`, exactly 0.0 below the observed minimum's bucket, exactly
    /// 1.0 at and above the observed maximum; 0.0 when empty.
    pub fn cdf_at(&self, x: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let mut acc = if x >= self.min { self.low } else { 0 };
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if Self::bucket_rep(i).clamp(self.min, self.max) <= x {
                acc += c;
            }
        }
        acc as f64 / self.count as f64
    }

    /// Merge another histogram into this one (same fixed bucketing, so
    /// it is exact bucket-wise addition; min/max/sum/count stay exact).
    pub fn merge(&mut self, other: &DelayHistogram) {
        debug_assert_eq!(self.buckets.len(), other.buckets.len());
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.low += other.low;
        for (a, &b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
    }

    /// Resident size — fixed at construction, independent of sample
    /// count (the CI memory smoke pins this flat under trace scaling).
    pub fn memory_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + self.buckets.len() * std::mem::size_of::<u64>()
    }
}

/// A delay population behind one of two backends: the fixed-memory
/// [`DelayHistogram`] sketch (the default) or the exact append-only
/// [`DelaySamples`] Vec, kept alive purely for golden comparisons
/// (`SimConfig::exact_delay_samples`) — mirroring the task arena's
/// `recycle_task_slots` pattern. `count`/`mean`/`min`/`max` are
/// bit-identical across backends; quantiles differ only within the
/// histogram's documented ≤1% bound.
#[derive(Clone, Debug, PartialEq)]
pub enum DelayDist {
    Exact(DelaySamples),
    Sketch(DelayHistogram),
}

impl DelayDist {
    /// The default fixed-memory backend.
    pub fn sketch() -> Self {
        DelayDist::Sketch(DelayHistogram::new())
    }

    /// The exact-Vec reference backend (memory grows with the run).
    pub fn exact() -> Self {
        DelayDist::Exact(DelaySamples::new())
    }

    pub fn new(exact: bool) -> Self {
        if exact {
            Self::exact()
        } else {
            Self::sketch()
        }
    }

    pub fn is_exact(&self) -> bool {
        matches!(self, DelayDist::Exact(_))
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        match self {
            DelayDist::Exact(s) => s.push(x),
            DelayDist::Sketch(h) => h.push(x),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            DelayDist::Exact(s) => s.len(),
            DelayDist::Sketch(h) => h.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn mean(&self) -> f64 {
        match self {
            DelayDist::Exact(s) => s.mean(),
            DelayDist::Sketch(h) => h.mean(),
        }
    }

    pub fn max(&self) -> f64 {
        match self {
            DelayDist::Exact(s) => s.max(),
            DelayDist::Sketch(h) => h.max(),
        }
    }

    pub fn min(&self) -> f64 {
        match self {
            DelayDist::Exact(s) => s.min(),
            DelayDist::Sketch(h) => h.min(),
        }
    }

    /// Quantile under the shared ceil-based nearest-rank convention:
    /// exact on the Vec backend, within the documented ≤1% relative
    /// bound on the sketch. (`&mut` because the exact backend sorts
    /// lazily.)
    pub fn percentile(&mut self, q: f64) -> f64 {
        match self {
            DelayDist::Exact(s) => s.percentile(q),
            DelayDist::Sketch(h) => h.percentile(q),
        }
    }

    /// Empirical CDF value at `x` (exact / bucket-approximate).
    pub fn cdf_at(&mut self, x: f64) -> f64 {
        match self {
            DelayDist::Exact(s) => s.cdf_at(x),
            DelayDist::Sketch(h) => h.cdf_at(x),
        }
    }

    /// Merge another population into this one (cross-run aggregation,
    /// e.g. a federation's per-cluster delay populations into one
    /// federated distribution). Bucket-wise exact on the sketch backend
    /// (identical fixed bucketing by construction), sample concatenation
    /// on the exact backend. Both sides must use the same backend — a
    /// mismatch is a wiring bug (one `SimConfig` builds every member),
    /// and panics rather than silently degrading.
    pub fn merge_from(&mut self, other: &DelayDist) {
        match (self, other) {
            (DelayDist::Sketch(a), DelayDist::Sketch(b)) => a.merge(b),
            (DelayDist::Exact(a), DelayDist::Exact(b)) => a.merge_from(b),
            _ => panic!("DelayDist::merge_from across mismatched backends"), // lint: allow(panic-surface): documented policy -- mismatched backends are a wiring bug, not data
        }
    }

    /// Raw samples, only available on the exact backend.
    pub fn samples(&self) -> Option<&[f64]> {
        match self {
            DelayDist::Exact(s) => Some(s.as_slice()),
            DelayDist::Sketch(_) => None,
        }
    }

    /// Resident size of the backing structure: fixed for the sketch,
    /// O(samples) — counted at Vec *capacity*, the truly resident
    /// allocation — for the exact backend.
    pub fn memory_bytes(&self) -> usize {
        match self {
            DelayDist::Exact(s) => std::mem::size_of::<Self>() + s.memory_bytes(),
            DelayDist::Sketch(h) => h.memory_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Rng;

    #[test]
    fn exact_fields_match_vec_backend_bitwise() {
        let mut exact = DelayDist::exact();
        let mut sketch = DelayDist::sketch();
        let mut rng = Rng::new(42);
        for _ in 0..5000 {
            // Mix of exact zeros (idle-start tasks), sub-ms noise and
            // lognormal-ish delays.
            let x = match rng.below(4) {
                0 => 0.0,
                1 => rng.f64() * 5e-4,
                _ => rng.f64() * rng.f64() * 3000.0,
            };
            exact.push(x);
            sketch.push(x);
        }
        assert_eq!(exact.len(), sketch.len());
        assert_eq!(exact.mean().to_bits(), sketch.mean().to_bits(), "mean not bit-identical");
        assert_eq!(exact.max().to_bits(), sketch.max().to_bits());
        assert_eq!(exact.min().to_bits(), sketch.min().to_bits());
    }

    #[test]
    fn quantile_error_within_documented_bound() {
        let mut exact = DelayDist::exact();
        let mut sketch = DelayDist::sketch();
        let mut rng = Rng::new(7);
        for _ in 0..20_000 {
            let x = (rng.f64() * 8.0).exp(); // ~[1, 3000] s, log-uniform
            exact.push(x);
            sketch.push(x);
        }
        for q in [0.0, 0.01, 0.1, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let e = exact.percentile(q);
            let s = sketch.percentile(q);
            let rel = (s - e).abs() / e.max(MIN_TRACKED);
            // Documented bound (γ-1)/2 = 1%, plus fp slack for samples
            // landing exactly on bucket edges.
            assert!(rel <= 0.0105, "q={q}: exact {e} vs sketch {s} (rel {rel})");
        }
        // Extremes are exact.
        assert_eq!(exact.percentile(0.0), sketch.percentile(0.0));
        assert_eq!(exact.percentile(1.0), sketch.percentile(1.0));
    }

    #[test]
    fn zero_dominated_population() {
        // The common Figure-3 regime: most short tasks start instantly.
        let mut h = DelayHistogram::new();
        for _ in 0..900 {
            h.push(0.0);
        }
        for i in 1..=100 {
            h.push(i as f64);
        }
        assert_eq!(h.percentile(0.5), 0.0); // rank 500 of 1000 -> low bucket
        assert_eq!(h.percentile(0.9), 0.0); // rank 900 -> still low
        let p99 = h.percentile(0.99); // rank 990 -> ~90 s
        assert!((p99 - 90.0).abs() / 90.0 < 0.011, "p99={p99}");
        assert_eq!(h.percentile(1.0), 100.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 100.0);
    }

    #[test]
    fn empty_histogram_is_all_zeros() {
        let h = DelayHistogram::new();
        assert_eq!(h.len(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.percentile(0.5), 0.0);
        assert_eq!(h.cdf_at(10.0), 0.0);
        assert!(h.mean().is_finite() && h.percentile(0.99).is_finite());
    }

    #[test]
    fn cdf_monotone_and_complete() {
        let mut h = DelayHistogram::new();
        for i in 0..1000 {
            h.push(i as f64 * 0.7 + 0.5);
        }
        let mut prev = -1.0;
        for k in 0..50 {
            let x = k as f64 * 16.0;
            let v = h.cdf_at(x);
            assert!(v >= prev, "CDF not monotone at {x}");
            assert!((0.0..=1.0).contains(&v));
            prev = v;
        }
        assert_eq!(h.cdf_at(h.max()), 1.0, "CDF must reach 1.0 at the observed max");
        assert_eq!(h.cdf_at(-1.0), 0.0);
    }

    #[test]
    fn memory_is_fixed_regardless_of_samples() {
        let mut h = DelayHistogram::new();
        let before = h.memory_bytes();
        for i in 0..100_000 {
            h.push((i % 977) as f64);
        }
        assert_eq!(h.memory_bytes(), before, "sketch memory grew with samples");
        let mut exact = DelayDist::exact();
        let b0 = exact.memory_bytes();
        for i in 0..1000 {
            exact.push(i as f64);
        }
        assert!(exact.memory_bytes() > b0, "exact backend should grow (reference mode)");
    }

    #[test]
    fn merge_is_bucketwise_exact() {
        let mut a = DelayHistogram::new();
        let mut b = DelayHistogram::new();
        let mut all = DelayHistogram::new();
        let mut rng = Rng::new(3);
        for i in 0..4000 {
            let x = rng.f64() * 500.0;
            if i % 2 == 0 {
                a.push(x);
            } else {
                b.push(x);
            }
            all.push(x);
        }
        a.merge(&b);
        assert_eq!(a.len(), all.len());
        assert_eq!(a.min(), all.min());
        assert_eq!(a.max(), all.max());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.percentile(q), all.percentile(q), "merged quantile diverged at {q}");
        }
    }

    #[test]
    fn out_of_range_samples_clamp_without_panicking() {
        let mut h = DelayHistogram::new();
        h.push(MAX_TRACKED * 100.0);
        h.push(MIN_TRACKED / 2.0);
        assert_eq!(h.len(), 2);
        assert_eq!(h.max(), MAX_TRACKED * 100.0); // exact max survives
        assert_eq!(h.percentile(1.0), MAX_TRACKED * 100.0);
        assert_eq!(h.percentile(0.0), MIN_TRACKED / 2.0);
    }
}
