//! Streaming and sample-based statistics.

/// Constant-memory running statistics (Welford).
#[derive(Clone, Debug, Default)]
pub struct StreamingStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl StreamingStats {
    pub fn new() -> Self {
        StreamingStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Full-sample container for metrics we need exact percentiles/CDFs of
/// (short-task queueing delays: one f64 per task, fine at trace scale).
#[derive(Clone, Debug, Default)]
pub struct DelaySamples {
    samples: Vec<f64>,
    sorted: bool,
}

impl DelaySamples {
    pub fn new() -> Self {
        DelaySamples { samples: Vec::new(), sorted: true }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.samples
    }

    pub fn mean(&self) -> f64 {
        crate::util::mean(&self.samples)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(0.0, f64::max)
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(|a, b| a.total_cmp(b));
            self.sorted = true;
        }
    }

    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let pos = (q.clamp(0.0, 1.0) * (self.samples.len() - 1) as f64).round() as usize;
        self.samples[pos]
    }

    /// Empirical CDF value at `x` (fraction of samples <= x).
    pub fn cdf_at(&mut self, x: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let idx = self.samples.partition_point(|&s| s <= x);
        idx as f64 / self.samples.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streaming_matches_closed_form() {
        let mut s = StreamingStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std_dev() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn empty_streaming_is_zeroes() {
        let s = StreamingStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.variance(), 0.0);
    }

    #[test]
    fn delay_samples_percentiles() {
        let mut d = DelaySamples::new();
        for i in (0..=100).rev() {
            d.push(i as f64);
        }
        assert_eq!(d.percentile(0.5), 50.0);
        assert_eq!(d.percentile(1.0), 100.0);
        assert_eq!(d.percentile(0.0), 0.0);
        assert_eq!(d.max(), 100.0);
        assert!((d.mean() - 50.0).abs() < 1e-12);
    }

    #[test]
    fn cdf_at_boundaries() {
        let mut d = DelaySamples::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            d.push(x);
        }
        assert_eq!(d.cdf_at(0.5), 0.0);
        assert_eq!(d.cdf_at(2.0), 0.5);
        assert_eq!(d.cdf_at(10.0), 1.0);
    }
}
