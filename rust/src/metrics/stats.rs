//! Streaming and sample-based statistics.

/// Constant-memory running statistics (Welford).
#[derive(Clone, Debug, Default)]
pub struct StreamingStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl StreamingStats {
    pub fn new() -> Self {
        StreamingStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    /// Merge another population in (Chan's parallel Welford update) —
    /// count/mean/min/max exact; variance exact up to fp reassociation.
    pub fn merge_from(&mut self, other: &StreamingStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        self.mean += delta * other.n as f64 / n as f64;
        self.m2 += other.m2 + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Full-sample container for metrics we need exact percentiles/CDFs of.
///
/// This is the **exact reference backend** behind
/// [`crate::metrics::DelayDist`]: one f64 per sample, so memory grows
/// with the run. The default simulation path uses the fixed-memory
/// [`crate::metrics::DelayHistogram`] sketch instead; this Vec path is
/// kept alive purely for golden comparisons
/// (`SimConfig::exact_delay_samples`). `mean` accumulates a running sum
/// in push order so it is bit-identical to the sketch backend's.
#[derive(Clone, Debug, Default)]
pub struct DelaySamples {
    samples: Vec<f64>,
    /// Running sum in push order (exact mean, sort-state independent).
    sum: f64,
    sorted: bool,
}

impl PartialEq for DelaySamples {
    /// Sample-*sequence* equality, excluding the sort flag itself. Note
    /// that quantile queries (`percentile`/`cdf_at`) sort `samples` in
    /// place, so equality IS sensitive to sort state: golden
    /// comparisons must compare distributions *before* querying
    /// quantiles on either side (all in-tree goldens do).
    fn eq(&self, other: &Self) -> bool {
        self.samples == other.samples
    }
}

impl DelaySamples {
    pub fn new() -> Self {
        DelaySamples { samples: Vec::new(), sum: 0.0, sorted: true }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        self.sum += x;
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.samples
    }

    /// Bytes of the backing allocation (Vec capacity, not just length —
    /// growth-doubling means the resident block can be ~2x the samples).
    pub fn memory_bytes(&self) -> usize {
        self.samples.capacity() * std::mem::size_of::<f64>()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.sum / self.samples.len() as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(0.0, f64::max)
    }

    /// Exact minimum (0.0 when empty, mirroring [`DelaySamples::max`]).
    pub fn min(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.samples.iter().copied().fold(f64::INFINITY, f64::min)
        }
    }

    /// Concatenate another sample set (cross-run aggregation on the
    /// exact backend). Order is self-then-other, so the merged running
    /// sum matches pushing the concatenated sequence.
    pub fn merge_from(&mut self, other: &DelaySamples) {
        if other.samples.is_empty() {
            return;
        }
        self.samples.extend_from_slice(&other.samples);
        self.sum += other.sum;
        self.sorted = false;
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(|a, b| a.total_cmp(b));
            self.sorted = true;
        }
    }

    /// Exact quantile under the crate-wide ceil-based nearest-rank
    /// convention ([`crate::util::nearest_rank_index`]): q = 0 is the
    /// minimum, q = 1 the maximum, and half-ranks are *defined* (n = 2,
    /// q = 0.5 is the lower sample) — no `.round()` half-away
    /// ambiguity. The histogram backend uses the identical convention.
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        self.samples[crate::util::nearest_rank_index(self.samples.len(), q)]
    }

    /// Empirical CDF value at `x` (fraction of samples <= x).
    pub fn cdf_at(&mut self, x: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let idx = self.samples.partition_point(|&s| s <= x);
        idx as f64 / self.samples.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streaming_matches_closed_form() {
        let mut s = StreamingStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std_dev() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn empty_streaming_is_zeroes() {
        let s = StreamingStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.variance(), 0.0);
    }

    #[test]
    fn delay_samples_percentiles() {
        let mut d = DelaySamples::new();
        for i in (0..=100).rev() {
            d.push(i as f64);
        }
        assert_eq!(d.percentile(0.5), 50.0);
        assert_eq!(d.percentile(1.0), 100.0);
        assert_eq!(d.percentile(0.0), 0.0);
        assert_eq!(d.max(), 100.0);
        assert_eq!(d.min(), 0.0);
        assert!((d.mean() - 50.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_convention_is_ceil_nearest_rank() {
        // n = 2, q = 0.5: rank ceil(1.0) = 1 -> the LOWER sample. The
        // old `(q*(n-1)).round()` hit an exact .5 here and depended on
        // platform round-half-away behaviour.
        let mut d = DelaySamples::new();
        d.push(10.0);
        d.push(20.0);
        assert_eq!(d.percentile(0.5), 10.0);
        // n = 10, q = 0.99: rank ceil(9.9) = 10 -> the maximum.
        let mut d10 = DelaySamples::new();
        for i in 1..=10 {
            d10.push(i as f64);
        }
        assert_eq!(d10.percentile(0.99), 10.0);
        // n = 10, q = 0.9: rank ceil(9.0) = 9 -> the 9th sample, not max.
        assert_eq!(d10.percentile(0.9), 9.0);
    }

    #[test]
    fn mean_is_push_order_sum_even_after_sorting() {
        // The running sum makes mean independent of percentile()'s lazy
        // sort — and bit-identical to the histogram backend's.
        let xs = [5.0, 1.0, 3.5, 0.25, 9.0];
        let mut d = DelaySamples::new();
        for &x in &xs {
            d.push(x);
        }
        let before = d.mean();
        d.percentile(0.5); // sorts internally
        assert_eq!(before.to_bits(), d.mean().to_bits());
        let seq_sum: f64 = xs.iter().sum();
        assert_eq!(before.to_bits(), (seq_sum / 5.0).to_bits());
    }

    #[test]
    fn cdf_at_boundaries() {
        let mut d = DelaySamples::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            d.push(x);
        }
        assert_eq!(d.cdf_at(0.5), 0.0);
        assert_eq!(d.cdf_at(2.0), 0.5);
        assert_eq!(d.cdf_at(10.0), 1.0);
    }
}
