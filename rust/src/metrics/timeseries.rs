//! Time-series recording: sampled series (for plots) and exact
//! step-function integration (for time-weighted averages like the paper's
//! "average number of active transient servers").

use crate::util::Time;

/// A sampled time series (snapshot points for plotting / reports).
#[derive(Clone, Debug, Default)]
pub struct TimeSeries {
    pub points: Vec<(Time, f64)>,
}

impl TimeSeries {
    pub fn new() -> Self {
        TimeSeries { points: Vec::new() }
    }

    pub fn push(&mut self, t: Time, v: f64) {
        debug_assert!(self.points.last().map_or(true, |&(pt, _)| t >= pt));
        self.points.push((t, v));
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn mean(&self) -> f64 {
        crate::util::mean(&self.points.iter().map(|&(_, v)| v).collect::<Vec<_>>())
    }

    pub fn max(&self) -> f64 {
        self.points.iter().map(|&(_, v)| v).fold(f64::NEG_INFINITY, f64::max)
    }

    /// Rebucket into fixed windows by averaging (the paper's Figure 1 does
    /// 100 s averages then 4 h averages; apply this twice).
    pub fn rebucket(&self, window: f64) -> TimeSeries {
        let mut out = TimeSeries::new();
        if self.points.is_empty() {
            return out;
        }
        let t0 = self.points[0].0;
        let mut bucket_end = t0 + window;
        let (mut sum, mut n) = (0.0, 0u32);
        for &(t, v) in &self.points {
            while t >= bucket_end {
                if n > 0 {
                    out.push(bucket_end - window / 2.0, sum / n as f64);
                }
                sum = 0.0;
                n = 0;
                bucket_end += window;
            }
            sum += v;
            n += 1;
        }
        if n > 0 {
            out.push(bucket_end - window / 2.0, sum / n as f64);
        }
        out
    }
}

/// Exact integrator for a step function of time (e.g. active transient
/// count): record value changes, read off the time-weighted average.
#[derive(Clone, Debug)]
pub struct StepIntegrator {
    value: f64,
    last_change: Time,
    integral: f64,
    max: f64,
}

impl StepIntegrator {
    pub fn new(start: Time, initial: f64) -> Self {
        StepIntegrator { value: initial, last_change: start, integral: 0.0, max: initial }
    }

    /// Record that the tracked quantity changed to `value` at time `t`.
    pub fn set(&mut self, t: Time, value: f64) {
        debug_assert!(t >= self.last_change, "time went backwards");
        self.integral += self.value * (t - self.last_change);
        self.last_change = t;
        self.value = value;
        self.max = self.max.max(value);
    }

    pub fn add(&mut self, t: Time, delta: f64) {
        self.set(t, self.value + delta);
    }

    pub fn value(&self) -> f64 {
        self.value
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Integral of the step function from start to `end`.
    pub fn integral_to(&self, end: Time) -> f64 {
        self.integral + self.value * (end - self.last_change)
    }

    /// Time-weighted average over `[start, end]`.
    pub fn mean_to(&self, start: Time, end: Time) -> f64 {
        if end <= start {
            return self.value;
        }
        self.integral_to(end) / (end - start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_integrator_exact() {
        let mut s = StepIntegrator::new(0.0, 0.0);
        s.set(10.0, 5.0); // 0 for [0,10)
        s.set(20.0, 2.0); // 5 for [10,20)
        // 2 for [20,30)
        assert!((s.integral_to(30.0) - (0.0 * 10.0 + 5.0 * 10.0 + 2.0 * 10.0)).abs() < 1e-12);
        assert!((s.mean_to(0.0, 30.0) - 70.0 / 30.0).abs() < 1e-12);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn add_tracks_deltas() {
        let mut s = StepIntegrator::new(0.0, 0.0);
        s.add(5.0, 3.0);
        s.add(10.0, -1.0);
        assert_eq!(s.value(), 2.0);
        assert!((s.integral_to(20.0) - (0.0 * 5.0 + 3.0 * 5.0 + 2.0 * 10.0)).abs() < 1e-12);
    }

    #[test]
    fn rebucket_averages() {
        let mut ts = TimeSeries::new();
        for i in 0..100 {
            ts.push(i as f64, if i < 50 { 10.0 } else { 20.0 });
        }
        let rb = ts.rebucket(50.0);
        assert_eq!(rb.len(), 2);
        assert!((rb.points[0].1 - 10.0).abs() < 1e-12);
        assert!((rb.points[1].1 - 20.0).abs() < 1e-12);
    }

    #[test]
    fn rebucket_handles_gaps() {
        let mut ts = TimeSeries::new();
        ts.push(0.0, 1.0);
        ts.push(1000.0, 3.0);
        let rb = ts.rebucket(100.0);
        assert_eq!(rb.len(), 2);
    }

    #[test]
    fn empty_series() {
        let ts = TimeSeries::new();
        assert!(ts.rebucket(10.0).is_empty());
        assert_eq!(ts.mean(), 0.0);
    }
}
