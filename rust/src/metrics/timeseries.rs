//! Time-series recording: sampled series (for plots) and exact
//! step-function integration (for time-weighted averages like the paper's
//! "average number of active transient servers").
//!
//! **Memory contract**: a [`TimeSeries`] can be bounded
//! ([`TimeSeries::bounded`]) to a fixed point capacity. When a bounded
//! series fills, it *rebuckets on the fly*: every other retained point is
//! dropped and the effective sampling stride doubles, so a year-long run
//! keeps a uniformly-decimated overview in O(capacity) memory instead of
//! one point per `snapshot_interval` (the last horizon-proportional
//! per-run structure — see the ROADMAP item this closes). The unbounded
//! exact path ([`TimeSeries::new`]) survives for golden comparisons,
//! mirroring `SimConfig::exact_delay_samples`.

use crate::util::Time;

/// Default point capacity for the recorder's bounded snapshot series
/// (~64 KiB per series at 16 bytes/point). At the default 60 s
/// `snapshot_interval` this holds ~2.8 simulated days before the first
/// rebucket, so short runs — and every in-tree golden — never decimate.
pub const DEFAULT_SNAPSHOT_POINTS: usize = 4096;

/// A sampled time series (snapshot points for plotting / reports),
/// optionally bounded by on-the-fly 2x decimation.
#[derive(Clone, Debug)]
pub struct TimeSeries {
    pub points: Vec<(Time, f64)>,
    /// Point capacity; 0 = unbounded (exact reference mode).
    max_points: usize,
    /// Keep every `stride`-th offered sample (1 until the first rebucket;
    /// doubles on each).
    stride: u64,
    /// Samples offered via [`TimeSeries::push`] since construction — the
    /// decimation phase reference, so retained points are exactly those
    /// with offer index ≡ 0 (mod `stride`).
    offered: u64,
}

impl Default for TimeSeries {
    /// The unbounded exact series (a derived `Default` would zero
    /// `stride`, which must never be 0 — it is a modulus).
    fn default() -> Self {
        Self::new()
    }
}

impl TimeSeries {
    /// Unbounded exact series (reference mode): every push is retained.
    pub fn new() -> Self {
        TimeSeries { points: Vec::new(), max_points: 0, stride: 1, offered: 0 }
    }

    /// Series bounded to at most `max_points` retained points; filling up
    /// coarsens the effective sampling interval by 2x instead of growing.
    /// (`max_points == 0` means unbounded; a bound below 2 is clamped to
    /// 2 — decimation needs at least two retained points to halve.)
    pub fn bounded(max_points: usize) -> Self {
        let max_points = if max_points == 0 { 0 } else { max_points.max(2) };
        TimeSeries { points: Vec::new(), max_points, stride: 1, offered: 0 }
    }

    /// Is every offered sample retained (no decimation configured or
    /// triggered yet)?
    pub fn is_exact(&self) -> bool {
        self.stride == 1
    }

    /// Current decimation stride: retained points are every `stride`-th
    /// offered sample, i.e. the effective sampling interval is
    /// `stride × snapshot_interval`.
    pub fn stride(&self) -> u64 {
        self.stride
    }

    /// Samples offered over the series' lifetime (≥ retained `len`).
    pub fn offered(&self) -> u64 {
        self.offered
    }

    pub fn push(&mut self, t: Time, v: f64) {
        debug_assert!(self.points.last().map_or(true, |&(pt, _)| t >= pt));
        let idx = self.offered;
        self.offered += 1;
        if idx % self.stride != 0 {
            return; // decimated: this offer falls between retained strides
        }
        self.points.push((t, v));
        if self.max_points > 0 && self.points.len() >= self.max_points {
            // Rebucket: keep offers ≡ 0 (mod 2·stride). Retained point i
            // holds offer i·stride, so the even positions survive.
            let mut keep = 0usize;
            self.points.retain(|_| {
                let kept = keep % 2 == 0;
                keep += 1;
                kept
            });
            self.stride *= 2;
        }
    }

    /// Resident bytes of the backing point storage (counted at Vec
    /// capacity, the truly resident allocation). Bounded series stay
    /// O(`max_points`) regardless of run length.
    pub fn memory_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.points.capacity() * std::mem::size_of::<(Time, f64)>()
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn mean(&self) -> f64 {
        crate::util::mean(&self.points.iter().map(|&(_, v)| v).collect::<Vec<_>>())
    }

    pub fn max(&self) -> f64 {
        self.points.iter().map(|&(_, v)| v).fold(f64::NEG_INFINITY, f64::max)
    }

    /// Rebucket into fixed windows by averaging (the paper's Figure 1 does
    /// 100 s averages then 4 h averages; apply this twice).
    pub fn rebucket(&self, window: f64) -> TimeSeries {
        let mut out = TimeSeries::new();
        if self.points.is_empty() {
            return out;
        }
        let t0 = self.points[0].0;
        let mut bucket_end = t0 + window;
        let (mut sum, mut n) = (0.0, 0u32);
        for &(t, v) in &self.points {
            while t >= bucket_end {
                if n > 0 {
                    out.push(bucket_end - window / 2.0, sum / n as f64);
                }
                sum = 0.0;
                n = 0;
                bucket_end += window;
            }
            sum += v;
            n += 1;
        }
        if n > 0 {
            out.push(bucket_end - window / 2.0, sum / n as f64);
        }
        out
    }
}

/// Exact integrator for a step function of time (e.g. active transient
/// count): record value changes, read off the time-weighted average.
#[derive(Clone, Debug)]
pub struct StepIntegrator {
    value: f64,
    last_change: Time,
    integral: f64,
    max: f64,
}

impl StepIntegrator {
    pub fn new(start: Time, initial: f64) -> Self {
        StepIntegrator { value: initial, last_change: start, integral: 0.0, max: initial }
    }

    /// Record that the tracked quantity changed to `value` at time `t`.
    pub fn set(&mut self, t: Time, value: f64) {
        debug_assert!(t >= self.last_change, "time went backwards");
        self.integral += self.value * (t - self.last_change);
        self.last_change = t;
        self.value = value;
        self.max = self.max.max(value);
    }

    pub fn add(&mut self, t: Time, delta: f64) {
        self.set(t, self.value + delta);
    }

    pub fn value(&self) -> f64 {
        self.value
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Integral of the step function from start to `end`.
    pub fn integral_to(&self, end: Time) -> f64 {
        self.integral + self.value * (end - self.last_change)
    }

    /// Time-weighted average over `[start, end]`.
    pub fn mean_to(&self, start: Time, end: Time) -> f64 {
        if end <= start {
            return self.value;
        }
        self.integral_to(end) / (end - start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_integrator_exact() {
        let mut s = StepIntegrator::new(0.0, 0.0);
        s.set(10.0, 5.0); // 0 for [0,10)
        s.set(20.0, 2.0); // 5 for [10,20)
        // 2 for [20,30)
        assert!((s.integral_to(30.0) - (0.0 * 10.0 + 5.0 * 10.0 + 2.0 * 10.0)).abs() < 1e-12);
        assert!((s.mean_to(0.0, 30.0) - 70.0 / 30.0).abs() < 1e-12);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn add_tracks_deltas() {
        let mut s = StepIntegrator::new(0.0, 0.0);
        s.add(5.0, 3.0);
        s.add(10.0, -1.0);
        assert_eq!(s.value(), 2.0);
        assert!((s.integral_to(20.0) - (0.0 * 5.0 + 3.0 * 5.0 + 2.0 * 10.0)).abs() < 1e-12);
    }

    #[test]
    fn rebucket_averages() {
        let mut ts = TimeSeries::new();
        for i in 0..100 {
            ts.push(i as f64, if i < 50 { 10.0 } else { 20.0 });
        }
        let rb = ts.rebucket(50.0);
        assert_eq!(rb.len(), 2);
        assert!((rb.points[0].1 - 10.0).abs() < 1e-12);
        assert!((rb.points[1].1 - 20.0).abs() < 1e-12);
    }

    #[test]
    fn rebucket_handles_gaps() {
        let mut ts = TimeSeries::new();
        ts.push(0.0, 1.0);
        ts.push(1000.0, 3.0);
        let rb = ts.rebucket(100.0);
        assert_eq!(rb.len(), 2);
    }

    #[test]
    fn empty_series() {
        let ts = TimeSeries::new();
        assert!(ts.rebucket(10.0).is_empty());
        assert_eq!(ts.mean(), 0.0);
    }

    #[test]
    fn unbounded_series_retains_everything() {
        let mut ts = TimeSeries::new();
        for i in 0..10_000 {
            ts.push(i as f64, i as f64);
        }
        assert_eq!(ts.len(), 10_000);
        assert!(ts.is_exact());
        assert_eq!(ts.stride(), 1);
    }

    #[test]
    fn bounded_series_decimates_and_stays_bounded() {
        let mut ts = TimeSeries::bounded(8);
        for i in 0..10_000u64 {
            ts.push(i as f64 * 60.0, i as f64);
        }
        assert!(ts.len() <= 8, "bounded series grew to {}", ts.len());
        assert!(!ts.is_exact());
        assert_eq!(ts.offered(), 10_000);
        // Stride is a power of two and large enough that the retained
        // count times the stride covers every offer.
        assert!(ts.stride().is_power_of_two());
        assert!(ts.stride() * 8 >= 10_000);
        // Retained points are exactly the offers ≡ 0 (mod stride) — a
        // uniform decimation, so times stay uniformly spaced.
        for (k, &(t, v)) in ts.points.iter().enumerate() {
            let offer = k as u64 * ts.stride();
            assert_eq!(t, offer as f64 * 60.0);
            assert_eq!(v, offer as f64);
        }
        // Memory is bounded by the cap, not the offer count.
        assert!(ts.memory_bytes() < 16 * 64 + std::mem::size_of::<TimeSeries>());
    }

    #[test]
    fn bounded_series_below_cap_is_exact() {
        // The golden-compatibility property: a bounded series that never
        // fills retains every point, bit-identical to the exact path.
        let mut bounded = TimeSeries::bounded(4096);
        let mut exact = TimeSeries::new();
        for i in 0..100 {
            bounded.push(i as f64, (i * 7) as f64);
            exact.push(i as f64, (i * 7) as f64);
        }
        assert!(bounded.is_exact());
        assert_eq!(bounded.points, exact.points);
    }

    #[test]
    fn tiny_bounds_clamp_to_two() {
        let mut ts = TimeSeries::bounded(1);
        for i in 0..64 {
            ts.push(i as f64, 0.0);
        }
        assert!(ts.len() <= 2);
        assert!(ts.stride() >= 32);
    }
}
