//! Metrics pipeline: streaming statistics, fixed-memory log-bucketed
//! delay sketches (with an exact-Vec reference backend for golden
//! comparisons), empirical CDFs, time-series recording with exact step
//! integration, transient cost accounting, and the per-run
//! [`Recorder`].
//!
//! Memory model: everything the recorder accumulates per *sample* (one
//! short/long queueing delay per task, one lifetime per retired
//! transient) streams through a [`DelayDist`] — by default the
//! fixed-size [`DelayHistogram`], so a run's metrics footprint is
//! constant no matter how long the trace is. Count, mean, min and max
//! are exact (and bit-identical to the exact backend); quantiles are
//! approximate within the histogram's documented ≤1% relative bound.

mod cdf;
mod cost;
mod histogram;
mod recorder;
pub(crate) mod stats;
mod timeseries;

pub use cdf::Cdf;
pub use cost::CostLedger;
pub use histogram::{DelayDist, DelayHistogram, GAMMA, MAX_TRACKED, MIN_TRACKED, N_BUCKETS};
pub use recorder::Recorder;
pub use stats::{DelaySamples, StreamingStats};
pub use timeseries::{StepIntegrator, TimeSeries, DEFAULT_SNAPSHOT_POINTS};
