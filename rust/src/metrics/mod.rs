//! Metrics pipeline: streaming statistics, exact empirical CDFs,
//! time-series recording with exact step integration, transient cost
//! accounting, and the per-run [`Recorder`].

mod cdf;
mod cost;
mod recorder;
mod stats;
mod timeseries;

pub use cdf::Cdf;
pub use cost::CostLedger;
pub use recorder::Recorder;
pub use stats::{DelaySamples, StreamingStats};
pub use timeseries::{StepIntegrator, TimeSeries};
