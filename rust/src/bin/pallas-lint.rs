//! `pallas-lint` — run the first-party static-analysis pass over the
//! crate's own sources and report violations of the simulator's
//! structural invariants (see `rust/LINTS.md`).
//!
//! Usage:
//!
//! ```text
//! pallas-lint [--json[=PATH]] [SRC_ROOT]
//! ```
//!
//! With no arguments, lints the `src/` directory of the crate this
//! binary was built from. `--json` prints the byte-deterministic JSON
//! report to stdout instead of the human rendering; `--json=PATH`
//! writes it to `PATH` and keeps the human rendering on stdout (the CI
//! gate uses this to fail loudly *and* upload the artifact). Exits 0
//! on a clean pass, 1 on any unsuppressed diagnostic, 2 on I/O errors.

use std::path::PathBuf;
use std::process::ExitCode;

use cloudcoaster::lint;

fn main() -> ExitCode {
    let mut json_to_stdout = false;
    let mut json_path: Option<PathBuf> = None;
    let mut src_root: Option<PathBuf> = None;

    for arg in std::env::args().skip(1) {
        if arg == "--json" {
            json_to_stdout = true;
        } else if let Some(p) = arg.strip_prefix("--json=") {
            json_path = Some(PathBuf::from(p));
        } else if arg == "--help" || arg == "-h" {
            eprintln!("usage: pallas-lint [--json[=PATH]] [SRC_ROOT]");
            return ExitCode::SUCCESS;
        } else if src_root.is_none() {
            src_root = Some(PathBuf::from(arg));
        } else {
            eprintln!("pallas-lint: unexpected argument `{arg}`");
            return ExitCode::from(2);
        }
    }

    let root = src_root
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src"));

    let report = match lint::run(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("pallas-lint: {e}");
            return ExitCode::from(2);
        }
    };

    if let Some(path) = &json_path {
        if let Err(e) = std::fs::write(path, report.to_json()) {
            eprintln!("pallas-lint: write {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }
    if json_to_stdout {
        print!("{}", report.to_json());
    } else {
        print!("{}", report.render_human());
    }

    if report.is_clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
