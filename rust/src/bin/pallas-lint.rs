//! `pallas-lint` — run the first-party static-analysis pass over the
//! crate's own sources and report violations of the simulator's
//! structural invariants (see `rust/LINTS.md`).
//!
//! Usage:
//!
//! ```text
//! pallas-lint [--json[=PATH]] [--deep] [--lenient] [SRC_ROOT]
//! ```
//!
//! With no arguments, lints the `src/` directory of the crate this
//! binary was built from. `--json` prints the byte-deterministic JSON
//! report to stdout instead of the human rendering; `--json=PATH`
//! writes it to `PATH` and keeps the human rendering on stdout (the CI
//! gate uses this to fail loudly *and* upload the artifact).
//!
//! `--deep` also runs the tier-2 crate-wide `pallas-check` analysis
//! and combines both reports (JSON schema `pallas-deep/1` with `lint`
//! and `check` sub-objects). By default an unused suppression marker
//! fails the run like a violation does; `--lenient` downgrades that to
//! the diagnostics-only gate. Exits 0 on a clean pass, 1 on any
//! unsuppressed diagnostic (or, without `--lenient`, any unused
//! suppression), 2 on I/O errors.

use std::path::PathBuf;
use std::process::ExitCode;

use cloudcoaster::lint;

/// Re-indent a child report's JSON for embedding as an object value:
/// first line stays put (it follows `"lint": `), later lines gain two
/// spaces so the combined document nests cleanly.
fn embed(json: &str) -> String {
    let mut out = String::new();
    for (i, l) in json.trim_end().lines().enumerate() {
        if i > 0 {
            out.push('\n');
            out.push_str("  ");
        }
        out.push_str(l);
    }
    out
}

fn main() -> ExitCode {
    let mut json_to_stdout = false;
    let mut json_path: Option<PathBuf> = None;
    let mut deep = false;
    let mut lenient = false;
    let mut src_root: Option<PathBuf> = None;

    for arg in std::env::args().skip(1) {
        if arg == "--json" {
            json_to_stdout = true;
        } else if let Some(p) = arg.strip_prefix("--json=") {
            json_path = Some(PathBuf::from(p));
        } else if arg == "--deep" {
            deep = true;
        } else if arg == "--lenient" {
            lenient = true;
        } else if arg == "--help" || arg == "-h" {
            eprintln!("usage: pallas-lint [--json[=PATH]] [--deep] [--lenient] [SRC_ROOT]");
            return ExitCode::SUCCESS;
        } else if src_root.is_none() {
            src_root = Some(PathBuf::from(arg));
        } else {
            eprintln!("pallas-lint: unexpected argument `{arg}`");
            return ExitCode::from(2);
        }
    }

    let root = src_root
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src"));

    let report = match lint::run(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("pallas-lint: {e}");
            return ExitCode::from(2);
        }
    };
    let check_report = if deep {
        match lint::check::run(&root) {
            Ok(r) => Some(r),
            Err(e) => {
                eprintln!("pallas-lint: {e}");
                return ExitCode::from(2);
            }
        }
    } else {
        None
    };

    let json = match &check_report {
        Some(c) => format!(
            "{{\n  \"schema\": \"pallas-deep/1\",\n  \"lint\": {},\n  \"check\": {}\n}}\n",
            embed(&report.to_json()),
            embed(&c.to_json())
        ),
        None => report.to_json(),
    };
    if let Some(path) = &json_path {
        if let Err(e) = std::fs::write(path, &json) {
            eprintln!("pallas-lint: write {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }
    if json_to_stdout {
        print!("{json}");
    } else {
        print!("{}", report.render_human());
        if let Some(c) = &check_report {
            print!("{}", c.render_human());
        }
    }

    let clean = |r: &lint::LintReport| if lenient { r.is_clean() } else { r.is_clean_strict() };
    if clean(&report) && check_report.as_ref().map_or(true, |c| clean(c)) {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
