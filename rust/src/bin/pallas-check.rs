//! `pallas-check` — run the tier-2 crate-wide symbol-resolution and
//! API-consistency analysis over the crate's own sources (see
//! `rust/LINTS.md` for the rule catalogue and
//! `cloudcoaster::lint::check` for the resolution discipline).
//!
//! Usage:
//!
//! ```text
//! pallas-check [--json[=PATH]] [--lenient] [SRC_ROOT]
//! ```
//!
//! With no arguments, analyses the `src/` directory of the crate this
//! binary was built from. `--json` prints the byte-deterministic JSON
//! report (schema `pallas-check/1`) to stdout; `--json=PATH` writes it
//! to `PATH` and keeps the human rendering on stdout. By default an
//! unused `check-*` suppression marker fails the run like a violation
//! does; `--lenient` downgrades that to the diagnostics-only gate.
//! Exits 0 on a clean pass, 1 otherwise, 2 on I/O errors.

use std::path::PathBuf;
use std::process::ExitCode;

use cloudcoaster::lint;

fn main() -> ExitCode {
    let mut json_to_stdout = false;
    let mut json_path: Option<PathBuf> = None;
    let mut lenient = false;
    let mut src_root: Option<PathBuf> = None;

    for arg in std::env::args().skip(1) {
        if arg == "--json" {
            json_to_stdout = true;
        } else if let Some(p) = arg.strip_prefix("--json=") {
            json_path = Some(PathBuf::from(p));
        } else if arg == "--lenient" {
            lenient = true;
        } else if arg == "--help" || arg == "-h" {
            eprintln!("usage: pallas-check [--json[=PATH]] [--lenient] [SRC_ROOT]");
            return ExitCode::SUCCESS;
        } else if src_root.is_none() {
            src_root = Some(PathBuf::from(arg));
        } else {
            eprintln!("pallas-check: unexpected argument `{arg}`");
            return ExitCode::from(2);
        }
    }

    let root = src_root
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src"));

    let report = match lint::check::run(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("pallas-check: {e}");
            return ExitCode::from(2);
        }
    };

    if let Some(path) = &json_path {
        if let Err(e) = std::fs::write(path, report.to_json()) {
            eprintln!("pallas-check: write {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }
    if json_to_stdout {
        print!("{}", report.to_json());
    } else {
        print!("{}", report.render_human());
    }

    let ok = if lenient { report.is_clean() } else { report.is_clean_strict() };
    if ok {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
