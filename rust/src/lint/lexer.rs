//! A minimal comment/string-aware Rust lexer for `pallas-lint`.
//!
//! This is deliberately **not** a full Rust lexer: the lint rules only
//! need identifiers, integer literals and single-character punctuation,
//! with comments, strings, char literals and lifetimes recognised well
//! enough that their *contents* never leak into the token stream (a
//! `"HashMap"` inside a string or a `panic!` inside a doc comment must
//! not trip a rule). It handles nested block comments, raw strings
//! (`r"…"`, `r#"…"#`, any hash depth), byte strings, escapes inside
//! string/char literals, and the char-literal-vs-lifetime ambiguity.
//!
//! Line comments are captured separately (with their line number and
//! whether they stand alone on the line) because the suppression and
//! `hot-path` markers live in them.

/// Token kind. Only `Ident` and `Int` carry text the rules inspect;
/// string/char/lifetime tokens exist so rules can see that *something*
/// non-matchable occupied the position.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum TokKind {
    Ident,
    Int,
    Str,
    Char,
    Lifetime,
    Punct,
}

#[derive(Debug, Clone)]
pub(crate) struct Tok {
    /// 1-based source line.
    pub line: u32,
    pub kind: TokKind,
    /// Identifier / integer-literal text; for `Punct` the single
    /// character; empty for string/char/lifetime tokens.
    pub text: String,
}

/// A `//` comment, captured for marker parsing.
#[derive(Debug, Clone)]
pub(crate) struct LineComment {
    /// 1-based source line the comment starts on.
    pub line: u32,
    /// Text after the `//` (and after any further leading `/` or `!`
    /// of doc comments), not trimmed.
    pub text: String,
    /// True when only whitespace precedes the `//` on its line.
    pub standalone: bool,
}

pub(crate) struct LexOutput {
    pub toks: Vec<Tok>,
    pub comments: Vec<LineComment>,
    /// Total number of source lines (1-based indexing convenience).
    pub n_lines: u32,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into tokens + line comments. Never fails: unterminated
/// constructs simply consume to end of input (the real compiler owns
/// error reporting; the lint only needs a best-effort scan).
pub(crate) fn lex(src: &str) -> LexOutput {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;
    // Whether a token has already been emitted on the current line
    // (drives LineComment::standalone).
    let mut line_has_code = false;

    macro_rules! bump_line {
        () => {{
            line += 1;
            line_has_code = false;
        }};
    }

    while i < n {
        let c = chars[i];
        if c == '\n' {
            bump_line!();
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Comments.
        if c == '/' && i + 1 < n {
            if chars[i + 1] == '/' {
                let start_line = line;
                let standalone = !line_has_code;
                let mut j = i + 2;
                // Fold doc-comment sigils into the prefix.
                while j < n && (chars[j] == '/' || chars[j] == '!') {
                    j += 1;
                }
                let mut text = String::new();
                while j < n && chars[j] != '\n' {
                    text.push(chars[j]);
                    j += 1;
                }
                comments.push(LineComment { line: start_line, text, standalone });
                i = j;
                continue;
            }
            if chars[i + 1] == '*' {
                // Nested block comment.
                let mut depth = 1usize;
                let mut j = i + 2;
                while j < n && depth > 0 {
                    if chars[j] == '\n' {
                        bump_line!();
                        j += 1;
                    } else if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                        depth += 1;
                        j += 2;
                    } else if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/' {
                        depth -= 1;
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                i = j;
                continue;
            }
        }
        // String literal.
        if c == '"' {
            let start_line = line;
            i = scan_string_body(&chars, i + 1, &mut line, &mut line_has_code);
            toks.push(Tok { line: start_line, kind: TokKind::Str, text: String::new() });
            line_has_code = true;
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 2 < n
                && is_ident_start(chars[i + 1])
                && chars[i + 2] != '\''
            {
                // Lifetime: 'a, 'static, '_ …
                let mut j = i + 1;
                while j < n && is_ident_continue(chars[j]) {
                    j += 1;
                }
                toks.push(Tok { line, kind: TokKind::Lifetime, text: String::new() });
                line_has_code = true;
                i = j;
                continue;
            }
            // Char literal: '\n', 'x', '\u{1F600}' …
            let mut j = i + 1;
            while j < n && chars[j] != '\'' {
                if chars[j] == '\\' {
                    j += 2;
                } else {
                    j += 1;
                }
            }
            toks.push(Tok { line, kind: TokKind::Char, text: String::new() });
            line_has_code = true;
            i = (j + 1).min(n);
            continue;
        }
        // Identifier — with raw-string / byte-string prefix handling.
        if is_ident_start(c) {
            let mut j = i;
            let mut text = String::new();
            while j < n && is_ident_continue(chars[j]) {
                text.push(chars[j]);
                j += 1;
            }
            let next = if j < n { chars[j] } else { '\0' };
            if (text == "r" || text == "br") && (next == '"' || next == '#') {
                // Possible raw string r"…" / r#"…"# / br#"…"#.
                let mut hashes = 0usize;
                let mut k = j;
                while k < n && chars[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && chars[k] == '"' {
                    let start_line = line;
                    // Scan to closing `"` followed by `hashes` hashes.
                    let mut m = k + 1;
                    'raw: while m < n {
                        if chars[m] == '\n' {
                            bump_line!();
                            m += 1;
                            continue;
                        }
                        if chars[m] == '"' {
                            let mut h = 0usize;
                            while m + 1 + h < n && h < hashes && chars[m + 1 + h] == '#' {
                                h += 1;
                            }
                            if h == hashes {
                                m += 1 + hashes;
                                break 'raw;
                            }
                        }
                        m += 1;
                    }
                    toks.push(Tok {
                        line: start_line,
                        kind: TokKind::Str,
                        text: String::new(),
                    });
                    line_has_code = true;
                    i = m;
                    continue;
                }
                // `r#ident` raw identifier or stray hash: fall through,
                // emit `r` as an ident and let the main loop resume at
                // the hash.
            }
            if text == "b" && next == '"' {
                let start_line = line;
                i = scan_string_body(&chars, j + 1, &mut line, &mut line_has_code);
                toks.push(Tok { line: start_line, kind: TokKind::Str, text: String::new() });
                line_has_code = true;
                continue;
            }
            toks.push(Tok { line, kind: TokKind::Ident, text });
            line_has_code = true;
            i = j;
            continue;
        }
        // Integer (and, loosely, float) literals. Rules only consume
        // integer values; float fragments lex as Int + Punct('.') + Int,
        // which no rule matches on.
        if c.is_ascii_digit() {
            let mut j = i;
            let mut text = String::new();
            while j < n && (is_ident_continue(chars[j])) {
                text.push(chars[j]);
                j += 1;
            }
            toks.push(Tok { line, kind: TokKind::Int, text });
            line_has_code = true;
            i = j;
            continue;
        }
        // Single-character punctuation.
        toks.push(Tok { line, kind: TokKind::Punct, text: c.to_string() });
        line_has_code = true;
        i += 1;
    }

    let n_lines = line.max(1);
    LexOutput { toks, comments, n_lines }
}

/// Scan a (non-raw) string body starting just past the opening quote;
/// returns the index just past the closing quote. Tracks newlines.
fn scan_string_body(
    chars: &[char],
    mut j: usize,
    line: &mut u32,
    line_has_code: &mut bool,
) -> usize {
    let n = chars.len();
    while j < n {
        match chars[j] {
            '\\' => j += 2,
            '"' => return j + 1,
            '\n' => {
                *line += 1;
                *line_has_code = false;
                j += 1;
            }
            _ => j += 1,
        }
    }
    n
}

/// Parse an integer literal's text (`0x5C`, `1_000u64`, `42`) into its
/// value. Returns `None` for malformed or non-integer text.
pub(crate) fn parse_int_literal(text: &str) -> Option<u64> {
    let t: String = text.chars().filter(|&c| c != '_').collect();
    let (radix, digits) = if let Some(rest) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X"))
    {
        (16u32, rest)
    } else if let Some(rest) = t.strip_prefix("0b").or_else(|| t.strip_prefix("0B")) {
        (2, rest)
    } else if let Some(rest) = t.strip_prefix("0o").or_else(|| t.strip_prefix("0O")) {
        (8, rest)
    } else {
        (10, t.as_str())
    };
    // Strip a type suffix (u8/u16/u32/u64/usize/i*…): cut at the first
    // char that is not a digit of the radix.
    let end = digits
        .char_indices()
        .find(|&(_, c)| !c.is_digit(radix))
        .map(|(idx, _)| idx)
        .unwrap_or(digits.len());
    let core = &digits[..end];
    if core.is_empty() {
        return None;
    }
    u64::from_str_radix(core, radix).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_hide_their_contents() {
        let src = r##"
            // HashMap in a comment
            /* Instant::now() in /* a nested */ block */
            let s = "HashMap::new()";
            let r = r#"panic!("x")"#;
            let c = 'x';
            let lt: &'static str = "SystemTime";
            real_ident();
        "##;
        let ids = idents(src);
        assert!(ids.contains(&"real_ident".to_string()));
        assert!(!ids.iter().any(|t| t == "HashMap"));
        assert!(!ids.iter().any(|t| t == "Instant"));
        assert!(!ids.iter().any(|t| t == "panic"));
        assert!(!ids.iter().any(|t| t == "SystemTime"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let out = lex("fn f<'a>(x: &'a str) -> &'a str { x }");
        let kinds: Vec<TokKind> = out.toks.iter().map(|t| t.kind).collect();
        assert!(kinds.contains(&TokKind::Lifetime));
        assert!(!kinds.contains(&TokKind::Char));
    }

    #[test]
    fn line_numbers_and_standalone_flags() {
        let src = "let a = 1; // trailing\n// standalone\nlet b = 2;\n";
        let out = lex(src);
        assert_eq!(out.comments.len(), 2);
        assert_eq!(out.comments[0].line, 1);
        assert!(!out.comments[0].standalone);
        assert_eq!(out.comments[1].line, 2);
        assert!(out.comments[1].standalone);
        let b_tok = out.toks.iter().find(|t| t.text == "b");
        assert_eq!(b_tok.map(|t| t.line), Some(3));
    }

    #[test]
    fn int_literal_parsing() {
        assert_eq!(parse_int_literal("0x5C"), Some(0x5C));
        assert_eq!(parse_int_literal("0xA11"), Some(0xA11));
        assert_eq!(parse_int_literal("42"), Some(42));
        assert_eq!(parse_int_literal("1_000"), Some(1000));
        assert_eq!(parse_int_literal("0x5Cu64"), Some(0x5C));
        assert_eq!(parse_int_literal("0x"), None);
        assert_eq!(parse_int_literal("nope"), None);
    }

    #[test]
    fn multiline_strings_track_lines() {
        let src = "let s = \"a\nb\nc\";\nafter();";
        let out = lex(src);
        let after = out.toks.iter().find(|t| t.text == "after");
        assert_eq!(after.map(|t| t.line), Some(4));
    }
}
