//! `wall-clock-quarantine`: wall-clock reads (`Instant`, `SystemTime`)
//! are only allowed in the whitelisted timing modules. Everything the
//! report surface touches must be driven by virtual time — a stray
//! `Instant::now()` in a component is exactly the kind of
//! nondeterminism the golden suites can only catch after the fact.
//!
//! Whitelist: the runner's wall-clock accounting, the benchmark kit,
//! and the hot-path profiler (whose `Stopwatch` is the sanctioned way
//! for sim code to measure real time).

use super::{Diagnostic, FileCtx};

const RULE: &str = "wall-clock-quarantine";

/// Files allowed to touch the wall clock directly.
const WHITELIST: [&str; 3] = ["coordinator/runner.rs", "benchkit.rs", "sim/profiler.rs"];

const BANNED: [&str; 2] = ["Instant", "SystemTime"];

pub(crate) fn check(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    if WHITELIST.contains(&ctx.rel) {
        return;
    }
    for (i, t) in ctx.toks.iter().enumerate() {
        let Some(name) = ctx.ident(i) else { continue };
        if BANNED.contains(&name) {
            out.push(ctx.diag(
                t.line,
                RULE,
                format!(
                    "`{name}` outside the timing whitelist ({}); route real-time \
                     measurement through `sim::profiler::Stopwatch`",
                    WHITELIST.join(", ")
                ),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::lint::{lint_file_source, LabelRegistry};

    #[test]
    fn flags_instant_outside_whitelist() {
        let src = "use std::time::Instant;\nfn f() { let _t = Instant::now(); }\n";
        let out = lint_file_source("sim/world.rs", src, &LabelRegistry::default());
        let hits: Vec<_> =
            out.kept.iter().filter(|d| d.rule == "wall-clock-quarantine").collect();
        assert_eq!(hits.len(), 2, "use line + call site: {hits:?}");
    }

    #[test]
    fn whitelisted_files_pass() {
        let src = "use std::time::Instant;\nfn f() { let _t = Instant::now(); }\n";
        for rel in ["coordinator/runner.rs", "benchkit.rs", "sim/profiler.rs"] {
            let out = lint_file_source(rel, src, &LabelRegistry::default());
            assert!(
                out.kept.iter().all(|d| d.rule != "wall-clock-quarantine"),
                "{rel} should be whitelisted"
            );
        }
    }

    #[test]
    fn mentions_in_comments_and_strings_pass() {
        let src = "// Instant::now() would be wrong here.\nfn f() -> &'static str { \"SystemTime\" }\n";
        let out = lint_file_source("sim/world.rs", src, &LabelRegistry::default());
        assert!(out.kept.iter().all(|d| d.rule != "wall-clock-quarantine"));
    }
}
