//! `raw-id-ban`: the raw `TaskId` / `ServerId` index types were
//! superseded by the generation-checked `TaskRef` / `ServerRef` arena
//! handles (PR 6); a raw index that outlives a slot recycle silently
//! addresses the slot's next tenant. Outside `util` (where a compat
//! shim may legitimately live), any mention of the raw types is a
//! regression.

use super::{Diagnostic, FileCtx};

const RULE: &str = "raw-id-ban";

const BANNED: [&str; 2] = ["TaskId", "ServerId"];

pub(crate) fn check(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    if ctx.rel.starts_with("util/") {
        return;
    }
    for (i, t) in ctx.toks.iter().enumerate() {
        let Some(name) = ctx.ident(i) else { continue };
        if BANNED.contains(&name) {
            out.push(ctx.diag(
                t.line,
                RULE,
                format!(
                    "raw `{name}` outside util: use the generation-checked \
                     `{}Ref` arena handle",
                    name.trim_end_matches("Id")
                ),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::lint::{lint_file_source, LabelRegistry};

    #[test]
    fn flags_raw_ids_outside_util() {
        let src = "fn f(id: TaskId) -> ServerId { todo!() }\n";
        let out = lint_file_source("cluster/x.rs", src, &LabelRegistry::default());
        let hits: Vec<_> = out.kept.iter().filter(|d| d.rule == "raw-id-ban").collect();
        assert_eq!(hits.len(), 2, "{hits:?}");
    }

    #[test]
    fn util_shims_and_ref_types_pass() {
        let shim = "pub struct TaskId(pub u32);\n";
        let out = lint_file_source("util/compat.rs", shim, &LabelRegistry::default());
        assert!(out.kept.iter().all(|d| d.rule != "raw-id-ban"));

        let refs = "fn f(id: TaskRef) -> ServerRef { todo!() }\n";
        let out = lint_file_source("cluster/x.rs", refs, &LabelRegistry::default());
        assert!(out.kept.iter().all(|d| d.rule != "raw-id-ban"));
    }

    #[test]
    fn doc_comment_mentions_pass() {
        let src = "/// Replaced the old raw `ServerId`.\nfn f() {}\n";
        let out = lint_file_source("cluster/x.rs", src, &LabelRegistry::default());
        assert!(out.kept.iter().all(|d| d.rule != "raw-id-ban"));
    }
}
