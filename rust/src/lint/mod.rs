//! `pallas-lint`: a first-party static-analysis pass over `rust/src/**`
//! that enforces the simulator's structural invariants as named,
//! suppressible rules. Zero dependencies — a hand-rolled
//! comment/string-aware lexer ([`lexer`]), no `syn` — so the build
//! stays fully vendored and the pass runs identically offline, in CI,
//! and in-process from `tests/lint_clean.rs`.
//!
//! ## Rules
//!
//! | rule | invariant |
//! |------|-----------|
//! | `wall-clock-quarantine` | wall-clock reads only in whitelisted timing modules |
//! | `unordered-iter` | no `HashMap`/`HashSet` in sim/report-surface modules without a keyed-access argument |
//! | `rng-label-registry` | every RNG fork label is a named constant from `util/rng_labels.rs`, unique crate-wide |
//! | `raw-id-ban` | no raw `TaskId`/`ServerId` outside `util` compat shims |
//! | `hot-path-no-alloc` | functions marked `// lint: hot-path` contain no allocating calls |
//! | `panic-surface` | `unwrap`/`expect`/`panic!` in library sim paths carry a justification |
//!
//! ## Suppression
//!
//! `// lint: allow(<rule>): <reason>` — trailing on a line it covers
//! that line; standing alone it covers the following statement (up to
//! and including the next line containing `;`, `{` or `}`). The reason
//! is mandatory; a missing reason is a malformed marker. Unused
//! suppressions are fatal by default ([`LintReport::is_clean_strict`]):
//! a drive-by refactor that removes a violation must also delete the
//! marker, or pass `--lenient` to downgrade the failure while cleaning
//! up.
//!
//! `// lint: hot-path` marks the next `fn` item for the
//! `hot-path-no-alloc` scan.
//!
//! Code under `#[cfg(test)]` / `#[test]` items is exempt from every
//! rule: tests may use wall clocks, ad-hoc fork labels and `unwrap`
//! freely.
//!
//! ## Tiers
//!
//! This module is tier 1: per-file, syntactic. The [`check`] submodule
//! is tier 2 (`pallas-check`): a crate-wide symbol-resolution and
//! API-consistency pass with its own `check-*` rules, run via
//! `pallas-check` or `pallas-lint --deep`. Tier-2 suppressions
//! (`// lint: allow(check-…): reason`) share this marker syntax and
//! are validated here, but matched against findings over there.
//!
//! See `rust/LINTS.md` for the full catalogue and how to add a rule.

pub mod check;
mod hot_path;
mod lexer;
mod panic_surface;
mod raw_ids;
mod rng_labels;
mod unordered_iter;
mod wall_clock;

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use lexer::{LexOutput, Tok, TokKind};

pub use rng_labels::LabelRegistry;

/// The closed set of rule names. Suppression markers naming anything
/// else are malformed (catches typos like `allow(panic_surface)`).
pub const RULES: [&str; 6] = [
    "wall-clock-quarantine",
    "unordered-iter",
    "rng-label-registry",
    "raw-id-ban",
    "hot-path-no-alloc",
    "panic-surface",
];

/// One finding, pre- or post-suppression.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Path relative to the scanned source root, `/`-separated.
    pub file: String,
    /// 1-based line.
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
}

/// A suppression that matched no diagnostic. Fails the strict gate
/// ([`LintReport::is_clean_strict`]) so stale markers get pruned.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UnusedSuppression {
    pub file: String,
    pub line: u32,
    pub rule: String,
}

#[derive(Debug, Clone, Default)]
pub struct RuleCount {
    pub violations: usize,
    pub suppressed: usize,
}

/// The result of a full pass. Every collection is sorted so that the
/// JSON rendering is byte-deterministic run-to-run. Shared by both
/// tiers: tier 1 fills it with `pallas-lint/1`, [`check::run`] with
/// `pallas-check/1`.
#[derive(Debug)]
pub struct LintReport {
    /// JSON schema tag; also names the tool in human output.
    pub schema: &'static str,
    pub files_scanned: usize,
    /// Unsuppressed findings — non-empty means the gate fails.
    pub diagnostics: Vec<Diagnostic>,
    pub suppressed: usize,
    pub rule_counts: BTreeMap<&'static str, RuleCount>,
    pub unused_suppressions: Vec<UnusedSuppression>,
    /// Malformed markers and other non-fatal scan notes.
    pub notes: Vec<String>,
}

impl Default for LintReport {
    fn default() -> Self {
        LintReport {
            schema: "pallas-lint/1",
            files_scanned: 0,
            diagnostics: Vec::new(),
            suppressed: 0,
            rule_counts: BTreeMap::new(),
            unused_suppressions: Vec::new(),
            notes: Vec::new(),
        }
    }
}

impl LintReport {
    /// True when the pass found zero unsuppressed diagnostics.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Strict cleanliness: no unsuppressed diagnostics AND no unused
    /// suppressions. The bins gate on this by default — a suppression
    /// whose violation is gone must be deleted, not left to rot —
    /// with `--lenient` falling back to [`is_clean`](Self::is_clean).
    pub fn is_clean_strict(&self) -> bool {
        self.diagnostics.is_empty() && self.unused_suppressions.is_empty()
    }

    /// The tool name half of the schema tag (`pallas-lint/1` →
    /// `pallas-lint`), used in human-readable output.
    pub fn tool_name(&self) -> &'static str {
        self.schema.split('/').next().unwrap_or(self.schema)
    }

    /// Deterministic JSON rendering: fixed key order, sorted
    /// collections, no timestamps or absolute paths.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        let _ = writeln!(s, "  \"schema\": {},", json_str(self.schema));
        let _ = writeln!(s, "  \"files_scanned\": {},", self.files_scanned);
        let _ = writeln!(s, "  \"unsuppressed\": {},", self.diagnostics.len());
        let _ = writeln!(s, "  \"suppressed\": {},", self.suppressed);
        s.push_str("  \"rules\": {\n");
        for (i, (rule, c)) in self.rule_counts.iter().enumerate() {
            let comma = if i + 1 < self.rule_counts.len() { "," } else { "" };
            let _ = writeln!(
                s,
                "    {}: {{\"violations\": {}, \"suppressed\": {}}}{}",
                json_str(rule),
                c.violations,
                c.suppressed,
                comma
            );
        }
        s.push_str("  },\n");
        s.push_str("  \"diagnostics\": [\n");
        for (i, d) in self.diagnostics.iter().enumerate() {
            let comma = if i + 1 < self.diagnostics.len() { "," } else { "" };
            let _ = writeln!(
                s,
                "    {{\"file\": {}, \"line\": {}, \"rule\": {}, \"message\": {}}}{}",
                json_str(&d.file),
                d.line,
                json_str(d.rule),
                json_str(&d.message),
                comma
            );
        }
        s.push_str("  ],\n");
        s.push_str("  \"unused_suppressions\": [\n");
        for (i, u) in self.unused_suppressions.iter().enumerate() {
            let comma = if i + 1 < self.unused_suppressions.len() { "," } else { "" };
            let _ = writeln!(
                s,
                "    {{\"file\": {}, \"line\": {}, \"rule\": {}}}{}",
                json_str(&u.file),
                u.line,
                json_str(&u.rule),
                comma
            );
        }
        s.push_str("  ],\n");
        s.push_str("  \"notes\": [\n");
        for (i, note) in self.notes.iter().enumerate() {
            let comma = if i + 1 < self.notes.len() { "," } else { "" };
            let _ = writeln!(s, "    {}{}", json_str(note), comma);
        }
        s.push_str("  ]\n");
        s.push_str("}\n");
        s
    }

    /// Human-readable rendering: `file:line: [rule] message` per
    /// finding plus a one-line summary.
    pub fn render_human(&self) -> String {
        let mut s = String::new();
        for d in &self.diagnostics {
            let _ = writeln!(s, "{}:{}: [{}] {}", d.file, d.line, d.rule, d.message);
        }
        for u in &self.unused_suppressions {
            let _ = writeln!(
                s,
                "{}:{}: note: unused suppression for `{}`",
                u.file, u.line, u.rule
            );
        }
        for note in &self.notes {
            let _ = writeln!(s, "note: {note}");
        }
        let _ = writeln!(
            s,
            "{}: {} file(s), {} unsuppressed diagnostic(s), {} suppressed",
            self.tool_name(),
            self.files_scanned,
            self.diagnostics.len(),
            self.suppressed
        );
        s
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

// ------------------------------------------------------------ markers

#[derive(Debug, Clone, PartialEq, Eq)]
enum Marker {
    Allow { rule: String, reason: String },
    HotPath,
}

/// Parse a line comment's text. `None`: not a lint marker at all.
/// `Some(Err(..))`: a lint marker that is malformed (reported as a
/// note — the comment author clearly meant to talk to us).
fn parse_marker(text: &str) -> Option<Result<Marker, String>> {
    let t = text.trim();
    let rest = t.strip_prefix("lint:")?.trim();
    if rest == "hot-path" {
        return Some(Ok(Marker::HotPath));
    }
    if let Some(inner) = rest.strip_prefix("allow(") {
        let close = match inner.find(')') {
            Some(c) => c,
            None => return Some(Err("unterminated `allow(`".to_string())),
        };
        let rule = inner[..close].trim().to_string();
        // Tier-2 `check-*` rules are valid marker targets too; tier 1
        // validates them here (one shared syntax, one error surface)
        // and `check::run` matches them against its findings.
        if !RULES.contains(&rule.as_str()) && !check::RULES.contains(&rule.as_str()) {
            return Some(Err(format!("unknown rule `{rule}` in allow marker")));
        }
        let after = inner[close + 1..].trim_start();
        let reason = match after.strip_prefix(':') {
            Some(r) => r.trim(),
            None => return Some(Err(format!("allow({rule}) is missing `: <reason>`"))),
        };
        if reason.is_empty() {
            return Some(Err(format!("allow({rule}) has an empty reason")));
        }
        return Some(Ok(Marker::Allow { rule, reason: reason.to_string() }));
    }
    Some(Err(format!("unrecognized lint marker `{t}`")))
}

#[derive(Debug)]
struct Suppression {
    rule: String,
    line: u32,
    /// Inclusive line range this suppression covers.
    covers: (u32, u32),
    used: bool,
}

/// How far a standalone suppression extends: through the next line
/// containing a statement/block terminator, capped defensively.
pub(crate) const STANDALONE_COVER_CAP: u32 = 12;

pub(crate) fn suppression_cover(standalone: bool, line: u32, lines: &[&str]) -> (u32, u32) {
    if !standalone {
        return (line, line);
    }
    let mut end = line + 1;
    let last = lines.len() as u32;
    while end <= last && end - line <= STANDALONE_COVER_CAP {
        let text = lines[(end - 1) as usize];
        if text.contains(';') || text.contains('{') || text.contains('}') {
            break;
        }
        end += 1;
    }
    (line + 1, end.min(last))
}

// ------------------------------------------------------- test regions

/// Mark every line belonging to a `#[test]` / `#[cfg(test)]`-gated item
/// (attribute through the end of the item). All rules skip those lines.
pub(crate) fn test_lines(toks: &[Tok], n_lines: u32) -> Vec<bool> {
    let mut marked = vec![false; n_lines as usize + 2];
    let is_p = |i: usize, c: char| {
        toks.get(i).is_some_and(|t| {
            t.kind == TokKind::Punct && t.text.len() == 1 && t.text.as_bytes()[0] == c as u8
        })
    };
    let mut i = 0usize;
    while i < toks.len() {
        if !(is_p(i, '#') && is_p(i + 1, '[')) {
            i += 1;
            continue;
        }
        // Scan the attribute to its matching `]`.
        let attr_start = i;
        let mut depth = 0i32;
        let mut j = i + 1;
        let mut has_test = false;
        let mut has_not = false;
        while j < toks.len() {
            let t = &toks[j];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "[" | "(" => depth += 1,
                    "]" | ")" => {
                        depth -= 1;
                        if depth == 0 {
                            j += 1;
                            break;
                        }
                    }
                    _ => {}
                }
            } else if t.kind == TokKind::Ident {
                if t.text == "test" {
                    has_test = true;
                } else if t.text == "not" {
                    has_not = true;
                }
            }
            j += 1;
        }
        if !(has_test && !has_not) {
            i = j;
            continue;
        }
        // Skip any further stacked attributes.
        while is_p(j, '#') && is_p(j + 1, '[') {
            let mut d = 0i32;
            j += 1;
            while j < toks.len() {
                if toks[j].kind == TokKind::Punct {
                    match toks[j].text.as_str() {
                        "[" | "(" => d += 1,
                        "]" | ")" => {
                            d -= 1;
                            if d == 0 {
                                j += 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                }
                j += 1;
            }
        }
        // Skip the item: to a `;` outside any bracket, or through the
        // matching `}` of its first top-level brace block.
        let mut pd = 0i32; // () and []
        let mut bd = 0i32; // {}
        let mut started = false;
        while j < toks.len() {
            let t = &toks[j];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "(" | "[" => pd += 1,
                    ")" | "]" => pd -= 1,
                    "{" => {
                        bd += 1;
                        started = true;
                    }
                    "}" => {
                        bd -= 1;
                        if started && bd == 0 {
                            j += 1;
                            break;
                        }
                    }
                    ";" if pd == 0 && bd == 0 && !started => {
                        j += 1;
                        break;
                    }
                    _ => {}
                }
            }
            j += 1;
        }
        let start_line = toks[attr_start].line;
        let end_line = if j > 0 && j <= toks.len() { toks[j - 1].line } else { n_lines };
        for l in start_line..=end_line.min(n_lines) {
            marked[l as usize] = true;
        }
        i = j;
    }
    marked
}

// -------------------------------------------------------- file context

/// Everything a rule sees about one file.
pub(crate) struct FileCtx<'a> {
    pub rel: &'a str,
    pub toks: &'a [Tok],
    /// Lines carrying a `// lint: hot-path` marker.
    pub hot_markers: &'a [u32],
    pub registry: &'a LabelRegistry,
}

impl FileCtx<'_> {
    pub(crate) fn ident(&self, i: usize) -> Option<&str> {
        match self.toks.get(i) {
            Some(t) if t.kind == TokKind::Ident => Some(&t.text),
            _ => None,
        }
    }

    pub(crate) fn is_punct(&self, i: usize, c: char) -> bool {
        self.toks
            .get(i)
            .is_some_and(|t| t.kind == TokKind::Punct && t.text.len() == 1 && t.text.as_bytes()[0] == c as u8)
    }

    pub(crate) fn diag(&self, line: u32, rule: &'static str, message: String) -> Diagnostic {
        Diagnostic { file: self.rel.to_string(), line, rule, message }
    }

    /// True when `prefix` is one of the module-path prefixes of this
    /// file (e.g. `in_module(&["sim/", "cluster/"])`).
    pub(crate) fn in_module(&self, prefixes: &[&str]) -> bool {
        prefixes.iter().any(|p| self.rel.starts_with(p))
    }
}

// ------------------------------------------------------------- driver

/// Outcome of linting one file (exposed for fixture tests).
#[derive(Debug, Default)]
pub(crate) struct FileLint {
    pub kept: Vec<Diagnostic>,
    pub suppressed: Vec<Diagnostic>,
    pub unused: Vec<UnusedSuppression>,
    pub notes: Vec<String>,
}

/// Lint one file's source text against a prebuilt registry. This is
/// the unit the fixture tests drive; [`run`] maps it over the tree.
pub(crate) fn lint_file_source(rel: &str, source: &str, registry: &LabelRegistry) -> FileLint {
    let LexOutput { toks, comments, n_lines } = lexer::lex(source);
    let lines: Vec<&str> = source.lines().collect();
    let tests = test_lines(&toks, n_lines);
    let mut suppressions: Vec<Suppression> = Vec::new();
    let mut hot_markers: Vec<u32> = Vec::new();
    let mut out = FileLint::default();

    for c in &comments {
        if tests.get(c.line as usize).copied().unwrap_or(false) {
            continue;
        }
        match parse_marker(&c.text) {
            None => {}
            Some(Err(e)) => out.notes.push(format!("{rel}:{}: {e}", c.line)),
            Some(Ok(Marker::HotPath)) => hot_markers.push(c.line),
            Some(Ok(Marker::Allow { rule, .. })) => {
                // Tier-2 suppressions belong to `check::run`; creating
                // a tier-1 suppression for them here would only ever
                // report it unused.
                if rule.starts_with("check-") {
                    continue;
                }
                let covers = suppression_cover(c.standalone, c.line, &lines);
                suppressions.push(Suppression { rule, line: c.line, covers, used: false });
            }
        }
    }

    let ctx = FileCtx { rel, toks: &toks, hot_markers: &hot_markers, registry };
    let mut raw: Vec<Diagnostic> = Vec::new();
    wall_clock::check(&ctx, &mut raw);
    unordered_iter::check(&ctx, &mut raw);
    rng_labels::check(&ctx, &mut raw);
    raw_ids::check(&ctx, &mut raw);
    hot_path::check(&ctx, &mut raw);
    panic_surface::check(&ctx, &mut raw);

    for d in raw {
        if tests.get(d.line as usize).copied().unwrap_or(false) {
            continue;
        }
        let hit = suppressions
            .iter_mut()
            .find(|s| s.rule == d.rule && s.covers.0 <= d.line && d.line <= s.covers.1);
        match hit {
            Some(s) => {
                s.used = true;
                out.suppressed.push(d);
            }
            None => out.kept.push(d),
        }
    }
    for s in &suppressions {
        if !s.used {
            out.unused.push(UnusedSuppression {
                file: rel.to_string(),
                line: s.line,
                rule: s.rule.clone(),
            });
        }
    }
    out
}

pub(crate) fn walk_rs_files(root: &Path) -> Result<Vec<PathBuf>, String> {
    let mut stack = vec![root.to_path_buf()];
    let mut files = Vec::new();
    while let Some(dir) = stack.pop() {
        let entries =
            std::fs::read_dir(&dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        for entry in entries {
            let entry = entry.map_err(|e| format!("read_dir entry: {e}"))?;
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|x| x == "rs") {
                files.push(path);
            }
        }
    }
    files.sort();
    Ok(files)
}

/// Run the full pass over every `.rs` file under `src_root` (the
/// crate's `src/` directory). The RNG label registry is parsed from
/// `src_root/util/rng_labels.rs` first; a missing or inconsistent
/// registry is itself a `rng-label-registry` diagnostic.
pub fn run(src_root: &Path) -> Result<LintReport, String> {
    let mut report = LintReport::default();
    for rule in RULES {
        report.rule_counts.insert(rule, RuleCount::default());
    }

    let registry_rel = "util/rng_labels.rs";
    let registry_path = src_root.join(registry_rel);
    let registry = match std::fs::read_to_string(&registry_path) {
        Ok(src) => {
            let (reg, problems) = LabelRegistry::parse(&src);
            for p in problems {
                report.diagnostics.push(Diagnostic {
                    file: registry_rel.to_string(),
                    line: 1,
                    rule: "rng-label-registry",
                    message: p,
                });
            }
            reg
        }
        Err(e) => {
            report.diagnostics.push(Diagnostic {
                file: registry_rel.to_string(),
                line: 1,
                rule: "rng-label-registry",
                message: format!("label registry unreadable: {e}"),
            });
            LabelRegistry::default()
        }
    };

    for path in walk_rs_files(src_root)? {
        let rel_os = path
            .strip_prefix(src_root)
            .map_err(|e| format!("strip_prefix: {e}"))?
            .to_string_lossy()
            .replace('\\', "/");
        let source = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let file_lint = lint_file_source(&rel_os, &source, &registry);
        report.files_scanned += 1;
        for d in file_lint.kept {
            if let Some(c) = report.rule_counts.get_mut(d.rule) {
                c.violations += 1;
            }
            report.diagnostics.push(d);
        }
        for d in file_lint.suppressed {
            if let Some(c) = report.rule_counts.get_mut(d.rule) {
                c.suppressed += 1;
            }
            report.suppressed += 1;
        }
        report.unused_suppressions.extend(file_lint.unused);
        report.notes.extend(file_lint.notes);
    }

    report
        .diagnostics
        .sort_by(|a, b| (&a.file, a.line, a.rule, &a.message).cmp(&(&b.file, b.line, b.rule, &b.message)));
    report
        .unused_suppressions
        .sort_by(|a, b| (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule)));
    report.notes.sort();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empty_registry() -> LabelRegistry {
        LabelRegistry::default()
    }

    #[test]
    fn marker_parsing() {
        assert_eq!(parse_marker(" just a comment"), None);
        assert_eq!(parse_marker(" lint: hot-path"), Some(Ok(Marker::HotPath)));
        let m = parse_marker(" lint: allow(panic-surface): lock is uncontended");
        assert_eq!(
            m,
            Some(Ok(Marker::Allow {
                rule: "panic-surface".to_string(),
                reason: "lock is uncontended".to_string(),
            }))
        );
        assert!(matches!(parse_marker(" lint: allow(panic-surface):"), Some(Err(_))));
        assert!(matches!(parse_marker(" lint: allow(nope): reason"), Some(Err(_))));
        assert!(matches!(parse_marker(" lint: frobnicate"), Some(Err(_))));
    }

    #[test]
    fn trailing_suppression_covers_its_line() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap() // lint: allow(panic-surface): caller checked\n}\n";
        let out = lint_file_source("sim/fixture.rs", src, &empty_registry());
        assert!(out.kept.is_empty(), "kept: {:?}", out.kept);
        assert_eq!(out.suppressed.len(), 1);
        assert!(out.unused.is_empty());
    }

    #[test]
    fn standalone_suppression_covers_a_multiline_statement() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    // lint: allow(panic-surface): invariant upheld by caller\n    x\n        .unwrap()\n}\n";
        let out = lint_file_source("sim/fixture.rs", src, &empty_registry());
        // `.unwrap()` sits two lines below the marker; the standalone
        // cover extends through the first `;`/`{`/`}` line.
        assert!(out.kept.is_empty(), "kept: {:?}", out.kept);
        assert_eq!(out.suppressed.len(), 1);
    }

    #[test]
    fn unused_suppressions_are_reported_not_fatal() {
        let src = "// lint: allow(panic-surface): nothing here\nfn f() {}\n";
        let out = lint_file_source("sim/fixture.rs", src, &empty_registry());
        assert!(out.kept.is_empty());
        assert_eq!(out.unused.len(), 1);
        assert_eq!(out.unused[0].rule, "panic-surface");
    }

    #[test]
    fn malformed_markers_become_notes() {
        let src = "// lint: allow(panic-surface) no colon\nfn f() {}\n";
        let out = lint_file_source("sim/fixture.rs", src, &empty_registry());
        assert_eq!(out.notes.len(), 1);
    }

    #[test]
    fn test_items_are_exempt_from_all_rules() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let x: Option<u32> = None;\n        x.unwrap();\n    }\n}\n";
        let out = lint_file_source("sim/fixture.rs", src, &empty_registry());
        assert!(out.kept.is_empty(), "kept: {:?}", out.kept);
    }

    #[test]
    fn cfg_not_test_is_not_a_test_region() {
        let src = "#[cfg(not(test))]\nfn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
        let out = lint_file_source("sim/fixture.rs", src, &empty_registry());
        assert_eq!(out.kept.len(), 1);
        assert_eq!(out.kept[0].rule, "panic-surface");
    }

    #[test]
    fn json_is_deterministic_and_escaped() {
        let mut r = LintReport::default();
        r.rule_counts.insert("panic-surface", RuleCount { violations: 1, suppressed: 2 });
        r.diagnostics.push(Diagnostic {
            file: "sim/a.rs".to_string(),
            line: 3,
            rule: "panic-surface",
            message: "say \"why\"".to_string(),
        });
        let a = r.to_json();
        let b = r.to_json();
        assert_eq!(a, b);
        assert!(a.contains("\\\"why\\\""));
        assert!(a.contains("\"schema\": \"pallas-lint/1\""));
    }
}
