//! `unordered-iter`: `HashMap`/`HashSet` inside the sim/report-surface
//! modules is a diagnostic. Iterating either feeds randomized order
//! into whatever consumes it; if that consumer is (or ever becomes) an
//! observable — a report, a golden, a tie-break — determinism dies
//! silently. The fix is `BTreeMap`/sorted keys, or, when the container
//! is provably keyed-access-only (insert/get/remove, never iterated),
//! a suppression stating that argument so the next editor re-audits
//! before adding a loop.
//!
//! `use` declaration lines are exempt (flagging both the import and
//! every mention would double-count a single decision).

use super::{Diagnostic, FileCtx};
use crate::lint::lexer::TokKind;

const RULE: &str = "unordered-iter";

/// Module prefixes whose state can reach a report observable.
const SCOPE: [&str; 8] = [
    "sim/",
    "cluster/",
    "sched/",
    "transient/",
    "metrics/",
    "trace/",
    "runtime/",
    "coordinator/",
];

const BANNED: [&str; 2] = ["HashMap", "HashSet"];

pub(crate) fn check(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    if !ctx.in_module(&SCOPE) {
        return;
    }
    // First ident on each line, to recognize `use …;` lines.
    let mut line_leader: Vec<(u32, String)> = Vec::new();
    for t in ctx.toks {
        if line_leader.last().map(|(l, _)| *l) != Some(t.line) {
            let leader = if t.kind == TokKind::Ident { t.text.clone() } else { String::new() };
            line_leader.push((t.line, leader));
        }
    }
    let leader_of = |line: u32| -> &str {
        line_leader
            .iter()
            .find(|(l, _)| *l == line)
            .map(|(_, s)| s.as_str())
            .unwrap_or("")
    };
    for (i, t) in ctx.toks.iter().enumerate() {
        let Some(name) = ctx.ident(i) else { continue };
        if BANNED.contains(&name) && leader_of(t.line) != "use" {
            out.push(ctx.diag(
                t.line,
                RULE,
                format!(
                    "`{name}` in a sim/report-surface module: iteration order is \
                     randomized; use BTreeMap/sorted keys, or suppress with the \
                     keyed-access-only argument"
                ),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::lint::{lint_file_source, LabelRegistry};

    #[test]
    fn flags_hashmap_in_sim_scope() {
        let src = "use std::collections::HashMap;\nstruct S { m: HashMap<u32, f64> }\n";
        let out = lint_file_source("sim/state.rs", src, &LabelRegistry::default());
        let hits: Vec<_> = out.kept.iter().filter(|d| d.rule == "unordered-iter").collect();
        // The `use` line is exempt; the field declaration is flagged.
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].line, 2);
    }

    #[test]
    fn out_of_scope_modules_pass() {
        let src = "use std::collections::HashMap;\nstruct S { m: HashMap<u32, f64> }\n";
        let out = lint_file_source("util/scratch.rs", src, &LabelRegistry::default());
        assert!(out.kept.iter().all(|d| d.rule != "unordered-iter"));
    }

    #[test]
    fn suppression_with_keyed_access_argument() {
        let src = "struct S {\n    // lint: allow(unordered-iter): keyed access only, never iterated\n    m: std::collections::HashMap<u32, f64>,\n}\n";
        let out = lint_file_source("sim/state.rs", src, &LabelRegistry::default());
        assert!(out.kept.iter().all(|d| d.rule != "unordered-iter"), "{:?}", out.kept);
        assert_eq!(out.suppressed.len(), 1);
    }

    #[test]
    fn btreemap_passes() {
        let src = "use std::collections::BTreeMap;\nstruct S { m: BTreeMap<u32, f64> }\n";
        let out = lint_file_source("sim/state.rs", src, &LabelRegistry::default());
        assert!(out.kept.iter().all(|d| d.rule != "unordered-iter"));
    }
}
