//! `panic-surface`: `.unwrap()` / `.expect(..)` / `panic!(..)` in the
//! library sim paths must carry a justification. A panic inside the
//! event loop is a correct response to a broken invariant — and a
//! terrible one to a recoverable condition; the rule forces each site
//! to state which it is via `// lint: allow(panic-surface): <why>`.
//! The justification *is* the suppression: every surviving site reads
//! as a documented invariant, and a new bare `unwrap` fails the gate.
//!
//! Scope: the library modules a simulation run executes. The
//! coordinator/CLI/benchkit layers are exempt — a driver aborting on
//! bad input is fine — as are `unwrap_or`/`unwrap_or_else`/
//! `unwrap_or_default` (they don't panic) and `unreachable!`/`assert!`
//! (self-justifying by name).

use super::{Diagnostic, FileCtx};

const RULE: &str = "panic-surface";

/// Library sim paths: code that runs inside a simulation.
const SCOPE: [&str; 7] =
    ["sim/", "cluster/", "sched/", "transient/", "metrics/", "trace/", "util/"];

pub(crate) fn check(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    if !ctx.in_module(&SCOPE) {
        return;
    }
    for (i, t) in ctx.toks.iter().enumerate() {
        let Some(name) = ctx.ident(i) else { continue };
        // .unwrap() / .expect(
        if (name == "unwrap" || name == "expect")
            && ctx.is_punct(i.wrapping_sub(1), '.')
            && ctx.is_punct(i + 1, '(')
        {
            out.push(ctx.diag(
                t.line,
                RULE,
                format!(
                    "`.{name}` in a library sim path: justify the invariant with \
                     `// lint: allow(panic-surface): <why>` or handle the None/Err"
                ),
            ));
            continue;
        }
        // panic!(
        if name == "panic" && ctx.is_punct(i + 1, '!') {
            out.push(ctx.diag(
                t.line,
                RULE,
                "`panic!` in a library sim path: justify with \
                 `// lint: allow(panic-surface): <why>`"
                    .to_string(),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::lint::{lint_file_source, LabelRegistry};

    #[test]
    fn flags_unwrap_expect_panic_in_scope() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    let a = x.unwrap();\n    let b = x.expect(\"b\");\n    if a + b > 9 { panic!(\"boom\") }\n    a\n}\n";
        let out = lint_file_source("trace/x.rs", src, &LabelRegistry::default());
        let hits: Vec<_> = out.kept.iter().filter(|d| d.rule == "panic-surface").collect();
        assert_eq!(hits.len(), 3, "{hits:?}");
    }

    #[test]
    fn non_panicking_unwrap_variants_pass() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap_or(0) + x.unwrap_or_else(|| 1) + x.unwrap_or_default()\n}\n";
        let out = lint_file_source("sim/x.rs", src, &LabelRegistry::default());
        assert!(out.kept.iter().all(|d| d.rule != "panic-surface"), "{:?}", out.kept);
    }

    #[test]
    fn driver_layers_are_exempt() {
        let src = "fn main() { std::fs::read(\"x\").unwrap(); }\n";
        for rel in ["coordinator/report.rs", "bin/cli.rs", "benchkit.rs"] {
            let out = lint_file_source(rel, src, &LabelRegistry::default());
            assert!(out.kept.iter().all(|d| d.rule != "panic-surface"), "{rel}");
        }
    }

    #[test]
    fn justified_sites_pass() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    // lint: allow(panic-surface): x is populated by the caller's invariant\n    x.unwrap()\n}\n";
        let out = lint_file_source("cluster/x.rs", src, &LabelRegistry::default());
        assert!(out.kept.iter().all(|d| d.rule != "panic-surface"), "{:?}", out.kept);
        assert_eq!(out.suppressed.len(), 1);
    }

    #[test]
    fn unreachable_and_asserts_pass() {
        let src = "fn f(n: u32) {\n    assert!(n > 0);\n    match n { 0 => unreachable!(\"checked\"), _ => {} }\n}\n";
        let out = lint_file_source("sim/x.rs", src, &LabelRegistry::default());
        assert!(out.kept.iter().all(|d| d.rule != "panic-surface"), "{:?}", out.kept);
    }
}
