//! `hot-path-no-alloc`: a function marked with a standalone
//! `// lint: hot-path` comment is scanned for allocating calls —
//! `Vec::new`, `String::new`, `vec![`, `format!(`, `.to_vec()`,
//! `.collect()`, `Box::new`, `Box::from`, `.clone()`, `.to_string()`,
//! `.to_owned()`. This turns PR 8's zero-alloc event-loop campaign
//! from after-the-fact pool counters into a gate that fires at lint
//! time, on the exact functions the profiler showed on the hot path.
//!
//! The marker attaches to the next `fn` item; the scan covers its
//! body (first `{` after the `fn` keyword through the matching `}`).
//! `Vec::with_capacity` is deliberately not banned: one-time arena
//! sizing inside setup branches is amortized, and banning it would
//! just push people to `resize`-style churn.

use super::{Diagnostic, FileCtx};
use crate::lint::lexer::TokKind;

const RULE: &str = "hot-path-no-alloc";

/// `.method()` calls that allocate.
const BANNED_METHODS: [&str; 5] = ["to_vec", "collect", "clone", "to_string", "to_owned"];

pub(crate) fn check(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    for &marker_line in ctx.hot_markers {
        // First `fn` token strictly after the marker line.
        let fn_idx = ctx
            .toks
            .iter()
            .position(|t| t.line > marker_line && t.kind == TokKind::Ident && t.text == "fn");
        let Some(fn_idx) = fn_idx else { continue };
        // Body: first `{` after the fn keyword, brace-matched.
        let Some(open) = (fn_idx..ctx.toks.len()).find(|&i| ctx.is_punct(i, '{')) else {
            continue;
        };
        let mut depth = 0i32;
        let mut close = open;
        for i in open..ctx.toks.len() {
            if ctx.is_punct(i, '{') {
                depth += 1;
            } else if ctx.is_punct(i, '}') {
                depth -= 1;
                if depth == 0 {
                    close = i;
                    break;
                }
            }
        }
        let fn_name = ctx.ident(fn_idx + 1).unwrap_or("<anonymous>").to_string();
        scan_body(ctx, open, close, &fn_name, out);
    }
}

fn scan_body(
    ctx: &FileCtx,
    open: usize,
    close: usize,
    fn_name: &str,
    out: &mut Vec<Diagnostic>,
) {
    for i in open..close {
        let line = ctx.toks[i].line;
        // Vec::new / Box::new / String::new / Box::from
        if let Some(head) = ctx.ident(i) {
            if (head == "Vec" || head == "Box" || head == "String")
                && ctx.is_punct(i + 1, ':')
                && ctx.is_punct(i + 2, ':')
            {
                let tail = ctx.ident(i + 3);
                if tail == Some("new") || (head == "Box" && tail == Some("from")) {
                    out.push(ctx.diag(
                        line,
                        RULE,
                        format!("`{head}::{}` in hot-path fn `{fn_name}`", tail.unwrap()),
                    ));
                    continue;
                }
            }
            // vec![ / format!(
            if (head == "vec" || head == "format") && ctx.is_punct(i + 1, '!') {
                out.push(ctx.diag(
                    line,
                    RULE,
                    format!("`{head}!` in hot-path fn `{fn_name}`"),
                ));
                continue;
            }
        }
        // .to_vec() / .collect() / .clone()
        if ctx.is_punct(i, '.') {
            if let Some(m) = ctx.ident(i + 1) {
                if BANNED_METHODS.contains(&m) && ctx.is_punct(i + 2, '(') {
                    out.push(ctx.diag(
                        ctx.toks[i + 1].line,
                        RULE,
                        format!("`.{m}()` in hot-path fn `{fn_name}`"),
                    ));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::lint::{lint_file_source, LabelRegistry};

    #[test]
    fn flags_allocation_in_marked_fn() {
        let src = "// lint: hot-path\nfn step(&mut self) {\n    let v: Vec<u32> = Vec::new();\n    let w = v.clone();\n    let _ = w;\n}\n";
        let out = lint_file_source("sim/x.rs", src, &LabelRegistry::default());
        let hits: Vec<_> = out.kept.iter().filter(|d| d.rule == "hot-path-no-alloc").collect();
        assert_eq!(hits.len(), 2, "{hits:?}");
    }

    #[test]
    fn unmarked_fns_are_not_scanned() {
        let src = "fn setup() -> Vec<u32> {\n    (0..4).collect()\n}\n";
        let out = lint_file_source("sim/x.rs", src, &LabelRegistry::default());
        assert!(out.kept.iter().all(|d| d.rule != "hot-path-no-alloc"));
    }

    #[test]
    fn marker_scope_ends_at_fn_body() {
        let src = "// lint: hot-path\nfn hot(&mut self) -> u32 {\n    self.n\n}\n\nfn cold() -> Vec<u32> {\n    vec![1, 2]\n}\n";
        let out = lint_file_source("sim/x.rs", src, &LabelRegistry::default());
        assert!(
            out.kept.iter().all(|d| d.rule != "hot-path-no-alloc"),
            "cold() is past hot()'s body: {:?}",
            out.kept
        );
    }

    #[test]
    fn flags_string_allocations() {
        let src = "// lint: hot-path\nfn step(&mut self) {\n    let s = String::new();\n    let t = format!(\"{s}\");\n    let u = t.to_string();\n    let v = u.to_owned();\n    let b = Box::from(v);\n    let _ = b;\n}\n";
        let out = lint_file_source("sim/x.rs", src, &LabelRegistry::default());
        let hits: Vec<_> = out.kept.iter().filter(|d| d.rule == "hot-path-no-alloc").collect();
        assert_eq!(hits.len(), 5, "{hits:?}");
    }

    #[test]
    fn with_capacity_is_allowed() {
        let src = "// lint: hot-path\nfn grow(&mut self) {\n    self.buf = Vec::with_capacity(64);\n}\n";
        let out = lint_file_source("sim/x.rs", src, &LabelRegistry::default());
        assert!(out.kept.iter().all(|d| d.rule != "hot-path-no-alloc"));
    }

    #[test]
    fn suppression_inside_hot_fn() {
        let src = "// lint: hot-path\nfn step(&mut self) {\n    // lint: allow(hot-path-no-alloc): one-time lazy init on first event\n    self.scratch = Vec::new();\n}\n";
        let out = lint_file_source("sim/x.rs", src, &LabelRegistry::default());
        assert!(out.kept.iter().all(|d| d.rule != "hot-path-no-alloc"), "{:?}", out.kept);
        assert_eq!(out.suppressed.len(), 1);
    }
}
