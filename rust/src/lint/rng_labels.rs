//! `rng-label-registry`: every RNG fork label at a call site must be a
//! named constant from the single registry table in
//! `util/rng_labels.rs`, and registry labels must be unique
//! crate-wide. Stream identity is what makes runs reproducible across
//! engines, thread counts and PDES windows; a raw `0x..` literal at a
//! call site is an unregistered stream that nothing audits, and two
//! registry entries with the same value are two streams that silently
//! collide.
//!
//! Call-site matching: an ident `fork` / `fork_rng` followed by `(`.
//! An integer literal argument is always a violation; an `RNG_*` ident
//! must exist in the registry; any other expression (a `label`
//! parameter being passed through, `self.label`, …) is out of the
//! rule's static reach and passes.

use super::{Diagnostic, FileCtx};
use crate::lint::lexer::{self, TokKind};

const RULE: &str = "rng-label-registry";

/// The parsed `util/rng_labels.rs` table: `(name, value)` per
/// `pub const RNG_…: u64 = 0x…;` entry, in file order.
#[derive(Debug, Clone, Default)]
pub struct LabelRegistry {
    pub entries: Vec<(String, u64)>,
}

impl LabelRegistry {
    pub fn contains(&self, name: &str) -> bool {
        self.entries.iter().any(|(n, _)| n == name)
    }

    /// Parse the registry source. Returns the table plus any
    /// consistency problems (duplicate names or values) phrased as
    /// diagnostic messages.
    pub fn parse(source: &str) -> (LabelRegistry, Vec<String>) {
        let toks = lexer::lex(source).toks;
        let mut entries: Vec<(String, u64)> = Vec::new();
        let mut problems = Vec::new();
        let mut i = 0usize;
        while i < toks.len() {
            let is_const =
                toks[i].kind == TokKind::Ident && toks[i].text == "const";
            if is_const {
                let name_ok = toks
                    .get(i + 1)
                    .filter(|t| t.kind == TokKind::Ident && t.text.starts_with("RNG_"));
                if let Some(name_tok) = name_ok {
                    // Scan forward to `= <int> ;`.
                    let mut j = i + 2;
                    let mut value = None;
                    while j < toks.len() && j < i + 10 {
                        if toks[j].kind == TokKind::Punct && toks[j].text == ";" {
                            break;
                        }
                        if toks[j].kind == TokKind::Int {
                            value = lexer::parse_int_literal(&toks[j].text);
                            break;
                        }
                        j += 1;
                    }
                    match value {
                        Some(v) => entries.push((name_tok.text.clone(), v)),
                        None => problems.push(format!(
                            "registry constant `{}` has no parseable integer value",
                            name_tok.text
                        )),
                    }
                }
            }
            i += 1;
        }
        for (idx, (name, value)) in entries.iter().enumerate() {
            for (name2, value2) in &entries[idx + 1..] {
                if name == name2 {
                    problems.push(format!("duplicate registry label name `{name}`"));
                }
                if value == value2 {
                    problems.push(format!(
                        "registry labels `{name}` and `{name2}` collide on value {value:#x}"
                    ));
                }
            }
        }
        (LabelRegistry { entries }, problems)
    }
}

pub(crate) fn check(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    for (i, t) in ctx.toks.iter().enumerate() {
        let Some(name) = ctx.ident(i) else { continue };
        if name != "fork" && name != "fork_rng" {
            continue;
        }
        if !ctx.is_punct(i + 1, '(') {
            continue;
        }
        // First token of the argument list.
        let Some(arg) = ctx.toks.get(i + 2) else { continue };
        match arg.kind {
            TokKind::Int => {
                let shown = &arg.text;
                out.push(ctx.diag(
                    t.line,
                    RULE,
                    format!(
                        "raw fork label `{shown}`: use a named `RNG_*` constant from \
                         util/rng_labels.rs so the stream is registered and collision-checked"
                    ),
                ));
            }
            TokKind::Ident if arg.text.starts_with("RNG_") => {
                if !ctx.registry.contains(&arg.text) {
                    out.push(ctx.diag(
                        t.line,
                        RULE,
                        format!(
                            "fork label `{}` is not in the util/rng_labels.rs registry",
                            arg.text
                        ),
                    ));
                }
            }
            // `&mut self` in the definition, a passed-through `label`
            // parameter, `self.label`, … — not statically checkable.
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::LabelRegistry;
    use crate::lint::lint_file_source;

    const REGISTRY_SRC: &str = "pub const RNG_SCHED: u64 = 0x5C;\npub const RNG_ARRIVALS: u64 = 0xAE;\n";

    fn registry() -> LabelRegistry {
        let (reg, problems) = LabelRegistry::parse(REGISTRY_SRC);
        assert!(problems.is_empty(), "{problems:?}");
        reg
    }

    #[test]
    fn registry_parses_names_and_values() {
        let reg = registry();
        assert_eq!(reg.entries.len(), 2);
        assert_eq!(reg.entries[0], ("RNG_SCHED".to_string(), 0x5C));
        assert!(reg.contains("RNG_ARRIVALS"));
        assert!(!reg.contains("RNG_NOPE"));
    }

    #[test]
    fn registry_value_collisions_are_reported() {
        let src = "pub const RNG_A: u64 = 0x10;\npub const RNG_B: u64 = 0x10;\n";
        let (_, problems) = LabelRegistry::parse(src);
        assert_eq!(problems.len(), 1);
        assert!(problems[0].contains("collide"));
    }

    #[test]
    fn raw_literal_labels_are_flagged() {
        let src = "fn f(rng: &mut Rng) { let _ = rng.fork(0x5C); }\n";
        let out = lint_file_source("sim/x.rs", src, &registry());
        let hits: Vec<_> = out.kept.iter().filter(|d| d.rule == "rng-label-registry").collect();
        assert_eq!(hits.len(), 1, "{hits:?}");
    }

    #[test]
    fn registered_constants_pass_unknown_ones_fail() {
        let ok = "fn f(rng: &mut Rng) { let _ = rng.fork(RNG_SCHED); }\n";
        let out = lint_file_source("sim/x.rs", ok, &registry());
        assert!(out.kept.iter().all(|d| d.rule != "rng-label-registry"));

        let bad = "fn f(rng: &mut Rng) { let _ = rng.fork(RNG_NOPE); }\n";
        let out = lint_file_source("sim/x.rs", bad, &registry());
        assert_eq!(
            out.kept.iter().filter(|d| d.rule == "rng-label-registry").count(),
            1
        );
    }

    #[test]
    fn passthrough_parameters_and_definitions_pass() {
        let src = "impl W {\n    pub fn fork_rng(&mut self, label: u64) -> Rng {\n        self.root.fork(label)\n    }\n}\n";
        let out = lint_file_source("sim/x.rs", src, &registry());
        assert!(out.kept.iter().all(|d| d.rule != "rng-label-registry"), "{:?}", out.kept);
    }
}
