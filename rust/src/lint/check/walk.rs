//! Reference walker for `pallas-check`: scans one file's token stream
//! and records every checkable *use* of a name — multi-segment paths,
//! calls with argument counts, struct literals/patterns with their
//! field lists, and `self.field` / `self.method(…)` accesses — each
//! tagged with the module whose scope the reference appears in.
//!
//! Bare single identifiers are never recorded: they could be local
//! variables, which this pass cannot see. Multi-segment paths are the
//! checkable surface (`a::b` must resolve no matter what locals exist).

use std::collections::BTreeMap;
use std::collections::BTreeSet;

use super::parse::{
    is_punct, match_close, skip_attr, FileParse, ModItems, KEYWORDS_NOT_PATH_START,
};
use crate::lint::lexer::TokKind;

/// References collected for one module's scope.
#[derive(Debug, Default)]
pub(crate) struct RefSink {
    /// (segments, line) — existence-checked only.
    pub paths: Vec<(Vec<String>, u32)>,
    /// (segments, nargs, line, has_top_level_dotdot).
    pub calls: Vec<(Vec<String>, usize, u32, bool)>,
    /// (segments, [(field, line)], has_base, line).
    pub struct_lits: Vec<(Vec<String>, Vec<(String, u32)>, bool, u32)>,
    /// (field name, line, impl type name).
    pub self_fields: Vec<(String, u32, String)>,
    /// (method name, nargs, line, impl type name, has_dotdot).
    pub self_methods: Vec<(String, usize, u32, String, bool)>,
}

/// Count call arguments between `(` at `lo` and its matching `)` at
/// `hi - 1`. Returns `(nargs, has_top_level_dotdot)` — a top-level
/// `..` (rest pattern or range) makes the count unreliable, so callers
/// skip arity checks when it is set.
pub(crate) fn count_args(toks: &[crate::lint::lexer::Tok], lo: usize, hi: usize) -> (usize, bool) {
    let mut i = lo + 1;
    let end = hi.saturating_sub(1);
    if i >= end {
        return (0, false);
    }
    let mut has_dotdot = false;
    let mut nargs = 1usize;
    let mut depth = 0i32;
    // Last significant token text, for closure-at-arg-start detection.
    let mut prev: Option<&str> = Some("(");
    while i < end {
        let t = &toks[i];
        if t.kind == TokKind::Punct {
            let c = t.text.as_str();
            match c {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => depth -= 1,
                "," if depth == 0 => nargs += 1,
                "." if depth == 0 => {
                    if i + 1 < end && is_punct(toks, i + 1, '.') {
                        has_dotdot = true;
                    }
                }
                ":" if depth == 0 => {
                    // Turbofish `::<…>` — skip the angle group.
                    if i + 1 < end
                        && is_punct(toks, i + 1, ':')
                        && i + 2 < end
                        && is_punct(toks, i + 2, '<')
                    {
                        let mut ad = 0i32;
                        let mut j = i + 2;
                        let mut prev2: Option<&str> = None;
                        while j < end {
                            let tt = &toks[j];
                            if tt.kind == TokKind::Punct {
                                if tt.text == "<" {
                                    ad += 1;
                                } else if tt.text == ">" && prev2 != Some("-") {
                                    ad -= 1;
                                    if ad == 0 {
                                        break;
                                    }
                                }
                                prev2 = Some(tt.text.as_str());
                            } else {
                                prev2 = None;
                            }
                            j += 1;
                        }
                        i = j + 1;
                        prev = Some(">");
                        continue;
                    }
                }
                "|" if depth == 0
                    && matches!(prev, Some("(") | Some(",") | Some("move")) =>
                {
                    // Closure at argument start: consume params up to
                    // the closing `|` (or `||` for no params).
                    if i + 1 < end && is_punct(toks, i + 1, '|') {
                        i += 2;
                        prev = Some("|");
                        continue;
                    }
                    let mut j = i + 1;
                    let mut d2 = 0i32;
                    while j < end {
                        let tt = &toks[j];
                        if tt.kind == TokKind::Punct {
                            match tt.text.as_str() {
                                "(" | "[" | "{" | "<" => d2 += 1,
                                ")" | "]" | "}" | ">" => d2 -= 1,
                                "|" if d2 == 0 => break,
                                _ => {}
                            }
                        }
                        j += 1;
                    }
                    i = j + 1;
                    prev = Some("|");
                    continue;
                }
                _ => {}
            }
            prev = Some(&toks[i].text);
        } else if t.kind == TokKind::Ident {
            prev = Some(&t.text);
        } else {
            prev = None;
        }
        i += 1;
    }
    if end > 0 && is_punct(toks, end - 1, ',') {
        nargs -= 1;
    }
    (nargs, has_dotdot)
}

/// Tokens that, directly before `Name {`, mean the brace is a block —
/// not a struct literal. `&` mostly precedes reference *types*
/// (`-> &Server {` starts a fn body); a borrowed struct literal
/// `&Foo { … }` goes unchecked (false-negative direction).
pub(crate) const STRUCT_LIT_BLOCKERS: [&str; 26] = [
    "impl", "for", "in", "dyn", "as", "where", "trait", "struct", "enum", "union", "fn", "mod",
    "use", "type", "else", "if", "while", "match", "loop", "return", "break", "move", "mut", "&",
    // `|x| Foo { … }` closure bodies are fine: prev is `|`, not listed.
    "unsafe", "do",
];

/// Field names + `..base` marker inside a struct literal or pattern
/// body (`lo..hi` exclusive of the braces).
pub(crate) fn collect_literal_fields(
    toks: &[crate::lint::lexer::Tok],
    lo: usize,
    hi: usize,
) -> (Vec<(String, u32)>, bool) {
    let mut fields = Vec::new();
    let mut has_base = false;
    let mut depth = 0i32;
    let mut at_entry_start = true;
    let mut j = lo;
    while j < hi {
        let t = &toks[j];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => depth -= 1,
                "," if depth == 0 => {
                    at_entry_start = true;
                    j += 1;
                    continue;
                }
                "." if depth == 0 && at_entry_start => {
                    // `..base` / `..` rest pattern.
                    has_base = true;
                    at_entry_start = false;
                }
                _ => {}
            }
            j += 1;
            continue;
        }
        if t.kind == TokKind::Ident && depth == 0 && at_entry_start {
            if matches!(t.text.as_str(), "ref" | "mut" | "box") {
                j += 1;
                continue;
            }
            // Shorthand `x` or `x: expr`; exclude `x::y` paths (a path
            // head, not a field name).
            let is_path = j + 1 < hi && is_punct(toks, j + 1, ':') && is_punct(toks, j + 2, ':');
            if !is_path {
                fields.push((t.text.clone(), t.line));
            }
            at_entry_start = false;
        } else if depth == 0 && at_entry_start && t.kind == TokKind::Int {
            // Tuple-struct numeric field init `0: x`.
            at_entry_start = false;
        }
        j += 1;
    }
    (fields, has_base)
}

/// Walk one file's tokens, emitting references keyed by the module
/// (arena index) whose span contains them.
pub(crate) struct Walker<'a> {
    toks: &'a [crate::lint::lexer::Tok],
    /// (tok_span, module idx), sorted by span size ascending so the
    /// first containing span is the innermost module.
    module_spans: Vec<((usize, usize), usize)>,
    /// (lo, hi, impl type name, impl generics).
    impl_spans: Vec<(usize, usize, Option<String>, BTreeSet<String>)>,
    /// (lo, hi, fn generic params).
    generic_spans: Vec<(usize, usize, BTreeSet<String>)>,
    skip_spans: Vec<(usize, usize)>,
    sinks: BTreeMap<usize, RefSink>,
}

impl<'a> Walker<'a> {
    pub fn new(fp: &'a FileParse, mut module_spans: Vec<((usize, usize), usize)>) -> Self {
        module_spans.sort_by_key(|(span, _)| span.1 - span.0);
        let mut skip_spans = fp.macro_spans.clone();
        skip_spans.sort_unstable();
        Walker {
            toks: &fp.toks,
            module_spans,
            impl_spans: Vec::new(),
            generic_spans: Vec::new(),
            skip_spans,
            sinks: BTreeMap::new(),
        }
    }

    /// Record impl body spans + fn generic spans from one module's
    /// items. The driver calls this for every arena module of the
    /// file (inline mods are separate arena nodes).
    pub fn prescan(&mut self, items: &ModItems) {
        for idef in &items.impls {
            self.impl_spans.push((
                idef.body.0,
                idef.body.1,
                idef.type_name.clone(),
                idef.generics.clone(),
            ));
            for fds in idef.methods.values() {
                for fd in fds {
                    if !fd.generics.is_empty() {
                        self.generic_spans.push((fd.body.0, fd.body.1, fd.generics.clone()));
                    }
                }
            }
        }
        for fds in items.fns.values() {
            for fd in fds {
                if !fd.generics.is_empty() {
                    self.generic_spans.push((fd.body.0, fd.body.1, fd.generics.clone()));
                }
            }
        }
    }

    fn module_for(&self, i: usize) -> Option<usize> {
        self.module_spans
            .iter()
            .find(|((lo, hi), _)| *lo <= i && i < *hi)
            .map(|&(_, m)| m)
    }

    /// Innermost impl block containing token `i` (largest `lo` wins).
    fn impl_type_at(&self, i: usize) -> (Option<&str>, Option<usize>) {
        let mut best: Option<usize> = None;
        for (k, (lo, hi, _, _)) in self.impl_spans.iter().enumerate() {
            if *lo <= i && i < *hi && best.is_none_or(|b| *lo >= self.impl_spans[b].0) {
                best = Some(k);
            }
        }
        (best.and_then(|k| self.impl_spans[k].2.as_deref()), best)
    }

    fn generic_in_scope(&self, i: usize, name: &str) -> bool {
        if self
            .generic_spans
            .iter()
            .any(|(lo, hi, g)| *lo <= i && i < *hi && g.contains(name))
        {
            return true;
        }
        let (_, k) = self.impl_type_at(i);
        k.is_some_and(|k| self.impl_spans[k].3.contains(name))
    }

    fn in_skip(&self, i: usize) -> Option<usize> {
        self.skip_spans.iter().find(|(lo, hi)| *lo <= i && i < *hi).map(|&(_, hi)| hi)
    }

    pub fn walk(mut self) -> BTreeMap<usize, RefSink> {
        let toks = self.toks;
        let n = toks.len();
        let mut i = 0usize;
        // Previous significant token texts (ident/punct only).
        let mut prev_sig: Option<String> = None;
        let mut prev_sig2: Option<String> = None;
        while i < n {
            if let Some(hi) = self.in_skip(i) {
                i = hi;
                prev_sig = None;
                prev_sig2 = None;
                continue;
            }
            let t = &toks[i];
            if t.kind == TokKind::Punct && t.text == "#" {
                if is_punct(toks, i + 1, '[') {
                    let (j, _) = skip_attr(toks, i);
                    i = j;
                    continue;
                }
                if is_punct(toks, i + 1, '!') && is_punct(toks, i + 2, '[') {
                    let mut depth = 0i32;
                    let mut j = i + 2;
                    while j < n {
                        if toks[j].kind == TokKind::Punct {
                            match toks[j].text.as_str() {
                                "[" | "(" => depth += 1,
                                "]" | ")" => {
                                    depth -= 1;
                                    if depth == 0 {
                                        j += 1;
                                        break;
                                    }
                                }
                                _ => {}
                            }
                        }
                        j += 1;
                    }
                    i = j;
                    continue;
                }
            }
            if t.kind == TokKind::Punct && t.text == "$" {
                // Macro fragment: skip the following ident too.
                i += 2;
                prev_sig = None;
                prev_sig2 = None;
                continue;
            }
            if t.kind != TokKind::Ident {
                prev_sig2 = prev_sig.take();
                // Lifetimes lex with empty text; mark them so
                // `-> &'c Foo {` blocks struct-lit collection the same
                // way `-> &Foo {` does.
                prev_sig = match t.kind {
                    TokKind::Punct => Some(t.text.clone()),
                    TokKind::Lifetime => Some("'".to_string()),
                    _ => None,
                };
                i += 1;
                continue;
            }
            let w = t.text.as_str();
            // `use` / `mod` declarations are item business — phase 1
            // already captured them.
            if w == "use" {
                while i < n && !is_punct(toks, i, ';') {
                    i += 1;
                }
                i += 1;
                prev_sig = Some(";".to_string());
                prev_sig2 = None;
                continue;
            }
            if w == "mod" {
                i += 2;
                prev_sig = None;
                continue;
            }
            if w == "macro_rules" {
                // Body was recorded as a skip span; just advance.
                i += 1;
                prev_sig = Some("macro_rules".to_string());
                continue;
            }
            // `self.x` / `self.x(…)`
            if w == "self" && is_punct(toks, i + 1, '.') && prev_sig.as_deref() != Some(".") {
                let j = i + 2;
                if j < n && toks[j].kind == TokKind::Ident {
                    let name = toks[j].text.clone();
                    if name == "await" {
                        i = j + 1;
                        prev_sig = None;
                        continue;
                    }
                    let (tname, _) = self.impl_type_at(i);
                    let tname = tname.map(str::to_string);
                    let module = self.module_for(i);
                    if let (Some(tname), Some(m)) = (tname, module) {
                        let sink = self.sinks.entry(m).or_default();
                        if is_punct(toks, j + 1, '(') {
                            let close = match_close(toks, j + 1, '(', ')');
                            let (nargs, dd) = count_args(toks, j + 1, close);
                            sink.self_methods.push((
                                name.clone(),
                                nargs,
                                toks[j].line,
                                tname,
                                dd,
                            ));
                        } else {
                            sink.self_fields.push((name.clone(), toks[j].line, tname));
                        }
                    }
                    i = j + 1;
                    prev_sig = Some(name);
                    prev_sig2 = Some(".".to_string());
                    continue;
                }
                i = j;
                continue;
            }
            // Path start? prev must not be `.` (method call) or `::`
            // (path tail). A single `:` — field init, type
            // annotation — is fine.
            if prev_sig.as_deref() == Some(".")
                || (prev_sig.as_deref() == Some(":") && prev_sig2.as_deref() == Some(":"))
            {
                prev_sig2 = prev_sig.take();
                prev_sig = Some(w.to_string());
                i += 1;
                continue;
            }
            if KEYWORDS_NOT_PATH_START.contains(&w) && w != "crate" && w != "super" {
                prev_sig2 = prev_sig.take();
                prev_sig = Some(w.to_string());
                i += 1;
                continue;
            }
            // Collect path segments (`a::b::c`, turbofish skipped).
            let mut segs = vec![w.to_string()];
            let line = t.line;
            let mut j = i + 1;
            while j + 1 < n && is_punct(toks, j, ':') && is_punct(toks, j + 1, ':') {
                let k = j + 2;
                if k < n && is_punct(toks, k, '<') {
                    // Turbofish: skip the angle group; the path may
                    // continue after it (`Vec::<u8>::new`).
                    let mut ad = 0i32;
                    let mut p2: Option<&str> = None;
                    let mut k2 = k;
                    while k2 < n {
                        let tt = &toks[k2];
                        if tt.kind == TokKind::Punct {
                            if tt.text == "<" {
                                ad += 1;
                            } else if tt.text == ">" && p2 != Some("-") {
                                ad -= 1;
                                if ad == 0 {
                                    k2 += 1;
                                    break;
                                }
                            }
                            p2 = Some(tt.text.as_str());
                        } else {
                            p2 = None;
                        }
                        k2 += 1;
                    }
                    j = k2;
                    continue;
                }
                if k < n && toks[k].kind == TokKind::Ident && toks[k].text != "crate" {
                    segs.push(toks[k].text.clone());
                    j = k + 1;
                    continue;
                }
                break;
            }
            let prev_for_guard = prev_sig.take();
            let prev2_for_guard = prev_sig2.take();
            prev_sig2 = prev_for_guard.clone();
            prev_sig = Some(segs[segs.len() - 1].clone());
            let Some(module) = self.module_for(i) else {
                i = j;
                continue;
            };
            // `Self::x` — substitute the enclosing impl's type.
            if segs[0] == "Self" {
                let (tname, _) = self.impl_type_at(i);
                let Some(tname) = tname else {
                    i = j;
                    continue;
                };
                segs[0] = tname.to_string();
            } else if segs[0] == "self" && segs.len() == 1 {
                i = j;
                continue;
            }
            // Generic parameters in scope shadow everything.
            if self.generic_in_scope(i, &segs[0]) {
                i = j;
                continue;
            }
            if j < n && is_punct(toks, j, '(') {
                if prev_for_guard.as_deref() == Some("fn") {
                    i = j;
                    continue;
                }
                let close = match_close(toks, j, '(', ')');
                let (nargs, dd) = count_args(toks, j, close);
                self.sinks.entry(module).or_default().calls.push((segs, nargs, line, dd));
                i = j + 1;
                prev_sig = Some("(".to_string());
                continue;
            }
            if j < n && is_punct(toks, j, '!') {
                // Macro invocation: its args are walked as ordinary
                // tokens; the macro name itself is not a value path.
                i = j + 1;
                prev_sig = Some("!".to_string());
                continue;
            }
            if j < n && is_punct(toks, j, '{') {
                let blocked = prev_for_guard
                    .as_deref()
                    .is_some_and(|p| STRUCT_LIT_BLOCKERS.contains(&p))
                    || (prev_for_guard.as_deref() == Some(">")
                        && prev2_for_guard.as_deref() == Some("-"))
                    // `-> &'c Foo {` — a lifetime before the path means
                    // reference-type position, never a literal.
                    || prev_for_guard.as_deref().is_some_and(|p| p.starts_with('\''));
                if !blocked {
                    let close = match_close(toks, j, '{', '}');
                    let (fields, has_base) =
                        collect_literal_fields(toks, j + 1, close.saturating_sub(1));
                    self.sinks
                        .entry(module)
                        .or_default()
                        .struct_lits
                        .push((segs, fields, has_base, line));
                    // Tokens inside the literal still get walked.
                }
                i = j;
                continue;
            }
            if segs.len() >= 2 {
                self.sinks.entry(module).or_default().paths.push((segs, line));
            }
            i = j;
        }
        self.sinks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::check::parse::parse_file;
    use crate::lint::lexer::lex;

    fn walk_src(src: &str) -> RefSink {
        let out = lex(src);
        let fp = parse_file(out.toks, out.comments, out.n_lines);
        let span = fp.root.as_ref().map(|r| r.tok_span).unwrap_or((0, 0));
        let mut w = Walker::new(&fp, vec![(span, 0)]);
        if let Some(r) = &fp.root {
            w.prescan(r);
        }
        let mut sinks = w.walk();
        sinks.remove(&0).unwrap_or_default()
    }

    #[test]
    fn collects_calls_with_arity() {
        let s = walk_src("fn f() { util::go(1, 2); other::make(); }\n");
        assert_eq!(s.calls.len(), 2);
        assert_eq!(s.calls[0].0, vec!["util", "go"]);
        assert_eq!(s.calls[0].1, 2);
        assert_eq!(s.calls[1].1, 0);
    }

    #[test]
    fn closures_and_turbofish_count_as_one_arg() {
        let s = walk_src(
            "fn f() { m::apply(|x, y| x + y, 5); m::parse::<u32, Error>(text, 3); }\n",
        );
        assert_eq!(s.calls.len(), 2, "{:?}", s.calls);
        assert_eq!(s.calls[0].1, 2, "closure params must not be counted");
        assert_eq!(s.calls[1].0, vec!["m", "parse"]);
        assert_eq!(s.calls[1].1, 2, "turbofish type args must not be counted");
    }

    #[test]
    fn struct_literals_and_patterns() {
        let s = walk_src(
            "fn f() { let w = geo::Widget { id: 4, name, ..base }; \
             if let shape::Point { x, .. } = p {} }\n",
        );
        assert_eq!(s.struct_lits.len(), 2, "{:?}", s.struct_lits);
        let (segs, fields, has_base, _) = &s.struct_lits[0];
        assert_eq!(segs, &vec!["geo".to_string(), "Widget".to_string()]);
        let names: Vec<&str> = fields.iter().map(|(f, _)| f.as_str()).collect();
        assert_eq!(names, ["id", "name"]);
        assert!(has_base);
        assert!(s.struct_lits[1].2, "`..` rest pattern sets has_base");
    }

    #[test]
    fn self_accesses_carry_impl_type() {
        let s = walk_src(
            "struct W { n: u32 }\nimpl W {\n  fn go(&mut self) { self.n += 1; self.step(4); }\n}\n",
        );
        assert_eq!(s.self_fields.len(), 1);
        assert_eq!(s.self_fields[0].0, "n");
        assert_eq!(s.self_fields[0].2, "W");
        assert_eq!(s.self_methods.len(), 1);
        assert_eq!(s.self_methods[0].0, "step");
        assert_eq!(s.self_methods[0].1, 1);
    }

    #[test]
    fn fn_body_after_ref_return_is_not_a_literal() {
        let s = walk_src("fn get(&self) -> &types::Server { &self.s }\n");
        assert!(s.struct_lits.is_empty(), "{:?}", s.struct_lits);
        // The return-type path is still existence-checked.
        assert!(s.paths.iter().any(|(segs, _)| segs == &vec!["types", "Server"]));
    }

    #[test]
    fn fn_body_after_lifetime_ref_return_is_not_a_literal() {
        // The lifetime between `&` and the path must not defeat the
        // reference-type blocker.
        let s = walk_src("fn get<'c>(&'c self) -> &'c types::Server { &self.s }\n");
        assert!(s.struct_lits.is_empty(), "{:?}", s.struct_lits);
    }

    #[test]
    fn generic_params_shadow_path_heads() {
        let s = walk_src("fn f<T: Clone>(x: T) { T::clone(&x); }\n");
        assert!(s.calls.is_empty(), "{:?}", s.calls);
    }
}
