//! Per-reference rules: everything driven by one module's [`RefSink`] —
//! path existence, call arity, struct-literal fields, enum-variant
//! payload shapes, and `self.`-access consistency.
//!
//! Every rule follows the same skip discipline: [`Res::External`] and
//! [`Res::Unknown`] (and a `None` resolution — bare heads that may be
//! locals) are silently passed over. Only a definitive
//! [`Res::Missing`] or a concrete definition that contradicts the use
//! site produces a diagnostic.

use std::collections::BTreeSet;

use super::parse::{AdtKind, FnDef, VariantDef};
use super::resolve::{FnRef, Res, Resolver};
use super::walk::RefSink;
use super::{Report, R_ARITY, R_FIELDS, R_PATHS, R_VARIANTS};

/// Format an expected-arity set the way the fixture corpus expects:
/// a bare number when unambiguous, a `[1, 2]` list for cfg twins.
fn fmt_arities(exp: &BTreeSet<usize>) -> String {
    if exp.len() == 1 {
        exp.iter().next().unwrap().to_string()
    } else {
        let items: Vec<String> = exp.iter().map(|x| x.to_string()).collect();
        format!("[{}]", items.join(", "))
    }
}

/// All `FnDef`s a resolved call path may refer to (cfg twins
/// included). `None` means "no signature known — skip arity".
fn fn_candidates<'c>(
    rz: &Resolver<'c>,
    module: usize,
    segs: &[String],
    r: &Res,
) -> Option<Vec<&'c FnDef>> {
    let Res::Fn { module: rm, name: rname, fn_ref } = r else {
        return None;
    };
    let last = segs.last()?.as_str();
    if segs.len() >= 2 {
        match rz.resolve_path(module, &segs[..segs.len() - 1]) {
            Some(Res::Struct { name: pname, .. }) | Some(Res::Enum { name: pname, .. }) => {
                // `Type::method` — all inherent + local-trait
                // signatures under that name.
                return match rz.type_method_candidates(&pname).get(last) {
                    Some(v) if !v.is_empty() => Some(v.clone()),
                    // Derive/std-trait-provided: no signature known.
                    _ => None,
                };
            }
            Some(Res::Module(pm)) => {
                return Some(
                    rz.krate.modules[pm]
                        .items
                        .fns
                        .get(last)
                        .map_or_else(Vec::new, |f| f.iter().collect()),
                );
            }
            _ => {}
        }
    }
    if let Some(fds) = rz.krate.modules[*rm].items.fns.get(last) {
        if !fds.is_empty() {
            return Some(fds.iter().collect());
        }
    }
    // Fall back to the resolved definition itself — covers
    // `use foo as bar` renames and impl/trait methods reached through
    // imports. Synthetic fns (derives) have no signature to check.
    let defn: Option<&'c FnDef> = match fn_ref {
        FnRef::ModFn => rz.krate.modules[*rm].items.fns.get(rname).and_then(|v| v.first()),
        FnRef::ImplMethod(ii) => {
            rz.krate.modules[*rm].items.impls[*ii].methods.get(rname).and_then(|v| v.first())
        }
        FnRef::TraitMethod(tr) => rz
            .trait_defs(*rm, tr)
            .first()
            .and_then(|td| td.provided.get(rname).or_else(|| td.required.get(rname))),
        FnRef::Synthetic => None,
    };
    defn.map(|d| vec![d])
}

/// Union of field names and body shapes across a struct's cfg twins.
fn struct_field_union<'c>(
    rz: &Resolver<'c>,
    m: usize,
    name: &str,
) -> (BTreeSet<&'c str>, BTreeSet<AdtKind>) {
    let mut fields = BTreeSet::new();
    let mut kinds = BTreeSet::new();
    for sd in rz.struct_defs(m, name) {
        kinds.insert(sd.kind);
        for f in &sd.fields {
            fields.insert(f.as_str());
        }
    }
    (fields, kinds)
}

fn variant_def<'c>(rz: &Resolver<'c>, r: &Res) -> Option<&'c VariantDef> {
    let Res::Variant { module, enum_name, name } = r else {
        return None;
    };
    rz.enum_def(*module, enum_name).and_then(|ed| ed.variant(name))
}

fn missing_suffix(rz: &Resolver<'_>, module: Option<usize>) -> String {
    match module {
        Some(m) => format!(" in `{}`", rz.krate.modules[m].display_path()),
        None => String::new(),
    }
}

/// Apply every per-reference rule to one module's sink.
pub(crate) fn check_sink(
    rz: &Resolver<'_>,
    module: usize,
    sink: &RefSink,
    rel: &str,
    rep: &mut Report,
) {
    // -- paths: existence only -------------------------------------------
    for (segs, line) in &sink.paths {
        if let Some(Res::Missing { module: dm, name, variant }) = rz.resolve_path(module, segs) {
            let rule = if variant { R_VARIANTS } else { R_PATHS };
            rep.diag(
                rel,
                *line,
                rule,
                format!(
                    "`{}` does not resolve: no `{name}`{}",
                    segs.join("::"),
                    missing_suffix(rz, dm)
                ),
            );
        }
    }

    // -- calls -------------------------------------------------------------
    for (segs, nargs, line, dd) in &sink.calls {
        let Some(r) = rz.resolve_path(module, segs) else {
            continue;
        };
        if r.is_skip() {
            continue;
        }
        let path_s = segs.join("::");
        match &r {
            Res::Missing { name, variant, .. } => {
                let rule = if *variant { R_VARIANTS } else { R_ARITY };
                rep.diag(
                    rel,
                    *line,
                    rule,
                    format!("call to `{path_s}` does not resolve: no `{name}`"),
                );
                continue;
            }
            _ if *dd => continue,
            Res::Fn { .. } => {
                let Some(cands) = fn_candidates(rz, module, segs, &r) else {
                    continue;
                };
                if cands.is_empty() {
                    continue;
                }
                if !cands.iter().any(|fd| fd.arity == *nargs) {
                    let exp: BTreeSet<usize> = cands.iter().map(|fd| fd.arity).collect();
                    rep.diag(
                        rel,
                        *line,
                        R_ARITY,
                        format!(
                            "`{path_s}` called with {nargs} arg(s); signature takes {} \
                             (self included for `Type::method` calls)",
                            fmt_arities(&exp)
                        ),
                    );
                }
            }
            Res::Struct { module: sm, name: sname } => {
                let (_, kinds) = struct_field_union(rz, *sm, sname);
                if kinds.len() == 1 && kinds.contains(&AdtKind::Tuple) {
                    let arities: BTreeSet<usize> =
                        rz.struct_defs(*sm, sname).iter().map(|sd| sd.tuple_arity).collect();
                    if !arities.contains(nargs) {
                        rep.diag(
                            rel,
                            *line,
                            R_ARITY,
                            format!(
                                "tuple-struct `{path_s}` constructed with {nargs} field(s); \
                                 definition has {}",
                                arities.iter().next().copied().unwrap_or(0)
                            ),
                        );
                    }
                }
            }
            Res::Variant { .. } => {
                let Some(v) = variant_def(rz, &r) else {
                    continue;
                };
                match v.kind {
                    AdtKind::Tuple if v.tuple_arity != *nargs => rep.diag(
                        rel,
                        *line,
                        R_VARIANTS,
                        format!(
                            "variant `{path_s}` has {} payload field(s), used with {nargs}",
                            v.tuple_arity
                        ),
                    ),
                    AdtKind::Unit if *nargs > 0 => rep.diag(
                        rel,
                        *line,
                        R_VARIANTS,
                        format!("variant `{path_s}` is a unit variant but is used with arguments"),
                    ),
                    AdtKind::Named => rep.diag(
                        rel,
                        *line,
                        R_VARIANTS,
                        format!("variant `{path_s}` has named fields; parenthesized use"),
                    ),
                    _ => {}
                }
            }
            _ => {}
        }
    }

    // -- struct literals / patterns ----------------------------------------
    for (segs, fields, _has_base, line) in &sink.struct_lits {
        let Some(r) = rz.resolve_path(module, segs) else {
            continue;
        };
        if r.is_skip() {
            continue;
        }
        let path_s = segs.join("::");
        match &r {
            Res::Missing { name, variant, .. } => {
                let rule = if *variant { R_VARIANTS } else { R_PATHS };
                rep.diag(rel, *line, rule, format!("`{path_s}` does not resolve: no `{name}`"));
            }
            Res::Struct { module: sm, name: sname } => {
                let (union, kinds) = struct_field_union(rz, *sm, sname);
                if !kinds.contains(&AdtKind::Named) {
                    continue;
                }
                for (fname, fline) in fields {
                    if !union.contains(fname.as_str()) {
                        rep.diag(
                            rel,
                            *fline,
                            R_FIELDS,
                            format!("`{path_s}` has no field `{fname}`"),
                        );
                    }
                }
            }
            Res::Variant { .. } => {
                let Some(v) = variant_def(rz, &r) else {
                    continue;
                };
                if v.kind != AdtKind::Named {
                    continue;
                }
                for (fname, fline) in fields {
                    if !v.fields.iter().any(|f| f == fname) {
                        rep.diag(
                            rel,
                            *fline,
                            R_FIELDS,
                            format!("variant `{path_s}` has no field `{fname}`"),
                        );
                    }
                }
            }
            _ => {}
        }
    }

    // -- self.field --------------------------------------------------------
    for (name, line, tname) in &sink.self_fields {
        let Some(rt) = rz.resolve_name(module, tname) else {
            continue;
        };
        let Res::Struct { module: sm, name: sname } = &rt else {
            continue;
        };
        let (union, kinds) = struct_field_union(rz, *sm, sname);
        if !kinds.contains(&AdtKind::Named) || union.contains(name.as_str()) {
            continue;
        }
        if rz.lookup_type_member(&rt, name).is_some() {
            continue; // a method referenced as a value; dot-calls below
        }
        if rz.type_is_closed(&rt) {
            rep.diag(
                rel,
                *line,
                R_FIELDS,
                format!("`{tname}` has no field or method `{name}`"),
            );
        }
    }

    // -- self.method(...) --------------------------------------------------
    for (name, nargs, line, tname, dd) in &sink.self_methods {
        let Some(rt) = rz.resolve_name(module, tname) else {
            continue;
        };
        if let Res::Struct { module: sm, name: sname } = &rt {
            let (union, _) = struct_field_union(rz, *sm, sname);
            if union.contains(name.as_str()) {
                continue; // closure-typed field called as `self.f(…)`
            }
        } else if !matches!(rt, Res::Enum { .. }) {
            continue;
        }
        if rz.lookup_type_member(&rt, name).is_none() {
            if rz.type_is_closed(&rt) {
                rep.diag(rel, *line, R_ARITY, format!("no method `{name}` on `{tname}`"));
            }
            continue;
        }
        if *dd {
            continue;
        }
        let cands = rz.type_method_candidates(tname);
        let cands: Vec<&FnDef> = cands.get(name.as_str()).cloned().unwrap_or_default();
        if cands.is_empty() || !cands.iter().any(|fd| fd.self_kind.is_some()) {
            continue;
        }
        if !cands.iter().any(|fd| fd.self_kind.is_some() && fd.arity - 1 == *nargs) {
            let exp: BTreeSet<usize> = cands
                .iter()
                .filter(|fd| fd.self_kind.is_some())
                .map(|fd| fd.arity - 1)
                .collect();
            rep.diag(
                rel,
                *line,
                R_ARITY,
                format!(
                    "`self.{name}(…)` on `{tname}` called with {nargs} arg(s); \
                     signature takes {}",
                    fmt_arities(&exp)
                ),
            );
        }
    }
}
