//! Crate module tree + path resolver for `pallas-check`.
//!
//! The tree builder roots at `lib.rs` (falling back to `main.rs`),
//! follows `mod x;` declarations through `x.rs` / `x/mod.rs`, and
//! attaches `main.rs` and `bin/*.rs` as standalone bin-crate roots
//! whose `crate::` resolves to themselves.
//!
//! Resolution is three-valued. A path is **external** (std / vendored /
//! prelude heads — never checkable), **unknown** (passes through a
//! macro-tainted module, a type alias, or an *open* type — skip,
//! false-negative direction), or it resolves to a concrete item /
//! is definitively **missing** (a finding). Only the third state ever
//! produces a diagnostic, which is what keeps the pass zero-false-
//! positive on code rustc accepts.

use std::collections::BTreeSet;
use std::path::Path;

use super::parse::{parse_file, EnumDef, FileParse, FnDef, ImplDef, ModItems, StructDef, TraitDef};
use crate::lint::lexer;

/// Crates resolvable outside this source tree: paths headed here are
/// external, never reported.
pub(crate) const EXTERNAL_CRATES: [&str; 6] =
    ["std", "core", "alloc", "anyhow", "proc_macro", "xla"];

/// Names that resolve via the std prelude / primitives; a path headed
/// by one of these is external.
pub(crate) const PRELUDE: [&str; 97] = [
    "Vec", "String", "Box", "Option", "Some", "None", "Result", "Ok", "Err", "Rc", "Arc",
    "RefCell", "Cell", "Mutex", "RwLock", "HashMap", "HashSet", "BTreeMap", "BTreeSet",
    "VecDeque", "BinaryHeap", "Cow", "PathBuf", "Path", "Ordering", "Duration", "Instant",
    "SystemTime", "ExitCode", "Iterator", "IntoIterator", "Default", "Clone", "Copy", "Debug",
    "Display", "From", "Into", "TryFrom", "TryInto", "FromStr", "ToString", "AsRef", "AsMut",
    "Drop", "Fn", "FnMut", "FnOnce", "Send", "Sync", "Sized", "Eq", "PartialEq", "Ord",
    "PartialOrd", "Hash", "Hasher", "Extend", "DoubleEndedIterator", "ExactSizeIterator",
    "Reverse", "Wrapping", "Saturating", "PhantomData", "ManuallyDrop", "MaybeUninit",
    "NonZeroU32", "NonZeroU64", "NonZeroUsize", "IpAddr", "SocketAddr", "TcpListener",
    "TcpStream", "ThreadId", "JoinHandle", "bool", "char", "str", "u8", "u16", "u32", "u64",
    "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize", "f32", "f64", "drop", "println",
    "print", "eprintln", "eprint",
];

// ("panic", "assert", "min", "max", "abs" round out the prelude set —
// they are macro/method names more than paths, kept separate so the
// array above stays recognizably "types + macros you'd import".)
pub(crate) const PRELUDE_EXTRA: [&str; 5] = ["panic", "assert", "min", "max", "abs"];

pub(crate) fn is_prelude(name: &str) -> bool {
    PRELUDE.contains(&name) || PRELUDE_EXTRA.contains(&name)
}

/// Method sets of std traits this crate implements; a type whose trait
/// impls all map through this table (or local traits) has a *closed*
/// method universe.
pub(crate) const STD_TRAIT_METHODS: [(&str, &[&str]); 18] = [
    ("Default", &["default"]),
    ("Clone", &["clone", "clone_from"]),
    ("Copy", &[]),
    ("Debug", &["fmt"]),
    ("Display", &["fmt"]),
    ("Error", &["source", "description", "cause"]),
    ("From", &["from"]),
    ("Into", &["into"]),
    ("TryFrom", &["try_from"]),
    ("FromStr", &["from_str"]),
    ("Eq", &[]),
    ("PartialEq", &["eq", "ne"]),
    ("Ord", &["cmp", "max", "min", "clamp"]),
    ("PartialOrd", &["partial_cmp", "lt", "le", "gt", "ge"]),
    ("Hash", &["hash", "hash_slice"]),
    ("Drop", &["drop"]),
    ("Send", &[]),
    ("Sync", &[]),
];

/// Derives that add a known method set.
pub(crate) const DERIVE_METHODS: [(&str, &[&str]); 9] = [
    ("Default", &["default"]),
    ("Clone", &["clone", "clone_from"]),
    ("Copy", &[]),
    ("Debug", &["fmt"]),
    ("PartialEq", &["eq", "ne"]),
    ("Eq", &[]),
    ("Ord", &["cmp", "max", "min", "clamp"]),
    ("PartialOrd", &["partial_cmp", "lt", "le", "gt", "ge"]),
    ("Hash", &["hash", "hash_slice"]),
];

pub(crate) fn std_trait_methods(name: &str) -> Option<&'static [&'static str]> {
    STD_TRAIT_METHODS.iter().find(|(n, _)| *n == name).map(|(_, m)| *m)
}

pub(crate) fn derive_methods(name: &str) -> Option<&'static [&'static str]> {
    DERIVE_METHODS.iter().find(|(n, _)| *n == name).map(|(_, m)| *m)
}

/// A node in the crate module tree. Nodes live in [`Crate::modules`]
/// and reference each other by index, so the whole tree is one arena
/// with no interior pointers.
#[derive(Debug)]
pub(crate) struct Module {
    /// Path segments from the crate root (bin roots get a synthetic
    /// `bin?<file>` head so rules can recognize them).
    pub path: Vec<String>,
    pub items: ModItems,
    /// Defining file (rel path, `/`-separated).
    pub file: String,
    /// name -> module index.
    pub children: std::collections::BTreeMap<String, usize>,
    pub parent: Option<usize>,
}

impl Module {
    pub fn display_path(&self) -> String {
        if self.path.is_empty() {
            "crate root".to_string()
        } else {
            self.path.join("::")
        }
    }

    pub fn is_bin_root_tree(&self) -> bool {
        self.path.first().is_some_and(|s| s.starts_with("bin?"))
    }
}

#[derive(Debug, Default)]
pub(crate) struct Crate {
    pub modules: Vec<Module>,
    /// Lib crate root (or `main.rs` when no `lib.rs` exists).
    pub root: Option<usize>,
    /// Standalone bin-root modules (`main.rs`, `bin/*.rs`).
    pub bins: Vec<usize>,
    /// rel path -> parse result (root [`ModItems`] taken on attach).
    pub files: std::collections::BTreeMap<String, FileParse>,
    /// rel path -> source text (kept for suppression line scans).
    pub sources: std::collections::BTreeMap<String, String>,
    /// Diagnostics raised during tree construction
    /// (file, line, rule, message).
    pub diags: Vec<(String, u32, &'static str, String)>,
}

impl Crate {
    /// Every module, depth-first from the lib root then each bin root.
    pub fn all_modules(&self) -> Vec<usize> {
        let mut out = Vec::new();
        fn walk(c: &Crate, m: usize, out: &mut Vec<usize>) {
            out.push(m);
            for &child in c.modules[m].children.values() {
                walk(c, child, out);
            }
        }
        if let Some(r) = self.root {
            walk(self, r, &mut out);
        }
        for &b in &self.bins {
            walk(self, b, &mut out);
        }
        out
    }

    pub fn module(&self, idx: usize) -> &Module {
        &self.modules[idx]
    }
}

/// Read + parse one file, caching in `crate.files`. Returns whether
/// the file exists (its root items stay in the cache until attached).
fn parse_rel(krate: &mut Crate, src_root: &Path, rel: &str) -> bool {
    if krate.files.contains_key(rel) {
        return true;
    }
    let path = src_root.join(rel);
    let Ok(src) = std::fs::read_to_string(&path) else {
        return false;
    };
    let out = lexer::lex(&src);
    let fp = parse_file(out.toks, out.comments, out.n_lines);
    krate.files.insert(rel.to_string(), fp);
    krate.sources.insert(rel.to_string(), src);
    true
}

/// Attach `items` as the module at `path`, recursing into inline mods
/// and `mod x;` files. Returns the new module's arena index.
fn attach(
    krate: &mut Crate,
    src_root: &Path,
    mut items: ModItems,
    path: Vec<String>,
    file_rel: &str,
    dir_rel: &str,
) -> usize {
    items.file = file_rel.to_string();
    let inline = std::mem::take(&mut items.inline_mods);
    let mod_decls = items.mod_decls.clone();
    let idx = krate.modules.len();
    krate.modules.push(Module {
        path: path.clone(),
        items,
        file: file_rel.to_string(),
        children: std::collections::BTreeMap::new(),
        parent: None,
    });
    for (name, mut inner) in inline {
        inner.test_only = inner.test_only || krate.modules[idx].items.test_only;
        let mut child_path = path.clone();
        child_path.push(name.clone());
        let child = attach(krate, src_root, inner, child_path, file_rel, dir_rel);
        krate.modules[child].parent = Some(idx);
        krate.modules[idx].children.insert(name, child);
    }
    for d in mod_decls {
        let cand1 = if dir_rel.is_empty() {
            format!("{}.rs", d.name)
        } else {
            format!("{dir_rel}/{}.rs", d.name)
        };
        let cand2 = if dir_rel.is_empty() {
            format!("{}/mod.rs", d.name)
        } else {
            format!("{dir_rel}/{}/mod.rs", d.name)
        };
        let sub_dir =
            if dir_rel.is_empty() { d.name.clone() } else { format!("{dir_rel}/{}", d.name) };
        let sub_rel = if parse_rel(krate, src_root, &cand1) {
            cand1.clone()
        } else if parse_rel(krate, src_root, &cand2) {
            cand2.clone()
        } else {
            if !d.cfg {
                krate.diags.push((
                    file_rel.to_string(),
                    d.line,
                    "check-path-resolution",
                    format!("`mod {};` resolves to no file ({cand1} or {cand2})", d.name),
                ));
            }
            continue;
        };
        let sub_items = krate
            .files
            .get_mut(&sub_rel)
            .and_then(|fp| fp.root.take())
            .unwrap_or_default();
        let mut child_path = path.clone();
        child_path.push(d.name.clone());
        let child = attach(krate, src_root, sub_items, child_path, &sub_rel, &sub_dir);
        krate.modules[child].parent = Some(idx);
        krate.modules[idx].children.insert(d.name, child);
    }
    idx
}

/// Build the crate module tree from `src_root` (the crate's `src/`
/// directory).
pub(crate) fn build_crate(src_root: &Path) -> Crate {
    let mut krate = Crate::default();
    if parse_rel(&mut krate, src_root, "lib.rs") {
        let items = krate.files.get_mut("lib.rs").and_then(|f| f.root.take()).unwrap_or_default();
        let r = attach(&mut krate, src_root, items, Vec::new(), "lib.rs", "");
        krate.root = Some(r);
    }
    if parse_rel(&mut krate, src_root, "main.rs") {
        let items = krate.files.get_mut("main.rs").and_then(|f| f.root.take()).unwrap_or_default();
        if krate.root.is_none() {
            let r = attach(&mut krate, src_root, items, Vec::new(), "main.rs", "");
            krate.root = Some(r);
        } else {
            let b = attach(
                &mut krate,
                src_root,
                items,
                vec!["bin?main".to_string()],
                "main.rs",
                "",
            );
            krate.bins.push(b);
        }
    }
    // bin/*.rs as standalone bin roots.
    let bin_dir = src_root.join("bin");
    if bin_dir.is_dir() {
        let mut names: Vec<String> = match std::fs::read_dir(&bin_dir) {
            Ok(rd) => rd
                .filter_map(|e| e.ok())
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .filter(|n| n.ends_with(".rs"))
                .collect(),
            Err(_) => Vec::new(),
        };
        names.sort();
        for name in names {
            let rel = format!("bin/{name}");
            if parse_rel(&mut krate, src_root, &rel) {
                let items =
                    krate.files.get_mut(&rel).and_then(|f| f.root.take()).unwrap_or_default();
                let b = attach(
                    &mut krate,
                    src_root,
                    items,
                    vec![format!("bin?{name}")],
                    &rel,
                    "bin",
                );
                krate.bins.push(b);
            }
        }
    }
    krate
}

// ------------------------------------------------------------ resolution

/// Where the signature(s) behind a `Res::Fn` live, so rules can fetch
/// `FnDef`s without the resolver holding borrows.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) enum FnRef {
    /// `module.items.fns[name]`.
    ModFn,
    /// `module.items.impls[idx].methods[name]`.
    ImplMethod(usize),
    /// Required/provided method of `module.items.traits[trait_name]`.
    TraitMethod(String),
    /// Derive- or std-trait-provided: no local signature to check.
    Synthetic,
}

/// Resolution result. `module` fields are arena indices into
/// [`Crate::modules`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) enum Res {
    /// std / vendored / prelude — not checkable, never reported.
    External,
    /// Macro-tainted scope, type alias, open type — cannot say.
    Unknown,
    /// Definitely does not resolve. `variant` marks an enum-member
    /// miss (routed to `check-enum-variants`).
    Missing { module: Option<usize>, name: String, variant: bool },
    Module(usize),
    Fn { module: usize, name: String, fn_ref: FnRef },
    Struct { module: usize, name: String },
    Enum { module: usize, name: String },
    Trait { module: usize, name: String },
    Const { module: usize, name: String },
    Type { module: usize, name: String },
    Variant { module: usize, enum_name: String, name: String },
    Assoc { module: usize, name: String },
}

impl Res {
    pub fn is_skip(&self) -> bool {
        matches!(self, Res::External | Res::Unknown)
    }
}

type Visited = BTreeSet<(usize, String, bool)>;

pub(crate) struct Resolver<'c> {
    pub krate: &'c Crate,
    /// type name -> [(module idx, impl idx within that module)].
    impls_by_type: std::collections::BTreeMap<&'c str, Vec<(usize, usize)>>,
}

impl<'c> Resolver<'c> {
    pub fn new(krate: &'c Crate) -> Self {
        let mut impls_by_type: std::collections::BTreeMap<&'c str, Vec<(usize, usize)>> =
            std::collections::BTreeMap::new();
        for m in krate.all_modules() {
            for (i, idef) in krate.modules[m].items.impls.iter().enumerate() {
                if let Some(t) = &idef.type_name {
                    impls_by_type.entry(t.as_str()).or_default().push((m, i));
                }
            }
        }
        Resolver { krate, impls_by_type }
    }

    fn items(&self, m: usize) -> &'c ModItems {
        &self.krate.modules[m].items
    }

    pub fn struct_def(&self, m: usize, name: &str) -> Option<&'c StructDef> {
        self.items(m).structs.get(name).and_then(|v| v.first())
    }

    pub fn struct_defs(&self, m: usize, name: &str) -> &'c [StructDef] {
        self.items(m).structs.get(name).map_or(&[], Vec::as_slice)
    }

    pub fn enum_def(&self, m: usize, name: &str) -> Option<&'c EnumDef> {
        self.items(m).enums.get(name).and_then(|v| v.first())
    }

    pub fn trait_defs(&self, m: usize, name: &str) -> &'c [TraitDef] {
        self.items(m).traits.get(name).map_or(&[], Vec::as_slice)
    }

    pub fn impls_for(&self, type_name: &str) -> &[(usize, usize)] {
        self.impls_by_type.get(type_name).map_or(&[], Vec::as_slice)
    }

    fn impl_at(&self, site: (usize, usize)) -> &'c ImplDef {
        &self.krate.modules[site.0].items.impls[site.1]
    }

    /// Find `name` among items defined directly in module `m`
    /// (no imports).
    pub fn lookup_local(&self, m: usize, name: &str) -> Option<Res> {
        let module = &self.krate.modules[m];
        if let Some(&child) = module.children.get(name) {
            return Some(Res::Module(child));
        }
        let it = &module.items;
        let owned = name.to_string();
        if it.structs.contains_key(name) {
            return Some(Res::Struct { module: m, name: owned });
        }
        if it.enums.contains_key(name) {
            return Some(Res::Enum { module: m, name: owned });
        }
        if it.traits.contains_key(name) {
            return Some(Res::Trait { module: m, name: owned });
        }
        if it.fns.contains_key(name) {
            return Some(Res::Fn { module: m, name: owned, fn_ref: FnRef::ModFn });
        }
        if it.consts.contains_key(name) {
            return Some(Res::Const { module: m, name: owned });
        }
        if it.types.contains_key(name) {
            return Some(Res::Type { module: m, name: owned });
        }
        None
    }

    /// Resolve `name` in module `m`'s scope: local items, explicit
    /// imports, then globs. `None` means "not found here" (which is
    /// *not* the same as [`Res::Missing`]).
    pub fn resolve_in_module(
        &self,
        m: usize,
        name: &str,
        visited: &mut Visited,
        imports_ok: bool,
    ) -> Option<Res> {
        let key = (m, name.to_string(), imports_ok);
        if visited.contains(&key) {
            return None;
        }
        visited.insert(key);
        if let Some(r) = self.lookup_local(m, name) {
            return Some(r);
        }
        let it = self.items(m);
        if !imports_ok {
            if it.macro_items {
                return Some(Res::Unknown);
            }
            return None;
        }
        // Explicit imports.
        for u in &it.uses {
            if !u.is_glob && u.alias.as_deref() == Some(name) {
                return self.resolve_path_in(m, &u.path, visited);
            }
        }
        // Glob imports: try each target.
        for u in &it.uses {
            if !u.is_glob {
                continue;
            }
            let Some(tgt) = self.resolve_path_in(m, &u.path, visited) else {
                continue;
            };
            match tgt {
                Res::Module(tm) => {
                    if let Some(r) = self.resolve_in_module(tm, name, visited, true) {
                        if !matches!(r, Res::Missing { .. }) {
                            return Some(r);
                        }
                    }
                }
                Res::Enum { module, name: ename } => {
                    // `use Enum::*` — variants become bare names.
                    if let Some(ed) = self.enum_def(module, &ename) {
                        if ed.variant(name).is_some() {
                            return Some(Res::Variant {
                                module,
                                enum_name: ename,
                                name: name.to_string(),
                            });
                        }
                    }
                }
                Res::External | Res::Unknown => {
                    // Glob from an external module: anything may be in
                    // scope.
                    return Some(Res::Unknown);
                }
                _ => {}
            }
        }
        if it.macro_items {
            return Some(Res::Unknown);
        }
        None
    }

    /// Resolve a multi-segment path from module `m`'s scope.
    /// `None` means "cannot say" (rules skip).
    pub fn resolve_path(&self, m: usize, segs: &[String]) -> Option<Res> {
        let mut visited = Visited::new();
        self.resolve_path_in(m, segs, &mut visited)
    }

    /// Resolve a bare name in module `m`'s scope with a fresh visited
    /// set (convenience for rules that start from a type name).
    pub fn resolve_name(&self, m: usize, name: &str) -> Option<Res> {
        let mut visited = Visited::new();
        self.resolve_in_module(m, name, &mut visited, true)
    }

    fn resolve_path_in(&self, m: usize, segs: &[String], visited: &mut Visited) -> Option<Res> {
        if segs.is_empty() {
            return None;
        }
        let first = segs[0].as_str();
        let mut i = 1usize;
        let mut cur: Res;
        match first {
            "crate" => cur = Res::Module(self.root_for(m)),
            "cloudcoaster" => match self.krate.root {
                Some(r) => cur = Res::Module(r),
                None => return Some(Res::External),
            },
            "self" => cur = Res::Module(m),
            "super" => {
                let mut up = self.krate.modules[m].parent;
                while i < segs.len() && segs[i] == "super" {
                    up = up.and_then(|u| self.krate.modules[u].parent);
                    i += 1;
                }
                match up {
                    Some(u) => cur = Res::Module(u),
                    None => return Some(Res::Unknown),
                }
            }
            "Self" => return Some(Res::Unknown), // substituted by the walker
            _ if EXTERNAL_CRATES.contains(&first) || is_prelude(first) => {
                return Some(Res::External);
            }
            _ => {
                cur = self.resolve_in_module(m, first, visited, true)?;
            }
        }
        // Walk the remaining segments.
        while i < segs.len() {
            if cur.is_skip() {
                return Some(cur);
            }
            let name = segs[i].as_str();
            cur = match cur {
                Res::Module(mm) => {
                    match self.resolve_in_module(mm, name, visited, true) {
                        Some(r) => r,
                        None => {
                            if self.items(mm).macro_items {
                                return Some(Res::Unknown);
                            }
                            return Some(Res::Missing {
                                module: Some(mm),
                                name: name.to_string(),
                                variant: false,
                            });
                        }
                    }
                }
                Res::Enum { module, name: ename } => {
                    let ed = self.enum_def(module, &ename);
                    if ed.is_some_and(|e| e.variant(name).is_some()) {
                        Res::Variant { module, enum_name: ename, name: name.to_string() }
                    } else {
                        let as_type =
                            Res::Enum { module, name: ename.clone() };
                        if let Some(r) = self.lookup_type_member(&as_type, name) {
                            r
                        } else if self.type_is_closed(&as_type) {
                            return Some(Res::Missing {
                                module: Some(module),
                                name: format!("{ename}::{name}"),
                                variant: true,
                            });
                        } else {
                            return Some(Res::Unknown);
                        }
                    }
                }
                Res::Type { .. } => return Some(Res::Unknown), // can't see through aliases
                Res::Trait { module, name: tname } => {
                    let member = self.trait_defs(module, &tname).first().is_some_and(|td| {
                        td.required.contains_key(name)
                            || td.provided.contains_key(name)
                            || td.assoc.contains(name)
                    });
                    if member {
                        Res::Assoc { module, name: name.to_string() }
                    } else {
                        return Some(Res::Missing {
                            module: Some(module),
                            name: format!("{tname}::{name}"),
                            variant: false,
                        });
                    }
                }
                Res::Struct { module, name: sname } => {
                    let as_type = Res::Struct { module, name: sname.clone() };
                    if let Some(r) = self.lookup_type_member(&as_type, name) {
                        r
                    } else if self.type_is_closed(&as_type) {
                        return Some(Res::Missing {
                            module: Some(module),
                            name: format!("{sname}::{name}"),
                            variant: false,
                        });
                    } else {
                        return Some(Res::Unknown);
                    }
                }
                // fn::x, const::x — nonsense, but could be a
                // module/value name clash; don't guess.
                _ => return Some(Res::Unknown),
            };
            i += 1;
        }
        Some(cur)
    }

    fn root_for(&self, m: usize) -> usize {
        let mut cur = m;
        while let Some(p) = self.krate.modules[cur].parent {
            cur = p;
        }
        // Bin roots resolve `crate::` to themselves.
        cur
    }

    // -- type member lookup ----------------------------------------------

    fn type_def_parts(&self, type_res: &Res) -> Option<(usize, &str)> {
        match type_res {
            Res::Struct { module, name } | Res::Enum { module, name } => Some((*module, name)),
            _ => None,
        }
    }

    fn type_derives(&self, type_res: &Res) -> Option<&'c BTreeSet<String>> {
        let (m, name) = self.type_def_parts(type_res)?;
        match type_res {
            Res::Struct { .. } => self.struct_def(m, name).map(|s| &s.derives),
            Res::Enum { .. } => self.enum_def(m, name).map(|e| &e.derives),
            _ => None,
        }
    }

    /// Find `name` as a method/assoc item of struct/enum `type_res`.
    pub fn lookup_type_member(&self, type_res: &Res, name: &str) -> Option<Res> {
        let (_, tname) = self.type_def_parts(type_res)?;
        let tname = tname.to_string();
        for &site in self.impls_for(&tname) {
            let (m, ii) = site;
            let idef = self.impl_at(site);
            if idef.methods.contains_key(name) {
                return Some(Res::Fn {
                    module: m,
                    name: name.to_string(),
                    fn_ref: FnRef::ImplMethod(ii),
                });
            }
            if idef.assoc.contains(name) {
                return Some(Res::Assoc { module: m, name: name.to_string() });
            }
            // Provided/required methods of the impl'd local trait.
            if let Some(tp) = &idef.trait_path {
                if let Some(Res::Trait { module: trm, name: trname }) = self.resolve_path(m, tp) {
                    if let Some(td) = self.trait_defs(trm, &trname).first() {
                        if td.provided.contains_key(name) || td.required.contains_key(name) {
                            // A required-but-unimplemented method still
                            // *resolves*; the trait-impls rule flags the
                            // impl itself.
                            return Some(Res::Fn {
                                module: trm,
                                name: name.to_string(),
                                fn_ref: FnRef::TraitMethod(trname),
                            });
                        }
                        if td.assoc.contains(name) {
                            return Some(Res::Assoc { module: trm, name: name.to_string() });
                        }
                    }
                }
            }
        }
        // Derive-provided methods.
        if let Some(derives) = self.type_derives(type_res) {
            for dv in derives {
                if derive_methods(dv).is_some_and(|ms| ms.contains(&name)) {
                    let (m, _) = self.type_def_parts(type_res)?;
                    return Some(Res::Fn {
                        module: m,
                        name: name.to_string(),
                        fn_ref: FnRef::Synthetic,
                    });
                }
            }
        }
        // Std-trait impls with known method sets.
        for &site in self.impls_for(&tname) {
            let idef = self.impl_at(site);
            if let Some(tp) = &idef.trait_path {
                if let Some(last) = tp.last() {
                    if std_trait_methods(last).is_some_and(|ms| ms.contains(&name)) {
                        return Some(Res::Fn {
                            module: site.0,
                            name: name.to_string(),
                            fn_ref: FnRef::Synthetic,
                        });
                    }
                }
            }
        }
        None
    }

    /// True when every method of the type is knowable: inherent impls,
    /// local-trait impls, known-std-trait impls, known derives — and
    /// the defining module is not macro-tainted.
    pub fn type_is_closed(&self, type_res: &Res) -> bool {
        let Some((m, tname)) = self.type_def_parts(type_res) else {
            return false;
        };
        if self.items(m).macro_items {
            return false;
        }
        if let Some(derives) = self.type_derives(type_res) {
            for dv in derives {
                if derive_methods(dv).is_none() {
                    return false;
                }
            }
        }
        let tname = tname.to_string();
        for &site in self.impls_for(&tname) {
            let idef = self.impl_at(site);
            if let Some(tp) = &idef.trait_path {
                let last = tp.last().map(String::as_str).unwrap_or("");
                if matches!(self.resolve_path(site.0, tp), Some(Res::Trait { .. })) {
                    continue;
                }
                if std_trait_methods(last).is_some() {
                    continue;
                }
                return false;
            }
        }
        true
    }

    /// All known methods of a type: inherent + local-trait
    /// (name -> candidate signatures, cfg twins included).
    pub fn type_method_candidates(
        &self,
        type_name: &str,
    ) -> std::collections::BTreeMap<&'c str, Vec<&'c FnDef>> {
        let mut out: std::collections::BTreeMap<&'c str, Vec<&'c FnDef>> =
            std::collections::BTreeMap::new();
        for &site in self.impls_for(type_name) {
            let idef = self.impl_at(site);
            for (name, fds) in &idef.methods {
                out.entry(name.as_str()).or_default().extend(fds.iter());
            }
            if let Some(tp) = &idef.trait_path {
                if let Some(Res::Trait { module: trm, name: trname }) =
                    self.resolve_path(site.0, tp)
                {
                    if let Some(td) = self.trait_defs(trm, &trname).first() {
                        for (name, fd) in td.provided.iter().chain(td.required.iter()) {
                            out.entry(name.as_str()).or_default().push(fd);
                        }
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn write_tree(files: &[(&str, &str)]) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "pallas-check-resolve-{}-{}",
            std::process::id(),
            files.len()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        for (rel, src) in files {
            let p = dir.join(rel);
            if let Some(parent) = p.parent() {
                std::fs::create_dir_all(parent).unwrap();
            }
            std::fs::write(p, src).unwrap();
        }
        dir
    }

    #[test]
    fn builds_tree_and_resolves_across_modules() {
        let root = write_tree(&[
            ("lib.rs", "pub mod util;\npub mod engine;\n"),
            ("util/mod.rs", "pub struct Widget { pub id: u64 }\npub fn helper(x: u32) -> u32 { x }\n"),
            ("engine.rs", "use crate::util::Widget;\npub fn go(w: Widget) {}\n"),
        ]);
        let krate = build_crate(&root);
        assert!(krate.diags.is_empty(), "{:?}", krate.diags);
        let r = Resolver::new(&krate);
        let eng = *krate.modules[krate.root.unwrap()].children.get("engine").unwrap();
        let segs: Vec<String> =
            ["crate", "util", "helper"].iter().map(|s| s.to_string()).collect();
        assert!(matches!(r.resolve_path(eng, &segs), Some(Res::Fn { .. })));
        let missing: Vec<String> =
            ["crate", "util", "nope"].iter().map(|s| s.to_string()).collect();
        assert!(matches!(r.resolve_path(eng, &missing), Some(Res::Missing { .. })));
        let ext: Vec<String> = ["std", "mem", "take"].iter().map(|s| s.to_string()).collect();
        assert_eq!(r.resolve_path(eng, &ext), Some(Res::External));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn missing_mod_file_is_a_finding() {
        let root = write_tree(&[("lib.rs", "mod ghost;\n")]);
        let krate = build_crate(&root);
        assert_eq!(krate.diags.len(), 1);
        assert!(krate.diags[0].3.contains("ghost"));
        let _ = std::fs::remove_dir_all(&root);
    }
}
