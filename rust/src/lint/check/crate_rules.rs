//! Crate-shape rules: import resolution, trait-impl conformance,
//! duplicate definitions, dead `pub` items, and the `Event`
//! exhaustiveness anchors. These run over the whole module tree
//! rather than one reference sink.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use super::parse::{match_close, FnDef};
use super::resolve::{Crate, Res, Resolver};
use super::{Report, R_DEAD, R_DUP, R_PATHS, R_TRAITS, R_VARIANTS};
use crate::lint::lexer::{self, TokKind};

/// `use` declarations must resolve; glob imports must come from a
/// module (or enum, for `use Enum::*`).
pub(crate) fn check_use_decls(krate: &Crate, rz: &Resolver<'_>, rep: &mut Report) {
    for m in krate.all_modules() {
        let module = &krate.modules[m];
        let rel = module.file.clone();
        for u in &module.items.uses {
            let r = rz.resolve_path(m, &u.path);
            let path_s =
                format!("{}{}", u.path.join("::"), if u.is_glob { "::*" } else { "" });
            match r {
                None => {
                    rep.diag(&rel, u.line, R_PATHS, format!("unresolved import `{path_s}`"));
                }
                Some(Res::Missing { name, .. }) => {
                    rep.diag(
                        &rel,
                        u.line,
                        R_PATHS,
                        format!("unresolved import `{path_s}`: no `{name}`"),
                    );
                }
                Some(tgt) => {
                    if u.is_glob
                        && !matches!(
                            tgt,
                            Res::Module(_) | Res::Enum { .. } | Res::External | Res::Unknown
                        )
                    {
                        rep.diag(
                            &rel,
                            u.line,
                            R_PATHS,
                            format!("glob import `{path_s}` from a non-module"),
                        );
                    }
                }
            }
        }
    }
}

/// `impl Trait for Type` blocks: the trait must resolve, every method
/// and associated item must be declared by it (with matching arity),
/// and every required method must be present.
pub(crate) fn check_trait_impls(krate: &Crate, rz: &Resolver<'_>, rep: &mut Report) {
    for m in krate.all_modules() {
        let module = &krate.modules[m];
        let rel = module.file.clone();
        for idef in &module.items.impls {
            let Some(tp) = &idef.trait_path else {
                continue;
            };
            let tpath = tp.join("::");
            let tr = rz.resolve_path(m, tp);
            let (trm, trname) = match tr {
                None => {
                    if !module.items.macro_items {
                        rep.diag(
                            &rel,
                            idef.line,
                            R_PATHS,
                            format!("`impl {tpath} for …`: unresolved trait"),
                        );
                    }
                    continue;
                }
                Some(Res::Missing { name, .. }) => {
                    rep.diag(&rel, idef.line, R_PATHS, format!("`impl {tpath} for …`: no `{name}`"));
                    continue;
                }
                Some(Res::Trait { module: trm, name }) => (trm, name),
                Some(_) => continue,
            };
            // Merge the declared surface across cfg twins of the trait.
            let mut required: BTreeMap<&str, &FnDef> = BTreeMap::new();
            let mut provided: BTreeMap<&str, &FnDef> = BTreeMap::new();
            let mut assoc: BTreeSet<&str> = BTreeSet::new();
            for td in rz.trait_defs(trm, &trname) {
                for (n, fd) in &td.required {
                    required.insert(n.as_str(), fd);
                }
                for (n, fd) in &td.provided {
                    provided.insert(n.as_str(), fd);
                }
                for a in &td.assoc {
                    assoc.insert(a.as_str());
                }
            }
            let declared: BTreeSet<&str> = required
                .keys()
                .chain(provided.keys())
                .copied()
                .chain(assoc.iter().copied())
                .collect();
            let tgt = idef.type_name.as_deref().unwrap_or("…");
            for (mname, fds) in &idef.methods {
                if !declared.contains(mname.as_str()) {
                    rep.diag(
                        &rel,
                        fds[0].line,
                        R_TRAITS,
                        format!(
                            "`impl {tpath} for {tgt}`: method `{mname}` is not a member \
                             of `{trname}`"
                        ),
                    );
                } else if let Some(tfd) =
                    required.get(mname.as_str()).or_else(|| provided.get(mname.as_str()))
                {
                    if !fds.iter().any(|fd| fd.arity == tfd.arity) {
                        rep.diag(
                            &rel,
                            fds[0].line,
                            R_TRAITS,
                            format!(
                                "`impl {tpath} for {tgt}`: `{mname}` has arity {}, \
                                 trait declares {}",
                                fds[0].arity, tfd.arity
                            ),
                        );
                    }
                }
            }
            for aname in &idef.assoc {
                if !declared.contains(aname.as_str()) {
                    rep.diag(
                        &rel,
                        idef.line,
                        R_TRAITS,
                        format!(
                            "`impl {tpath} for {tgt}`: associated item `{aname}` is not \
                             a member of `{trname}`"
                        ),
                    );
                }
            }
            for rname in required.keys() {
                if !idef.methods.contains_key(*rname) {
                    rep.diag(
                        &rel,
                        idef.line,
                        R_TRAITS,
                        format!("`impl {tpath} for {tgt}` is missing required method `{rname}`"),
                    );
                }
            }
        }
    }
}

/// Duplicate definitions: same name twice in one namespace of one
/// module, double imports, and repeated methods within or across
/// inherent impls. `#[cfg]`-gated twins are expected and skipped.
pub(crate) fn check_duplicates(krate: &Crate, rep: &mut Report) {
    for m in krate.all_modules() {
        let module = &krate.modules[m];
        let rel = module.file.clone();
        let it = &module.items;
        let mpath = module.display_path();

        let mut dup_scan = |groups: &[BTreeMap<String, Vec<(u32, bool)>>], what: &str| {
            let mut names: BTreeMap<&str, Vec<(u32, bool)>> = BTreeMap::new();
            for g in groups {
                for (name, defs) in g {
                    names.entry(name.as_str()).or_default().extend(defs.iter().copied());
                }
            }
            for (name, defs) in names {
                let mut live: Vec<u32> =
                    defs.iter().filter(|(_, cfg)| !cfg).map(|(l, _)| *l).collect();
                if live.len() > 1 {
                    live.sort_unstable();
                    rep.diag(
                        &rel,
                        live[1],
                        R_DUP,
                        format!("duplicate {what} definition `{name}` in `{mpath}`"),
                    );
                }
            }
        };

        let structs: BTreeMap<String, Vec<(u32, bool)>> = it
            .structs
            .iter()
            .map(|(n, v)| (n.clone(), v.iter().map(|d| (d.line, d.cfg)).collect()))
            .collect();
        let enums: BTreeMap<String, Vec<(u32, bool)>> = it
            .enums
            .iter()
            .map(|(n, v)| (n.clone(), v.iter().map(|d| (d.line, d.cfg)).collect()))
            .collect();
        let traits: BTreeMap<String, Vec<(u32, bool)>> = it
            .traits
            .iter()
            .map(|(n, v)| (n.clone(), v.iter().map(|d| (d.line, d.cfg)).collect()))
            .collect();
        let types: BTreeMap<String, Vec<(u32, bool)>> = it
            .types
            .iter()
            .map(|(n, v)| (n.clone(), v.iter().map(|d| (d.line, d.cfg)).collect()))
            .collect();
        let fns: BTreeMap<String, Vec<(u32, bool)>> = it
            .fns
            .iter()
            .map(|(n, v)| (n.clone(), v.iter().map(|d| (d.line, d.cfg)).collect()))
            .collect();
        let consts: BTreeMap<String, Vec<(u32, bool)>> = it
            .consts
            .iter()
            .map(|(n, v)| (n.clone(), v.iter().map(|d| (d.line, d.cfg)).collect()))
            .collect();
        dup_scan(&[structs, enums, traits, types], "type");
        dup_scan(&[fns], "fn");
        dup_scan(&[consts], "const/static");

        // Duplicate explicit imports of the same alias from two paths.
        let mut alias_seen: BTreeMap<&str, &[String]> = BTreeMap::new();
        for u in &it.uses {
            let Some(alias) = u.alias.as_deref() else {
                continue;
            };
            if u.is_glob || u.cfg || alias == "_" {
                continue;
            }
            match alias_seen.get(alias) {
                Some(path) if *path != u.path.as_slice() => {
                    rep.diag(
                        &rel,
                        u.line,
                        R_DUP,
                        format!("`{alias}` imported more than once in `{mpath}`"),
                    );
                }
                Some(_) => {}
                None => {
                    alias_seen.insert(alias, &u.path);
                }
            }
        }

        // Duplicate methods within one impl block.
        for idef in &it.impls {
            for (mname, fds) in &idef.methods {
                let mut live: Vec<u32> =
                    fds.iter().filter(|fd| !fd.cfg).map(|fd| fd.line).collect();
                if live.len() > 1 {
                    live.sort_unstable();
                    rep.diag(
                        &rel,
                        live[1],
                        R_DUP,
                        format!("method `{mname}` defined twice in the same impl block"),
                    );
                }
            }
        }
    }

    // Duplicate methods across inherent impls of one type name.
    let mut inherent: BTreeMap<(String, String), Vec<(String, u32)>> = BTreeMap::new();
    for m in krate.all_modules() {
        let module = &krate.modules[m];
        for idef in &module.items.impls {
            if idef.trait_path.is_some() || idef.cfg {
                continue;
            }
            let Some(tname) = &idef.type_name else {
                continue;
            };
            for (mname, fds) in &idef.methods {
                for fd in fds {
                    if !fd.cfg {
                        inherent
                            .entry((tname.clone(), mname.clone()))
                            .or_default()
                            .push((module.file.clone(), fd.line));
                    }
                }
            }
        }
    }
    for ((tname, mname), mut sites) in inherent {
        if sites.len() > 1 {
            sites.sort();
            rep.diag(
                &sites[1].0,
                sites[1].1,
                R_DUP,
                format!("method `{mname}` defined in more than one inherent impl of `{tname}`"),
            );
        }
    }
}

/// `pub` items (plain `pub` only — rustc's `dead_code` lint already
/// covers private and `pub(crate)` items) that no other file in the
/// crate, its tests, benches, or examples ever names.
pub(crate) fn check_dead_pub(
    krate: &Crate,
    src_root: &Path,
    test_marks: &BTreeMap<String, Vec<bool>>,
    rep: &mut Report,
) {
    // name -> set of "containers" (files) where the ident appears.
    let mut ident_files: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    let mut index = |label: &str, toks: &[lexer::Tok]| {
        for t in toks {
            if t.kind == TokKind::Ident {
                ident_files.entry(t.text.clone()).or_default().insert(label.to_string());
            }
        }
    };
    for (rel, fp) in &krate.files {
        index(rel, &fp.toks);
    }
    for extra_dir in ["../tests", "../benches", "../../examples"] {
        let d = src_root.join(extra_dir);
        if !d.is_dir() {
            continue;
        }
        let mut stack = vec![d];
        while let Some(dir) = stack.pop() {
            let Ok(rd) = std::fs::read_dir(&dir) else {
                continue;
            };
            let mut entries: Vec<_> = rd.filter_map(|e| e.ok()).map(|e| e.path()).collect();
            entries.sort();
            for p in entries {
                if p.is_dir() {
                    stack.push(p);
                } else if p.extension().is_some_and(|x| x == "rs") {
                    if let Ok(src) = std::fs::read_to_string(&p) {
                        let out = lexer::lex(&src);
                        index(&format!("ext:{}", p.display()), &out.toks);
                    }
                }
            }
        }
    }

    let empty: Vec<bool> = Vec::new();
    for m in krate.all_modules() {
        let module = &krate.modules[m];
        if module.items.test_only || module.is_bin_root_tree() {
            continue;
        }
        let rel = module.file.clone();
        let marks = test_marks.get(&rel).unwrap_or(&empty);
        let it = &module.items;
        // (line, vis) of the first def under each name, per namespace.
        let mut candidates: Vec<(&str, u32, &str, &str)> = Vec::new();
        for (name, v) in &it.fns {
            if let Some(d) = v.first() {
                candidates.push((name, d.line, &d.vis, "fn"));
            }
        }
        for (name, v) in &it.structs {
            if let Some(d) = v.first() {
                candidates.push((name, d.line, &d.vis, "struct"));
            }
        }
        for (name, v) in &it.enums {
            if let Some(d) = v.first() {
                candidates.push((name, d.line, &d.vis, "enum"));
            }
        }
        for (name, v) in &it.traits {
            if let Some(d) = v.first() {
                candidates.push((name, d.line, &d.vis, "trait"));
            }
        }
        for (name, v) in &it.consts {
            if let Some(d) = v.first() {
                candidates.push((name, d.line, &d.vis, "const"));
            }
        }
        for (name, v) in &it.types {
            if let Some(d) = v.first() {
                candidates.push((name, d.line, &d.vis, "type alias"));
            }
        }
        for (name, line, vis, what) in candidates {
            if vis != "pub" || name == "main" || name.starts_with('_') {
                continue;
            }
            if marks.get(line as usize).copied().unwrap_or(false) {
                continue;
            }
            let referenced_elsewhere = ident_files
                .get(name)
                .is_some_and(|refs| refs.iter().any(|r| r != &rel));
            if referenced_elsewhere {
                continue;
            }
            rep.diag(
                &rel,
                line,
                R_DEAD,
                format!("pub {what} `{name}` is never referenced outside `{rel}`"),
            );
        }
    }
}

/// The `Event` enum's exhaustiveness anchors: `N_KINDS`, `KINDS`,
/// `kind_index`, `dispatch_event_core` must exist and stay in sync
/// with the variant list — the manual dispatch tables the calendar
/// queue relies on cannot drift when a variant is added.
pub(crate) fn check_event_anchors(krate: &Crate, rep: &mut Report) {
    // First `Event` enum in module-tree order (bin roots skipped).
    let mut found_ev: Option<(usize, &super::parse::EnumDef)> = None;
    for m in krate.all_modules() {
        if krate.modules[m].is_bin_root_tree() {
            continue;
        }
        if let Some(ed) = krate.modules[m].items.enums.get("Event").and_then(|v| v.first()) {
            found_ev = Some((m, ed));
            break;
        }
    }
    let Some((em, ed)) = found_ev else {
        return;
    };
    let rel = krate.modules[em].file.clone();
    let Some(fp) = krate.files.get(&rel) else {
        return;
    };
    let toks = &fp.toks;
    let variants: Vec<&str> = ed.variants.iter().map(|v| v.name.as_str()).collect();

    let mut n_kinds: Option<i64> = None;
    let mut kinds_count: Option<usize> = None;
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || i == 0 {
            continue;
        }
        let prev_is_const =
            toks[i - 1].kind == TokKind::Ident && toks[i - 1].text == "const";
        if t.text == "N_KINDS" && prev_is_const {
            let mut j = i + 1;
            while j < toks.len()
                && !(toks[j].kind == TokKind::Punct && toks[j].text == "=")
            {
                j += 1;
            }
            if j + 1 < toks.len() && toks[j + 1].kind == TokKind::Int {
                if let Ok(v) = toks[j + 1].text.parse::<i64>() {
                    n_kinds = Some(v);
                }
            }
        }
        if t.text == "KINDS" && prev_is_const {
            // Scan past the type annotation (`[&str; N]`) to the `=`.
            let mut j = i + 1;
            let mut depth = 0i32;
            while j < toks.len() {
                let tt = &toks[j];
                if tt.kind == TokKind::Punct {
                    match tt.text.as_str() {
                        "=" if depth == 0 => break,
                        "(" | "[" | "{" | "<" => depth += 1,
                        ")" | "]" | "}" | ">" => depth -= 1,
                        _ => {}
                    }
                }
                j += 1;
            }
            if j < toks.len()
                && j + 1 < toks.len()
                && toks[j + 1].kind == TokKind::Punct
                && toks[j + 1].text == "["
            {
                let close = match_close(toks, j + 1, '[', ']');
                let mut commas = 0usize;
                let mut depth = 0i32;
                let mut last_sig: Option<&str> = None;
                for tt in &toks[j + 2..close.saturating_sub(1)] {
                    if tt.kind == TokKind::Punct {
                        match tt.text.as_str() {
                            "(" | "[" | "{" => depth += 1,
                            ")" | "]" | "}" => depth -= 1,
                            "," if depth == 0 => commas += 1,
                            _ => {}
                        }
                        last_sig = Some(tt.text.as_str());
                    } else {
                        last_sig = Some("x");
                    }
                }
                // Trailing comma means `commas` == element count.
                kinds_count = Some(if last_sig == Some(",") { commas } else { commas + 1 });
            }
        }
    }

    let nv = variants.len();
    match n_kinds {
        None => rep.diag(
            &rel,
            ed.line,
            R_VARIANTS,
            "`Event` exhaustiveness anchor `const N_KINDS` not found".to_string(),
        ),
        Some(n) if n != nv as i64 => rep.diag(
            &rel,
            ed.line,
            R_VARIANTS,
            format!("`Event::N_KINDS` is {n} but `Event` has {nv} variants"),
        ),
        _ => {}
    }
    match kinds_count {
        None => rep.diag(
            &rel,
            ed.line,
            R_VARIANTS,
            "`Event` exhaustiveness anchor `const KINDS` not found".to_string(),
        ),
        Some(k) if k != nv => rep.diag(
            &rel,
            ed.line,
            R_VARIANTS,
            format!("`Event::KINDS` lists {k} names but `Event` has {nv} variants"),
        ),
        _ => {}
    }

    for fn_name in ["kind_index", "dispatch_event_core"] {
        // LAST definition found in tree order wins — mirrors a human
        // reading the final override.
        let mut found: Option<(String, &FnDef)> = None;
        for m2 in krate.all_modules() {
            let m2ref = &krate.modules[m2];
            if let Some(fds) = m2ref.items.fns.get(fn_name) {
                if let Some(fd) = fds.last() {
                    found = Some((m2ref.file.clone(), fd));
                }
            }
            for idef in &m2ref.items.impls {
                if let Some(fds) = idef.methods.get(fn_name) {
                    if let Some(fd) = fds.last() {
                        found = Some((m2ref.file.clone(), fd));
                    }
                }
            }
        }
        let Some((frel, fd)) = found else {
            rep.diag(
                &rel,
                ed.line,
                R_VARIANTS,
                format!("`Event` exhaustiveness anchor fn `{fn_name}` not found"),
            );
            continue;
        };
        let Some(ffp) = krate.files.get(&frel) else {
            continue;
        };
        let (lo, hi) = fd.body;
        let idents: BTreeSet<&str> = ffp.toks[lo.min(ffp.toks.len())..hi.min(ffp.toks.len())]
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        for v in &variants {
            if !idents.contains(v) {
                rep.diag(
                    &frel,
                    fd.line,
                    R_VARIANTS,
                    format!("`{fn_name}` does not mention `Event::{v}`"),
                );
            }
        }
    }
}
